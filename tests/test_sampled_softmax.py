"""Sampled-softmax head tests: unbiasedness, gradients, shortlist, lifecycle.

The statistical tests follow the ``tests/_stats.py`` convention (fixed
seeds, measured margins, regime guards first) and sit in the family's
CALIBRATED REGIME: moderate-spread head rows (Gaussian init at d >= 32
concentrates row norms) with small K so every probed bucket stays
populated — mean probes ~ 1, where the (1-q)^(l-1) miss factor behind
the Algorithm-1 probabilities is exact (see
``test_families.py::test_mips_unit_inverse_probability_over_builds``
for the measured boundary outside it).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _stats import mean_band
from repro.core.families import get_family
from repro.core.sampler import sample_batched
from repro.core.simhash import LSHParams
from repro.core.tables import IndexMutation, mutate_index
from repro.models import (
    LMHeadIndex,
    ModelConfig,
    SampledSoftmaxConfig,
    init_params,
    loss,
    lsh_decode_step,
    sampled_softmax_loss,
)
from repro.models.sampled_softmax import (
    head_lsh_params,
    sampled_head_xent,
    shortlist_candidates,
    shortlist_logits,
)
from repro.train import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


def _tiny_cfg(vocab=512, d=32):
    return ModelConfig(
        name="sst-tiny", n_layers=2, d_model=d, n_heads=2, n_kv_heads=2,
        d_ff=64, vocab=vocab, chunk=16, loss_chunk=128, dtype="float32",
        rope_theta=10000.0)


def _head_setup(V=512, d=32, scale=0.25, k=3, l=8, seed=0):
    """A synthetic lm_head corpus in the calibrated regime + queries."""
    fam = get_family("mips")
    rows = jax.random.normal(jax.random.PRNGKey(seed), (V, d)) * scale
    x_aug = fam.augment_data(rows, scale=fam.data_scale(rows))
    p = LSHParams(k=k, l=l, dim=fam.aug_dim(d), family="mips", seed=seed)
    return fam, rows, x_aug, p


class TestNormalizerUnbiasedness:
    @pytest.mark.statistical
    def test_zhat_unbiased_over_index_builds(self):
        """E[Zhat] = Z, expectation over index builds AND draws.

        Zhat = (1/m) sum_j exp(l_j)/p_j with Algorithm-1 probabilities —
        the sum-estimator identity the sampled loss rests on.  Regime
        guard first: mean probes ~ 1 (populated buckets), where the
        probability law is exact."""
        fam, rows, x_aug, p = _head_setup()
        V = rows.shape[0]
        q = jax.random.normal(jax.random.PRNGKey(1), (4, rows.shape[1]))
        q_aug = fam.augment_query(q)
        logits = q @ rows.T                              # (4, V)
        z = np.asarray(jnp.sum(jnp.exp(logits), -1))

        builds, m = 40, 64
        trials, probes = [], []
        for t in range(builds):
            kb = jax.random.fold_in(jax.random.PRNGKey(7), t)
            idx = mutate_index(
                None, IndexMutation("build", key=kb, x_aug=x_aug), p)
            res = sample_batched(jax.random.fold_in(kb, 99), idx, x_aug,
                                 q_aug, p, m=m, multiprobe=0)
            l_neg = jnp.take_along_axis(logits, res.indices, axis=1)
            trials.append(np.asarray(
                jnp.mean(jnp.exp(l_neg) / res.probs, -1)))
            probes.append(float(jnp.mean(res.n_probes.astype(jnp.float32))))
        assert np.mean(probes) < 1.1, f"regime drifted: {np.mean(probes)}"
        trials = np.stack(trials)                        # (builds, 4)
        rel = trials / z                                 # want E[rel] = 1
        grand = rel.mean(0)
        # measured per-trial rel sd ~0.45-0.6 at these seeds ->
        # mean_band(0.6, 40) ~ 0.28 (3 sigma); plus the family's own
        # calibration residual (~0.05, see test_families.py)
        band = mean_band(0.6, builds) + 0.05
        assert np.all(np.abs(grand - 1.0) < band), (
            f"E[Zhat]/Z = {grand} outside 1 +/- {band:.3f} "
            f"(per-trial rel sd {rel.std(0)})")

    def test_zhat_exact_when_sampling_covers_vocab(self):
        """Degenerate sanity: per-token xent reduces to log-Zhat - gold
        and matches the closed form on hand-fed samples/probs."""
        d, V, T, m = 8, 32, 3, 5
        q = jax.random.normal(jax.random.PRNGKey(2), (T, d))
        head = jax.random.normal(jax.random.PRNGKey(3), (d, V)) * 0.3
        targets = jnp.array([1, 5, 9], jnp.int32)
        neg = jax.random.randint(jax.random.PRNGKey(4), (T, m), 0, V)
        probs = jax.random.uniform(jax.random.PRNGKey(5), (T, m),
                                   minval=0.01, maxval=0.2)
        got = sampled_head_xent(q, head, targets, neg, probs)
        logits = q @ head
        l_neg = jnp.take_along_axis(logits, neg, axis=1)
        want = (jax.nn.logsumexp(l_neg - jnp.log(probs), -1)
                - jnp.log(float(m))
                - jnp.take_along_axis(logits, targets[:, None], 1)[:, 0])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5)


class TestGradientAgreement:
    @pytest.mark.statistical
    def test_sampled_gradient_matches_full_softmax_in_expectation(self):
        """d/d(head) of the sampled xent agrees with the full-softmax
        xent gradient averaged over builds+draws (cosine + rel norm on
        the lm_head block) — the property that makes --head lsh train.
        Self-normalised IS gradient: consistent with O(1/m) bias, so
        the band is wider than the Zhat identity's."""
        fam, rows, x_aug, p = _head_setup()
        V, d = rows.shape
        T, m, builds = 8, 128, 30
        q = jax.random.normal(jax.random.PRNGKey(11), (T, d)) * 0.5
        targets = jax.random.randint(jax.random.PRNGKey(12), (T,), 0, V)
        head0 = rows.T                                   # (d, V)

        def full_xent(head):
            logits = q @ head
            return jnp.mean(jax.nn.logsumexp(logits, -1) -
                            jnp.take_along_axis(
                                logits, targets[:, None], 1)[:, 0])
        g_full = jax.grad(full_xent)(head0)

        q_aug = fam.augment_query(q)

        def sampled(head, res):
            return jnp.mean(sampled_head_xent(q, head, targets,
                                              res.indices, res.probs))

        grads = []
        for t in range(builds):
            kb = jax.random.fold_in(jax.random.PRNGKey(13), t)
            idx = mutate_index(
                None, IndexMutation("build", key=kb, x_aug=x_aug), p)
            res = sample_batched(jax.random.fold_in(kb, 99), idx, x_aug,
                                 q_aug, p, m=m, multiprobe=0)
            grads.append(jax.grad(sampled)(head0, res))
        g_est = jnp.mean(jnp.stack(grads), 0)
        cos = float(jnp.vdot(g_est, g_full) /
                    (jnp.linalg.norm(g_est) * jnp.linalg.norm(g_full)))
        rel = float(jnp.linalg.norm(g_est - g_full) /
                    jnp.linalg.norm(g_full))
        # measured at the committed seeds: cos ~0.99+, rel ~0.1-0.2
        assert cos > 0.95, f"gradient direction disagrees: cos {cos}"
        assert rel < 0.4, f"gradient biased: rel err {rel}"

    def test_gradient_only_touches_sampled_columns(self):
        """The O(m)-sparsity contract: d(xent)/d(head) is zero outside
        the target + sampled columns (that is what makes the step
        O(m d) instead of O(V d))."""
        d, V, T, m = 8, 64, 2, 4
        q = jax.random.normal(jax.random.PRNGKey(20), (T, d))
        head = jax.random.normal(jax.random.PRNGKey(21), (d, V)) * 0.3
        targets = jnp.array([3, 7], jnp.int32)
        neg = jnp.array([[1, 2, 3, 4], [10, 11, 12, 13]], jnp.int32)
        probs = jnp.full((T, m), 0.05)
        g = jax.grad(lambda h: jnp.sum(
            sampled_head_xent(q, h, targets, neg, probs)))(head)
        touched = np.unique(np.concatenate(
            [np.asarray(targets), np.asarray(neg).ravel()]))
        untouched = np.setdiff1d(np.arange(V), touched)
        assert np.all(np.asarray(g)[:, untouched] == 0.0)
        assert np.any(np.asarray(g)[:, touched] != 0.0)


class TestShortlist:
    @pytest.mark.statistical
    def test_shortlist_recall_on_structured_head(self):
        """recall@1 of the LSH shortlist >= a pinned floor on a head
        with planted winners (queries = noisy copies of head rows — the
        trained-head regime where the argmax has margin)."""
        V, d = 512, 32
        fam = get_family("mips")
        rows = jax.random.normal(jax.random.PRNGKey(30), (V, d))
        rows = rows / jnp.linalg.norm(rows, axis=-1, keepdims=True)
        winners = jax.random.randint(jax.random.PRNGKey(31), (64,), 0, V)
        q = rows[winners] + 0.1 * jax.random.normal(
            jax.random.PRNGKey(32), (64, d))
        x_aug = fam.augment_data(rows, scale=fam.data_scale(rows))
        # k sized so mean bucket occupancy V/2^k ~ 8 <= shortlist slots:
        # truncating a bucket below its occupancy silently drops winners
        scfg = SampledSoftmaxConfig(k=6, l=10, multiprobe=2,
                                    shortlist_per_table=16)
        p = LSHParams(k=scfg.k, l=scfg.l, dim=fam.aug_dim(d),
                      family="mips", seed=0)
        idx = mutate_index(
            None,
            IndexMutation("build", key=jax.random.PRNGKey(33), x_aug=x_aug),
            p)
        ids, valid = shortlist_candidates(idx, fam.augment_query(q), p,
                                          scfg)
        logits = shortlist_logits(rows.T, q, ids, valid)
        got = np.asarray(jnp.take_along_axis(
            ids, jnp.argmax(logits, -1)[:, None], 1)[:, 0])
        true = np.asarray(jnp.argmax(q @ rows.T, -1))
        recall = float(np.mean(got == true))
        # measured 1.0 at the committed seeds; the floor leaves headroom
        # for cross-version RNG drift in projections/bucket layout
        assert recall >= 0.85, f"shortlist recall@1 {recall} < 0.85"

    @pytest.mark.statistical
    def test_shortlist_recall_banded_beats_global_scale(self):
        """On an UN-normalised head (spread row norms — every real init),
        the norm-ranged (banded) index must clear the recall floor the
        single-scale family cannot: one global Simple-LSH M caps an
        exact-match query's per-table collision at cos ~ ||x||/M
        (measured ~0.6 recall here), while per-band scales restore it
        (measured 1.0 at these seeds).  This is the decode-path config
        (examples/serve.py --head lsh, benchmarks tab_softmax)."""
        V, d = 512, 32
        fam = get_family("mips_banded")
        rows = jax.random.normal(jax.random.PRNGKey(50), (V, d)) * 0.3
        winners = jax.random.randint(jax.random.PRNGKey(51), (64,), 0, V)
        q = rows[winners] + 0.1 * 0.3 * jax.random.normal(
            jax.random.PRNGKey(52), (64, d))
        true = np.asarray(jnp.argmax(q @ rows.T, -1))
        x_aug = fam.augment_data(rows, scale=fam.data_scale(rows))
        scfg = SampledSoftmaxConfig(family="mips_banded", k=5, l=8,
                                    multiprobe=2, shortlist_per_table=8)
        p = LSHParams(k=scfg.k, l=scfg.l, dim=fam.aug_dim(d),
                      family="mips_banded", seed=0)
        idx = mutate_index(
            None,
            IndexMutation("build", key=jax.random.PRNGKey(53), x_aug=x_aug),
            p)
        ids, valid = shortlist_candidates(idx, fam.augment_query(q), p,
                                          scfg)
        logits = shortlist_logits(rows.T, q, ids, valid)
        got = np.asarray(jnp.take_along_axis(
            ids, jnp.argmax(logits, -1)[:, None], 1)[:, 0])
        recall = float(np.mean(got == true))
        assert recall >= 0.9, f"banded shortlist recall@1 {recall} < 0.9"

    def test_shortlist_masks_out_of_bucket_slots(self):
        """Slots past a bucket's [lo, hi) are invalid and must be -inf
        in the candidate logits (never win the argmax)."""
        fam, rows, x_aug, p = _head_setup(V=64, d=16, k=5, l=4)
        scfg = SampledSoftmaxConfig(k=5, l=4, multiprobe=1,
                                    shortlist_per_table=16)
        idx = mutate_index(
            None,
            IndexMutation("build", key=jax.random.PRNGKey(40), x_aug=x_aug),
            p)
        q = jax.random.normal(jax.random.PRNGKey(41), (3, rows.shape[1]))
        ids, valid = shortlist_candidates(idx, fam.augment_query(q), p,
                                          scfg)
        logits = np.asarray(shortlist_logits(rows.T, q, ids, valid))
        valid = np.asarray(valid)
        assert np.all(logits[~valid] == -np.inf)
        assert np.all(np.isfinite(logits[valid]))
        # at least SOME valid candidates exist for every query
        assert np.all(valid.any(-1))


class TestLifecycle:
    def test_delta_all_dirty_equals_full_warm_refresh(self):
        """A delta refresh with every row dirty is bitwise a full warm
        refresh at the pinned scale — the head-index inheritance of the
        mutate_index tie-stability contract."""
        cfg = _tiny_cfg(vocab=128, d=16)
        params = init_params(KEY, cfg)
        scfg = SampledSoftmaxConfig(k=3, l=4, drift_sample=0.0)
        a = LMHeadIndex(params, cfg, scfg)
        b = LMHeadIndex(params, cfg, scfg)
        # train-ish drift: perturb the head, then refresh both ways
        params2 = jax.tree.map(lambda x: x, params)
        params2["embed_group"]["lm_head"] = (
            params["embed_group"]["lm_head"]
            + 0.01 * jax.random.normal(jax.random.PRNGKey(50),
                                       params["embed_group"]["lm_head"].shape))
        a.note_targets(np.arange(cfg.vocab))     # every row dirty
        a.refresh(params2, mode="delta")
        b.refresh(params2, mode="full", repin_scale=False)
        np.testing.assert_array_equal(np.asarray(a.index.sorted_codes),
                                      np.asarray(b.index.sorted_codes))
        np.testing.assert_array_equal(np.asarray(a.index.order),
                                      np.asarray(b.index.order))
        np.testing.assert_allclose(np.asarray(a.x_aug), np.asarray(b.x_aug),
                                   rtol=1e-6)

    def test_refresh_cadence_keyed_off_optimizer_steps(self):
        """maybe_refresh fires every refresh_every steps, with every
        full_every-th refresh forced full (re-pinning the MIPS scale)."""
        cfg = _tiny_cfg(vocab=128, d=16)
        params = init_params(KEY, cfg)
        scfg = SampledSoftmaxConfig(k=3, l=4, refresh_every=10,
                                    refresh_mode="delta", full_every=3,
                                    drift_sample=0.0)
        head = LMHeadIndex(params, cfg, scfg)
        fired = [head.maybe_refresh(s, params) for s in range(1, 61)]
        assert sum(fired) == 6
        assert head.delta_refreshes == 4 and head.full_refreshes == 2
        # steps 1..9 must not fire
        assert not any(fired[:9])

    def test_trainer_integration_smoke(self):
        """3 steps of Trainer with the sampled loss + step-hook refresh:
        finite losses, params move, the injected index leaves flow
        through the jitted step (no stale-closure recompiles)."""
        from repro.models import make_sampled_loss
        from repro.optim import make_optimizer

        cfg = _tiny_cfg(vocab=256, d=32)
        params = init_params(KEY, cfg)
        scfg = SampledSoftmaxConfig(k=3, l=4, n_samples=16, multiprobe=1,
                                    refresh_every=2, refresh_mode="delta")
        head = LMHeadIndex(params, cfg, scfg)

        def batches():
            k = jax.random.PRNGKey(60)
            i = 0
            while True:
                k = jax.random.fold_in(k, i)
                toks = jax.random.randint(k, (2, 17), 0, cfg.vocab)
                yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
                i += 1

        tr = Trainer(cfg, params, make_optimizer("sgd", lambda s: 1e-2),
                     head.wrap_batches(batches()),
                     TrainerConfig(log_every=100, donate=False,
                                   step_hook=head.step_hook),
                     loss_fn=make_sampled_loss(cfg, scfg))
        tr.run(5)
        assert tr.step == 5
        assert all(np.isfinite(m["loss"]) for m in tr.metrics_history)
        assert head.refreshes >= 2       # cadence fired through the hook
        # exact full-vocab eval still works on the trained params
        toks = jax.random.randint(jax.random.PRNGKey(61), (2, 17), 0,
                                  cfg.vocab)
        ev = float(loss(tr.params, cfg,
                        {"tokens": toks[:, :-1], "targets": toks[:, 1:]}))
        assert np.isfinite(ev)

    def test_sampled_loss_tracks_full_loss(self):
        """At matched params the sampled loss sits near the exact loss
        (same model, same batch) — a one-shot sanity anchor, not a
        statistical identity (that is TestNormalizerUnbiasedness)."""
        cfg = _tiny_cfg(vocab=256, d=32)
        params = init_params(KEY, cfg)
        scfg = SampledSoftmaxConfig(k=3, l=8, n_samples=64, multiprobe=1)
        head = LMHeadIndex(params, cfg, scfg)
        toks = jax.random.randint(jax.random.PRNGKey(70), (4, 17), 0,
                                  cfg.vocab)
        batch = head.inject(
            {"tokens": toks[:, :-1], "targets": toks[:, 1:]}, step=0)
        ls = float(sampled_softmax_loss(params, cfg, scfg, batch))
        lf = float(loss(params, cfg, {"tokens": toks[:, :-1],
                                      "targets": toks[:, 1:]}))
        assert abs(ls - lf) / lf < 0.2, (ls, lf)


class TestDecodeParity:
    def test_lsh_decode_step_runs_and_types(self):
        """lsh_decode_step returns (B,1) int32 token ids in-vocab and
        the same cache pytree structure as decode_step."""
        from repro.models import decode_step, init_cache, prefill

        cfg = _tiny_cfg(vocab=256, d=32)
        params = init_params(KEY, cfg)
        scfg = SampledSoftmaxConfig(k=3, l=8, multiprobe=2,
                                    shortlist_per_table=8)
        head = LMHeadIndex(params, cfg, scfg)
        toks = jax.random.randint(jax.random.PRNGKey(80), (2, 9), 0,
                                  cfg.vocab)
        cache = init_cache(cfg, 2, 16)
        _, cache = prefill(params, cfg, {"tokens": toks[:, :8]}, cache)
        db = {"tokens": toks[:, 8:9],
              "positions": jnp.full((2, 1), 8, jnp.int32)}
        tok, c2 = lsh_decode_step(params, cfg, scfg, db, cache, head.index)
        assert tok.shape == (2, 1) and tok.dtype == jnp.int32
        assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab)))
        lg, c3 = decode_step(params, cfg, db, cache)
        assert jax.tree.structure(c2) == jax.tree.structure(c3)
