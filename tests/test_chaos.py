"""Chaos suite: every injected fault must leave a 60-step LGD run
alive, learning, and bit-deterministically resumable.

Each test drives the full Trainer + ShardedLSHPipeline stack on CPU
with one deterministic fault from ``repro.testing.faults`` and asserts
the self-healing contract (docs/ARCHITECTURE.md "Failure model"):

  * the run COMPLETES (no exception surfaces from the fault),
  * the loss still FALLS (the degraded estimator stays unbiased),
  * the degradation/recovery story is AUDITABLE in
    ``metrics_history`` (health transitions, ``skipped_steps``),
  * a post-fault restore replays BIT-IDENTICAL batches (the
    restore-at-step determinism contract survives the fault).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (
    HEALTHY,
    STALE_INDEX,
    UNIFORM_FALLBACK,
    HealthConfig,
    LSHPipelineConfig,
    ShardedLSHPipeline,
    make_token_corpus,
    mean_pool_feature_fn,
    lm_head_query_fn,
)
from repro.models import ModelConfig, init_params
from repro.optim import Adam
from repro.testing import (
    NanLossWeights,
    RefreshHang,
    RefreshRaise,
    truncate_arrays,
)
from repro.train import Trainer, TrainerConfig, checkpoint as ckpt

KEY = jax.random.PRNGKey(0)
STEPS = 60


def _lm_cfg():
    return ModelConfig(
        name="chaos", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, chunk=16, loss_chunk=16, dtype="float32",
        rope_theta=10000.0, lgd_enabled=True)


def _corpus(cfg):
    return make_token_corpus(11, 256, 16, cfg.vocab, hard_frac=0.15)


def _sampler(cfg, corpus, params, **pipe_kw):
    # the toy fixture's trained query organically drifts into empty
    # buckets (fallback rate -> 1.0), which is Algorithm 1 working as
    # designed at this scale — disable the spike detector by default so
    # each chaos test isolates ITS fault signal (the spike path is unit-
    # tested on HealthMonitor directly).
    pipe_kw.setdefault("health", HealthConfig(fallback_spike=1.1))
    pcfg = LSHPipelineConfig(
        k=5, l=10, minibatch=16, refresh_every=10, refresh_async=True,
        refresh_backoff=0.0, **pipe_kw)
    return ShardedLSHPipeline(
        jax.random.PRNGKey(12), corpus.tokens, mean_pool_feature_fn(cfg),
        lm_head_query_fn(), pcfg, n_shards=2, params=params)


def _loss_falls(losses):
    head = np.mean(losses[:5])
    tail = np.mean(losses[-5:])
    assert np.isfinite(tail), f"final losses not finite: {losses[-5:]}"
    assert tail < head, f"loss did not fall: {head} -> {tail}"


def _assert_bit_identical_replay(cfg, corpus, params, step, pipe_kw=None,
                                 k=5):
    """Two restores at ``step`` must draw bitwise-identical batches —
    the determinism contract the faults must not break."""
    def replay():
        s = _sampler(cfg, corpus, params, **(pipe_kw or {}))
        s.restore_at(step)
        return [s.next_batch() for _ in range(k)]
    a, b = replay(), replay()
    for ba, bb in zip(a, b):
        for key in ("example_ids", "loss_weights", "tokens"):
            np.testing.assert_array_equal(
                np.asarray(ba[key]), np.asarray(bb[key]))


def _transitions(trainer):
    """Latest surfaced health transitions, as (to_state, reason) pairs
    (sharded summaries prefix a shard index; the tail layout is shared:
    ..., from, to, reason)."""
    for entry in reversed(trainer.metrics_history):
        if "health_transitions" in entry:
            return [(t[-2], t[-1]) for t in entry["health_transitions"]]
    return []


class TestRefreshRaiseChaos:
    def test_three_failed_refresh_cycles_survive_as_stale_index(self):
        cfg = _lm_cfg()
        corpus = _corpus(cfg)
        params = init_params(KEY, cfg)
        sampler = _sampler(cfg, corpus, params, refresh_retries=1)
        fault = RefreshRaise(cycles=3)
        sampler.set_fault_injector(fault, shard=0)
        tr = Trainer(cfg, params, Adam(lr=1e-2),
                     tcfg=TrainerConfig(log_every=10), sampler=sampler)
        out = tr.run(STEPS)
        tr.finalize()
        assert len(out["losses"]) == STEPS
        _loss_falls(out["losses"])
        # retries were exhausted on each injected cycle: 3 cycles x
        # (1 + refresh_retries) attempts
        assert fault.fired == 3 * 2
        trans = _transitions(tr)
        assert any(t[0] == STALE_INDEX for t in trans), trans
        # the fourth refresh cycle succeeds organically -> recovered
        assert any(t[0] == HEALTHY for t in trans), trans
        assert sampler.health_state() == HEALTHY
        _assert_bit_identical_replay(cfg, corpus, tr.params, tr.step,
                                     pipe_kw={"refresh_retries": 1})

    def test_persistent_failure_degrades_to_uniform_and_recovers(self):
        cfg = _lm_cfg()
        corpus = _corpus(cfg)
        params = init_params(KEY, cfg)
        sampler = _sampler(
            cfg, corpus, params, refresh_retries=0,
            health=HealthConfig(max_stale_refreshes=1, recover_after=8,
                                fallback_spike=1.1))
        # enough failing cycles to blow the staleness bound on shard 0
        sampler.set_fault_injector(RefreshRaise(cycles=2), shard=0)
        tr = Trainer(cfg, params, Adam(lr=1e-2),
                     tcfg=TrainerConfig(log_every=10), sampler=sampler)
        out = tr.run(STEPS)
        tr.finalize()
        _loss_falls(out["losses"])
        trans = _transitions(tr)
        assert any(t[0] == UNIFORM_FALLBACK for t in trans), trans
        # recovery rebuild brought the shard back
        assert sampler.health_state() == HEALTHY
        assert sampler.health_summary()["recoveries"] >= 1


class TestRefreshHangChaos:
    def test_hung_worker_is_abandoned_by_watchdog(self):
        cfg = _lm_cfg()
        corpus = _corpus(cfg)
        params = init_params(KEY, cfg)
        sampler = _sampler(cfg, corpus, params, refresh_retries=0,
                           refresh_timeout=0.25)
        fault = RefreshHang(seconds=5.0, cycles=1)
        sampler.set_fault_injector(fault, shard=0)
        tr = Trainer(cfg, params, Adam(lr=1e-2),
                     tcfg=TrainerConfig(log_every=10), sampler=sampler)
        out = tr.run(STEPS)
        tr.finalize()
        assert len(out["losses"]) == STEPS
        _loss_falls(out["losses"])
        assert fault.fired >= 1
        trans = _transitions(tr)
        assert any(t[0] == STALE_INDEX for t in trans), trans
        assert sampler.health_state() == HEALTHY   # next cycle recovered


class TestCheckpointTruncationChaos:
    def test_truncated_latest_checkpoint_resumes_from_previous(
            self, tmp_path):
        d = os.fspath(tmp_path)
        cfg = _lm_cfg()
        corpus = _corpus(cfg)
        params = init_params(KEY, cfg)

        def make(p, resume):
            return Trainer(
                cfg, p, Adam(lr=1e-2),
                tcfg=TrainerConfig(ckpt_dir=d, ckpt_every=10,
                                   log_every=10),
                resume=resume,
                sampler=_sampler(cfg, corpus, p))

        t1 = make(params, resume=False)
        out1 = t1.run(30)
        t1.finalize()
        assert ckpt.latest_step(d) == 30
        truncate_arrays(d, 30)                      # the incident

        t2 = make(init_params(KEY, cfg), resume=True)
        assert t2.step == 20                        # newest VALID step
        out2 = t2.run(STEPS - 20)
        t2.finalize()
        assert t2.step == STEPS
        _loss_falls(out1["losses"][:20] + out2["losses"])
        _assert_bit_identical_replay(cfg, corpus, t2.params, t2.step)


class TestNanGradChaos:
    def test_nan_batches_are_skipped_without_update(self):
        cfg = _lm_cfg()
        corpus = _corpus(cfg)
        params = init_params(KEY, cfg)
        inner = _sampler(cfg, corpus, params)
        sampler = NanLossWeights(inner, at_step=20, count=2)
        tr = Trainer(cfg, params, Adam(lr=1e-2),
                     tcfg=TrainerConfig(log_every=10), sampler=sampler)
        out = tr.run(STEPS)
        tr.finalize()
        assert len(out["losses"]) == STEPS
        assert sampler.fired == 2
        assert tr.skipped_steps == 2
        assert not np.isfinite(out["losses"][20])   # recorded faithfully
        _loss_falls([l for l in out["losses"] if np.isfinite(l)])
        # skipped_steps surfaced at log cadence
        assert any(e.get("skipped_steps") == 2
                   for e in tr.metrics_history)
        _assert_bit_identical_replay(cfg, corpus, tr.params, tr.step)

    def test_nan_streak_rolls_back_to_verified_checkpoint(self, tmp_path):
        d = os.fspath(tmp_path)
        cfg = _lm_cfg()
        corpus = _corpus(cfg)
        params = init_params(KEY, cfg)
        inner = _sampler(cfg, corpus, params)
        # 6 poisoned draws >= rollback_after=3 -> rollback fires; the
        # poison budget is one-shot, so the replay comes through clean
        sampler = NanLossWeights(inner, at_step=20, count=6)
        tr = Trainer(
            cfg, params, Adam(lr=1e-2),
            tcfg=TrainerConfig(ckpt_dir=d, ckpt_every=10, log_every=10,
                               rollback_after=3,
                               # keep the ladder out of this test: the
                               # rollback must fire before fallback
                               skip_nonfinite=True),
            resume=False, sampler=sampler)
        out = tr.run(STEPS)
        tr.finalize()
        assert tr.rollbacks >= 1
        assert tr.step == STEPS
        assert any(e.get("event") == "rollback"
                   for e in tr.metrics_history)
        _loss_falls([l for l in out["losses"] if np.isfinite(l)])
        assert np.isfinite(out["losses"][-1])

    def test_nan_update_is_fully_suppressed(self):
        """A poisoned step leaves params and optimiser state BITWISE
        unchanged (the jitted where-guard, not a host-side undo)."""
        cfg = _lm_cfg()
        corpus = _corpus(cfg)
        params = init_params(KEY, cfg)
        inner = _sampler(cfg, corpus, params)
        sampler = NanLossWeights(inner, at_step=3, count=1)
        tr = Trainer(cfg, params, Adam(lr=1e-2),
                     tcfg=TrainerConfig(log_every=100), sampler=sampler)
        tr.run(3)
        before = jax.tree.map(np.asarray, tr.params)
        before_opt = jax.tree.map(np.asarray, tr.opt_state)
        tr.run(1)                                   # the poisoned step
        tr.finalize()
        assert tr.skipped_steps == 1
        jax.tree.map(np.testing.assert_array_equal, before,
                     jax.tree.map(np.asarray, tr.params))
        jax.tree.map(np.testing.assert_array_equal, before_opt,
                     jax.tree.map(np.asarray, tr.opt_state))


class TestUniformFallbackUnbiased:
    def test_uniform_batches_have_unit_weights_and_cover_corpus(self):
        cfg = _lm_cfg()
        corpus = _corpus(cfg)
        params = init_params(KEY, cfg)
        sampler = _sampler(
            cfg, corpus, params, refresh_retries=0,
            health=HealthConfig(max_stale_refreshes=0,
                                recover_after=10**6))
        sampler.set_fault_injector(RefreshRaise(cycles=10**6))
        seen = set()
        for i in range(40):
            b = sampler.next_batch()
            if sampler.health_state() == UNIFORM_FALLBACK:
                np.testing.assert_array_equal(
                    np.asarray(b["loss_weights"]),
                    np.ones_like(np.asarray(b["loss_weights"])))
                seen.update(np.asarray(b["example_ids"]).tolist())
        assert sampler.health_state() == UNIFORM_FALLBACK
        # uniform draws range over the whole corpus, not one shard
        assert len(seen) > 64
        ids = np.array(sorted(seen))
        assert ids.min() < 128 <= ids.max()         # both shards' spans

class TestHealthMonitorUnit:
    """State-machine unit coverage (no JAX): every ladder edge."""

    def test_staleness_bound(self):
        from repro.data import HealthMonitor
        h = HealthMonitor(HealthConfig(max_stale_refreshes=2))
        h.note_refresh_failure(10)
        assert h.state == STALE_INDEX
        h.note_refresh_failure(20)
        assert h.state == STALE_INDEX
        h.note_refresh_failure(30)              # 3 > 2: bound crossed
        assert h.state == UNIFORM_FALLBACK
        assert [t[2] for t in h.transitions] == [STALE_INDEX,
                                                 UNIFORM_FALLBACK]

    def test_refresh_success_recovers_from_stale(self):
        from repro.data import HealthMonitor
        h = HealthMonitor(HealthConfig())
        h.note_refresh_failure(10)
        h.note_refresh_success(20)
        assert h.state == HEALTHY
        assert h.recoveries == 1
        assert h.stale_refreshes == 0           # strike counter reset

    def test_fallback_rate_spike_needs_consecutive_strikes(self):
        from repro.data import HealthMonitor
        h = HealthMonitor(HealthConfig(fallback_spike=0.9,
                                       fallback_strikes=3))
        h.note_fallback_rate(10, 0.95)
        h.note_fallback_rate(20, 0.95)
        h.note_fallback_rate(30, 0.5)           # streak broken
        h.note_fallback_rate(40, 0.95)
        h.note_fallback_rate(50, 0.95)
        assert h.state == HEALTHY
        h.note_fallback_rate(60, 1.0)           # third consecutive
        assert h.state == UNIFORM_FALLBACK

    def test_nonfinite_loss_streak(self):
        from repro.data import HealthMonitor
        h = HealthMonitor(HealthConfig(nonfinite_strikes=2))
        h.note_loss(1, False)
        h.note_loss(2, True)                    # streak broken
        h.note_loss(3, False)
        assert h.state == HEALTHY
        h.note_loss(4, False)
        assert h.state == UNIFORM_FALLBACK

    def test_recovery_cadence(self):
        from repro.data import HealthMonitor
        h = HealthMonitor(HealthConfig(max_stale_refreshes=0,
                                       recover_after=5))
        h.note_refresh_failure(7)
        assert h.state == UNIFORM_FALLBACK
        assert not h.should_attempt_recovery(7)
        assert not h.should_attempt_recovery(11)
        assert h.should_attempt_recovery(12)    # 5 steps after entry
        h.note_recovered(12)
        assert h.state == HEALTHY
        assert h.degraded is False
        assert h.recoveries == 1
