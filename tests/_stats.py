"""Centralised statistical-test calibration for the whole suite.

Every statistical test in this repo follows ONE convention, so the
thresholds live in one place instead of being re-derived (or silently
diverging) per test file:

* **Fixed seeds, measured margins.**  All draws come from fixed
  ``jax.random.PRNGKey`` seeds, and JAX programs are bit-deterministic
  per backend — a "statistical" test is therefore reproducible, and its
  tolerance is calibrated by MEASURING the statistic at the committed
  seeds and asserting with explicit sigma headroom.  The residual flake
  surface is cross-version RNG/kernel drift (jax upgrades), which the
  ``@pytest.mark.statistical`` marker + the CI rerun-once policy
  absorb: non-statistical tests run with NO retry, statistical tests
  get exactly one ``--lf`` retry (see .github/workflows/ci.yml).

* **Chi-square caps** (``chi2_cap``): a chi-square statistic over
  ``ncell`` non-degenerate cells has mean ``ncell`` and sd
  ``sqrt(2 ncell)``; tests cap at ``CHI2_SIGMA = 5`` sigma — a
  one-sided alpha well below 1e-6, so a trip means a real law
  disagreement, not sampling noise.

* **Mean bands** (``mean_band``): a grand mean over ``n_trials``
  independent trials with measured per-trial sd gets a
  ``MEAN_SIGMA = 3`` sigma band around its expectation
  (alpha ~ 2.7e-3 per test if the trials were re-randomised; with
  fixed seeds it is a regression pin with that much headroom).

* **Regime guards**: calibration identities (e.g. ``E[1/(p·N)] = 1``)
  hold exactly only in their calibrated regime (populated buckets,
  ``mean_l`` close to 1).  Tests assert the guard FIRST so a regime
  drift fails loudly as "regime drifted" instead of as a mysterious
  tolerance trip.
"""

import math

# sigma levels shared by every statistical test (see module docstring)
CHI2_SIGMA = 5.0
MEAN_SIGMA = 3.0


def chi2_cap(ncell: int, n_sigma: float = CHI2_SIGMA) -> float:
    """Upper cap for a chi-square statistic over ``ncell`` cells.

    ChiSq(ncell) has mean ``ncell`` and sd ``sqrt(2 ncell)``; the
    default 5-sigma cap corresponds to alpha < 1e-6 one-sided.
    """
    return ncell + n_sigma * math.sqrt(2.0 * ncell)


def mean_band(per_trial_sd: float, n_trials: int,
              n_sigma: float = MEAN_SIGMA) -> float:
    """Half-width of the n-sigma band for a grand mean over trials.

    ``per_trial_sd`` is the MEASURED per-trial standard deviation at
    the committed seeds (document the measurement next to the assert).
    """
    return n_sigma * per_trial_sd / math.sqrt(float(n_trials))
