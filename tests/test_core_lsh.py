"""Unit + property tests for the LGD core (simhash, tables, sampler)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; use the shim
    from _hypothesis_compat import given, settings, st

from repro.core import (
    LGDProblem,
    LSHParams,
    IndexMutation,
    mutate_index,
    bucket_bounds,
    collision_probability,
    collision_probability_quadratic,
    compute_codes,
    exact_inclusion_probability,
    hash_points,
    make_projections,
    query_codes,
    regression_query,
    sample,
    sample_drain,
)
from repro.core.simhash import _pack_bits


KEY = jax.random.PRNGKey(0)


def _build_index(key, x_aug, p, **kw):
    return mutate_index(
        None, IndexMutation("build", key=key, x_aug=x_aug), p, **kw)


def _unit_rows(key, n, d):
    x = jax.random.normal(key, (n, d))
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


# ---------------------------------------------------------------------------
# simhash
# ---------------------------------------------------------------------------

class TestSimHash:
    def test_pack_bits_roundtrip(self):
        bits = jnp.array([[[1, 0, 1, 1, 0]]], dtype=bool)
        code = _pack_bits(bits, 5)
        assert code.shape == (1, 1)
        assert int(code[0, 0]) == 0b01101

    @pytest.mark.parametrize("family", ["dense", "sparse", "quadratic"])
    def test_code_shapes(self, family):
        p = LSHParams(k=5, l=7, dim=16, family=family)
        proj = make_projections(KEY, p)
        x = _unit_rows(jax.random.PRNGKey(1), 10, 16)
        codes = compute_codes(x, proj, k=5, l=7, quadratic=family == "quadratic")
        assert codes.shape == (10, 7)
        assert codes.dtype == jnp.uint32
        assert int(jnp.max(codes)) < 2**5
        # single-vector path
        c1 = compute_codes(x[0], proj, k=5, l=7, quadratic=family == "quadratic")
        assert c1.shape == (7,)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(codes[0]))

    def test_identical_vectors_collide(self):
        p = LSHParams(k=8, l=4, dim=12, family="dense")
        proj = make_projections(KEY, p)
        x = _unit_rows(jax.random.PRNGKey(2), 3, 12)
        c1 = compute_codes(x, proj, k=8, l=4)
        c2 = compute_codes(x, proj, k=8, l=4)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))

    def test_collision_probability_range_and_monotonicity(self):
        q = jnp.array([1.0, 0.0])
        angles = jnp.linspace(0, jnp.pi, 50)
        xs = jnp.stack([jnp.cos(angles), jnp.sin(angles)], axis=-1)
        cp = collision_probability(xs, q)
        assert float(cp[0]) == pytest.approx(1.0, abs=1e-5)
        assert float(cp[-1]) == pytest.approx(0.0, abs=1e-5)
        assert bool(jnp.all(jnp.diff(cp) <= 1e-6))  # decreasing with angle

    def test_quadratic_cp_monotone_in_abs_inner_product(self):
        q = jnp.array([1.0, 0.0])
        xs = jnp.stack(
            [jnp.linspace(-1, 1, 41), jnp.sqrt(1 - jnp.linspace(-1, 1, 41) ** 2)],
            axis=-1,
        )
        cp = collision_probability_quadratic(xs, q)
        ips = jnp.abs(xs @ q)
        order = jnp.argsort(ips)
        assert bool(jnp.all(jnp.diff(cp[order]) >= -1e-6))
        assert float(jnp.min(cp)) >= 0.5 - 1e-6  # quadratic cp in [0.5, 1]

    def test_empirical_collision_rate_matches_cp(self):
        """P(h(x)=h(q)) over many hash draws == 1 - theta/pi (Eq. 14)."""
        d, trials = 8, 6000
        kx, kq = jax.random.split(jax.random.PRNGKey(3))
        x = _unit_rows(kx, 1, d)[0]
        q = _unit_rows(kq, 1, d)[0]
        p = LSHParams(k=1, l=trials, dim=d, family="dense")
        proj = make_projections(jax.random.PRNGKey(4), p)
        cx = compute_codes(x, proj, k=1, l=trials)
        cq = compute_codes(q, proj, k=1, l=trials)
        emp = float(jnp.mean((cx == cq).astype(jnp.float32)))
        expected = float(collision_probability(x, q))
        assert emp == pytest.approx(expected, abs=0.03)

    def test_sparse_projection_density(self):
        p = LSHParams(k=5, l=100, dim=300, family="sparse", sparsity=1 / 30)
        proj = make_projections(KEY, p)
        density = float(jnp.mean((proj != 0).astype(jnp.float32)))
        assert density == pytest.approx(1 / 30, rel=0.2)


# ---------------------------------------------------------------------------
# tables (sorted-code index)
# ---------------------------------------------------------------------------

class TestIndex:
    def _build(self, n=256, d=10, k=4, l=8, family="dense"):
        p = LSHParams(k=k, l=l, dim=d, family=family)
        x = _unit_rows(jax.random.PRNGKey(5), n, d)
        return _build_index(jax.random.PRNGKey(6), x, p), x, p

    def test_order_is_permutation(self):
        index, _, _ = self._build()
        for t in range(index.n_tables):
            assert sorted(np.asarray(index.order[t]).tolist()) == list(range(256))

    def test_sorted_codes_ascending(self):
        index, _, _ = self._build()
        assert bool(jnp.all(jnp.diff(index.sorted_codes.astype(jnp.int64), axis=1) >= 0))

    def test_bucket_bounds_recover_exact_bucket(self):
        """Slice [lo,hi) must contain exactly the points with the query code."""
        index, x, p = self._build()
        q = _unit_rows(jax.random.PRNGKey(7), 1, 10)[0]
        qc = query_codes(index, q, p)
        lo, hi = bucket_bounds(index, qc)
        codes = compute_codes(x, index.projections, k=p.k, l=p.l).T  # (L, N)
        for t in range(p.l):
            expected = set(np.nonzero(np.asarray(codes[t]) == int(qc[t]))[0].tolist())
            got = set(np.asarray(index.order[t, int(lo[t]):int(hi[t])]).tolist())
            assert got == expected

    def test_point_hashes_into_own_bucket(self):
        index, x, p = self._build()
        qc = query_codes(index, x[13], p)
        lo, hi = bucket_bounds(index, qc)
        for t in range(p.l):
            members = np.asarray(index.order[t, int(lo[t]):int(hi[t])])
            assert 13 in members


# ---------------------------------------------------------------------------
# delta refresh (segmented merge through the previous order)
# ---------------------------------------------------------------------------

class TestDeltaRefresh:
    def _setup(self, n=257, d=16, k=4, l=8):
        p = LSHParams(k=k, l=l, dim=d, family="dense")
        x = _unit_rows(jax.random.PRNGKey(11), n, d)
        index = _build_index(jax.random.PRNGKey(12), x, p)
        x2 = _unit_rows(jax.random.PRNGKey(13), n, d)
        return index, x, x2, p

    def test_all_dirty_bitwise_equals_full_warm_start(self):
        index, _, x2, p = self._setup()
        full = mutate_index(index, IndexMutation("refresh", x_aug=x2),
                            p, use_pallas=False)
        codes = hash_points(x2, index.projections, p, use_pallas=False)
        got = mutate_index(index, IndexMutation(
            "delta", ids=jnp.arange(x2.shape[0], dtype=jnp.int32),
            codes=codes))
        np.testing.assert_array_equal(np.asarray(full.order),
                                      np.asarray(got.order))
        np.testing.assert_array_equal(np.asarray(full.sorted_codes),
                                      np.asarray(got.sorted_codes))

    def test_partial_dirty_equals_full_refresh_of_mixed_features(self):
        """Merging D changed rows must equal the full warm-started
        refresh of the corpus where exactly those rows changed —
        including duplicate (padding) ids in the dirty set."""
        index, x, x2, p = self._setup()
        changed = jnp.array([0, 3, 17, 100, 256], jnp.int32)
        dirty = jnp.concatenate([changed,
                                 jnp.array([3, 3, 17], jnp.int32)])  # pad
        x_mixed = x.at[changed].set(x2[changed])
        want = mutate_index(index,
                            IndexMutation("refresh", x_aug=x_mixed),
                            p, use_pallas=False)
        codes_d = hash_points(x_mixed[dirty], index.projections, p,
                              use_pallas=False)
        got = mutate_index(index, IndexMutation(
            "delta", ids=dirty, codes=codes_d))
        np.testing.assert_array_equal(np.asarray(want.order),
                                      np.asarray(got.order))
        np.testing.assert_array_equal(np.asarray(want.sorted_codes),
                                      np.asarray(got.sorted_codes))

    def test_unchanged_codes_keep_slots(self):
        """A dirty row whose code did not change keeps its exact slot
        (the tie-stability / double-buffer contract)."""
        index, x, _, p = self._setup()
        dirty = jnp.array([5, 42, 99], jnp.int32)
        codes_d = hash_points(x[dirty], index.projections, p,
                              use_pallas=False)   # same features -> same codes
        got = mutate_index(index, IndexMutation(
            "delta", ids=dirty, codes=codes_d))
        np.testing.assert_array_equal(np.asarray(index.order),
                                      np.asarray(got.order))
        np.testing.assert_array_equal(np.asarray(index.sorted_codes),
                                      np.asarray(got.sorted_codes))

    def test_merge_preserves_permutation_and_sortedness(self):
        index, _, x2, p = self._setup()
        dirty = jnp.arange(0, 257, 3, dtype=jnp.int32)
        codes_d = hash_points(x2[dirty], index.projections, p,
                              use_pallas=False)
        got = mutate_index(index, IndexMutation(
            "delta", ids=dirty, codes=codes_d))
        for t in range(p.l):
            assert sorted(np.asarray(got.order[t]).tolist()) == \
                list(range(257))
        assert bool(jnp.all(jnp.diff(
            got.sorted_codes.astype(jnp.int64), axis=1) >= 0))


# ---------------------------------------------------------------------------
# sampler (Algorithm 1)
# ---------------------------------------------------------------------------

class TestSampler:
    def _setup(self, n=512, d=12, k=4, l=16, family="dense"):
        p = LSHParams(k=k, l=l, dim=d, family=family)
        x = _unit_rows(jax.random.PRNGKey(8), n, d)
        index = _build_index(jax.random.PRNGKey(9), x, p)
        q = _unit_rows(jax.random.PRNGKey(10), 1, d)[0]
        return index, x, q, p

    def test_sample_shapes_and_ranges(self):
        index, x, q, p = self._setup()
        res = sample(jax.random.PRNGKey(11), index, x, q, p, m=32)
        assert res.indices.shape == (32,)
        assert bool(jnp.all((res.indices >= 0) & (res.indices < 512)))
        assert bool(jnp.all(res.probs > 0)) and bool(jnp.all(res.probs <= 1.0))
        assert bool(jnp.all(res.n_probes >= 1))

    def test_sampled_points_share_bucket_code(self):
        """Every non-fallback sample must actually collide with the query."""
        index, x, q, p = self._setup()
        res = sample(jax.random.PRNGKey(12), index, x, q, p, m=64)
        qc = np.asarray(query_codes(index, q, p))
        codes = np.asarray(
            compute_codes(x, index.projections, k=p.k, l=p.l)
        )  # (N, L)
        for i, fb in zip(np.asarray(res.indices), np.asarray(res.fallback)):
            if not fb:
                assert any(codes[i, t] == qc[t] for t in range(p.l))

    def test_marginal_inclusion_probability(self):
        """Over independent table builds, P(x_i in query bucket) -> cp_i^K."""
        d, n, k = 8, 64, 3
        p = LSHParams(k=k, l=1, dim=d, family="dense")
        x = _unit_rows(jax.random.PRNGKey(13), n, d)
        q = _unit_rows(jax.random.PRNGKey(14), 1, d)[0]
        builds = 1500
        hits = np.zeros(n)
        keys = jax.random.split(jax.random.PRNGKey(15), builds)

        def one(key):
            idx = _build_index(key, x, p)
            qc = query_codes(idx, q, p)
            lo, hi = bucket_bounds(idx, qc)
            in_bucket = jnp.zeros(n, bool).at[idx.order[0, :]].set(
                (jnp.arange(n) >= lo[0]) & (jnp.arange(n) < hi[0])
            )
            return in_bucket

        hits = np.mean(np.asarray(jax.lax.map(one, keys)), axis=0)
        expected = np.asarray(exact_inclusion_probability(x, q, p, l=1))
        # expected = cp^K; hits estimates it with MC error ~ sqrt(p/q)/sqrt(B)
        np.testing.assert_allclose(hits, expected, atol=0.05)

    def test_sampling_frequency_monotonic_in_cp(self):
        """Points with higher cp must be sampled more often (adaptivity)."""
        index, x, q, p = self._setup(n=256, l=32)
        res = sample(jax.random.PRNGKey(16), index, x, q, p, m=8192)
        counts = np.bincount(np.asarray(res.indices), minlength=256)
        cp = np.asarray(collision_probability(x, q))
        top = np.argsort(cp)[-25:]
        bot = np.argsort(cp)[:25]
        assert counts[top].mean() > counts[bot].mean()

    def test_drain_mode(self):
        index, x, q, p = self._setup()
        res = sample_drain(jax.random.PRNGKey(17), index, x, q, p, m=16)
        assert res.indices.shape == (16,)
        # all from the same bucket => same probability basis & same l
        assert len(set(np.asarray(res.n_probes).tolist())) == 1

    @pytest.mark.statistical
    @pytest.mark.parametrize("bound", [3, 7, 13])
    def test_uniform_below_is_uniform(self, bound):
        """Chi-square regression for the modulo-bias fix: draws in
        [0, bound) must be uniform.  The old ``randint(0, N) % bound``
        skewed small residues by up to bound/N relative mass."""
        from repro.core.sampler import _uniform_below

        draws = 30_000
        slots = np.asarray(_uniform_below(
            jax.random.PRNGKey(100 + bound), jnp.int32(bound), (draws,)))
        assert slots.min() >= 0 and slots.max() < bound
        counts = np.bincount(slots, minlength=bound)
        expected = draws / bound
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        # 99.9th percentile of chi2 with (bound-1) dof is < 35 for bound<=13
        assert chi2 < 35.0, (bound, counts.tolist(), chi2)

    @pytest.mark.statistical
    def test_within_bucket_sampling_uniform(self):
        """End-to-end chi-square: identical points share every bucket, so
        drain-mode sampling must hit each of them uniformly."""
        n, d = 8, 12
        p = LSHParams(k=3, l=4, dim=d, family="dense")
        x = jnp.tile(_unit_rows(jax.random.PRNGKey(22), 1, d), (n, 1))
        index = _build_index(jax.random.PRNGKey(23), x, p)
        res = sample_drain(jax.random.PRNGKey(24), index, x, x[0], p, m=8192)
        assert not bool(jnp.any(res.fallback))
        counts = np.bincount(np.asarray(res.indices), minlength=n)
        expected = 8192 / n
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        # 99.9th percentile of chi2 with 7 dof ~= 24.3
        assert chi2 < 24.3, (counts.tolist(), chi2)

    @settings(deadline=None, max_examples=10)
    @given(
        k=st.integers(min_value=1, max_value=8),
        l=st.integers(min_value=1, max_value=20),
        m=st.integers(min_value=1, max_value=16),
    )
    def test_sampler_total_probability_valid(self, k, l, m):
        """Property: any (K, L, m) yields valid probs and indices."""
        p = LSHParams(k=k, l=l, dim=8, family="dense")
        x = _unit_rows(jax.random.PRNGKey(18), 64, 8)
        index = _build_index(jax.random.PRNGKey(19), x, p)
        q = _unit_rows(jax.random.PRNGKey(20), 1, 8)[0]
        res = sample(jax.random.PRNGKey(21), index, x, q, p, m=m)
        assert res.indices.shape == (m,)
        assert bool(jnp.all(res.probs > 0))
        assert bool(jnp.all(jnp.isfinite(res.probs)))
