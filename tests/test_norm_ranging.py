"""Norm-ranged (banded) MIPS: the statistical battery + mutation pins.

The banded family exists to fix a DOCUMENTED estimator-correctness
hole: plain Simple-LSH's single max-norm scale collapses on
heavy-tailed (log-normal) norm distributions and the 1/(p·N) weights
silently break (docs/ARCHITECTURE.md).  Per Needell–Srebro–Ward, every
convergence claim of weighted SGD rests on the inclusion probabilities
being exact — so this battery leads with the unbiasedness identities in
the exact regime where the plain family measurably fails:

  * E[1/(p·N)] = 1 over index builds on the log-normal corpus where
    plain ``mips`` is grossly miscalibrated (measured here side by
    side);
  * chi-square of empirical in-band collision frequency vs the
    composed per-band ``collision_prob``;
  * full-gradient unbiasedness on an un-normalised heavy-tailed
    regression, banded vs plain;
  * estimator variance strictly below plain ``mips``;
  * band-boundary edge cases (one-band corpora, exact-boundary ties,
    empty bands after evict);
  * property-based mutation pins: random append/evict/delta
    interleavings equal a fresh build of the survivors (band
    reassignment on drift included), and streaming restore-at-step-t
    replay is bit-deterministic under banded delta refresh.

Statistical conventions (seeds, sigma bands, regime guards) follow
``tests/_stats.py``; every tolerance below states the measurement it
was calibrated against.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _stats import chi2_cap, mean_band

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_compat import given, settings, st

import repro.core.estimator as E
import repro.core.sampler as S
from repro.core import (
    IndexMutation,
    LSHParams,
    band_starts,
    empirical_estimator_covariance_trace,
    exact_inclusion_probability,
    get_family,
    mutate_index,
    preprocess_regression_mips,
    regression_query,
)
from repro.core.families import normalize_rows
from repro.core.simhash import compute_codes, make_projections
from repro.core.tables import hash_points
from repro.data.lsh_pipeline import LSHPipelineConfig, LSHSampledPipeline

FAM = get_family("mips_banded")
NB = FAM.num_bands()


def _heavy_tail(n, d, seed=8, sigma=0.8):
    """Unit directions x log-normal exp(sigma·z) norms + a raw query —
    the corpus family where plain Simple-LSH's max-norm scale fails."""
    kx, kn, kq = jax.random.split(jax.random.PRNGKey(seed), 3)
    dirs = normalize_rows(jax.random.normal(kx, (n, d)))
    norms = jnp.exp(sigma * jax.random.normal(kn, (n, 1)))
    return dirs * norms, jax.random.normal(kq, (d,))


def _build(key, x_aug, p, live_mask=None):
    return mutate_index(
        None, IndexMutation("build", key=key, x_aug=x_aug,
                            live_mask=live_mask), p)


def _calibration(fam_name, x, q_raw, k, l, n_builds, m, build_seed=11):
    """(grand E[1/(pN)], per-build sd, mean tables probed) over builds."""
    n = x.shape[0]
    fam = get_family(fam_name)
    x_aug = fam.augment_data(x)
    q = fam.augment_query(q_raw)
    p = LSHParams(k=k, l=l, dim=x_aug.shape[-1], family=fam_name)

    def per_build(key):
        kb, ks = jax.random.split(key)
        index = _build(kb, x_aug, p)
        res = S.sample(ks, index, x_aug, q, p, m=m)
        return (jnp.mean(1.0 / (res.probs * n)),
                jnp.mean(res.n_probes.astype(jnp.float32)))

    keys = jax.random.split(jax.random.PRNGKey(build_seed), n_builds)
    means, mean_l = jax.lax.map(per_build, keys)
    means = np.asarray(means)
    return float(means.mean()), float(means.std()), \
        float(np.mean(np.asarray(mean_l)))


def _bands_of(x):
    scale = FAM.data_scale(x)
    return np.asarray(FAM.band_of_norms(
        jnp.linalg.norm(x, axis=-1), scale.boundaries)), scale


def _live_sets(index, n_live):
    """Per-table {code: frozenset(slot ids)} over the live prefix."""
    out = []
    sc = np.asarray(index.sorted_codes)
    od = np.asarray(index.order)
    for t in range(sc.shape[0]):
        live_sc, live_od = sc[t, :n_live], od[t, :n_live]
        out.append({int(code): frozenset(live_od[live_sc == code].tolist())
                    for code in np.unique(live_sc)})
    return out


def squared_loss_grad(theta, x, y):
    return (x @ theta - y) * x


# ---------------------------------------------------------------------------
# 1. BandedScale: quantile banding, tie rules, augmentation geometry
# ---------------------------------------------------------------------------

class TestBandedScale:
    def test_boundaries_ascending_scales_are_band_maxima(self):
        x, _ = _heavy_tail(400, 6)
        bands, scale = _bands_of(x)
        b = np.asarray(scale.boundaries)
        s = np.asarray(scale.scales)
        assert b.shape == (NB - 1,) and s.shape == (NB,)
        assert np.all(np.diff(b) >= 0)
        norms = np.asarray(jnp.linalg.norm(x, axis=-1))
        for j in range(NB):
            members = norms[bands == j]
            if members.size:
                np.testing.assert_allclose(s[j], members.max(), rtol=1e-6)
                assert np.all(members <= s[j] * (1 + 1e-6))

    def test_row_exactly_on_boundary_joins_upper_band(self):
        """The committed tie rule: norm == boundaries[j] -> band j+1
        (searchsorted side="right"), so per-band scales M_j never sit
        BELOW a member's norm because of a tie."""
        x, _ = _heavy_tail(64, 4)
        _, scale = _bands_of(x)
        got = np.asarray(FAM.band_of_norms(scale.boundaries,
                                           scale.boundaries))
        np.testing.assert_array_equal(got, np.arange(1, NB))

    def test_augmentation_geometry(self):
        """[x/M_band, tail, band]: unit-sphere lift within the band
        scale, integer band coordinate, subset == full at pinned scale."""
        x, _ = _heavy_tail(200, 6)
        bands, scale = _bands_of(x)
        x_aug = np.asarray(FAM.augment_data(x, scale=scale))
        assert x_aug.shape == (200, FAM.aug_dim(6))
        body, tail, band = x_aug[:, :-2], x_aug[:, -2], x_aug[:, -1]
        lifted = np.sum(body * body, axis=-1) + tail * tail
        np.testing.assert_allclose(lifted, 1.0, atol=1e-5)
        np.testing.assert_array_equal(band.astype(np.int32), bands)
        # re-augmenting a subset at the pinned scale is bitwise the
        # full augmentation's rows — the delta-refresh contract
        sub = np.asarray(FAM.augment_data(x[50:70], scale=scale))
        np.testing.assert_array_equal(sub, x_aug[50:70])

    def test_all_rows_in_one_band(self):
        """Equal norms collapse every row into the top band; the
        composite index degenerates to one sub-index and sampling still
        works with exact probabilities."""
        n, d = 128, 6
        # exactly-representable equal norms (signed one-hot rows x 2.0):
        # float jitter in jnp.linalg.norm would otherwise split ties
        cols = np.arange(n) % d
        signs = np.where(np.arange(n) % 2 == 0, 2.0, -2.0)
        x = jnp.asarray(np.eye(d, dtype=np.float32)[cols] *
                        signs[:, None].astype(np.float32))
        bands, scale = _bands_of(x)
        assert np.all(bands == NB - 1)
        x_aug = FAM.augment_data(x, scale=scale)
        p = LSHParams(k=3, l=16, dim=x_aug.shape[-1], family="mips_banded")
        index = _build(jax.random.PRNGKey(4), x_aug, p)
        starts = np.asarray(band_starts(index, p))
        np.testing.assert_array_equal(starts[:NB], np.zeros(NB))
        assert starts[-1] == n
        q = FAM.augment_query(jax.random.normal(jax.random.PRNGKey(5), (d,)))
        res = S.sample(jax.random.PRNGKey(6), index, x_aug, q, p, m=256)
        assert np.all(np.asarray(res.probs) > 0)
        assert not np.any(np.asarray(res.fallback))


# ---------------------------------------------------------------------------
# 2. Code layout: high-bit tags, contiguous band regions, width guards
# ---------------------------------------------------------------------------

class TestBandedCodes:
    def test_band_tags_contiguous_and_starts_match(self):
        x, q_raw = _heavy_tail(300, 8)
        bands, scale = _bands_of(x)
        x_aug = FAM.augment_data(x, scale=scale)
        p = LSHParams(k=3, l=12, dim=x_aug.shape[-1], family="mips_banded")
        index = _build(jax.random.PRNGKey(9), x_aug, p)
        sc = np.asarray(index.sorted_codes)
        od = np.asarray(index.order)
        tags = sc >> p.k
        # every table: band tags ascend along the sorted order and agree
        # with the per-row band assignment
        for t in range(p.l):
            assert np.all(np.diff(tags[t]) >= 0)
            np.testing.assert_array_equal(tags[t], bands[od[t]])
        starts = np.asarray(band_starts(index, p))
        counts = np.bincount(bands, minlength=NB)
        np.testing.assert_array_equal(np.diff(starts), counts)
        # query codes carry NO tag (band coordinate zeroed in both the
        # augmentation and the projection row)
        qc = np.asarray(compute_codes(
            FAM.augment_query(q_raw), index.projections, k=p.k, l=p.l))
        assert np.all(qc < (1 << p.k))

    def test_projection_band_row_is_zero(self):
        p = LSHParams(k=3, l=8, dim=FAM.aug_dim(6), family="mips_banded")
        proj = np.asarray(make_projections(jax.random.PRNGKey(10), p))
        assert np.all(proj[-1] == 0.0)
        assert np.any(proj[:-1] != 0.0)

    def test_flat_family_hooks_default_to_noop(self):
        """The multi-index hooks must stay parity-safe no-ops for every
        flat family (the SRP / plain-mips golden pins rest on this)."""
        x = jax.random.normal(jax.random.PRNGKey(11), (5, 4))
        proj = jax.random.normal(jax.random.PRNGKey(12), (4, 6))
        for name in ("dense", "sparse", "quadratic", "mips"):
            fam = get_family(name)
            assert fam.num_bands() == 1
            assert fam.code_tags(x, 3) is None
            np.testing.assert_array_equal(np.asarray(fam.mask_projections(proj)),
                                          np.asarray(proj))

    def test_code_width_guards(self):
        assert FAM.code_width(3) == 3 + (NB - 1).bit_length()
        with pytest.raises(ValueError, match="code width"):
            LSHParams(k=30, l=2, dim=8, family="mips_banded")
        with pytest.raises(ValueError, match="code_width"):
            LSHPipelineConfig(streaming=True, k=29, family="mips_banded")
        # k=28 -> width 31: the widest streaming-legal banded code
        LSHPipelineConfig(streaming=True, k=28, family="mips_banded")


# ---------------------------------------------------------------------------
# 3. The statistical battery (see tests/_stats.py for conventions)
# ---------------------------------------------------------------------------

class TestBandedCalibration:
    @pytest.mark.statistical
    def test_unit_inverse_probability_where_plain_mips_fails(self):
        """THE headline identity: on the log-normal corpus where plain
        ``mips`` is grossly miscalibrated, banded E[1/(p·N)] = 1.

        Bench-shaped regime (n=2000, d=32, K=3, L=100 — the
        ``tab_families`` heavy-tail column).  Measured at these seeds:
        banded grand 1.029, per-build sd 0.091, mean_l 1.042; plain
        mips grand 1.666, sd 0.437 (direction of the plain-family error
        is seed-dependent — the committed failure mode is |grand-1|
        large with huge per-build spread, ARCHITECTURE.md's measured
        0.55 run being one instance).  Bands: banded 1 +- 0.1 (>= 3
        sigma headroom via _stats.mean_band(0.091, 8) ~ 0.097); plain
        |grand-1| > 0.3."""
        x, q_raw = _heavy_tail(2000, 32)
        grand_b, sd_b, mean_l_b = _calibration(
            "mips_banded", x, q_raw, k=3, l=100, n_builds=8, m=2000)
        assert mean_l_b < 1.15, f"banded regime drifted: mean_l={mean_l_b}"
        band = max(0.1, mean_band(sd_b, 8))
        assert abs(grand_b - 1.0) < band, (
            f"banded E[1/(pN)] = {grand_b:.3f} (sd {sd_b:.3f}) — "
            "the norm-ranged composition is miscalibrated")
        grand_p, sd_p, _ = _calibration(
            "mips", x, q_raw, k=3, l=100, n_builds=8, m=2000)
        assert abs(grand_p - 1.0) > 0.3, (
            f"plain mips E[1/(pN)] = {grand_p:.3f} — the documented "
            "heavy-tail failure regime no longer reproduces; "
            "re-calibrate this battery")
        assert sd_b < sd_p, "banded per-build spread should shrink"

    @pytest.mark.statistical
    def test_chi_square_per_band_collision_law(self):
        """Empirical in-band collision frequency vs the composed
        per-band closed form: point i lands in the probed bucket of ITS
        band iff its tagged code equals (query code | tag_i), with
        probability cp_i^K at the band's scale.  L = 1500 tables as
        Bernoulli trials, 5-sigma chi-square cap (_stats.chi2_cap)."""
        k, l, n, d = 3, 1500, 24, 8
        x, q_raw = _heavy_tail(n, d, seed=7)
        bands, scale = _bands_of(x)
        x_aug = FAM.augment_data(x, scale=scale)
        q_aug = FAM.augment_query(q_raw)
        p = LSHParams(k=k, l=l, dim=x_aug.shape[-1], family="mips_banded")
        proj = make_projections(jax.random.PRNGKey(21), p)
        cx = np.asarray(hash_points(x_aug, proj, p))          # (L, N) tagged
        cq = np.asarray(compute_codes(q_aug, proj, k=k, l=l))  # (L,) untagged
        tags = np.asarray(FAM.code_tags(x_aug, k))
        match = cx == (cq[:, None] | tags[None, :])
        freq = match.mean(axis=0)                              # (N,)
        cp = np.asarray(FAM.collision_prob(x_aug, q_aug))
        expect = cp ** k
        keep = (expect > 0.005) & (expect < 0.995)
        assert keep.sum() >= 10, "collision-law regime degenerate"
        obs, exp = freq[keep] * l, expect[keep] * l
        chi2 = float(np.sum((obs - exp) ** 2 /
                            (l * expect[keep] * (1 - expect[keep]))))
        ncell = int(keep.sum())
        assert chi2 < chi2_cap(ncell), (
            f"chi2 {chi2:.1f} over {ncell} cells — empirical banded "
            "collision frequency disagrees with the composed law")
        # the composed per-draw inclusion probability is the band share
        # times the in-band law (estimator.exact_inclusion_probability)
        starts_share = np.bincount(bands, minlength=NB)[bands] / n
        got = np.asarray(exact_inclusion_probability(
            x_aug, q_aug, p, band_select=jnp.asarray(starts_share,
                                                     jnp.float32)))
        np.testing.assert_allclose(got, starts_share * expect, rtol=1e-5)

    @pytest.mark.statistical
    def test_full_gradient_unbiased_heavy_tail(self):
        """Importance-weighted minibatch gradient == full-batch gradient
        on an UN-normALISED log-normal regression — banded converges
        where plain mips stays biased.  Measured at these seeds over 60
        builds x m=1000: banded rel err 0.193 (K=2), plain mips 0.919
        (K=3, its documented calibration); asserts 0.35 / 0.5."""
        n, d = 400, 8
        kx, kt, kn, ke = jax.random.split(jax.random.PRNGKey(14), 4)
        dirs = normalize_rows(jax.random.normal(kx, (n, d)))
        x = dirs * jnp.exp(0.8 * jax.random.normal(kn, (n, 1)))
        y = x @ jax.random.normal(kt, (d,)) + \
            0.1 * jax.random.normal(ke, (n,))
        theta = 0.1 * jax.random.normal(jax.random.PRNGKey(15), (d,))

        def rel_err(fam_name, k):
            fam = get_family(fam_name)
            xt, yt, x_aug = preprocess_regression_mips(x, y, fam)
            p = LSHParams(k=k, l=16, dim=x_aug.shape[-1], family=fam_name)
            q = fam.augment_query(regression_query(theta))
            full_grad = jnp.mean(jax.vmap(
                lambda a, b: squared_loss_grad(theta, a, b))(xt, yt), 0)

            def per_build(key):
                kb, ks = jax.random.split(key)
                index = _build(kb, x_aug, p)
                res = S.sample(ks, index, x_aug, q, p, m=1000)
                return E.lgd_gradient(squared_loss_grad, theta,
                                      xt[res.indices], yt[res.indices],
                                      res, n)

            keys = jax.random.split(jax.random.PRNGKey(16), 60)
            grand = jnp.mean(jax.lax.map(per_build, keys), axis=0)
            return float(jnp.linalg.norm(grand - full_grad) /
                         jnp.linalg.norm(full_grad))

        rel_banded = rel_err("mips_banded", 2)
        assert rel_banded < 0.35, (
            f"banded gradient biased on heavy tails: rel {rel_banded:.3f}")
        rel_plain = rel_err("mips", 3)
        assert rel_plain > 0.5, (
            f"plain mips rel err {rel_plain:.3f} — failure regime no "
            "longer reproduces; re-calibrate this battery")

    @pytest.mark.statistical
    def test_variance_below_plain_mips(self):
        """Single-draw minibatch-estimator Tr Cov over builds: banded
        strictly below plain mips on the heavy-tailed corpus (same
        K=3/L=16/m=400 protocol).  Measured at these seeds: plain 1.82,
        banded 1.05 — asserted with a 20% margin."""
        n, d = 400, 8
        kx, kt, kn, ke = jax.random.split(jax.random.PRNGKey(14), 4)
        dirs = normalize_rows(jax.random.normal(kx, (n, d)))
        x = dirs * jnp.exp(0.8 * jax.random.normal(kn, (n, 1)))
        y = x @ jax.random.normal(kt, (d,)) + \
            0.1 * jax.random.normal(ke, (n,))
        theta = 0.1 * jax.random.normal(jax.random.PRNGKey(15), (d,))

        def trace_cov(fam_name):
            fam = get_family(fam_name)
            xt, yt, x_aug = preprocess_regression_mips(x, y, fam)
            p = LSHParams(k=3, l=16, dim=x_aug.shape[-1], family=fam_name)
            q = fam.augment_query(regression_query(theta))

            def per_build(key):
                kb, ks = jax.random.split(key)
                index = _build(kb, x_aug, p)
                res = S.sample(ks, index, x_aug, q, p, m=400)
                return E.lgd_gradient(squared_loss_grad, theta,
                                      xt[res.indices], yt[res.indices],
                                      res, n)

            keys = jax.random.split(jax.random.PRNGKey(16), 60)
            ests = jax.lax.map(per_build, keys)
            return float(empirical_estimator_covariance_trace(ests))

        tr_banded = trace_cov("mips_banded")
        tr_plain = trace_cov("mips")
        assert tr_banded < 0.8 * tr_plain, (
            f"banded Tr Cov {tr_banded:.3f} not below plain mips "
            f"{tr_plain:.3f}")


# ---------------------------------------------------------------------------
# 4. Edge cases: empty bands under evict, live-count composition
# ---------------------------------------------------------------------------

class TestBandedEdgeCases:
    @pytest.mark.statistical
    def test_empty_band_after_evict_stays_unbiased(self):
        """Evicting EVERY row of one band leaves a zero-width region:
        the band is never drawn, no sample comes from it, and
        E[1/(p·n_live)] stays 1 over the survivors (band shares are
        read off the live index, not the build)."""
        n, d = 256, 6
        x, q_raw = _heavy_tail(n, d, seed=19)
        bands, scale = _bands_of(x)
        x_aug = FAM.augment_data(x, scale=scale)
        p = LSHParams(k=2, l=24, dim=x_aug.shape[-1], family="mips_banded")
        index = _build(jax.random.PRNGKey(20), x_aug, p,
                       live_mask=jnp.ones((n,), bool))
        victims = np.flatnonzero(bands == 3).astype(np.int32)
        assert victims.size > 0
        index = mutate_index(
            index, IndexMutation("evict", ids=jnp.asarray(victims)), p)
        starts = np.asarray(band_starts(index, p))
        assert starts[4] - starts[3] == 0, "evicted band not empty"
        n_live = n - victims.size
        assert starts[-1] == n_live
        q = FAM.augment_query(q_raw)
        res = S.sample(jax.random.PRNGKey(22), index, x_aug, q, p, m=4000)
        idx = np.asarray(res.indices)
        assert not np.any(np.isin(idx, victims)), "sampled an evicted row"
        inv = float(np.mean(1.0 / (np.asarray(res.probs) * n_live)))
        # measured 0.98 at these seeds; 0.25 band >> the m=4000 se
        assert abs(inv - 1.0) < 0.25, (
            f"E[1/(p·n_live)] = {inv:.3f} after band evict")


# ---------------------------------------------------------------------------
# 5. Property-based mutation pins (hypothesis or the committed shim)
# ---------------------------------------------------------------------------

def _hash(x_aug, index, p):
    return hash_points(x_aug, index.projections, p)


class TestBandedMutationProperties:
    @settings(deadline=None, max_examples=6)
    @given(seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_interleavings_match_fresh_build(self, seed):
        """Random append/evict/delta interleavings on a banded index ==
        a fresh build of the surviving rows (same projections): same
        sorted live codes, same per-(table, code) bucket membership —
        band reassignment on drift included, because delta re-hash tags
        by the row's CURRENT norm under the PINNED boundaries."""
        rng = np.random.default_rng(seed)
        n, cap, d = 48, 64, 6
        x0, _ = _heavy_tail(n, d, seed=int(rng.integers(1 << 16)))
        raw = np.zeros((cap, d), np.float32)
        raw[:n] = np.asarray(x0)
        live = np.zeros((cap,), bool)
        live[:n] = True
        scale = FAM.data_scale(jnp.asarray(raw) *
                               live[:, None].astype(np.float32))
        p = LSHParams(k=3, l=6, dim=FAM.aug_dim(d), family="mips_banded")

        def aug(rows):
            return FAM.augment_data(jnp.asarray(rows, jnp.float32),
                                    scale=scale)

        index = _build(jax.random.PRNGKey(33), aug(raw), p,
                       live_mask=jnp.asarray(live))
        for _ in range(int(rng.integers(3, 7))):
            op = rng.choice(["append", "evict", "delta"])
            if op == "append" and (~live).sum() >= 4:
                ids = np.flatnonzero(~live)[:4].astype(np.int32)
                fresh, _ = _heavy_tail(4, d, seed=int(rng.integers(1 << 16)))
                raw[ids] = np.asarray(fresh)
                live[ids] = True
                index = mutate_index(index, IndexMutation(
                    "append", ids=jnp.asarray(ids),
                    codes=_hash(aug(raw[ids]), index, p)))
            elif op == "evict" and live.sum() > 8:
                ids = rng.choice(np.flatnonzero(live), size=4,
                                 replace=False).astype(np.int32)
                live[ids] = False
                index = mutate_index(index, IndexMutation(
                    "evict", ids=jnp.asarray(ids)), p)
            elif op == "delta" and live.sum() >= 4:
                ids = rng.choice(np.flatnonzero(live), size=4,
                                 replace=False).astype(np.int32)
                # drift rows across norm bands: band reassignment must
                # ride the ordinary tie-stable merge
                raw[ids] *= rng.uniform(0.25, 4.0, (4, 1)).astype(np.float32)
                index = mutate_index(index, IndexMutation(
                    "delta", ids=jnp.asarray(ids),
                    codes=_hash(aug(raw[ids]), index, p)))
        n_live = int(live.sum())
        masked = raw * live[:, None]
        fresh_index = _build(jax.random.PRNGKey(33), aug(masked), p,
                             live_mask=jnp.asarray(live))
        np.testing.assert_array_equal(
            np.asarray(index.sorted_codes)[:, :n_live],
            np.asarray(fresh_index.sorted_codes)[:, :n_live])
        assert _live_sets(index, n_live) == _live_sets(fresh_index, n_live)
        np.testing.assert_array_equal(np.asarray(band_starts(index, p)),
                                      np.asarray(band_starts(fresh_index, p)))

    def test_streaming_restore_replays_banded_delta(self):
        """restore_at(t) under a banded streaming pipeline with DELTA
        refresh: the JSON-round-tripped mutation log replays to an
        identical index and bit-identical batch draws."""
        import json

        vocab, dim, seq = 50, 16, 9
        embed = jax.random.normal(jax.random.PRNGKey(1), (vocab, dim))
        params = {"embed": embed, "q": jnp.ones((dim,))}

        def feature_fn(prm, chunk):
            return jnp.mean(prm["embed"][chunk], axis=1)

        def query_fn(prm):
            return prm["q"]

        def tokens(n, seed):
            return np.asarray(jax.random.randint(
                jax.random.PRNGKey(seed), (n, seq), 0, vocab), np.int32)

        def pipe():
            cfg = LSHPipelineConfig(
                streaming=True, k=4, l=8, minibatch=8, window=48,
                refresh_every=3, refresh_mode="delta",
                family="mips_banded")
            return LSHSampledPipeline(jax.random.PRNGKey(7), tokens(48, 2),
                                      feature_fn, query_fn, cfg,
                                      params=params)

        one = pipe()
        for _ in range(4):
            one.next_batch()                # crosses a delta refresh
        one.append_rows(tokens(6, 31))
        for _ in range(3):
            one.next_batch()
        gids = one.append_rows(tokens(2, 37))
        one.evict_rows(gids[:1])
        t = one._step
        log = json.loads(json.dumps(one.mutation_log()))
        live_before = one._live_np.copy()

        one.restore_at(t)
        np.testing.assert_array_equal(one._live_np, live_before)
        expect = [np.asarray(one.next_batch()["example_ids"])
                  for _ in range(4)]

        other = pipe()
        other.load_mutation_log(log)
        other.restore_at(t)
        np.testing.assert_array_equal(other._live_np, live_before)
        np.testing.assert_array_equal(
            np.asarray(other.index.sorted_codes),
            np.asarray(one.index.sorted_codes))
        for a in expect:
            np.testing.assert_array_equal(
                a, np.asarray(other.next_batch()["example_ids"]))


# ---------------------------------------------------------------------------
# 6. Pipeline smoke: dense banded pipeline end to end
# ---------------------------------------------------------------------------

class TestBandedPipeline:
    def test_dense_pipeline_draws_weighted_batches(self):
        vocab, dim, seq = 40, 12, 7
        embed = jax.random.normal(jax.random.PRNGKey(2), (vocab, dim))
        params = {"embed": embed, "q": jnp.ones((dim,))}
        toks = np.asarray(jax.random.randint(
            jax.random.PRNGKey(3), (64, seq), 0, vocab), np.int32)
        cfg = LSHPipelineConfig(k=3, l=8, minibatch=8, refresh_every=0,
                                family="mips_banded")
        pipe = LSHSampledPipeline(
            jax.random.PRNGKey(4), toks,
            lambda prm, chunk: jnp.mean(prm["embed"][chunk], axis=1),
            lambda prm: prm["q"], cfg, params=params)
        assert pipe.lsh.dim == dim + 2
        b = pipe.next_batch()
        assert b["tokens"].shape == (8, seq - 1)
        assert np.all(np.asarray(b["loss_weights"]) > 0)
