"""Estimator tests: unbiasedness (Thm 1), variance advantage (Lemma 1), LGD training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.estimator as E
import repro.core.sampler as S
from repro.core import (
    LGDProblem,
    LGDState,
    LSHParams,
    IndexMutation,
    mutate_index,
    full_loss,
    init,
    lgd_step,
    regression_query,
    sgd_step,
)
from repro.core.lgd import (
    logistic_loss_grad,
    preprocess_regression,
    squared_loss_grad,
)
from repro.optim import SGD, AdaGrad, Adam


KEY = jax.random.PRNGKey(0)


def _build_index(key, x_aug, p, **kw):
    return mutate_index(
        None, IndexMutation("build", key=key, x_aug=x_aug), p, **kw)


def _regression_data(key, n=1500, d=16, pareto=False):
    kx, ky, kt, kn = jax.random.split(key, 4)
    x = jax.random.normal(kx, (n, d))
    theta = jax.random.normal(kt, (d,))
    if pareto:
        noise = jax.random.pareto(kn, 1.5, (n,)) * \
            jax.random.rademacher(ky, (n,)).astype(jnp.float32) * 0.1
    else:
        noise = 0.1 * jax.random.normal(kn, (n,))
    return x, x @ theta + noise


class TestUnbiasedness:
    @pytest.mark.statistical
    def test_estimator_unbiased_over_hash_draws(self):
        """Theorem 1: E[Est] = full gradient, expectation over hash draws
        AND sampling.  Quadratic family => bounded weights => CLT applies."""
        n, d = 400, 8
        x, y = _regression_data(jax.random.PRNGKey(1), n, d)
        xt, yt, x_aug = preprocess_regression(x, y)
        p = LSHParams(k=3, l=10, dim=d + 1, family="quadratic")
        theta = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (d,))
        q = regression_query(theta)
        full_grad = jnp.mean(
            jax.vmap(lambda a, b: squared_loss_grad(theta, a, b))(xt, yt), 0
        )

        builds = 30
        samples_per_build = 400

        def per_build(key):
            kb, ks = jax.random.split(key)
            index = _build_index(kb, x_aug, p)
            res = S.sample(ks, index, x_aug, q, p, m=samples_per_build)
            return E.lgd_gradient(
                squared_loss_grad, theta, xt[res.indices], yt[res.indices],
                res, n,
            )

        keys = jax.random.split(jax.random.PRNGKey(3), builds)
        ests = jax.lax.map(per_build, keys)
        grand = jnp.mean(ests, axis=0)
        rel = float(jnp.linalg.norm(grand - full_grad) /
                    jnp.linalg.norm(full_grad))
        assert rel < 0.25, f"estimator biased: rel err {rel}"

    def test_importance_weights(self):
        res = S.SampleResult(
            indices=jnp.array([0, 1]),
            probs=jnp.array([0.5, 0.25]),
            n_probes=jnp.array([1, 1]),
            bucket_sizes=jnp.array([2, 4]),
            fallback=jnp.array([False, False]),
        )
        w = E.importance_weights(res, n_points=10)
        np.testing.assert_allclose(np.asarray(w), [1 / 5.0, 1 / 2.5], rtol=1e-6)


class TestVariance:
    @pytest.mark.statistical
    def test_lgd_variance_below_sgd_on_powerlaw(self):
        """Lemma 1 regime: power-law gradient norms => Tr cov(LGD) < Tr cov(SGD).

        Early training (theta=0) is where gradient-norm heterogeneity is
        largest and the LGD advantage is provable; near the optimum the
        bucket-size noise term of Theorem 2 can dominate (recorded in
        EXPERIMENTS.md as an honest boundary of the paper's claim)."""
        n, d = 2000, 16
        x, y = _regression_data(jax.random.PRNGKey(4), n, d, pareto=True)
        xt, yt, x_aug = preprocess_regression(x, y)
        p = LSHParams(k=5, l=100, dim=d + 1, family="quadratic")
        index = _build_index(jax.random.PRNGKey(5), x_aug, p)
        theta = jnp.zeros(d)
        q = regression_query(theta)

        keys = jax.random.split(jax.random.PRNGKey(7), 2000)

        def one_lgd(k):
            res = S.sample(k, index, x_aug, q, p, m=1)
            return E.lgd_gradient(
                squared_loss_grad, theta, xt[res.indices], yt[res.indices],
                res, n,
            )

        def one_sgd(k):
            i = jax.random.randint(k, (), 0, n)
            return squared_loss_grad(theta, xt[i], yt[i])

        var_lgd = float(E.empirical_estimator_covariance_trace(
            jax.lax.map(one_lgd, keys)))
        var_sgd = float(E.empirical_estimator_covariance_trace(
            jax.lax.map(one_sgd, keys)))
        assert var_lgd < var_sgd, (var_lgd, var_sgd)

    @pytest.mark.statistical
    def test_lgd_samples_have_larger_gradient_norm(self):
        """Paper Fig. 9(a-c): LGD-sampled points have larger ||grad|| than SGD.

        Like the paper, measured at a warm-started theta ('freeze after 1/4
        epoch') — at random init the separation is invisible (Sec. 3.1)."""
        n, d = 3000, 16
        kx, ky, kt, kn = jax.random.split(jax.random.PRNGKey(8), 4)
        x = jax.random.normal(kx, (n, d))
        noise = jax.random.pareto(kn, 1.2, (n,)) * \
            jax.random.rademacher(ky, (n,)).astype(jnp.float32)
        y = x @ jax.random.normal(kt, (d,)) + noise
        xt, yt, x_aug = preprocess_regression(x, y)
        theta, *_ = jnp.linalg.lstsq(xt, yt)  # warm start at the bulk fit
        p = LSHParams(k=5, l=100, dim=d + 1, family="quadratic")
        index = _build_index(jax.random.PRNGKey(9), x_aug, p)
        q = regression_query(theta)
        res = S.sample(jax.random.PRNGKey(11), index, x_aug, q, p, m=2048)
        gn = jax.vmap(
            lambda i: jnp.linalg.norm(squared_loss_grad(theta, xt[i], yt[i]))
        )
        lgd_norm = float(jnp.mean(gn(res.indices)))
        unif = jax.random.randint(jax.random.PRNGKey(12), (2048,), 0, n)
        sgd_norm = float(jnp.mean(gn(unif)))
        assert lgd_norm > 1.2 * sgd_norm, (lgd_norm, sgd_norm)

    def test_lgd_estimate_better_aligned_with_true_gradient(self):
        """Paper Fig. 9(d-f): LGD minibatch estimate has higher cosine
        similarity to the full gradient than the SGD estimate.

        Measured partway toward the bulk fit (the paper's 'freeze after
        1/4 epoch'): AT the exact lstsq optimum the full gradient of the
        quadratic loss vanishes, so cosine alignment there is pure noise
        — both samplers score ~0.05 and the comparison is meaningless."""
        n, d = 3000, 16
        kx, ky, kt, kn = jax.random.split(jax.random.PRNGKey(42), 4)
        x = jax.random.normal(kx, (n, d))
        noise = jax.random.pareto(kn, 1.2, (n,)) * \
            jax.random.rademacher(ky, (n,)).astype(jnp.float32)
        y = x @ jax.random.normal(kt, (d,)) + noise
        xt, yt, x_aug = preprocess_regression(x, y)
        theta_opt, *_ = jnp.linalg.lstsq(xt, yt)
        theta = 0.15 * theta_opt
        p = LSHParams(k=5, l=100, dim=d + 1, family="quadratic")
        index = _build_index(jax.random.PRNGKey(1), x_aug, p)
        q = regression_query(theta)
        full_grad = jnp.mean(
            jax.vmap(lambda a, b: squared_loss_grad(theta, a, b))(xt, yt), 0
        )
        keys = jax.random.split(jax.random.PRNGKey(21), 500)

        def one_lgd(k):
            r = S.sample(k, index, x_aug, q, p, m=16)
            return E.lgd_gradient(squared_loss_grad, theta, xt[r.indices],
                                  yt[r.indices], r, n)

        def one_sgd(k):
            i = jax.random.randint(k, (16,), 0, n)
            return jnp.mean(
                jax.vmap(lambda j: squared_loss_grad(theta, xt[j], yt[j]))(i), 0
            )

        def mean_cos(a):
            return float(jnp.mean(
                jnp.sum(a * full_grad, -1)
                / (jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(full_grad)
                   + 1e-30)))

        cos_lgd = mean_cos(jax.lax.map(one_lgd, keys))
        cos_sgd = mean_cos(jax.lax.map(one_sgd, keys))
        assert cos_lgd > cos_sgd, (cos_lgd, cos_sgd)

    # regime pinned to the calibration in EXPERIMENTS.md §Repro — the
    # alignment gap is real but modest, so the dataset seed is fixed.


class TestLGDTraining:
    @pytest.mark.parametrize("opt", [SGD(lr=5e-3), AdaGrad(lr=5e-2), Adam(lr=1e-2)])
    def test_lgd_decreases_loss(self, opt):
        x, y = _regression_data(jax.random.PRNGKey(13), 1000, 12)
        prob = LGDProblem(
            kind="regression",
            lsh=LSHParams(k=5, l=20, dim=13, family="sparse"),
            minibatch=8,
        )
        state, xt, yt, x_aug = init(jax.random.PRNGKey(14), prob, x, y, opt)
        loss0 = float(full_loss(state.theta, xt, yt, prob))
        s = state
        for i in range(200):
            s, m = lgd_step(jax.random.fold_in(KEY, i), s, xt, yt, x_aug,
                            prob, opt)
        loss1 = float(full_loss(s.theta, xt, yt, prob))
        assert loss1 < loss0
        assert np.isfinite(loss1)

    def test_lgd_matches_sgd_convergence_on_powerlaw(self):
        """Paper Fig. 10 setting: LGD must converge at least as fast as SGD
        (same optimiser/lr) on heavy-tail data.  The sampling advantage
        shows up in the variance/cosine tests above; here we require
        parity-or-better within a 10% margin at convergence (600 steps —
        mid-trajectory the bucket-size noise term of Theorem 2 keeps LGD
        ~13% behind on this dataset; both settle to the same loss)."""
        kx, ky, kt, kn = jax.random.split(jax.random.PRNGKey(42), 4)
        x = jax.random.normal(kx, (3000, 16))
        noise = jax.random.pareto(kn, 2.0, (3000,)) * \
            jax.random.rademacher(ky, (3000,)).astype(jnp.float32) * 0.5
        y = x @ jax.random.normal(kt, (16,)) + noise
        prob = LGDProblem(
            kind="regression",
            lsh=LSHParams(k=5, l=100, dim=17, family="quadratic"),
            minibatch=16,
        )
        opt = SGD(lr=5e-2)
        state, xt, yt, x_aug = init(jax.random.PRNGKey(16), prob, x, y, opt)
        sL = sU = state
        for i in range(600):
            kk = jax.random.fold_in(KEY, 50_000 + i)
            sL, _ = lgd_step(kk, sL, xt, yt, x_aug, prob, opt)
            sU, _ = sgd_step(kk, sU, xt, yt, prob, opt)
        loss_lgd = float(full_loss(sL.theta, xt, yt, prob))
        loss_sgd = float(full_loss(sU.theta, xt, yt, prob))
        assert loss_lgd < 1.10 * loss_sgd, (loss_lgd, loss_sgd)

    def test_logistic_lgd(self):
        kx, kt = jax.random.split(jax.random.PRNGKey(17))
        n, d = 1000, 10
        x = jax.random.normal(kx, (n, d))
        theta_true = jax.random.normal(kt, (d,))
        y = jnp.sign(x @ theta_true + 0.01)
        prob = LGDProblem(
            kind="logistic",
            lsh=LSHParams(k=5, l=20, dim=d, family="sparse"),
            minibatch=8,
        )
        opt = SGD(lr=1e-1)
        state, xt, yt, x_aug = init(jax.random.PRNGKey(18), prob, x, y, opt)
        loss0 = float(full_loss(state.theta, xt, yt, prob))
        s = state
        for i in range(300):
            s, _ = lgd_step(jax.random.fold_in(KEY, 99_000 + i), s, xt, yt,
                            x_aug, prob, opt)
        loss1 = float(full_loss(s.theta, xt, yt, prob))
        assert loss1 < loss0
        acc = float(jnp.mean((jnp.sign(xt @ s.theta) == yt).astype(jnp.float32)))
        assert acc > 0.8
