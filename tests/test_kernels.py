"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle.

Shape/dtype sweeps as required: each kernel is exercised across block
boundaries, GQA group sizes, and bf16/f32.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (
    attention_ref,
    decode_ref,
    flash_attention_pallas,
    flash_decode_pallas,
    gqa_attention,
    gqa_decode,
)
from repro.kernels.simhash import simhash_codes, simhash_codes_ref

KEY = jax.random.PRNGKey(0)


class TestSimhashKernel:
    @pytest.mark.parametrize("n,d,k,l", [
        (256, 64, 5, 8),      # exact block fit
        (300, 91, 5, 100),    # paper's YearMSD-like dims, padding needed
        (64, 530, 7, 10),     # paper's BERT params, UJIIndoorLoc dims
        (8, 16, 1, 1),        # degenerate
        (512, 128, 32, 4),    # max K
    ])
    def test_matches_ref(self, n, d, k, l):
        kx, kw = jax.random.split(jax.random.fold_in(KEY, n * d))
        x = jax.random.normal(kx, (n, d))
        w = jax.random.normal(kw, (d, l * k))
        got = simhash_codes(x, w, k=k, l=l, use_pallas=True, interpret=True)
        want = simhash_codes_ref(x, w, k=k, l=l)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bf16_input(self):
        kx, kw = jax.random.split(KEY)
        x = jax.random.normal(kx, (128, 64), jnp.bfloat16)
        w = jax.random.normal(kw, (64, 40))
        got = simhash_codes(x, w, k=5, l=8, use_pallas=True, interpret=True)
        want = simhash_codes_ref(x, w, k=5, l=8)
        # bf16 rounding can flip signs on near-zero projections
        agree = np.mean(np.asarray(got) == np.asarray(want))
        assert agree > 0.97, agree

    def test_matches_core_compute_codes(self):
        """The kernel must agree with repro.core.simhash.compute_codes."""
        from repro.core.simhash import LSHParams, compute_codes, make_projections
        p = LSHParams(k=5, l=10, dim=33, family="dense")
        proj = make_projections(KEY, p)
        x = jax.random.normal(jax.random.PRNGKey(1), (100, 33))
        want = compute_codes(x, proj, k=5, l=10)
        got = simhash_codes(x, proj, k=5, l=10, use_pallas=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _qkv(key, b, hkv, g, s, d, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hkv, g, s, d), dtype)
    k = jax.random.normal(kk, (b, hkv, s, d), dtype)
    v = jax.random.normal(kv, (b, hkv, s, d), dtype)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize("b,hkv,g,s,d,bq,bk", [
        (1, 1, 1, 128, 64, 64, 64),
        (2, 2, 4, 128, 64, 64, 64),     # GQA group 4
        (1, 1, 2, 256, 128, 128, 64),   # uneven q/k blocks
        (1, 2, 1, 64, 32, 64, 32),      # single q block
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, b, hkv, g, s, d, bq, bk, causal):
        q, k, v = _qkv(jax.random.fold_in(KEY, s * d + g), b, hkv, g, s, d)
        got = flash_attention_pallas(
            q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True
        )
        want = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )

    def test_bf16(self):
        q, k, v = _qkv(KEY, 1, 2, 2, 128, 64, jnp.bfloat16)
        got = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                     block_k=64, interpret=True)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2,
        )

    def test_gqa_wrapper_model_layout(self):
        b, s, hq, hkv, d = 2, 128, 8, 2, 64
        kq, kk, kv = jax.random.split(KEY, 3)
        q = jax.random.normal(kq, (b, s, hq, d))
        k = jax.random.normal(kk, (b, s, hkv, d))
        v = jax.random.normal(kv, (b, s, hkv, d))
        got = gqa_attention(q, k, v, causal=True, use_pallas=True,
                            interpret=True, block_q=64, block_k=64)
        want = gqa_attention(q, k, v, causal=True, use_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestFlashDecode:
    @pytest.mark.parametrize("b,hkv,g,s,d,bk", [
        (2, 2, 1, 512, 64, 256),
        (1, 4, 4, 1024, 128, 512),
        (3, 1, 8, 256, 64, 128),
    ])
    def test_matches_ref(self, b, hkv, g, s, d, bk):
        kq, kk, kv, kl = jax.random.split(jax.random.fold_in(KEY, s + d), 4)
        q = jax.random.normal(kq, (b, hkv, g, d))
        k = jax.random.normal(kk, (b, hkv, s, d))
        v = jax.random.normal(kv, (b, hkv, s, d))
        kv_len = jax.random.randint(kl, (b,), 1, s + 1)
        got = flash_decode_pallas(q, k, v, kv_len, block_k=bk, interpret=True)
        want = decode_ref(q, k, v, kv_len)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_gqa_decode_wrapper(self):
        b, s, hq, hkv, d = 2, 256, 8, 4, 64
        kq, kk, kv = jax.random.split(KEY, 3)
        q = jax.random.normal(kq, (b, 1, hq, d))
        kc = jax.random.normal(kk, (b, s, hkv, d))
        vc = jax.random.normal(kv, (b, s, hkv, d))
        kv_len = jnp.array([s, s // 2], jnp.int32)
        got = gqa_decode(q, kc, vc, kv_len, use_pallas=True, interpret=True,
                         block_k=128)
        want = gqa_decode(q, kc, vc, kv_len, use_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_decode_agrees_with_full_attention_last_token(self):
        """Decoding token s against cache[0:s] == causal attention row s."""
        b, hkv, g, s, d = 1, 2, 2, 128, 64
        q5, k5, v5 = _qkv(KEY, b, hkv, g, s, d)
        full = attention_ref(q5, k5, v5, causal=True)
        got = flash_decode_pallas(
            q5[:, :, :, -1], k5, v5, jnp.array([s]), block_k=64,
            interpret=True,
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(full[:, :, :, -1]), rtol=1e-5,
            atol=1e-5,
        )
