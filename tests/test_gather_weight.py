"""Parity + contract tests for the fused gather+weight kernel and the
device-resident draw entry points built on it."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    LSHParams,
    IndexMutation,
    mutate_index,
    sample,
    sample_batched,
    sample_gather,
    sample_gather_batched,
)
from repro.kernels.gather_weight import gather_weight, gather_weight_ref

KEY = jax.random.PRNGKey(0)


def _build_index(key, x_aug, p, **kw):
    return mutate_index(
        None, IndexMutation("build", key=key, x_aug=x_aug), p, **kw)


def _store(n, s, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (n, s), 0, 997,
                              jnp.int32)


class TestGatherWeightKernel:
    @pytest.mark.parametrize("n,s,m", [
        (256, 128, 16),     # lane-exact row width
        (200, 33, 8),       # padding needed (33 -> 128)
        (1000, 17, 64),     # short rows, bigger batch
        (64, 257, 1),       # single-sample draw, two-lane rows
    ])
    def test_matches_ref(self, n, s, m):
        store = _store(n, s, seed=n + s)
        idx = jax.random.randint(jax.random.PRNGKey(2), (m,), 0, n,
                                 jnp.int32)
        probs = jax.random.uniform(jax.random.PRNGKey(3), (m,),
                                   minval=1e-6, maxval=0.2)
        rows_k, w_k = gather_weight(store, idx, probs,
                                    use_pallas=True, interpret=True)
        rows_r, w_r = gather_weight_ref(store, idx, probs, p_floor=1e-8)
        np.testing.assert_array_equal(np.asarray(rows_k),
                                      np.asarray(rows_r))
        np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))

    def test_p_floor_clips_tiny_probabilities(self):
        store = _store(32, 8)
        idx = jnp.array([0, 1], jnp.int32)
        probs = jnp.array([0.0, 0.5], jnp.float32)
        for up in (False, True):
            _, w = gather_weight(store, idx, probs, p_floor=1e-4,
                                 use_pallas=up, interpret=up)
            np.testing.assert_allclose(
                np.asarray(w), [1.0 / (1e-4 * 32), 1.0 / (0.5 * 32)],
                rtol=1e-6)

    def test_duplicate_indices(self):
        store = _store(32, 8)
        idx = jnp.array([5, 5, 5, 9], jnp.int32)
        probs = jnp.full((4,), 0.1, jnp.float32)
        for up in (False, True):
            rows, _ = gather_weight(store, idx, probs,
                                    use_pallas=up, interpret=up)
            np.testing.assert_array_equal(np.asarray(rows[:3]),
                                          np.asarray(store[jnp.array([5] * 3)]))

    def test_shape_validation(self):
        store = _store(32, 8)
        with pytest.raises(ValueError):
            gather_weight(store, jnp.zeros((4,), jnp.int32),
                          jnp.zeros((5,)), use_pallas=False)


class TestSampleGather:
    def _setup(self, n=300, d=12, s=10):
        p = LSHParams(k=4, l=8, dim=d, family="dense")
        x = jax.random.normal(jax.random.PRNGKey(4), (n, d))
        x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
        index = _build_index(jax.random.PRNGKey(5), x, p)
        store = _store(n, s, seed=6)
        return index, x, p, store

    def test_matches_separate_sample_plus_gather(self):
        """sample_gather == sample() then gather: same indices/probs, and
        the gathered rows + weights are exactly the reference assembly."""
        index, x, p, store = self._setup()
        k = jax.random.PRNGKey(7)
        gb = sample_gather(k, index, x, x[0], store, p, m=16,
                           example_offset=50, use_pallas=False)
        res = sample(k, index, x, x[0], p, m=16, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(gb.indices),
                                      np.asarray(res.indices))
        np.testing.assert_array_equal(np.asarray(gb.probs),
                                      np.asarray(res.probs))
        np.testing.assert_array_equal(
            np.asarray(gb.tokens), np.asarray(store)[res.indices, :-1])
        np.testing.assert_array_equal(
            np.asarray(gb.targets), np.asarray(store)[res.indices, 1:])
        np.testing.assert_array_equal(
            np.asarray(gb.example_ids), np.asarray(res.indices) + 50)
        w = 1.0 / (np.maximum(np.asarray(res.probs), 1e-8) * x.shape[0])
        np.testing.assert_allclose(
            np.asarray(gb.loss_weights), w / w.mean(), rtol=1e-6)

    def test_raw_weights_without_normalize(self):
        index, x, p, store = self._setup()
        gb = sample_gather(jax.random.PRNGKey(8), index, x, x[1], store, p,
                           m=8, normalize=False, use_pallas=False)
        w = 1.0 / (np.maximum(np.asarray(gb.probs), 1e-8) * x.shape[0])
        np.testing.assert_allclose(np.asarray(gb.loss_weights), w,
                                   rtol=1e-6)

    def test_batched_matches_sample_batched(self):
        index, x, p, store = self._setup()
        qs = x[:3]
        k = jax.random.PRNGKey(9)
        gb = sample_gather_batched(k, index, x, qs, store, p, m=4,
                                   use_pallas=False)
        res = sample_batched(k, index, x, qs, p, m=4, use_pallas=False)
        assert gb.tokens.shape == (3, 4, store.shape[1] - 1)
        np.testing.assert_array_equal(np.asarray(gb.indices),
                                      np.asarray(res.indices))
        # per-chain mean-1 normalisation
        np.testing.assert_allclose(
            np.asarray(gb.loss_weights).mean(axis=1), 1.0, rtol=1e-5)

    def test_kernel_and_ref_paths_agree_end_to_end(self):
        """Dispatch parity: identical integer draw, float fields equal up
        to compile-order rounding (the two paths are different XLA
        programs, so cp/weight floats may differ by ~1 ulp)."""
        index, x, p, store = self._setup()
        k = jax.random.PRNGKey(10)
        ref = sample_gather(k, index, x, x[2], store, p, m=8,
                            use_pallas=False)
        ker = sample_gather(k, index, x, x[2], store, p, m=8,
                            use_pallas=True, interpret=True)
        for name in ("tokens", "targets", "example_ids", "indices",
                     "fallback"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, name)),
                np.asarray(getattr(ker, name)))
        np.testing.assert_allclose(np.asarray(ref.probs),
                                   np.asarray(ker.probs), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(ref.loss_weights),
                                   np.asarray(ker.loss_weights), rtol=1e-5)

    def test_pipeline_pads_store_once_for_kernel_path(self):
        """A use_pallas pipeline lane-pads its device store at BUILD (so
        the kernel wrapper's per-call pad is zero-width) and still draws
        batches identical to the reference pipeline, with logical-width
        token rows."""
        from repro.data import LSHPipelineConfig, LSHSampledPipeline
        embed = jax.random.normal(jax.random.PRNGKey(1), (50, 16))
        params = {"e": embed}
        tokens = np.asarray(jax.random.randint(
            jax.random.PRNGKey(2), (64, 9), 0, 50), np.int32)
        ffn = lambda p, c: jnp.mean(p["e"][c], axis=1)      # noqa: E731
        qfn = lambda p: jnp.ones((16,))                      # noqa: E731

        def mk(up, itp):
            return LSHSampledPipeline(
                jax.random.PRNGKey(7), tokens, ffn, qfn,
                LSHPipelineConfig(k=4, l=8, minibatch=8, refresh_every=3,
                                  use_pallas=up, interpret=itp),
                params=params)

        ref, ker = mk(False, False), mk(True, True)
        assert ker.store.shape == (64, 128)        # padded once at build
        assert ref.store.shape == (64, 9)
        for _ in range(7):                 # crosses a refresh boundary
            br, bk = ref.next_batch(), ker.next_batch()
            assert bk["tokens"].shape == (8, 8)
            for k in ("example_ids", "tokens", "targets"):
                np.testing.assert_array_equal(np.asarray(br[k]),
                                              np.asarray(bk[k]))
