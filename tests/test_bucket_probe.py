"""Parity + integration tests for the fused LSH sampling fast path.

Pins the interpret-mode Pallas kernels to the XLA oracles exactly (the
contract that lets TPU runs trust CPU CI), across block boundaries and
non-multiple-of-block shapes through the padding wrappers, and checks
that the fast path is plumbed end-to-end: index build/refresh, scalar
and batched sampling.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    LSHParams,
    IndexMutation,
    mutate_index,
    bucket_bounds,
    bucket_bounds_batched,
    query_codes,
    sample,
    sample_batched,
    sample_drain,
)
from repro.kernels.bucket_probe import (
    bucket_probe,
    bucket_probe_codes,
    bucket_probe_codes_ref,
    bucket_probe_ref,
)
from repro.kernels.simhash import simhash_codes_ref

KEY = jax.random.PRNGKey(0)


def _build_index(key, x_aug, p, **kw):
    return mutate_index(
        None, IndexMutation("build", key=key, x_aug=x_aug), p, **kw)


def _unit_rows(key, n, d):
    x = jax.random.normal(key, (n, d))
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def _sorted_codes(key, n, d, k, l):
    kw, kx = jax.random.split(key)
    w = jax.random.normal(kw, (d, l * k))
    x = jax.random.normal(kx, (n, d))
    codes = simhash_codes_ref(x, w, k=k, l=l).T        # (L, N)
    return w, jnp.sort(codes, axis=1)


class TestBucketProbeKernel:
    @pytest.mark.parametrize("b,d,k,l,n", [
        (8, 64, 5, 8, 512),       # exact block fit
        (3, 91, 5, 100, 300),     # paper-ish dims, padding on every axis
        (1, 33, 7, 10, 1000),     # single query, ragged N
        (130, 16, 4, 3, 129),     # B and N just past a block boundary
        (16, 64, 32, 4, 256),     # max K (uint32 top bit exercised)
        (5, 24, 1, 1, 8),         # degenerate
    ])
    def test_fused_matches_ref(self, b, d, k, l, n):
        kq, kr = jax.random.split(jax.random.fold_in(KEY, b * d + n))
        q = jax.random.normal(kq, (b, d))
        w, sc = _sorted_codes(kr, n, d, k, l)
        lo_r, hi_r = bucket_probe_ref(q, w, sc, k=k, l=l)
        lo_p, hi_p = bucket_probe(q, w, sc, k=k, l=l, use_pallas=True,
                                  interpret=True)
        np.testing.assert_array_equal(np.asarray(lo_p), np.asarray(lo_r))
        np.testing.assert_array_equal(np.asarray(hi_p), np.asarray(hi_r))

    @pytest.mark.parametrize("b,k,l,n", [
        (4, 5, 8, 512),
        (3, 32, 100, 300),        # k=32: unsigned-order bias trick
        (1, 7, 10, 257),
    ])
    def test_codes_variant_matches_ref(self, b, k, l, n):
        kq, kr = jax.random.split(jax.random.fold_in(KEY, b + k * n))
        d = 32
        q = jax.random.normal(kq, (b, d))
        w, sc = _sorted_codes(kr, n, d, k, l)
        qc = simhash_codes_ref(q, w, k=k, l=l)
        lo_r, hi_r = bucket_probe_codes_ref(qc, sc)
        lo_p, hi_p = bucket_probe_codes(qc, sc, use_pallas=True,
                                        interpret=True)
        np.testing.assert_array_equal(np.asarray(lo_p), np.asarray(lo_r))
        np.testing.assert_array_equal(np.asarray(hi_p), np.asarray(hi_r))

    def test_single_query_squeeze(self):
        kq, kr = jax.random.split(KEY)
        w, sc = _sorted_codes(kr, 200, 16, 5, 9)
        q = jax.random.normal(kq, (16,))
        lo, hi = bucket_probe(q, w, sc, k=5, l=9, use_pallas=True,
                              interpret=True)
        assert lo.shape == hi.shape == (9,)
        lo_r, hi_r = bucket_probe(q, w, sc, k=5, l=9, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(lo_r))
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(hi_r))


class TestIndexFastPath:
    @pytest.mark.parametrize("family", ["dense", "sparse"])
    def test_build_index_pallas_parity(self, family):
        p = LSHParams(k=5, l=10, dim=24, family=family)
        x = _unit_rows(jax.random.PRNGKey(1), 300, 24)   # ragged N
        ref = _build_index(jax.random.PRNGKey(2), x, p, use_pallas=False)
        fused = _build_index(jax.random.PRNGKey(2), x, p, use_pallas=True,
                            interpret=True)
        np.testing.assert_array_equal(np.asarray(ref.sorted_codes),
                                      np.asarray(fused.sorted_codes))
        np.testing.assert_array_equal(np.asarray(ref.order),
                                      np.asarray(fused.order))

    def test_refresh_warm_start_equals_cold_rebuild(self):
        """Warm-started refresh must index the same buckets as a cold
        rebuild: identical sorted_codes, and per (table, code) identical
        bucket *membership* (order within ties may legally differ)."""
        p = LSHParams(k=4, l=6, dim=12, family="dense")
        x0 = _unit_rows(jax.random.PRNGKey(3), 200, 12)
        index = _build_index(jax.random.PRNGKey(4), x0, p)
        # drift the points slightly, as between periodic refreshes
        x1 = x0 + 0.05 * jax.random.normal(jax.random.PRNGKey(5), x0.shape)
        x1 = x1 / jnp.linalg.norm(x1, axis=-1, keepdims=True)
        warm = mutate_index(index, IndexMutation(
            "refresh", x_aug=x1, warm_start=True), p)
        cold = mutate_index(index, IndexMutation(
            "refresh", x_aug=x1, warm_start=False), p)
        np.testing.assert_array_equal(np.asarray(warm.sorted_codes),
                                      np.asarray(cold.sorted_codes))
        for t in range(p.l):
            ow, oc = np.asarray(warm.order[t]), np.asarray(cold.order[t])
            assert sorted(ow.tolist()) == list(range(200))
            sc = np.asarray(warm.sorted_codes[t])
            for code in np.unique(sc):
                mask = sc == code
                assert set(ow[mask]) == set(oc[mask])

    def test_refresh_warm_start_is_stable_on_no_drift(self):
        """No drift => warm-started refresh reproduces the index exactly
        (the double-buffer property: unchanged codes keep their slots)."""
        p = LSHParams(k=5, l=8, dim=10, family="sparse")
        x = _unit_rows(jax.random.PRNGKey(6), 128, 10)
        index = _build_index(jax.random.PRNGKey(7), x, p)
        again = mutate_index(index, IndexMutation(
            "refresh", x_aug=x, warm_start=True), p)
        np.testing.assert_array_equal(np.asarray(index.order),
                                      np.asarray(again.order))
        np.testing.assert_array_equal(np.asarray(index.sorted_codes),
                                      np.asarray(again.sorted_codes))


class TestSamplerFastPath:
    def _setup(self, n=512, d=12, k=4, l=16, family="dense"):
        p = LSHParams(k=k, l=l, dim=d, family=family)
        x = _unit_rows(jax.random.PRNGKey(8), n, d)
        index = _build_index(jax.random.PRNGKey(9), x, p)
        return index, x, p

    @pytest.mark.parametrize("family", ["dense", "quadratic"])
    def test_bucket_bounds_batched_matches_scalar(self, family):
        index, x, p = self._setup(family=family)
        queries = _unit_rows(jax.random.PRNGKey(10), 5, 12)
        lo_b, hi_b = bucket_bounds_batched(index, queries, p,
                                           use_pallas=True, interpret=True)
        assert lo_b.shape == (5, p.l)
        for i in range(5):
            qc = query_codes(index, queries[i], p)
            lo, hi = bucket_bounds(index, qc)
            np.testing.assert_array_equal(np.asarray(lo_b[i]), np.asarray(lo))
            np.testing.assert_array_equal(np.asarray(hi_b[i]), np.asarray(hi))

    def test_sample_pallas_path_matches_reference_path(self):
        """Identical codes => identical bounds => identical samples."""
        index, x, p = self._setup()
        q = _unit_rows(jax.random.PRNGKey(11), 1, 12)[0]
        key = jax.random.PRNGKey(12)
        ref = sample(key, index, x, q, p, m=32, use_pallas=False)
        fused = sample(key, index, x, q, p, m=32, use_pallas=True,
                       interpret=True)
        for a, b in zip(ref, fused):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        ref_d = sample_drain(key, index, x, q, p, m=8, use_pallas=False)
        fused_d = sample_drain(key, index, x, q, p, m=8, use_pallas=True,
                               interpret=True)
        for a, b in zip(ref_d, fused_d):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_sample_batched_shapes_and_validity(self):
        index, x, p = self._setup()
        queries = _unit_rows(jax.random.PRNGKey(13), 4, 12)
        res = sample_batched(jax.random.PRNGKey(14), index, x, queries, p,
                             m=16)
        assert res.indices.shape == (4, 16)
        assert bool(jnp.all((res.indices >= 0) & (res.indices < 512)))
        assert bool(jnp.all(res.probs > 0)) and bool(jnp.all(res.probs <= 1))
        assert bool(jnp.all(jnp.isfinite(res.probs)))

    def test_lgd_step_query_jitter_branch(self):
        """query_jitter>0 routes lgd_step through sample_batched (one
        perturbed query per repetition) and must still train."""
        from repro.core import LGDProblem, full_loss, init, lgd_step
        from repro.optim import SGD

        kx, ky, kt = jax.random.split(jax.random.PRNGKey(17), 3)
        x = jax.random.normal(kx, (400, 10))
        y = x @ jax.random.normal(kt, (10,)) + 0.1 * jax.random.normal(
            ky, (400,))
        prob = LGDProblem(
            kind="regression",
            lsh=LSHParams(k=5, l=20, dim=11, family="sparse"),
            minibatch=8, query_jitter=0.05)
        opt = SGD(lr=5e-3)
        state, xt, yt, xa = init(jax.random.PRNGKey(18), prob, x, y, opt)
        loss0 = float(full_loss(state.theta, xt, yt, prob))
        s = state
        for i in range(100):
            s, m = lgd_step(jax.random.fold_in(KEY, i), s, xt, yt, xa,
                            prob, opt)
        assert float(full_loss(s.theta, xt, yt, prob)) < loss0
        assert np.isfinite(float(m["grad_norm"]))

    def test_query_jitter_rejects_drain(self):
        from repro.core import LGDProblem

        with pytest.raises(ValueError, match="drain"):
            LGDProblem(kind="regression",
                       lsh=LSHParams(k=5, l=8, dim=4, family="dense"),
                       drain=True, query_jitter=0.1)

    def test_pipeline_next_batch_multi(self):
        """Multi-chain pipeline: one fused probe, one batch per chain,
        consistent with the single-chain assembly."""
        from repro.data.lsh_pipeline import (
            LSHPipelineConfig,
            LSHSampledPipeline,
        )

        n, seq, dim = 64, 9, 16
        tokens = np.asarray(
            jax.random.randint(jax.random.PRNGKey(19), (n, seq), 0, 50),
            np.int32)
        embed = jax.random.normal(jax.random.PRNGKey(20), (50, dim))

        def feature_fn(_p, chunk):        # deterministic toy embedding
            return jnp.mean(embed[chunk], axis=1)

        pipe = LSHSampledPipeline(
            jax.random.PRNGKey(21), tokens, jax.jit(feature_fn),
            lambda _p: jnp.ones((dim,)),
            LSHPipelineConfig(k=4, l=6, minibatch=5, refresh_every=2),
            params=())
        single = pipe.next_batch()
        assert single["tokens"].shape == (5, seq - 1)
        queries = jax.random.normal(jax.random.PRNGKey(22), (3, dim))
        batches = pipe.next_batch_multi(queries)   # also crosses a refresh
        assert len(batches) == 3
        for b in batches:
            assert b["tokens"].shape == (5, seq - 1)
            assert b["targets"].shape == (5, seq - 1)
            assert bool(jnp.all(b["loss_weights"] > 0))
            assert float(b["loss_weights"].mean()) == pytest.approx(1.0,
                                                                    rel=1e-4)
            assert bool(jnp.all((b["example_ids"] >= 0)
                                & (b["example_ids"] < n)))

    def test_sample_batched_samples_collide_with_own_query(self):
        """Every non-fallback sample must share a bucket code with *its*
        query — the per-row pairing the fused probe must preserve."""
        from repro.core import compute_codes

        index, x, p = self._setup(l=32)
        queries = _unit_rows(jax.random.PRNGKey(15), 3, 12)
        res = sample_batched(jax.random.PRNGKey(16), index, x, queries, p,
                             m=32)
        codes = np.asarray(compute_codes(x, index.projections, k=p.k, l=p.l))
        for b in range(3):
            qc = np.asarray(query_codes(index, queries[b], p))
            for i, fb in zip(np.asarray(res.indices[b]),
                             np.asarray(res.fallback[b])):
                if not fb:
                    assert any(codes[i, t] == qc[t] for t in range(p.l))
