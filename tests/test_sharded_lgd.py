"""End-to-end sharded LGD: weight composition, unbiasedness, overlapped
refresh determinism, elastic reshard-on-restore, Trainer sampler hook."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (
    LSHPipelineConfig,
    ShardedLSHPipeline,
    lm_head_query_fn,
    make_token_corpus,
    mean_pool_feature_fn,
)
from repro.dist.sharding import example_shard_bounds
from repro.models import ModelConfig, init_params
from repro.optim import Adam
from repro.train import Trainer, TrainerConfig
from repro.train.elastic import rebuild_sharded_pipeline

KEY = jax.random.PRNGKey(0)
VOCAB, DIM = 50, 16
EMBED = jax.random.normal(jax.random.PRNGKey(1), (VOCAB, DIM))
PARAMS = {"embed": EMBED, "q": jnp.ones((DIM,))}


def _tokens(n=128, seq=9, seed=2):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n, seq), 0, VOCAB),
        np.int32)


def feature_fn(params, chunk):              # toy params-aware embedding
    return jnp.mean(params["embed"][chunk], axis=1)


def query_fn(params):
    return params["q"]


def _pipe(tokens=None, n_shards=4, minibatch=16, refresh_every=6, **kw):
    cfg = LSHPipelineConfig(k=4, l=8, minibatch=minibatch,
                            refresh_every=refresh_every, **kw)
    return ShardedLSHPipeline(
        jax.random.PRNGKey(7), tokens if tokens is not None else _tokens(),
        feature_fn, query_fn, cfg, n_shards=n_shards, params=PARAMS)


class TestShardBounds:
    @pytest.mark.parametrize("n,s", [(128, 4), (130, 4), (7, 3), (5, 5)])
    def test_bounds_partition_corpus(self, n, s):
        spans = [example_shard_bounds(n, i, s) for i in range(s)]
        assert spans[0][0] == 0 and spans[-1][1] == n
        for (lo_a, hi_a), (lo_b, _) in zip(spans, spans[1:]):
            assert hi_a == lo_b          # contiguous, disjoint
        sizes = [hi - lo for lo, hi in spans]
        assert max(sizes) - min(sizes) <= 1


class TestShardedBatches:
    def test_global_batch_well_formed(self):
        pipe = _pipe()
        b = pipe.next_batch()
        assert b["tokens"].shape == (16, 8)
        assert b["targets"].shape == (16, 8)
        assert b["shard_ids"].shape == (16,)
        # sub-batches are contiguous: shard s owns rows [4s, 4s+4)
        assert np.array_equal(np.asarray(b["shard_ids"]),
                              np.repeat(np.arange(4), 4))
        # example_ids are GLOBAL and land inside each owner shard's span
        ids = np.asarray(b["example_ids"])
        for s in range(4):
            lo, hi = example_shard_bounds(128, s, 4)
            chunk = ids[np.asarray(b["shard_ids"]) == s]
            assert np.all((chunk >= lo) & (chunk < hi))
        assert float(jnp.mean(b["loss_weights"])) == pytest.approx(
            1.0, rel=1e-4)

    def test_per_shard_means_average_to_global_mean_exactly(self):
        """Composition identity (deterministic, per batch): the plain
        mean of the composed global weights w = S/(p N) times v over the
        whole batch EQUALS the average over shards of the per-shard
        weighted means taken with the LOCAL weights 1/(p n_s) scaled by
        n_s S / N — i.e. per-shard weighted means average to the
        full-corpus weighted mean, which is what the DP all-reduce of
        per-device means computes."""
        tokens = _tokens(n=96, seed=3)
        v = np.asarray(
            jnp.mean(EMBED[tokens[:, :-1]], axis=(1, 2))) + 2.0  # (N,)
        pipe = _pipe(tokens=tokens, n_shards=4, minibatch=16,
                     refresh_every=0, normalize_weights=False)
        n, s_count = 96, 4
        for _ in range(5):
            b = pipe.next_batch()
            w = np.asarray(b["loss_weights"], np.float64)
            ids = np.asarray(b["example_ids"])
            sh = np.asarray(b["shard_ids"])
            global_est = np.mean(w * v[ids])
            per_shard = []
            for s in range(s_count):
                lo, hi = example_shard_bounds(n, s, s_count)
                m = sh == s
                local_w = w[m] * n / ((hi - lo) * s_count)  # 1/(p n_s)
                per_shard.append(
                    np.mean(local_w * v[ids[m]]) * (hi - lo) * s_count / n)
            np.testing.assert_allclose(global_est, np.mean(per_shard),
                                       rtol=1e-9)

    @pytest.mark.statistical
    def test_sharded_estimator_unbiased(self):
        """Sharding must add NO bias: the sharded estimator's mean
        matches the unsharded Algorithm-1 estimator's mean over the same
        corpus within sampling noise, and both land on the true corpus
        mean up to the documented finite-L approximation (the reported
        p uses the analytic cp^K, the L->inf idealisation of the
        realised table ensemble — the same calibration note as
        tests/test_estimator.py)."""
        tokens = _tokens(n=96, seed=3)
        v = np.asarray(
            jnp.mean(EMBED[tokens[:, :-1]], axis=(1, 2))) + 2.0  # (N,)
        truth = float(v.mean())

        def estimate(n_shards, draws=300):
            cfg = LSHPipelineConfig(k=3, l=64, minibatch=16,
                                    refresh_every=0,
                                    normalize_weights=False)
            pipe = ShardedLSHPipeline(
                jax.random.PRNGKey(7), tokens, feature_fn, query_fn, cfg,
                n_shards=n_shards, params=PARAMS)
            es = []
            for _ in range(draws):
                b = pipe.next_batch()
                w = np.asarray(b["loss_weights"], np.float64)
                es.append(np.mean(w * v[np.asarray(b["example_ids"])]))
            return np.mean(es), np.std(es) / np.sqrt(len(es))

        est_1, sem_1 = estimate(n_shards=1)
        est_4, sem_4 = estimate(n_shards=4)
        # sharded == unsharded within noise (no sharding bias)
        assert abs(est_4 - est_1) < 5 * np.hypot(sem_1, sem_4), \
            (est_1, est_4, sem_1, sem_4)
        # both track the true mean in this calibrated regime
        assert abs(est_4 - truth) / truth < 0.10, (est_4, truth)
        assert abs(est_1 - truth) / truth < 0.10, (est_1, truth)

    def test_minibatch_must_divide_by_shards(self):
        with pytest.raises(ValueError):
            _pipe(n_shards=3, minibatch=16)


class TestDeviceResidentBatches:
    def test_next_batch_never_touches_host_numpy(self, monkeypatch):
        """The per-step path must be pure device work: a batch draw that
        calls ANY host-numpy function fails this test, and every emitted
        field must be a jax.Array (not a host ndarray)."""
        import repro.data.lsh_pipeline as L
        pipe = _pipe(refresh_every=0)
        pipe.next_batch()                  # warm up compile caches
        monkeypatch.setattr(L, "np", _NumpyGuardModule())
        b = pipe.next_batch()
        for k, v in b.items():
            assert isinstance(v, jax.Array), (k, type(v))
            assert not isinstance(v, np.ndarray), k

    def test_refresh_boundary_also_numpy_free(self, monkeypatch):
        """Crossing a (sync, full) refresh boundary stays off host numpy."""
        import repro.data.lsh_pipeline as L
        pipe = _pipe(refresh_every=2)
        for _ in range(2):
            pipe.next_batch()
        monkeypatch.setattr(L, "np", _NumpyGuardModule())
        pipe.next_batch()                  # step 2: refresh fires here

    def test_single_pipeline_batch_is_device_resident(self, monkeypatch):
        from repro.data import LSHSampledPipeline
        import repro.data.lsh_pipeline as L
        pipe = LSHSampledPipeline(
            jax.random.PRNGKey(3), _tokens(n=64), feature_fn, query_fn,
            LSHPipelineConfig(k=4, l=8, minibatch=8, refresh_every=0),
            params=PARAMS)
        pipe.next_batch()
        monkeypatch.setattr(L, "np", _NumpyGuardModule())
        b = pipe.next_batch()
        assert all(isinstance(v, jax.Array) for v in b.values())
        multi = pipe.next_batch_multi(jnp.stack([PARAMS["q"], -PARAMS["q"]]))
        assert len(multi) == 2
        assert all(isinstance(v, jax.Array)
                   for m in multi for v in m.values())


class _NumpyGuardModule:
    def __getattr__(self, name):
        raise AssertionError(
            f"host numpy.{name} called inside the step path")


class TestDeltaRefresh:
    def test_all_dirty_delta_bitwise_equals_full_refresh(self):
        """refresh(full=False) with every row dirty must produce the
        bit-exact index and features of refresh(full=True)."""
        tokens = _tokens(n=128, seed=8)
        cfg = LSHPipelineConfig(k=4, l=8, minibatch=8, refresh_every=0,
                                refresh_mode="delta", drift_frac=0.0)
        from repro.data import LSHSampledPipeline
        a = LSHSampledPipeline(jax.random.PRNGKey(4), tokens, feature_fn,
                               query_fn, cfg, params=PARAMS)
        b = LSHSampledPipeline(jax.random.PRNGKey(4), tokens, feature_fn,
                               query_fn, cfg, params=PARAMS)
        a._dirty = jnp.ones((a.n,), jnp.bool_)     # mark ALL rows dirty
        a.refresh(full=False)
        b.refresh(full=True)
        assert a._refresh_count == b._refresh_count == 1
        np.testing.assert_array_equal(np.asarray(a.index.order),
                                      np.asarray(b.index.order))
        np.testing.assert_array_equal(np.asarray(a.index.sorted_codes),
                                      np.asarray(b.index.sorted_codes))
        np.testing.assert_array_equal(np.asarray(a.features),
                                      np.asarray(b.features))

    def test_delta_mode_draws_match_full_mode_when_features_static(self):
        """With params-independent features a delta refresh is an index
        no-op (codes unchanged -> every row keeps its slot), so delta-
        and full-mode pipelines draw bit-identical batch sequences
        across refresh boundaries."""
        full = _pipe(refresh_every=5)
        delta = _pipe(refresh_every=5, refresh_mode="delta",
                      drift_frac=0.25)
        for _ in range(17):
            bf, bd = full.next_batch(), delta.next_batch()
            np.testing.assert_array_equal(np.asarray(bf["example_ids"]),
                                          np.asarray(bd["example_ids"]))
            np.testing.assert_array_equal(np.asarray(bf["loss_weights"]),
                                          np.asarray(bd["loss_weights"]))
        assert all(p._refresh_count >= 3 for p in delta.shards)

    def test_restored_delta_pipeline_replays_uninterrupted_run(self):
        """fold_in-salt contract under delta refresh: a pipeline rebuilt
        at step t (canonical build + empty dirty mask) draws the exact
        batches of the uninterrupted delta-mode run, params unchanged —
        every delta refresh re-hashes to identical codes, so both order
        chains stay at the canonical layout."""
        tokens = _tokens(n=120, seed=9)
        cfg = LSHPipelineConfig(k=4, l=8, minibatch=8, refresh_every=4,
                                refresh_mode="delta", drift_frac=0.3)
        live = ShardedLSHPipeline(jax.random.PRNGKey(15), tokens,
                                  feature_fn, query_fn, cfg, n_shards=2,
                                  params=PARAMS)
        for _ in range(9):                 # crosses two refresh boundaries
            live.next_batch()
        restored = rebuild_sharded_pipeline(
            jax.random.PRNGKey(15), tokens, feature_fn, query_fn, cfg,
            step=9, n_shards=2, params=PARAMS)
        assert all(p._refresh_count == 2 for p in restored.shards)
        for _ in range(8):                 # crosses another boundary
            bl, br = live.next_batch(), restored.next_batch()
            np.testing.assert_array_equal(np.asarray(bl["example_ids"]),
                                          np.asarray(br["example_ids"]))
            np.testing.assert_array_equal(np.asarray(bl["loss_weights"]),
                                          np.asarray(br["loss_weights"]))

    def test_async_delta_refresh_is_deterministic(self):
        """Two async delta pipelines (same key) stay bitwise in lock-step
        through overlapped refreshes — thread timing must not leak."""
        mk = lambda: _pipe(refresh_every=4, refresh_mode="delta",   # noqa: E731
                           refresh_async=True, refresh_lead=2,
                           drift_frac=0.2)
        a, b = mk(), mk()
        for _ in range(14):
            ba, bb = a.next_batch(), b.next_batch()
            np.testing.assert_array_equal(np.asarray(ba["example_ids"]),
                                          np.asarray(bb["example_ids"]))
        a.finalize(), b.finalize()

    def test_dirty_mask_tracks_visits_and_resets(self):
        from repro.data import LSHSampledPipeline
        pipe = LSHSampledPipeline(
            jax.random.PRNGKey(5), _tokens(n=64), feature_fn, query_fn,
            LSHPipelineConfig(k=4, l=8, minibatch=8, refresh_every=100,
                              refresh_mode="delta"),
            params=PARAMS)
        seen = set()
        for _ in range(3):
            seen |= set(np.asarray(pipe.next_batch()["example_ids"]).tolist())
        dirty = set(np.flatnonzero(np.asarray(pipe._dirty)).tolist())
        assert dirty == seen
        pipe.refresh(full=False)
        assert not np.any(np.asarray(pipe._dirty))

    def test_invalid_refresh_mode_rejected(self):
        with pytest.raises(ValueError):
            LSHPipelineConfig(refresh_mode="incremental")


class TestOverlappedRefresh:
    def test_async_refresh_bit_matches_sync(self):
        """The double-buffered host-thread refresh swaps at the same step
        boundary as the synchronous path -> identical batch sequences."""
        sync = _pipe(refresh_every=6, refresh_async=False)
        asyn = _pipe(refresh_every=6, refresh_async=True, refresh_lead=2)
        for _ in range(20):
            bs, ba = sync.next_batch(), asyn.next_batch()
            assert np.array_equal(np.asarray(bs["example_ids"]),
                                  np.asarray(ba["example_ids"]))
            np.testing.assert_allclose(
                np.asarray(bs["loss_weights"]),
                np.asarray(ba["loss_weights"]), rtol=1e-6)
        assert all(p._refresh_count >= 3 for p in asyn.shards)
        asyn.finalize()


class TestElasticReshard:
    def test_reshard_restore_is_bit_deterministic(self):
        """Restoring onto a CHANGED mesh shape (4 -> 2 shards) rebuilds
        per-shard indexes bit-identically across repeated restores."""
        tokens = _tokens(n=120, seed=5)
        cfg = LSHPipelineConfig(k=4, l=8, minibatch=16, refresh_every=6)

        def rebuild():
            return rebuild_sharded_pipeline(
                jax.random.PRNGKey(7), tokens, feature_fn, query_fn, cfg,
                step=13, n_shards=2, params=PARAMS)

        a, b = rebuild(), rebuild()
        assert len(a.shards) == 2
        for sa, sb in zip(a.shards, b.shards):
            assert sa._step == 13
            assert sa._refresh_count == (13 - 1) // 6
            np.testing.assert_array_equal(
                np.asarray(sa.index.sorted_codes),
                np.asarray(sb.index.sorted_codes))
            np.testing.assert_array_equal(np.asarray(sa.index.order),
                                          np.asarray(sb.index.order))
            np.testing.assert_array_equal(np.asarray(sa.index.projections),
                                          np.asarray(sb.index.projections))
        for _ in range(5):
            ba, bb = a.next_batch(), b.next_batch()
            np.testing.assert_array_equal(np.asarray(ba["example_ids"]),
                                          np.asarray(bb["example_ids"]))
            np.testing.assert_array_equal(np.asarray(ba["loss_weights"]),
                                          np.asarray(bb["loss_weights"]))

    def test_restored_step_continues_native_key_streams(self):
        """A pipeline restored at step t draws the same sample indices as
        one that ran to t without interruption (fold_in key streams),
        as long as no refresh re-embedded the features in between."""
        tokens = _tokens(n=80, seed=6)
        cfg = LSHPipelineConfig(k=4, l=8, minibatch=8, refresh_every=0)
        live = ShardedLSHPipeline(jax.random.PRNGKey(9), tokens, feature_fn,
                                  query_fn, cfg, n_shards=2, params=PARAMS)
        for _ in range(4):
            live.next_batch()
        restored = rebuild_sharded_pipeline(
            jax.random.PRNGKey(9), tokens, feature_fn, query_fn, cfg,
            step=4, n_shards=2, params=PARAMS)
        for _ in range(3):
            bl, br = live.next_batch(), restored.next_batch()
            np.testing.assert_array_equal(np.asarray(bl["example_ids"]),
                                          np.asarray(br["example_ids"]))


def _lm_cfg():
    return ModelConfig(
        name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, chunk=16, loss_chunk=16, dtype="float32",
        rope_theta=10000.0, lgd_enabled=True)


class TestTrainerSamplerHook:
    def test_end_to_end_sharded_lgd_training(self):
        cfg = _lm_cfg()
        corpus = make_token_corpus(11, 256, 16, cfg.vocab, hard_frac=0.15)
        params = init_params(KEY, cfg)
        sampler = ShardedLSHPipeline(
            jax.random.PRNGKey(12), corpus.tokens,
            mean_pool_feature_fn(cfg), lm_head_query_fn(),
            LSHPipelineConfig(k=5, l=10, minibatch=16, refresh_every=10,
                              refresh_async=True),
            n_shards=2, params=params)
        tr = Trainer(cfg, params, Adam(lr=1e-2),
                     tcfg=TrainerConfig(log_every=100), sampler=sampler)
        assert tr.tcfg.donate is False        # forced: sampler reads params
        out = tr.run(25)
        tr.finalize()
        assert all(np.isfinite(out["losses"]))
        assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])
        # the hook kept the sampler pointed at the live params
        assert sampler.params is tr.params

    def test_legacy_closure_pipeline_as_sampler(self):
        """A PR-1-era pipeline (closures, no params=) must survive the
        trainer's unconditional set_params calls: set_params only stores
        the value, it must not flip the hook calling convention."""
        from repro.data import LSHSampledPipeline
        cfg = _lm_cfg()
        with pytest.warns(DeprecationWarning, match="legacy closure"):
            pipe = LSHSampledPipeline(
                jax.random.PRNGKey(13), _tokens(n=64, seq=9),
                lambda chunk: jnp.mean(EMBED[chunk], axis=1),   # legacy
                lambda: jnp.ones((DIM,)),                        # legacy
                LSHPipelineConfig(k=4, l=8, minibatch=8,
                                  refresh_every=4))
        tr = Trainer(cfg, init_params(KEY, cfg), Adam(lr=1e-2),
                     tcfg=TrainerConfig(log_every=100), sampler=pipe)
        out = tr.run(6)                    # crosses a refresh boundary
        tr.finalize()
        assert all(np.isfinite(out["losses"]))

    def test_chunked_runs_match_single_run_batch_stream(self):
        """run(8)+run(8) must consume exactly the ticks a run(16)
        consumes — no thrown-away prefetch at chunk boundaries (the
        restore-at-step contract depends on batch k training step k)."""
        cfg = _lm_cfg()
        corpus = make_token_corpus(11, 128, 16, cfg.vocab)

        def make(seed_params):
            sampler = ShardedLSHPipeline(
                jax.random.PRNGKey(14), corpus.tokens,
                mean_pool_feature_fn(cfg), lm_head_query_fn(),
                LSHPipelineConfig(k=4, l=8, minibatch=8, refresh_every=0),
                n_shards=2, params=seed_params)
            return Trainer(cfg, seed_params, Adam(lr=1e-2),
                           tcfg=TrainerConfig(log_every=100),
                           sampler=sampler), sampler

        tr_a, samp_a = make(init_params(KEY, cfg))
        losses_a = tr_a.run(16)["losses"]
        tr_b, samp_b = make(init_params(KEY, cfg))
        losses_b = tr_b.run(8)["losses"] + tr_b.run(8)["losses"]
        np.testing.assert_allclose(losses_a, losses_b, rtol=1e-5)
        assert all(p._step == 16 for p in samp_a.shards)
        assert all(p._step == 16 for p in samp_b.shards)

    def test_exactly_one_batch_source(self):
        cfg = _lm_cfg()
        params = init_params(KEY, cfg)
        with pytest.raises(ValueError):
            Trainer(cfg, params, Adam(lr=1e-2))


class TestDPAllReduceComposition:
    def test_shard_map_mean_equals_host_composition(self):
        """On a forced 4-device host mesh, the DP all-reduce (pmean of
        per-device weighted means) over a ShardedLSHPipeline batch equals
        the host-side global weighted mean — the estimator the sharded
        weights were composed for.  Runs in a subprocess because device
        count must be fixed before jax initialises."""
        script = textwrap.dedent("""
            import jax, numpy as np, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from jax.experimental.shard_map import shard_map
            from repro.data import LSHPipelineConfig, ShardedLSHPipeline
            from repro.dist.sharding import batch_sharding

            assert jax.device_count() == 4, jax.device_count()
            VOCAB, DIM = 50, 16
            EMBED = jax.random.normal(jax.random.PRNGKey(1), (VOCAB, DIM))
            PARAMS = {"embed": EMBED, "q": jnp.ones((DIM,))}
            tokens = np.asarray(jax.random.randint(
                jax.random.PRNGKey(2), (96, 9), 0, VOCAB), np.int32)
            ffn = lambda p, c: jnp.mean(p["embed"][c], axis=1)
            qfn = lambda p: p["q"]
            mesh = jax.make_mesh((4, 1), ("data", "model"))
            pipe = ShardedLSHPipeline(
                jax.random.PRNGKey(7), tokens, ffn, qfn,
                LSHPipelineConfig(k=4, l=8, minibatch=16, refresh_every=0,
                                  normalize_weights=False),
                n_shards=4, params=PARAMS, mesh=mesh)
            b = pipe.next_batch()
            v = jnp.mean(EMBED[b["tokens"]], axis=(1, 2)) + 2.0
            host = float(jnp.mean(b["loss_weights"] * v))

            @jax.jit
            def dp_estimate(w, v):
                def per_device(w, v):
                    return jax.lax.pmean(jnp.mean(w * v), "data")
                return shard_map(per_device, mesh=mesh,
                                 in_specs=(P("data"), P("data")),
                                 out_specs=P())(w, v)

            dist = float(dp_estimate(b["loss_weights"], v))
            assert abs(dist - host) < 1e-5 * max(1.0, abs(host)), (dist, host)
            print("OK", dist, host)
        """)
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            " --xla_force_host_platform_device_count=4")
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "OK" in r.stdout
