"""Multi-probe querying: probe sequence, kernel parity, statistics.

Pins the tentpole contracts of the Hamming-ball multi-probe sampler:
  * ``probe_masks`` is the deterministic flip-1-then-flip-2 sequence;
  * the fused multi-probe kernel (interpret mode) matches the XLA
    oracle exactly, across padding shapes and families;
  * ``multiprobe=0`` is bit-identical to the original single-probe
    sampler (the compiled program may differ, the numbers may not);
  * the probe-class collision frequencies match the corrected-p factors
    q_r = cp^(K-r) (1-cp)^r (chi-square over random hash draws);
  * the multi-probe estimator stays unbiased (E[1/(pN)] = 1 over
    index builds, and the gradient estimator matches the full-batch
    gradient);
  * the uniform-fallback rate strictly drops vs single-probe on a
    skewed corpus — at the sampler level and through the pipeline's
    ``sampler_stats`` metrics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.estimator as E
import repro.core.sampler as S
from repro.core import (
    LSHParams,
    bucket_bounds_batched,
    bucket_bounds_multi,
    IndexMutation,
    mutate_index,
    probe_masks,
)
from repro.core.lgd import preprocess_regression, squared_loss_grad
from repro.data import make_regression
from repro.data.lsh_pipeline import LSHPipelineConfig, LSHSampledPipeline
from repro.kernels.bucket_probe import (
    bucket_probe_multi,
    bucket_probe_multi_ref,
)

KEY = jax.random.PRNGKey(0)


def _build_index(key, x_aug, p, **kw):
    return mutate_index(
        None, IndexMutation("build", key=key, x_aug=x_aug), p, **kw)


def _unit(x):
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def _skewed(n=256, d=24, spread=0.55, qnoise=0.9, nq=64, xseed=30):
    """Tight cluster + partially-aligned query batch (empty buckets)."""
    c = jax.random.normal(jax.random.PRNGKey(9), (d,))
    x = _unit(c[None] + spread * jax.random.normal(
        jax.random.PRNGKey(xseed), (n, d)))
    qs = _unit(c[None] + qnoise * jax.random.normal(
        jax.random.PRNGKey(11), (nq, d)))
    return x, qs


class TestProbeMasks:
    def test_sequence_shape_and_order(self):
        masks = probe_masks(4, 11)
        # exact bucket, flip-1 ascending, then flip-2 lexicographic
        assert masks == (0, 1, 2, 4, 8, 3, 5, 9, 6, 10, 12)

    def test_clamped_to_radius_2_ball(self):
        assert len(probe_masks(3, 50)) == 1 + 3 + 3
        assert len(probe_masks(1, 50)) == 2

    def test_invalid(self):
        with pytest.raises(ValueError):
            probe_masks(5, 0)

    def test_popcounts(self):
        masks = probe_masks(6, 1 + 6 + 15)
        rs = [bin(m).count("1") for m in masks]
        assert rs == [0] + [1] * 6 + [2] * 15


class TestMultiProbeKernel:
    @pytest.mark.parametrize("b,d,k,l,n,j", [
        (8, 64, 5, 8, 512, 3),     # exact block fit
        (3, 33, 7, 10, 300, 6),    # padding on every axis
        (1, 16, 4, 3, 129, 2),     # single query, ragged N
        (16, 24, 32, 4, 256, 5),   # max K (uint32 top bit exercised)
        (5, 24, 1, 1, 8, 2),       # degenerate K=1 (flip-1 only)
    ])
    def test_fused_matches_ref(self, b, d, k, l, n, j):
        from repro.kernels.simhash import simhash_codes_ref
        kq, kw, kx = jax.random.split(jax.random.fold_in(KEY, b + n), 3)
        q = jax.random.normal(kq, (b, d))
        w = jax.random.normal(kw, (d, l * k))
        codes = simhash_codes_ref(jax.random.normal(kx, (n, d)), w,
                                  k=k, l=l).T
        sc = jnp.sort(codes, axis=1)
        masks = probe_masks(k, j)
        lo_r, hi_r = bucket_probe_multi(q, w, sc, masks, k=k, l=l,
                                        use_pallas=False)
        lo_k, hi_k = bucket_probe_multi(q, w, sc, masks, k=k, l=l,
                                        use_pallas=True, interpret=True)
        assert lo_r.shape == (b, j, l)
        np.testing.assert_array_equal(np.asarray(lo_r), np.asarray(lo_k))
        np.testing.assert_array_equal(np.asarray(hi_r), np.asarray(hi_k))

    def test_mask_zero_matches_single_probe(self):
        """Probe 0 of the multi path == the single-probe bounds."""
        x, qs = _skewed()
        p = LSHParams(k=9, l=5, dim=x.shape[1], family="dense")
        idx = _build_index(jax.random.PRNGKey(1), x, p)
        lo1, hi1 = bucket_bounds_batched(idx, qs, p, use_pallas=False)
        lom, him = bucket_bounds_multi(idx, qs, p, probe_masks(9, 4),
                                       use_pallas=False)
        np.testing.assert_array_equal(np.asarray(lo1), np.asarray(lom[:, 0]))
        np.testing.assert_array_equal(np.asarray(hi1), np.asarray(him[:, 0]))

    def test_masked_bounds_are_xored_code_bounds(self):
        """Probe j's slice == searching the XORed code directly."""
        from repro.core.tables import bucket_bounds, query_codes
        x, qs = _skewed(nq=4)
        p = LSHParams(k=8, l=4, dim=x.shape[1], family="dense")
        idx = _build_index(jax.random.PRNGKey(1), x, p)
        masks = probe_masks(8, 5)
        lom, him = bucket_bounds_multi(idx, qs, p, masks, use_pallas=False)
        qc = query_codes(idx, qs, p)                      # (B, L)
        for b in range(qs.shape[0]):
            for j, m in enumerate(masks):
                lo_d, hi_d = bucket_bounds(idx, qc[b] ^ jnp.uint32(m))
                np.testing.assert_array_equal(np.asarray(lom[b, j]),
                                              np.asarray(lo_d))
                np.testing.assert_array_equal(np.asarray(him[b, j]),
                                              np.asarray(hi_d))

    def test_quadratic_family_multi_bounds(self):
        """Quadratic SRP hashes on the XLA path but probes multi codes."""
        ds = make_regression(jax.random.PRNGKey(3), "yearmsd-like",
                             n_train=200, n_test=10, d=12, noise="pareto")
        _, _, x_aug = preprocess_regression(ds.x_train, ds.y_train)
        p = LSHParams(k=6, l=4, dim=x_aug.shape[1], family="quadratic")
        idx = _build_index(jax.random.PRNGKey(1), x_aug, p)
        masks = probe_masks(6, 4)
        lom, him = bucket_bounds_multi(idx, x_aug[:3], p, masks,
                                       use_pallas=False)
        assert lom.shape == (3, 4, 4)
        lo1, hi1 = bucket_bounds_batched(idx, x_aug[:3], p,
                                         use_pallas=False)
        np.testing.assert_array_equal(np.asarray(lo1), np.asarray(lom[:, 0]))


class TestMultiProbeSampling:
    def test_multiprobe_zero_bit_identical(self):
        x, qs = _skewed()
        p = LSHParams(k=9, l=5, dim=x.shape[1], family="dense")
        idx = _build_index(jax.random.PRNGKey(1), x, p)
        r0 = S.sample(jax.random.PRNGKey(3), idx, x, qs[0], p, m=128)
        r1 = S.sample(jax.random.PRNGKey(3), idx, x, qs[0], p, m=128,
                      multiprobe=0)
        for a, b in zip(r0[:5], r1[:5]):    # all pre-existing fields
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_probe_code_semantics(self):
        x, qs = _skewed()
        p = LSHParams(k=16, l=3, dim=x.shape[1], family="dense")
        idx = _build_index(jax.random.PRNGKey(1), x, p)
        r = S.sample_batched(jax.random.PRNGKey(4), idx, x, qs, p, m=64,
                             multiprobe=8)
        pc = np.asarray(r.probe_code)
        fb = np.asarray(r.fallback)
        assert pc.min() >= -1 and pc.max() <= 8
        # fallback <=> probe_code == -1
        np.testing.assert_array_equal(fb, pc == -1)
        # multi-probe must actually fire in this regime
        assert ((pc > 0) & ~fb).any()

    def test_fallback_strictly_drops_on_skewed_corpus(self):
        """The satellite regression test: multi < single, with margin."""
        x, qs = _skewed()
        p = LSHParams(k=16, l=3, dim=x.shape[1], family="dense")
        idx = _build_index(jax.random.PRNGKey(1), x, p)
        rates = {}
        for mp in (0, 8):
            r = S.sample_batched(jax.random.PRNGKey(4), idx, x, qs, p,
                                 m=64, multiprobe=mp)
            rates[mp] = float(jnp.mean(r.fallback))
        assert rates[0] > 0.2, f"regime not skewed enough: {rates}"
        assert rates[8] < 0.75 * rates[0], \
            f"multi-probe fallback did not drop: {rates}"

    @pytest.mark.statistical
    def test_chi_square_probe_class_frequencies(self):
        """Corrected-p factors match empirical collision frequencies.

        Over random hash draws, P(code(x) ^ code(q) == mask) must equal
        cp^(K-r) (1-cp)^r for a weight-r mask (SimHash bits are iid
        across hash functions).  Chi-square over the probed masks plus
        an 'elsewhere' cell, many independent single-table draws.
        """
        from repro.core.simhash import (
            collision_probability, compute_codes, make_projections)
        d, k = 16, 6
        kx, kq = jax.random.split(jax.random.PRNGKey(7))
        x = _unit(jax.random.normal(kx, (d,)))
        q = _unit(x + 0.45 * jax.random.normal(kq, (d,)))
        cp = float(collision_probability(x, q))
        p = LSHParams(k=k, l=1, dim=d, family="dense")
        masks = probe_masks(k, 1 + k + 3)       # all flip-1, some flip-2
        trials = 4000

        def diff_one(key):
            proj = make_projections(key, p)
            cx = compute_codes(x, proj, k=k, l=1)
            cq = compute_codes(q, proj, k=k, l=1)
            return (cx ^ cq)[0]

        diffs = np.asarray(jax.lax.map(
            diff_one, jax.random.split(jax.random.PRNGKey(8), trials)))
        probs = []
        counts = []
        for m in masks:
            r = bin(m).count("1")
            probs.append(cp ** (k - r) * (1 - cp) ** r)
            counts.append(int((diffs == m).sum()))
        probs.append(1.0 - sum(probs))          # everything else
        counts.append(trials - sum(counts))
        exp = np.array(probs) * trials
        assert (exp > 5).all(), "cells too small for chi-square"
        chi2 = float((((np.array(counts) - exp) ** 2) / exp).sum())
        # dof = cells - 1 = len(masks); 99.9% critical value for
        # dof=10 is 29.6 — generous but catches a wrong exponent
        # (swapping r and K-r sends chi2 into the thousands).
        assert chi2 < 35.0, (
            f"probe-class frequencies deviate from corrected-p factors: "
            f"chi2={chi2:.1f}, counts={counts}, expected={exp.tolist()}")

    @pytest.mark.statistical
    def test_weights_unbiased_over_builds(self):
        """E[1/(pN)] = 1 with multi-probe firing (over index builds)."""
        ds = make_regression(jax.random.PRNGKey(42), "yearmsd-like",
                             n_train=2000, n_test=10, d=24, noise="pareto")
        _, _, x_aug = preprocess_regression(ds.x_train, ds.y_train)
        n = x_aug.shape[0]
        p = LSHParams(k=10, l=8, dim=x_aug.shape[1], family="dense")
        theta = 0.05 * jax.random.normal(jax.random.PRNGKey(6), (24,))
        q = _unit(jnp.concatenate([theta, -jnp.ones(1)]))

        def mean_w(mp):
            def per_build(key):
                kb, ks = jax.random.split(key)
                idx = _build_index(kb, x_aug, p)
                r = S.sample(ks, idx, x_aug, q, p, m=128, multiprobe=mp)
                return jnp.mean(1.0 / (r.probs * n))
            keys = jax.random.split(jax.random.PRNGKey(4), 200)
            return float(jnp.mean(jax.lax.map(per_build, keys)))

        w_multi = mean_w(3)
        assert abs(w_multi - 1.0) < 0.15, (
            f"multi-probe weights biased: E[w]={w_multi:.3f}")

    @pytest.mark.statistical
    def test_gradient_estimator_unbiased_with_multiprobe(self):
        """E[weighted grad] ~= full-batch grad with multi-probe firing.

        In this sparse-table regime (K=10, L=8 over pareto targets) the
        importance weights are heavy-tailed, so the empirical mean of
        ~16k draws still carries sampling noise — the single-probe
        estimator measured identically is the honest yardstick (its
        rare uniform fallbacks carry the worst 1/(pN) tails; resolving
        them via corrected near-bucket probes is exactly what shrinks
        the error here).  The multi-probe correction must (a) track the
        full-batch gradient to a bounded error and (b) be no noisier
        than single-probe at matched sample count.
        """
        ds = make_regression(jax.random.PRNGKey(42), "yearmsd-like",
                             n_train=1500, n_test=10, d=16, noise="pareto")
        xt, yt, x_aug = preprocess_regression(ds.x_train, ds.y_train)
        n = xt.shape[0]
        p = LSHParams(k=10, l=8, dim=x_aug.shape[1], family="dense")
        theta = 0.05 * jax.random.normal(jax.random.PRNGKey(2), (16,))
        q = _unit(jnp.concatenate([theta, -jnp.ones(1)]))
        full_grad = jnp.mean(jax.vmap(
            lambda a, b: squared_loss_grad(theta, a, b))(xt, yt), 0)

        def rel_err(mp):
            def per_build(key):
                kb, ks = jax.random.split(key)
                idx = _build_index(kb, x_aug, p)
                r = S.sample(ks, idx, x_aug, q, p, m=64, multiprobe=mp)
                return E.lgd_gradient(squared_loss_grad, theta,
                                      xt[r.indices], yt[r.indices], r, n)
            keys = jax.random.split(jax.random.PRNGKey(3), 250)
            grand = jnp.mean(jax.lax.map(per_build, keys), axis=0)
            return float(jnp.linalg.norm(grand - full_grad) /
                         jnp.linalg.norm(full_grad))

        rel_multi, rel_single = rel_err(3), rel_err(0)
        assert rel_multi < 0.6, (
            f"multi-probe estimator biased: rel err {rel_multi}")
        assert rel_multi <= rel_single + 0.05, (
            f"multi-probe noisier than single-probe: {rel_multi:.3f} vs "
            f"{rel_single:.3f}")


class TestPipelineMultiprobe:
    def _pipe(self, multiprobe):
        # skewed feature geometry: the feature hook embeds rows by
        # their first token into a tight cluster; the query sits
        # partially off it -> empty buckets.
        n, d, seq, vocab = 192, 24, 12, 64
        c = jax.random.normal(jax.random.PRNGKey(9), (d,))
        table = jnp.asarray(c[None] + 0.55 * jax.random.normal(
            jax.random.PRNGKey(30), (vocab, d)))
        tokens = np.asarray(
            jax.random.randint(jax.random.PRNGKey(5), (n, seq + 1), 0,
                               vocab), np.int32)
        qv = c + 0.9 * jax.random.normal(jax.random.PRNGKey(11), (d,))
        cfg = LSHPipelineConfig(k=16, l=3, minibatch=32, refresh_every=0,
                                multiprobe=multiprobe)
        return LSHSampledPipeline(
            jax.random.PRNGKey(2), tokens,
            lambda _p, t: table[t[:, 0]],
            lambda _p: qv,
            cfg, params=())

    def test_config_validation(self):
        with pytest.raises(ValueError):
            LSHPipelineConfig(multiprobe=-1)

    def test_drain_mode_rejects_multiprobe(self):
        from repro.core import LGDProblem
        with pytest.raises(ValueError):
            LGDProblem(kind="regression",
                       lsh=LSHParams(k=5, l=10, dim=8, family="dense"),
                       drain=True, multiprobe=2)

    def test_stats_and_fallback_drop_through_pipeline(self):
        rates = {}
        for mp in (0, 8):
            pipe = self._pipe(mp)
            for _ in range(30):
                b = pipe.next_batch()
            st = pipe.sampler_stats()
            assert st["draws"] == 30 * 32
            assert 0.0 <= st["fallback_rate"] <= 1.0
            assert st["primary_miss_rate"] >= st["fallback_rate"]
            rates[mp] = st["fallback_rate"]
            assert set(b) == {"tokens", "targets", "loss_weights",
                              "example_ids"}
        assert rates[0] > 0.05, f"pipeline regime not skewed: {rates}"
        assert rates[8] < rates[0], (
            f"pipeline multi-probe fallback did not drop: {rates}")

    def test_multiprobe_pipeline_deterministic(self):
        a, b = self._pipe(4), self._pipe(4)
        for _ in range(3):
            ba, bb = a.next_batch(), b.next_batch()
            np.testing.assert_array_equal(np.asarray(ba["tokens"]),
                                          np.asarray(bb["tokens"]))
            np.testing.assert_array_equal(np.asarray(ba["loss_weights"]),
                                          np.asarray(bb["loss_weights"]))
