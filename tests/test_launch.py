"""Launch-layer unit tests: sharding rules, HLO analyzer, roofline math.

These run on a single CPU device — meshes are stubbed where only shapes
and axis names matter.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import SHAPES
from repro.dist.sharding import param_spec
from repro.launch.hlo_analysis import analyze, parse_hlo
from repro.launch.roofline import (
    _WIRE_FACTOR,
    active_params,
    model_flops,
    roofline_terms,
)
from repro.models import ModelConfig


@dataclasses.dataclass
class StubMesh:
    shape: dict
    axis_names: tuple


POD = StubMesh({"data": 16, "model": 16}, ("data", "model"))
MULTI = StubMesh({"pod": 2, "data": 16, "model": 16},
                 ("pod", "data", "model"))


class TestParamRules:
    def test_embed_tp_vocab_fsdp_d(self):
        spec = param_spec("embed_group/embed", (151936, 4096), POD)
        assert spec[0] == "model" and spec[1] in ("data", ("data",))

    def test_stacked_block_param_offsets_roles(self):
        """Stacked experts (L, E, d, ff): layer dim must stay unsharded."""
        spec = param_spec("blocks/0/ffn/experts_gate", (94, 128, 4096, 1536),
                          POD)
        assert spec[0] is None
        assert spec[1] == "model"                      # experts TP
        assert spec[2] in ("data", ("data",))          # d FSDP

    def test_unstacked_shared_block(self):
        spec = param_spec("shared/attn/wq", (2048, 32, 64), POD)
        assert spec[0] in ("data", ("data",)) and spec[1] == "model" \
            and spec[2] is None

    def test_indivisible_dim_replicated(self):
        # 24 heads don't divide model=16 -> replicated head dim
        spec = param_spec("blocks/0/attn/wq", (32, 3072, 24, 128), POD)
        assert spec[2] is None

    def test_multipod_fsdp_uses_both_data_axes(self):
        spec = param_spec("blocks/0/ffn/w_up", (40, 4096, 12800), MULTI)
        assert spec[1] == ("pod", "data")
        assert spec[2] == "model"

    def test_norm_replicated(self):
        spec = param_spec("blocks/0/attn/norm/scale", (40, 4096), POD)
        assert all(s is None for s in spec)


HLO_FIXTURE = """
HloModule test

%body (arg: (s32[], f32[64,64], f32[4,64,64])) -> (s32[], f32[64,64], f32[4,64,64]) {
  %arg = (s32[], f32[64,64]{1,0}, f32[4,64,64]{2,1,0}) parameter(0)
  %g0 = s32[] get-tuple-element(%arg), index=0
  %g1 = f32[64,64]{1,0} get-tuple-element(%arg), index=1
  %g2 = f32[4,64,64]{2,1,0} get-tuple-element(%arg), index=2
  %dot.1 = f32[64,64]{1,0} dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="test/dot1"}
  %ar = f32[64,64]{1,0} all-reduce(%dot.1), replica_groups={}, metadata={op_name="test/ar"}
  ROOT %tup = (s32[], f32[64,64]{1,0}, f32[4,64,64]{2,1,0}) tuple(%g0, %ar, %g2)
}

%cond (arg2: (s32[], f32[64,64], f32[4,64,64])) -> pred[] {
  %arg2 = (s32[], f32[64,64]{1,0}, f32[4,64,64]{2,1,0}) parameter(0)
  %gi = s32[] get-tuple-element(%arg2), index=0
  %c = s32[] constant(4)
  ROOT %lt = pred[] compare(%gi, %c), direction=LT
}

ENTRY %main (x: f32[64,64], w: f32[4,64,64]) -> f32[64,64] {
  %x = f32[64,64]{1,0} parameter(0)
  %w = f32[4,64,64]{2,1,0} parameter(1)
  %c0 = s32[] constant(0)
  %t = (s32[], f32[64,64]{1,0}, f32[4,64,64]{2,1,0}) tuple(%c0, %x, %w)
  %wh = (s32[], f32[64,64]{1,0}, f32[4,64,64]{2,1,0}) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  %out = f32[64,64]{1,0} get-tuple-element(%wh), index=1
  %dot.2 = f32[64,64]{1,0} dot(%out, %out), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %ag = f32[64,64]{1,0} all-gather(%dot.2), dimensions={0}
}
"""


class TestHLOAnalysis:
    def test_parse_computations(self):
        comps, entry = parse_hlo(HLO_FIXTURE)
        assert entry == "main"
        assert {"body", "cond", "main"} <= set(comps)

    def test_trip_count_multiplied_flops(self):
        a = analyze(HLO_FIXTURE)
        # dot.1 (in 4-trip while) + dot.2: (2*64^3) * (4 + 1)
        assert a.flops == 2 * 64**3 * 5

    def test_collectives_trip_adjusted(self):
        a = analyze(HLO_FIXTURE)
        assert a.collectives["all-reduce"] == 64 * 64 * 4 * 4  # 4 trips
        assert a.collectives["all-gather"] == 64 * 64 * 4

    def test_real_program_scan(self):
        def body(x, w):
            return jnp.tanh(x @ w), None

        def f(x, ws):
            return jax.lax.scan(body, x, ws)[0]

        d = 128
        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((6, d, d), jnp.float32),
        ).compile().as_text()
        a = analyze(txt)
        assert a.flops == 2 * 6 * d**3


class TestRoofline:
    def test_active_params_dense(self):
        cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab=100, act="swiglu")
        # qkvo: 64*16*(4*2 + 2*2) + mlp 3*64*128 per layer; embed 2*100*64
        per_layer = 64 * 16 * (8 + 4) + 3 * 64 * 128
        want = 2 * per_layer + 2 * 100 * 64
        assert active_params(cfg) == want

    def test_active_params_moe_counts_topk_only(self):
        cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=4, d_ff=0, vocab=100, moe_experts=8,
                          moe_top_k=2, moe_d_ff=32)
        dense_like = ModelConfig(name="t2", n_layers=2, d_model=64,
                                 n_heads=4, n_kv_heads=4, d_ff=0, vocab=100,
                                 moe_experts=8, moe_top_k=8, moe_d_ff=32)
        assert active_params(cfg) < active_params(dense_like)

    def test_model_flops_train_vs_prefill(self):
        cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab=100)
        tr = model_flops(cfg, SHAPES["train_4k"], 256)
        pf = model_flops(cfg, SHAPES["prefill_32k"], 256)
        # same token count; train = 3x prefill FLOPs (fwd+bwd)
        assert tr / pf == pytest.approx(3.0, rel=1e-6)

    def test_roofline_terms_dominant(self):
        rec = {
            "arch": "granite_3_8b", "shape": "train_4k", "n_devices": 256,
            "flops_per_device": 197e12,       # exactly 1s of compute
            "bytes_per_device": 819e9 * 2,    # 2s of memory
            "collectives": {"all-reduce": 25e9},  # 2*25e9/50e9 = 1s
        }
        t = roofline_terms(rec)
        assert t["dominant"] == "memory"
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["collective_s"] == pytest.approx(1.0)
        assert t["step_time_lower_bound_s"] == pytest.approx(2.0)

    def test_vocab_large_overrides_vocab_on_roofline_path(self):
        """vocab_large pins V=131072 on the dryrun/roofline path only:
        apply_vocab rewrites the config, active_params grows by exactly
        2*(V_big - V_small)*d, and smoke/tier-1 configs are untouched."""
        from repro import configs
        from repro.configs.shapes import apply_vocab, shape_applicable

        shape = SHAPES["vocab_large"]
        assert shape.vocab >= 128_000 and shape.kind == "decode"
        cfg = configs.get("granite_3_8b")
        big = apply_vocab(cfg, shape)
        assert big.vocab == shape.vocab and cfg.vocab != shape.vocab
        assert active_params(big) - active_params(cfg) == \
            2 * (shape.vocab - cfg.vocab) * cfg.d_model
        # decode model_flops reflect the larger head
        assert model_flops(big, shape, 256) > model_flops(cfg, shape, 256)
        # applicable to every arch (it is an abstract-eval cell) and a
        # no-op override on shapes that do not pin a vocab
        assert shape_applicable(cfg, shape) is None
        assert apply_vocab(cfg, SHAPES["decode_32k"]) is cfg

    def test_wire_factors(self):
        assert _WIRE_FACTOR["all-reduce"] == 2.0
        assert _WIRE_FACTOR["all-gather"] == 1.0
