"""Model zoo tests: numerics, decode consistency, scan equivalence, MoE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; use the shim
    from _hypothesis_compat import given, settings, st

from repro.models import (
    ModelConfig,
    decode_step,
    forward,
    init_cache,
    init_params,
    logits,
    loss,
    prefill,
)
from repro.models.moe import init_moe, moe_ffn
from repro.models.ssm import (
    gla_chunked,
    gla_decode_step,
)

KEY = jax.random.PRNGKey(0)


def tiny(name, **kw):
    base = dict(
        name=name, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=97, chunk=16, loss_chunk=16, dtype="float32",
        rope_theta=10000.0,
    )
    base.update(kw)
    return ModelConfig(**base)


CONFIGS = {
    "dense": tiny("dense"),
    "sqrelu": tiny("sqrelu", act="squared_relu"),
    "gelu": tiny("gelu", act="gelu"),
    "moe": tiny("moe", n_kv_heads=4, moe_experts=8, moe_top_k=2, moe_d_ff=32),
    "mamba": tiny("mamba", n_layers=4, d_ff=0, n_kv_heads=4,
                  block_pattern=("mamba2",), ssm_state=16),
    "xlstm": tiny("xlstm", n_layers=4, d_ff=0, n_kv_heads=4,
                  block_pattern=("mlstm", "slstm")),
    "zamba": tiny("zamba", n_layers=6, n_kv_heads=4,
                  block_pattern=("mamba2", "mamba2", "shared_attn"),
                  ssm_state=16),
    "vision": tiny("vision", n_layers=4,
                   block_pattern=("attn", "cross_attn")),
    "audio": tiny("audio", n_kv_heads=4, frontend="embed_stub"),
}


def make_batch(cfg, b=2, s=32, key=KEY):
    kt, ke, ki = jax.random.split(key, 3)
    batch = {"targets": jax.random.randint(kt, (b, s), 0, cfg.vocab)}
    if cfg.frontend == "embed_stub":
        batch["embeds"] = jax.random.normal(ke, (b, s, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(ke, (b, s), 0, cfg.vocab)
    if "cross_attn" in cfg.block_pattern:
        batch["image_embeds"] = jax.random.normal(ki, (b, 8, cfg.d_model))
    return batch


class TestForward:
    @pytest.mark.parametrize("name", list(CONFIGS))
    def test_loss_finite_and_near_uniform_at_init(self, name):
        cfg = CONFIGS[name]
        params = init_params(KEY, cfg)
        batch = make_batch(cfg)
        l = float(jax.jit(lambda p, b: loss(p, cfg, b))(params, batch))
        assert np.isfinite(l)
        # at random init the LM loss should be near ln(vocab)
        assert abs(l - np.log(cfg.vocab)) < 1.5, l

    @pytest.mark.parametrize("name", ["dense", "mamba", "zamba"])
    def test_scan_equals_unrolled(self, name):
        cfg = CONFIGS[name]
        params = init_params(KEY, cfg)
        batch = make_batch(cfg)
        h_scan = forward(params, cfg.with_(scan_layers=True), batch)
        h_loop = forward(params, cfg.with_(scan_layers=False), batch)
        np.testing.assert_allclose(
            np.asarray(h_scan), np.asarray(h_loop), rtol=2e-4, atol=2e-4)

    def test_remat_matches_no_remat(self):
        cfg = CONFIGS["dense"]
        params = init_params(KEY, cfg)
        batch = make_batch(cfg)
        g1 = jax.grad(lambda p: loss(p, cfg.with_(remat=True), batch))(params)
        g2 = jax.grad(lambda p: loss(p, cfg.with_(remat=False), batch))(params)
        flat1, flat2 = jax.tree.leaves(g1), jax.tree.leaves(g2)
        for a, b in zip(flat1, flat2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)

    def test_grads_nonzero_everywhere(self):
        """No dead parameters: every leaf gets gradient signal."""
        cfg = CONFIGS["zamba"]
        params = init_params(KEY, cfg)
        batch = make_batch(cfg)
        g = jax.grad(lambda p: loss(p, cfg, batch))(params)
        flat = jax.tree_util.tree_flatten_with_path(g)[0]
        dead = [
            "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)
            for kp, v in flat if float(jnp.max(jnp.abs(v))) == 0.0
        ]
        assert not dead, f"dead params: {dead}"


class TestDecode:
    @pytest.mark.parametrize("name", ["dense", "mamba", "xlstm", "zamba",
                                      "audio", "vision"])
    def test_decode_matches_forward(self, name):
        """prefill(prompt) then decode(next) == forward(prompt+next) last pos."""
        cfg = CONFIGS[name]
        params = init_params(KEY, cfg)
        b, s = 2, 17
        batch = make_batch(cfg, b=b, s=s)
        full = logits(params, cfg, batch)                 # (B, S, V)

        prompt = {k: (v[:, : s - 1] if v.ndim >= 2 and v.shape[1] == s else v)
                  for k, v in batch.items()}
        cache = init_cache(cfg, b, 32)
        _, cache = prefill(params, cfg, prompt, cache)
        step = {"positions": jnp.full((b, 1), s - 1, jnp.int32)}
        if cfg.frontend == "embed_stub":
            step["embeds"] = batch["embeds"][:, s - 1:s]
        else:
            step["tokens"] = batch["tokens"][:, s - 1:s]
        if "image_embeds" in batch:
            step["image_embeds"] = batch["image_embeds"]
        lg, _ = decode_step(params, cfg, step, cache)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
            rtol=2e-3, atol=2e-3,
        )

    def test_multi_step_decode_consistent(self):
        cfg = CONFIGS["dense"]
        params = init_params(KEY, cfg)
        b, s = 2, 12
        batch = make_batch(cfg, b=b, s=s)
        full = logits(params, cfg, batch)
        prompt = {"tokens": batch["tokens"][:, :8], "targets": None}
        cache = init_cache(cfg, b, 32)
        _, cache = prefill(params, cfg, {"tokens": prompt["tokens"]}, cache)
        for t in range(8, s):
            step = {"tokens": batch["tokens"][:, t:t + 1],
                    "positions": jnp.full((b, 1), t, jnp.int32)}
            lg, cache = decode_step(params, cfg, step, cache)
            np.testing.assert_allclose(
                np.asarray(lg[:, 0]), np.asarray(full[:, t]),
                rtol=2e-3, atol=2e-3,
            )


class TestGLACore:
    @settings(deadline=None, max_examples=15)
    @given(
        s=st.sampled_from([8, 16, 32]),
        chunk=st.sampled_from([4, 8, 16, 32]),
        n=st.sampled_from([4, 8]),
        p=st.sampled_from([4, 8]),
    )
    def test_chunked_equals_naive_recurrence(self, s, chunk, n, p):
        """Property: chunked scan == step-by-step recurrence for any shapes."""
        b, h = 2, 3
        kq, kk, kv, ka = jax.random.split(jax.random.PRNGKey(s * chunk), 4)
        q = jax.random.normal(kq, (b, s, h, n))
        k = jax.random.normal(kk, (b, s, h, n))
        v = jax.random.normal(kv, (b, s, h, p))
        log_a = -jax.nn.softplus(jax.random.normal(ka, (b, s, h)))
        y_chunk, state_chunk = gla_chunked(q, k, v, log_a, chunk)

        state = jnp.zeros((b, h, n, p))
        ys = []
        for t in range(s):
            yt, state = gla_decode_step(
                q[:, t], k[:, t], v[:, t], log_a[:, t], state)
            ys.append(yt)
        y_naive = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(state_chunk), np.asarray(state),
                                   rtol=1e-4, atol=1e-4)


class TestMoE:
    def test_moe_matches_dense_per_token_at_high_capacity(self):
        """With capacity >= T*k the dispatch must equal exact top-k routing."""
        cfg = tiny("moe_exact", n_kv_heads=4, moe_experts=4, moe_top_k=2,
                   moe_d_ff=16, moe_capacity_factor=8.0)
        p = init_moe(KEY, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        got = moe_ffn(p, cfg, x)

        # naive per-token reference
        from repro.models.layers import rms_norm
        h = rms_norm(p["norm"], x, cfg.norm_eps).reshape(-1, cfg.d_model)
        logits_r = h @ p["router"]
        gates, experts = jax.lax.top_k(logits_r, 2)
        gates = jax.nn.softmax(gates, axis=-1)
        out = jnp.zeros_like(h)
        for t in range(h.shape[0]):
            acc = jnp.zeros((cfg.d_model,))
            for j in range(2):
                e = int(experts[t, j])
                ge = jax.nn.silu(h[t] @ p["experts_gate"][e]) * (
                    h[t] @ p["experts_up"][e])
                acc = acc + gates[t, j] * (ge @ p["experts_down"][e])
            out = out.at[t].set(acc)
        want = x + out.reshape(x.shape)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_moe_capacity_drops_are_bounded(self):
        """With capacity_factor 1.0 some tokens drop but output stays finite
        and the residual path preserves them."""
        cfg = tiny("moe_drop", n_kv_heads=4, moe_experts=4, moe_top_k=1,
                   moe_d_ff=16, moe_capacity_factor=1.0)
        p = init_moe(KEY, cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
        y = moe_ffn(p, cfg, x)
        assert bool(jnp.all(jnp.isfinite(y)))
        assert y.shape == x.shape


class TestChunkedAttention:
    @pytest.mark.parametrize("s,bq", [(32, 8), (33, 8), (64, 64), (17, 32)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, s, bq, causal):
        from repro.models.attention_xla import chunked_gqa_attention
        from repro.kernels.flash_attention import gqa_attention
        b, hq, hkv, d = 2, 8, 2, 16
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(s), 3)
        q = jax.random.normal(kq, (b, s, hq, d))
        k = jax.random.normal(kk, (b, s, hkv, d))
        v = jax.random.normal(kv, (b, s, hkv, d))
        got = chunked_gqa_attention(q, k, v, causal=causal, block_q=bq)
        want = gqa_attention(q, k, v, causal=causal, use_pallas=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_gradients_match_ref(self):
        from repro.models.attention_xla import chunked_gqa_attention
        from repro.kernels.flash_attention import gqa_attention
        b, s, hq, hkv, d = 1, 32, 4, 2, 8
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(kq, (b, s, hq, d))
        k = jax.random.normal(kk, (b, s, hkv, d))
        v = jax.random.normal(kv, (b, s, hkv, d))
        f1 = lambda q, k, v: jnp.sum(
            chunked_gqa_attention(q, k, v, causal=True, block_q=8) ** 2)
        f2 = lambda q, k, v: jnp.sum(
            gqa_attention(q, k, v, causal=True, use_pallas=False) ** 2)
        g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-4, atol=1e-4)
