"""Adaptive optimisers under LGD: weight/moment composition contracts.

The sampler path applies 1/(p·N) importance weights INSIDE the loss, so
the gradient any optimiser receives is already the unbiased estimate —
moments must be running statistics OF that estimate.  Pinned here:

  * ORDER: after one Trainer step under Adam, the first/second moments
    equal (1-b1)·g and (1-b2)·g² for g = grad of the importance-
    weighted loss at the initial params — i.e. weights are applied
    strictly BEFORE moment accumulation (a sampler-unaware optimiser).
  * UNBIASEDNESS against full-batch moments: E over independent LGD
    draws of Adam's first moment equals (1-b1)·(full-batch gradient)
    — the moment tracks the true mean gradient, not a reweighted one.
  * AdaGrad's accumulator is the square of the weighted estimate.
  * End-to-end: Trainer + ShardedLSHPipeline trains under Adam,
    AdaGrad and momentum-SGD (losses finite and decreasing-ish), and
    ``make_optimizer`` builds every family by name.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.estimator as E
import repro.core.sampler as S
from repro.core import (
    LGDProblem,
    LSHParams,
    IndexMutation,
    mutate_index,
    full_loss,
    init as lgd_init,
    lgd_step,
)
from repro.core.lgd import preprocess_regression, squared_loss_grad
from repro.data import make_regression, make_token_corpus
from repro.data.lsh_pipeline import (
    LSHPipelineConfig,
    LSHSampledPipeline,
    lm_head_query_fn,
    mean_pool_feature_fn,
)
from repro.models import ModelConfig, init_params, loss as lm_loss
from repro.optim import SGD, AdaGrad, Adam, make_optimizer
from repro.train import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


def _build_index(key, x_aug, p, **kw):
    return mutate_index(
        None, IndexMutation("build", key=key, x_aug=x_aug), p, **kw)

CFG = ModelConfig(
    name="lm-optim-test", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
    d_ff=64, vocab=128, chunk=8, loss_chunk=32, dtype="float32",
    rope_theta=10000.0)


def _pipeline(params, minibatch=16, multiprobe=0):
    corpus = make_token_corpus(13, 192, 12, CFG.vocab, hard_frac=0.15)
    return LSHSampledPipeline(
        jax.random.PRNGKey(21), corpus.tokens, mean_pool_feature_fn(CFG),
        lm_head_query_fn(),
        LSHPipelineConfig(k=5, l=6, minibatch=minibatch, refresh_every=0,
                          multiprobe=multiprobe),
        params=params)


class TestMakeOptimizer:
    def test_families(self):
        assert isinstance(make_optimizer("sgd"), SGD)
        mom = make_optimizer("momentum")
        assert isinstance(mom, SGD) and mom.momentum == 0.9
        assert isinstance(make_optimizer("adagrad"), AdaGrad)
        assert isinstance(make_optimizer("adam"), Adam)
        assert make_optimizer("adamw").weight_decay > 0
        assert make_optimizer("adam", lr=1e-4).lr == 1e-4

    def test_unknown(self):
        with pytest.raises(ValueError):
            make_optimizer("sophia")


class TestWeightsBeforeMoments:
    def test_adam_moments_of_weighted_estimate_trainer_path(self):
        """m_1 == (1-b1)·grad(weighted loss), v_1 == (1-b2)·grad²."""
        params = init_params(KEY, CFG)
        b1, b2 = 0.9, 0.999
        tr = Trainer(CFG, params, Adam(lr=1e-3, b1=b1, b2=b2),
                     tcfg=TrainerConfig(log_every=10_000, grad_clip=None),
                     sampler=_pipeline(params))
        # twin pipeline with the same constructor key draws the exact
        # batch the trainer consumes (determinism contract)
        twin = _pipeline(init_params(KEY, CFG))
        batch = twin.next_batch()
        g = jax.grad(lambda p: lm_loss(p, CFG, batch))(params)
        tr.run(1)
        m_leaves = jax.tree.leaves(tr.opt_state.m)
        v_leaves = jax.tree.leaves(tr.opt_state.v)
        g_leaves = jax.tree.leaves(g)
        assert len(m_leaves) == len(g_leaves)
        for gm, gl in zip(m_leaves, g_leaves):
            np.testing.assert_allclose(
                np.asarray(gm), (1 - b1) * np.asarray(gl, np.float32),
                rtol=2e-4, atol=1e-7)
        for gv, gl in zip(v_leaves, g_leaves):
            np.testing.assert_allclose(
                np.asarray(gv),
                (1 - b2) * np.square(np.asarray(gl, np.float32)),
                rtol=2e-4, atol=1e-10)

    def test_adagrad_accumulates_squared_weighted_estimate(self):
        """Linear path: accum_1 == g_est² for the weighted estimate."""
        ds = make_regression(jax.random.PRNGKey(1), "yearmsd-like",
                             n_train=800, n_test=10, d=12, noise="pareto")
        prob = LGDProblem(
            kind="regression",
            lsh=LSHParams(k=5, l=20, dim=13, family="quadratic"),
            minibatch=8)
        opt = AdaGrad(lr=1e-2)
        state, xt, yt, x_aug = lgd_init(jax.random.PRNGKey(2), prob,
                                        ds.x_train, ds.y_train, opt)
        k = jax.random.PRNGKey(3)
        new_state, _ = lgd_step(k, state, xt, yt, x_aug, prob, opt)
        # replay the draw: same key, same index -> same estimate
        res = S.sample(k, state.index, x_aug,
                       jnp.concatenate([state.theta, -jnp.ones(1)]),
                       prob.lsh, m=prob.minibatch)
        g_est = E.lgd_gradient(squared_loss_grad, state.theta,
                               xt[res.indices], yt[res.indices], res,
                               xt.shape[0])
        np.testing.assert_allclose(
            np.asarray(new_state.opt_state.accum),
            np.square(np.asarray(g_est)), rtol=1e-5, atol=1e-10)

    def test_momentum_buffer_is_weighted_estimate(self):
        ds = make_regression(jax.random.PRNGKey(1), "yearmsd-like",
                             n_train=800, n_test=10, d=12, noise="pareto")
        prob = LGDProblem(
            kind="regression",
            lsh=LSHParams(k=5, l=20, dim=13, family="quadratic"),
            minibatch=8)
        opt = SGD(lr=1e-2, momentum=0.9)
        state, xt, yt, x_aug = lgd_init(jax.random.PRNGKey(2), prob,
                                        ds.x_train, ds.y_train, opt)
        k = jax.random.PRNGKey(3)
        new_state, _ = lgd_step(k, state, xt, yt, x_aug, prob, opt)
        res = S.sample(k, state.index, x_aug,
                       jnp.concatenate([state.theta, -jnp.ones(1)]),
                       prob.lsh, m=prob.minibatch)
        g_est = E.lgd_gradient(squared_loss_grad, state.theta,
                               xt[res.indices], yt[res.indices], res,
                               xt.shape[0])
        np.testing.assert_allclose(np.asarray(new_state.opt_state.momentum),
                                   np.asarray(g_est), rtol=1e-6)


class TestMomentUnbiasedness:
    def test_adam_first_moment_tracks_full_batch_gradient(self):
        """E[m_1] == (1-b1)·full-batch grad, over independent draws.

        This is the 'unbiasedness against full-batch moments' pin: the
        first moment of a sampler-fed Adam is an unbiased estimate of
        the full-batch first moment because the weights act on the
        estimate BEFORE accumulation.  (Second moments accumulate
        E[g²] ≥ E[g]² by design — only the first moment admits a
        full-batch comparison.)
        """
        ds = make_regression(jax.random.PRNGKey(42), "yearmsd-like",
                             n_train=1500, n_test=10, d=16, noise="pareto")
        xt, yt, x_aug = preprocess_regression(ds.x_train, ds.y_train)
        n = xt.shape[0]
        p = LSHParams(k=5, l=100, dim=17, family="quadratic")
        theta = 0.05 * jax.random.normal(jax.random.PRNGKey(2), (16,))
        q = jnp.concatenate([theta, -jnp.ones(1)])
        q = q / jnp.linalg.norm(q)
        full_grad = jnp.mean(jax.vmap(
            lambda a, b: squared_loss_grad(theta, a, b))(xt, yt), 0)
        b1 = 0.9
        opt = Adam(lr=1e-3, b1=b1)

        def m1_of_draw(key):
            kb, ks = jax.random.split(key)
            index = _build_index(kb, x_aug, p)
            r = S.sample(ks, index, x_aug, q, p, m=64, multiprobe=2)
            g = E.lgd_gradient(squared_loss_grad, theta, xt[r.indices],
                               yt[r.indices], r, n)
            _, st = opt.update(g, opt.init(theta), theta)
            return st.m

        keys = jax.random.split(jax.random.PRNGKey(3), 150)
        mean_m1 = jnp.mean(jax.lax.map(m1_of_draw, keys), axis=0)
        rel = float(jnp.linalg.norm(mean_m1 - (1 - b1) * full_grad) /
                    jnp.linalg.norm((1 - b1) * full_grad))
        assert rel < 0.25, (
            f"Adam first moment biased vs full-batch moment: rel {rel}")


class TestEndToEnd:
    @pytest.mark.parametrize("name", ["momentum", "adagrad", "adam"])
    def test_trainer_trains_under_each_optimizer(self, name):
        params = init_params(KEY, CFG)
        pipe = _pipeline(params, multiprobe=2)
        tr = Trainer(CFG, params, make_optimizer(name),
                     tcfg=TrainerConfig(log_every=5), sampler=pipe)
        out = tr.run(10)
        losses = out["losses"]
        assert len(losses) == 10
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0] * 1.05   # no blow-up
        assert tr.metrics_history and \
            "fallback_rate" in tr.metrics_history[-1]
        tr.finalize()

    @pytest.mark.parametrize("name", ["momentum", "adagrad", "adam"])
    def test_linear_lgd_converges_under_each_optimizer(self, name):
        ds = make_regression(jax.random.PRNGKey(5), "yearmsd-like",
                             n_train=1000, n_test=10, d=16, noise="pareto")
        prob = LGDProblem(
            kind="regression",
            lsh=LSHParams(k=5, l=50, dim=17, family="quadratic"),
            minibatch=16, multiprobe=1)
        opt = make_optimizer(name, 2e-2)
        state, xt, yt, x_aug = lgd_init(jax.random.PRNGKey(6), prob,
                                        ds.x_train, ds.y_train, opt)
        loss0 = float(full_loss(state.theta, xt, yt, prob))
        for i in range(120):
            state, _ = lgd_step(jax.random.fold_in(KEY, i), state, xt, yt,
                                x_aug, prob, opt)
        loss1 = float(full_loss(state.theta, xt, yt, prob))
        assert np.isfinite(loss1) and loss1 < loss0, (
            f"{name}: {loss0} -> {loss1}")


class TestOptaxAdapter:
    """``optax:<ctor>`` routing through make_optimizer and numerical
    parity of the adapted optax.adam against the built-in Adam (same
    additive-updates convention, so the adapter is a passthrough)."""

    optax = pytest.importorskip("optax")

    def test_routing_and_errors(self):
        from repro.optim import OptaxAdapter, from_optax

        opt = make_optimizer("optax:adam", lr=1e-3)
        assert isinstance(opt, OptaxAdapter)
        assert opt.name == "optax:adam"
        assert isinstance(from_optax(self.optax.sgd(1e-2)), OptaxAdapter)
        with pytest.raises(ValueError):
            make_optimizer("optax:sophia")
        with pytest.raises(TypeError):
            from_optax(object())

    def test_adam_parity_with_builtin(self):
        from repro.optim import apply_updates

        params = {
            "w": jax.random.normal(jax.random.PRNGKey(1), (8, 4)),
            "b": jnp.zeros((4,)),
        }
        builtin = Adam(lr=3e-3)
        adapted = make_optimizer("optax:adam", lr=3e-3)
        pa, pb = params, params
        sa, sb = builtin.init(pa), adapted.init(pb)
        for i in range(20):
            g = jax.tree_util.tree_map(
                lambda p, i=i: p * 0.1 + jax.random.normal(
                    jax.random.fold_in(KEY, i), p.shape) * 0.01, pa)
            ua, sa = builtin.update(g, sa, pa)
            ub, sb = adapted.update(g, sb, pb)
            pa = apply_updates(pa, ua)
            pb = apply_updates(pb, ub)
        for ka in pa:
            np.testing.assert_allclose(
                np.asarray(pa[ka]), np.asarray(pb[ka]),
                atol=1e-5, rtol=1e-5)

    def test_trains_under_trainer(self):
        params = init_params(jax.random.PRNGKey(2), CFG)
        pipe = _pipeline(params)
        tr = Trainer(CFG, params, make_optimizer("optax:adamw", 1e-3),
                     tcfg=TrainerConfig(log_every=100), sampler=pipe)
        out = tr.run(4)
        assert all(np.isfinite(out["losses"]))
        tr.finalize()
