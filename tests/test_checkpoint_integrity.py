"""Checkpoint integrity: verify(), latest_valid_step fallback, async
error surfacing, .tmp garbage collection, and iterator-resume hygiene.

Each corruption class here mimics a distinct real incident (truncated
write, lost object, bit rot) applied with the deterministic corrupters
from ``repro.testing.faults``; the contract is that ``verify()`` turns
the damage into an INVALID verdict and the restore path falls back to
the newest valid step instead of crashing or silently resuming from
garbage.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_token_corpus, uniform_batches
from repro.models import ModelConfig, init_params
from repro.optim import Adam
from repro.testing import delete_leaf, flip_manifest_byte, truncate_arrays
from repro.train import Trainer, TrainerConfig, checkpoint as ckpt

TREE = {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((5,)),
        "nested": {"m": jnp.zeros((2, 2), jnp.int32)}}


def _save_steps(d, steps):
    for s in steps:
        ckpt.save(d, s, TREE, extra={"step": s})


class TestVerify:
    def test_pristine_checkpoint_verifies(self, tmp_path):
        d = os.fspath(tmp_path)
        _save_steps(d, [3])
        ok, reason = ckpt.verify(d, 3)
        assert ok, reason

    def test_truncated_arrays_fail_verify(self, tmp_path):
        d = os.fspath(tmp_path)
        _save_steps(d, [3])
        truncate_arrays(d, 3)
        ok, reason = ckpt.verify(d, 3)
        assert not ok and "arrays.npz" in reason

    def test_deleted_leaf_fails_verify(self, tmp_path):
        d = os.fspath(tmp_path)
        _save_steps(d, [3])
        victim = delete_leaf(d, 3)
        ok, reason = ckpt.verify(d, 3)
        assert not ok and "missing" in reason
        assert victim.endswith(".npy")

    def test_flipped_manifest_byte_fails_verify(self, tmp_path):
        d = os.fspath(tmp_path)
        _save_steps(d, [3])
        flip_manifest_byte(d, 3)
        ok, reason = ckpt.verify(d, 3)
        assert not ok
        assert "manifest" in reason       # unparseable OR checksum fail

    def test_flipped_array_byte_fails_crc(self, tmp_path):
        """Bit rot INSIDE a stored array: zip + manifest stay valid, only
        the per-leaf CRC32 catches it."""
        d = os.fspath(tmp_path)
        _save_steps(d, [3])
        import zipfile
        p = os.path.join(d, "step_00000003", "arrays.npz")
        with zipfile.ZipFile(p) as z:
            second = z.infolist()[1].header_offset
        with open(p, "r+b") as f:
            data = bytearray(f.read())
            # the store is ZIP_STORED (raw .npy payloads): the byte just
            # before the second member's local header is the last DATA
            # byte of the first member
            data[second - 1] ^= 0xFF
            f.seek(0)
            f.write(data)
        ok, reason = ckpt.verify(d, 3)
        assert not ok, reason

    def test_legacy_manifest_without_checksums_passes_structural(
            self, tmp_path):
        d = os.fspath(tmp_path)
        _save_steps(d, [3])
        mpath = os.path.join(d, "step_00000003", "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        manifest.pop("checksum")
        for leaf in manifest["leaves"]:
            leaf.pop("crc32")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        ok, reason = ckpt.verify(d, 3)
        assert ok, reason


class TestLatestValidStep:
    def test_skips_corrupt_newest(self, tmp_path):
        d = os.fspath(tmp_path)
        _save_steps(d, [10, 20, 30])
        truncate_arrays(d, 30)
        assert ckpt.latest_step(d) == 30           # existence only
        assert ckpt.latest_valid_step(d) == 20     # integrity-checked

    def test_skips_multiple_corrupt(self, tmp_path):
        d = os.fspath(tmp_path)
        _save_steps(d, [10, 20, 30])
        truncate_arrays(d, 30)
        flip_manifest_byte(d, 20)
        assert ckpt.latest_valid_step(d) == 10

    def test_none_when_all_corrupt(self, tmp_path):
        d = os.fspath(tmp_path)
        _save_steps(d, [10])
        truncate_arrays(d, 10)
        assert ckpt.latest_valid_step(d) is None

    def test_trainer_resume_skips_corrupt_and_replays_bitwise(
            self, tmp_path):
        """resume=True lands on the newest VALID step and the two
        restored trainers draw bit-identical parameters."""
        d = os.fspath(tmp_path)
        cfg = ModelConfig(
            name="tiny", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
            d_ff=64, vocab=64, chunk=16, loss_chunk=16, dtype="float32",
            rope_theta=10000.0)
        corpus = make_token_corpus(5, 64, 16, cfg.vocab)

        def fresh(resume):
            return Trainer(
                cfg, init_params(jax.random.PRNGKey(0), cfg),
                Adam(lr=1e-2), uniform_batches(corpus, 8, seed=1),
                TrainerConfig(ckpt_dir=d, ckpt_every=10, log_every=50),
                resume=resume)

        t1 = fresh(resume=False)
        t1.run(30)
        t1.finalize()
        truncate_arrays(d, 30)
        t2 = fresh(resume=True)
        assert t2.step == 20
        t3 = fresh(resume=True)
        assert t3.step == 20
        jax.tree.map(
            np.testing.assert_array_equal,
            jax.tree.map(np.asarray, t2.params),
            jax.tree.map(np.asarray, t3.params))


class TestAsyncCheckpointerErrors:
    def test_write_failure_reraised_at_wait(self, tmp_path):
        a = ckpt.AsyncCheckpointer()
        # a FILE where the step dir must go forces the writer to fail
        bad_dir = os.fspath(tmp_path / "ckpts")
        with open(bad_dir, "w") as f:
            f.write("not a directory")
        a.save(bad_dir, 1, TREE)
        with pytest.raises(RuntimeError, match="async checkpoint"):
            a.wait()
        a.wait()                     # error is consumed, not sticky

    def test_write_failure_reraised_at_next_save(self, tmp_path):
        a = ckpt.AsyncCheckpointer()
        bad_dir = os.fspath(tmp_path / "ckpts")
        with open(bad_dir, "w") as f:
            f.write("x")
        a.save(bad_dir, 1, TREE)
        with pytest.raises(RuntimeError, match="async checkpoint"):
            a.save(os.fspath(tmp_path), 2, TREE)


class TestTmpGarbageCollection:
    def test_keep_last_reaps_orphaned_tmp(self, tmp_path):
        d = os.fspath(tmp_path)
        _save_steps(d, [10, 20])
        os.makedirs(os.path.join(d, "step_00000015.tmp"))  # dead writer
        ckpt.keep_last(d, 2)
        assert not os.path.exists(os.path.join(d, "step_00000015.tmp"))
        assert ckpt.latest_valid_step(d) == 20

    def test_keep_last_spares_inflight_tmp(self, tmp_path):
        """A .tmp for a step NEWER than every completed checkpoint is an
        in-flight async write, never garbage."""
        d = os.fspath(tmp_path)
        _save_steps(d, [10, 20])
        os.makedirs(os.path.join(d, "step_00000030.tmp"))
        ckpt.keep_last(d, 2)
        assert os.path.exists(os.path.join(d, "step_00000030.tmp"))

    def test_keep_last_removes_manifestless_dirs(self, tmp_path):
        """A step dir without a manifest (killed between npz write and
        manifest write pre-atomic-rename eras, or manual damage) must
        not survive GC forever."""
        d = os.fspath(tmp_path)
        _save_steps(d, [10, 20, 30])
        os.remove(os.path.join(d, "step_00000010", "manifest.json"))
        ckpt.keep_last(d, 2)
        assert not os.path.exists(os.path.join(d, "step_00000010"))

    def test_save_clobbers_stale_tmp_with_warning(self, tmp_path, caplog):
        d = os.fspath(tmp_path)
        os.makedirs(os.path.join(d, "step_00000005.tmp"))
        import logging
        with caplog.at_level(logging.WARNING, logger="repro.checkpoint"):
            ckpt.save(d, 5, TREE)
        assert any("clobbering" in r.message for r in caplog.records)
        ok, reason = ckpt.verify(d, 5)
        assert ok, reason


class TestIteratorResumeHygiene:
    def _cfg(self):
        return ModelConfig(
            name="tiny", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
            d_ff=64, vocab=64, chunk=16, loss_chunk=16, dtype="float32",
            rope_theta=10000.0)

    def test_empty_iterator_first_draw_returns_cleanly(self):
        cfg = self._cfg()
        tr = Trainer(cfg, init_params(jax.random.PRNGKey(0), cfg),
                     Adam(lr=1e-2), iter([]),
                     TrainerConfig(log_every=50), resume=False)
        out = tr.run(5)              # must NOT raise bare StopIteration
        assert out["losses"] == []
        assert tr.step == 0

    def test_short_iterator_on_restore_raises_clear_error(self, tmp_path):
        d = os.fspath(tmp_path)
        cfg = self._cfg()
        corpus = make_token_corpus(5, 64, 16, cfg.vocab)

        def fresh(batches, resume):
            return Trainer(cfg, init_params(jax.random.PRNGKey(0), cfg),
                           Adam(lr=1e-2), batches,
                           TrainerConfig(ckpt_dir=d, ckpt_every=10,
                                         log_every=50), resume=resume)

        t1 = fresh(uniform_batches(corpus, 8, seed=1), resume=False)
        t1.run(10)
        t1.finalize()
        short = (b for _, b in zip(range(3),
                                   uniform_batches(corpus, 8, seed=1)))
        with pytest.raises(RuntimeError, match="shorter than the"):
            fresh(short, resume=True)
