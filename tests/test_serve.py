"""CPU smoke test for ``examples/serve.py`` (the batched serving driver).

``serve.py`` was the only example with zero CI coverage; this pins the
prefill + N-step decode path end-to-end for two architectures — one
attention-KV-cache arch (``phi4_mini_3_8b``) and one hybrid-SSM arch
(``zamba2_1_2b``, serve's default) — by running the script exactly as
documented, as a subprocess.  Part of the tier-1 job (plain pytest
collection), so the documented serving invocation cannot rot.
"""

import os
import re
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SERVE = os.path.join(ROOT, "examples", "serve.py")

NEW_TOKENS = 4


def _run_serve(arch: str, head: str = "full") -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, SERVE, "--arch", arch, "--batch", "2",
         "--prompt-len", "16", "--new-tokens", str(NEW_TOKENS),
         "--head", head],
        capture_output=True, text=True, timeout=300, cwd=ROOT, env=env)
    assert proc.returncode == 0, (
        f"serve.py --arch {arch} --head {head} failed "
        f"(exit {proc.returncode}):\n"
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    return proc.stdout


def _check_decode_output(out: str, head: str) -> None:
    assert re.search(r"prefill 2x16", out), out
    # per-phase timing: decode p10/p50 ms/token alongside the prefill line
    m = re.search(
        rf"decode head={head}: p10 ([\d.]+) ms/token +p50 ([\d.]+) ms/token",
        out)
    assert m, f"per-phase decode timing line missing:\n{out}"
    assert float(m.group(1)) <= float(m.group(2)), out
    m = re.search(rf"decoded {NEW_TOKENS} tokens/seq", out)
    assert m, f"decode line missing:\n{out}"
    # the sample row must contain NEW_TOKENS generated token ids
    m = re.search(r"sample row: \[([^\]]*)\]", out)
    assert m, out
    toks = [t for t in m.group(1).split(",") if t.strip()]
    assert len(toks) == min(NEW_TOKENS, 12), out


@pytest.mark.parametrize("arch", ["phi4_mini_3_8b", "zamba2_1_2b"])
def test_serve_prefill_and_decode(arch):
    _check_decode_output(_run_serve(arch), "full")


def test_serve_lsh_head():
    """The LSH-shortlisted head decodes end to end: index built over the
    lm_head rows, per-token probe -> shortlist -> argmax, same output
    contract (per-phase timing + sample row) as the full head."""
    out = _run_serve("zamba2_1_2b", head="lsh")
    assert re.search(r"head=lsh: \d+ rows x \d+ tables", out), out
    _check_decode_output(out, "lsh")
