"""Per-architecture smoke tests: reduced config, one fwd/train step on CPU.

FULL configs are never allocated here (dry-run only, via ShapeDtypeStruct);
each SMOKE config is the same family at toy width/depth.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import SHAPES, shape_applicable
from repro.models import (
    decode_step,
    init_cache,
    init_params,
    loss,
    prefill,
)
from repro.optim import Adam, apply_updates

KEY = jax.random.PRNGKey(0)


def _smoke_batch(cfg, b=2, s=32):
    kt, ke, ki = jax.random.split(KEY, 3)
    batch = {"targets": jax.random.randint(kt, (b, s), 0, cfg.vocab)}
    if cfg.frontend == "embed_stub":
        batch["embeds"] = jax.random.normal(ke, (b, s, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(ke, (b, s), 0, cfg.vocab)
    if "cross_attn" in cfg.block_pattern:
        batch["image_embeds"] = jax.random.normal(
            ki, (b, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", configs.all_archs())
class TestArchSmoke:
    def test_full_config_matches_assignment(self, arch):
        """The FULL config must carry the exact assigned hyperparameters."""
        cfg = configs.get(arch)
        expected = {
            "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
            "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 0, 151936),
            "llama4_maverick_400b_a17b": (48, 5120, 40, 8, 0, 202048),
            "phi4_mini_3_8b": (32, 3072, 24, 8, 8192, 200064),
            "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
            "starcoder2_15b": (40, 6144, 48, 4, 24576, 49152),
            "nemotron_4_15b": (32, 6144, 48, 8, 24576, 256000),
            "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
            "llama_3_2_vision_90b": (100, 8192, 64, 8, 28672, 128256),
            "zamba2_1_2b": (38, 2048, 32, 32, 8192, 32000),
        }[arch]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == expected, (got, expected)
        if arch == "qwen3_moe_235b_a22b":
            assert (cfg.moe_experts, cfg.moe_top_k, cfg.moe_d_ff) == \
                (128, 8, 1536)
        if arch == "llama4_maverick_400b_a17b":
            assert (cfg.moe_experts, cfg.moe_top_k, cfg.moe_d_ff) == \
                (128, 1, 8192)
        if arch == "zamba2_1_2b":
            assert cfg.ssm_state == 64

    def test_train_step(self, arch):
        """One forward+backward+update on the reduced config: finite, moving."""
        cfg = configs.get_smoke(arch)
        params = init_params(KEY, cfg)
        batch = _smoke_batch(cfg)
        opt = Adam(lr=1e-3)
        opt_state = opt.init(params)

        @jax.jit
        def step(p, s, b):
            l, g = jax.value_and_grad(lambda pp: loss(pp, cfg, b))(p)
            upd, s = opt.update(g, s, p)
            return apply_updates(p, upd), s, l

        l0 = None
        for i in range(3):
            params, opt_state, l = step(params, opt_state, batch)
            assert np.isfinite(float(l)), (arch, i)
            l0 = float(l) if l0 is None else l0
        assert float(l) < l0 + 1e-3, f"{arch}: loss not decreasing"

    def test_serve_path(self, arch):
        """prefill + one decode token: correct shapes, no NaNs."""
        cfg = configs.get_smoke(arch)
        params = init_params(KEY, cfg)
        b, s = 2, 16
        batch = _smoke_batch(cfg, b=b, s=s)
        batch.pop("targets")
        cache = init_cache(cfg, b, 32)
        h, cache = prefill(params, cfg, batch, cache)
        assert h.shape == (b, s, cfg.d_model)
        step = {"positions": jnp.full((b, 1), s, jnp.int32)}
        if cfg.frontend == "embed_stub":
            step["embeds"] = jax.random.normal(KEY, (b, 1, cfg.d_model))
        else:
            step["tokens"] = jnp.zeros((b, 1), jnp.int32)
        if "cross_attn" in cfg.block_pattern:
            step["image_embeds"] = batch["image_embeds"]
        lg, cache2 = decode_step(params, cfg, step, cache)
        assert lg.shape == (b, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(lg))), arch

    def test_shape_applicability(self, arch):
        """long_500k runs iff the arch is sub-quadratic (SSM/hybrid)."""
        cfg = configs.get(arch)
        skip = shape_applicable(cfg, SHAPES["long_500k"])
        if arch in ("xlstm_350m", "zamba2_1_2b"):
            assert skip is None
        else:
            assert skip is not None
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(cfg, SHAPES[s]) is None
