"""Shared input construction for the SRP parity pin (tests/test_families.py).

The pluggable-family refactor must leave the SRP path bit-identical to
the pre-refactor sampler/pipeline.  This module builds the exact inputs
for the pinned entry points — ``sample``, ``sample_gather_batched`` and
``LSHSampledPipeline.next_batch_multi``, each at multiprobe 0 and 2 —
and, when run as a script, records their outputs to
``tests/golden/srp_parity.npz``:

    PYTHONPATH=src python tests/_parity_cases.py

The golden file was generated BEFORE the family refactor landed, so the
test comparing against it pins the refactor to the old behaviour.
Integer outputs (indices, probe codes, fallback flags, example ids)
must match exactly; float outputs (probs, weights) to tight tolerance
(the golden file may have been written on a different host).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "srp_parity.npz")


def _feature_fn(tokens: jax.Array) -> jax.Array:
    """Deterministic params-free embedding: (B, S) int32 -> (B, 8) f32."""
    t = tokens.astype(jnp.float32)
    scales = (jnp.arange(8, dtype=jnp.float32) + 1.0) * 0.1
    return jnp.mean(jnp.sin(t[..., None] * scales), axis=1)


def sample_case(multiprobe: int):
    """Inputs + outputs of ``sample`` on a dense-SRP index."""
    from repro.core import IndexMutation, LSHParams, mutate_index, sample

    kx, kq, kb, ks = jax.random.split(jax.random.PRNGKey(7), 4)
    x = jax.random.normal(kx, (512, 16))
    x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
    q = jax.random.normal(kq, (16,))
    p = LSHParams(k=6, l=12, dim=16, family="dense")
    index = mutate_index(None, IndexMutation("build", key=kb, x_aug=x), p)
    res = sample(ks, index, x, q, p, m=64, multiprobe=multiprobe)
    return {
        "indices": res.indices, "probs": res.probs,
        "n_probes": res.n_probes, "bucket_sizes": res.bucket_sizes,
        "fallback": res.fallback, "probe_code": res.probe_code,
    }


def quadratic_sample_case(multiprobe: int):
    """Same pin for the quadratic family (refactor covers it too)."""
    from repro.core import IndexMutation, LSHParams, mutate_index, sample

    kx, kq, kb, ks = jax.random.split(jax.random.PRNGKey(11), 4)
    x = jax.random.normal(kx, (256, 10))
    x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
    q = jax.random.normal(kq, (10,))
    p = LSHParams(k=4, l=8, dim=10, family="quadratic")
    index = mutate_index(None, IndexMutation("build", key=kb, x_aug=x), p)
    res = sample(ks, index, x, q, p, m=48, multiprobe=multiprobe)
    return {
        "indices": res.indices, "probs": res.probs,
        "fallback": res.fallback, "probe_code": res.probe_code,
    }


def gather_case(multiprobe: int):
    """Inputs + outputs of ``sample_gather_batched`` (device-resident path)."""
    from repro.core import (IndexMutation, LSHParams, mutate_index,
                            sample_gather_batched)

    kx, kq, kb, ks, kt = jax.random.split(jax.random.PRNGKey(13), 5)
    n, d, s = 384, 12, 20
    x = jax.random.normal(kx, (n, d))
    x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
    queries = jax.random.normal(kq, (4, d))
    store = jax.random.randint(kt, (n, s + 1), 0, 101, dtype=jnp.int32)
    p = LSHParams(k=5, l=10, dim=d, family="dense")
    index = mutate_index(None, IndexMutation("build", key=kb, x_aug=x), p)
    gb = sample_gather_batched(ks, index, x, queries, store, p, m=8,
                               example_offset=17, multiprobe=multiprobe)
    return {
        "tokens": gb.tokens, "targets": gb.targets,
        "loss_weights": gb.loss_weights, "example_ids": gb.example_ids,
        "indices": gb.indices, "probs": gb.probs,
        "fallback": gb.fallback, "probe_code": gb.probe_code,
    }


def pipeline_case(multiprobe: int):
    """Inputs + outputs of ``LSHSampledPipeline.next_batch_multi``."""
    from repro.data import LSHPipelineConfig, LSHSampledPipeline

    kt, kq, kp = jax.random.split(jax.random.PRNGKey(19), 3)
    tokens = np.asarray(
        jax.random.randint(kt, (256, 25), 0, 97, dtype=jnp.int32))
    qfix = jax.random.normal(kq, (8,))

    pipe = LSHSampledPipeline(
        kp, tokens, lambda _p, t: _feature_fn(t), lambda _p: qfix,
        LSHPipelineConfig(k=6, l=8, minibatch=8, refresh_every=0,
                          multiprobe=multiprobe), params=())
    queries = jax.random.normal(jax.random.fold_in(kq, 1), (3, 8))
    outs = [pipe.next_batch_multi(queries) for _ in range(2)]
    flat = {}
    for step, chains in enumerate(outs):
        for c, b in enumerate(chains):
            for k, v in b.items():
                flat[f"s{step}_c{c}_{k}"] = v
    return flat


def all_cases():
    cases = {}
    for mp in (0, 2):
        for name, fn in (("sample", sample_case),
                         ("quad", quadratic_sample_case),
                         ("gather", gather_case),
                         ("pipe", pipeline_case)):
            for k, v in fn(mp).items():
                cases[f"{name}_mp{mp}_{k}"] = np.asarray(v)
    return cases


def main():
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    cases = all_cases()
    np.savez_compressed(GOLDEN, **cases)
    print(f"wrote {len(cases)} arrays to {GOLDEN}")


if __name__ == "__main__":
    main()
