"""Substrate tests: optimizers, checkpoint/restart, trainer, LGD pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (
    LSHPipelineConfig,
    LSHSampledPipeline,
    make_token_corpus,
    uniform_batches,
)
from repro.models import ModelConfig, forward, init_params, loss
from repro.optim import (
    SGD,
    AdaGrad,
    Adafactor,
    Adam,
    Adam8bit,
    apply_updates,
    schedules,
)
from repro.train import Trainer, TrainerConfig, checkpoint as ckpt
from repro.train.elastic import rescale_plan

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_problem():
    target = jnp.array([1.0, -2.0, 3.0])
    def loss_fn(p):
        return jnp.sum((p - target) ** 2)
    return target, loss_fn


class TestOptimizers:
    @pytest.mark.parametrize("opt,tol", [
        (SGD(lr=0.1), 1e-2), (SGD(lr=0.1, momentum=0.9), 1e-2),
        (SGD(lr=0.05, momentum=0.9, nesterov=True), 1e-2),
        (AdaGrad(lr=1.0), 1e-2), (Adam(lr=0.3), 1e-2),
        (Adam(lr=0.3, weight_decay=1e-4), 1e-2),
        (Adam8bit(lr=0.3), 1e-2),
        # Adafactor's relative-scale update crawls near the optimum of a
        # tiny quadratic; looser tolerance is expected behaviour.
        (Adafactor(lr=0.5), 1e-1),
    ])
    def test_converges_on_quadratic(self, opt, tol):
        target, loss_fn = _quad_problem()
        p = jnp.zeros(3)
        state = opt.init(p)
        for _ in range(300):
            g = jax.grad(loss_fn)(p)
            upd, state = opt.update(g, state, p)
            p = apply_updates(p, upd)
        assert float(loss_fn(p)) < tol, (opt, p)

    def test_adam8bit_tracks_adam(self):
        """int8 moments must approximate fp32 Adam closely on a short run."""
        target, loss_fn = _quad_problem()
        p1 = p2 = jnp.zeros(3)
        a, a8 = Adam(lr=0.1), Adam8bit(lr=0.1)
        s1, s2 = a.init(p1), a8.init(p2)
        for _ in range(50):
            g1 = jax.grad(loss_fn)(p1)
            u1, s1 = a.update(g1, s1, p1)
            p1 = apply_updates(p1, u1)
            g2 = jax.grad(loss_fn)(p2)
            u2, s2 = a8.update(g2, s2, p2)
            p2 = apply_updates(p2, u2)
        np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                                   atol=0.05)

    def test_adam8bit_memory_footprint(self):
        """Optimiser state must be ~2 bytes/param (vs 8 for Adam fp32)."""
        p = {"w": jnp.zeros((4096, 256))}
        s = Adam8bit().init(p)
        nbytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(s) if hasattr(x, "dtype"))
        assert nbytes < 2.5 * 4096 * 256, nbytes

    def test_schedules(self):
        s = schedules.warmup_cosine(1.0, 10, 100)
        assert float(s(jnp.array(0))) == 0.0
        assert float(s(jnp.array(10))) == pytest.approx(1.0)
        assert float(s(jnp.array(100))) == pytest.approx(0.0, abs=1e-6)
        sd = schedules.step_decay(1.0, 0.5, 10)
        assert float(sd(jnp.array(25))) == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# checkpoint / restart / elastic
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10.0), "b": [jnp.ones((3, 4)),
                                             {"c": jnp.zeros(2)}]}
        ckpt.save(str(tmp_path), 7, tree, extra={"step": 7})
        assert ckpt.latest_step(str(tmp_path)) == 7
        got, extra = ckpt.restore(str(tmp_path), 7, tree)
        assert extra["step"] == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomicity_tmp_dir_ignored(self, tmp_path):
        tree = {"a": jnp.zeros(3)}
        ckpt.save(str(tmp_path), 1, tree)
        # simulate a crashed writer
        os.makedirs(tmp_path / "step_00000002.tmp")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_keep_last(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        for s in range(5):
            ckpt.save(str(tmp_path), s, tree)
        ckpt.keep_last(str(tmp_path), 2)
        assert ckpt.latest_step(str(tmp_path)) == 4
        assert sorted(os.listdir(tmp_path))[-2:] == [
            "step_00000003", "step_00000004"]

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros(4)})

    def test_rescale_plan_scale_down_grows_accumulation(self):
        # half the devices: same global batch via 2x accumulation, and
        # the per-device batch never exceeds what a device already ran.
        plan = rescale_plan(8, 4, 64)
        assert plan["per_device_batch_new"] == 8
        assert plan["grad_accum_steps"] == 2
        assert (plan["per_device_batch_new"] * 4
                * plan["grad_accum_steps"]) == 64
        assert plan["per_device_batch_new"] <= plan[
            "per_device_batch_old"]

    def test_rescale_plan_scale_up_no_accumulation(self):
        plan = rescale_plan(4, 8, 64)
        assert plan["grad_accum_steps"] == 1
        assert plan["per_device_batch_new"] == 8

    def test_rescale_plan_rejects_indivisible_batch(self):
        # more devices than batch rows cannot keep the global batch
        # fixed — must be an explicit error, not a silent resize.
        with pytest.raises(ValueError, match="does not divide"):
            rescale_plan(256, 512, 256)

    def test_rescale_plan_consistency_sweep(self):
        for old in (1, 2, 3, 4, 8):
            for new in (1, 2, 4, 8):
                plan = rescale_plan(old, new, 64)
                assert (plan["per_device_batch_new"] * new
                        * plan["grad_accum_steps"]) == 64, plan
                if new >= old:
                    assert plan["grad_accum_steps"] == 1, plan


# ---------------------------------------------------------------------------
# trainer: resume determinism + fault tolerance
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return ModelConfig(
        name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=64, chunk=16, loss_chunk=16, dtype="float32",
        rope_theta=10000.0)


class TestTrainer:
    def test_loss_decreases(self, tmp_path):
        cfg = _tiny_cfg()
        corpus = make_token_corpus(0, 256, 16, cfg.vocab)
        params = init_params(KEY, cfg)
        tr = Trainer(cfg, params, Adam(lr=1e-2),
                     uniform_batches(corpus, 8, seed=1),
                     TrainerConfig(ckpt_dir=None, log_every=5))
        out = tr.run(60)
        assert np.mean(out["losses"][-10:]) < np.mean(out["losses"][:10])

    def test_restart_resumes_identically(self, tmp_path):
        """Kill after 40 steps; a fresh Trainer must resume from ckpt and
        produce the same trajectory as an uninterrupted run."""
        cfg = _tiny_cfg()
        corpus = make_token_corpus(0, 256, 16, cfg.vocab)

        def fresh(ckpt_dir, resume):
            return Trainer(
                cfg, init_params(KEY, cfg), Adam(lr=1e-2),
                uniform_batches(corpus, 8, seed=2),
                TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=20,
                              log_every=100),
                resume=resume)

        # uninterrupted reference
        ref = fresh(None, False)
        ref_losses = ref.run(60)["losses"]

        d = str(tmp_path / "ck")
        t1 = fresh(d, False)
        t1.run(40)
        t1.finalize()
        assert ckpt.latest_step(d) == 40
        # "crash" -> new process -> resume
        t2 = fresh(d, True)
        assert t2.step == 40
        got = t2.run(20)["losses"]
        np.testing.assert_allclose(got, ref_losses[40:], rtol=1e-4,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# LGD data pipeline (the paper's technique at LM scale)
# ---------------------------------------------------------------------------

class TestLSHPipeline:
    def _setup(self):
        cfg = _tiny_cfg()
        corpus = make_token_corpus(3, 512, 16, cfg.vocab, hard_frac=0.15)
        params = init_params(KEY, cfg)

        def feature_fn(p, tokens):
            h = forward(p, cfg, {"tokens": tokens})
            return jnp.mean(h.astype(jnp.float32), axis=1)

        def query_fn(p):
            w = p["embed_group"]["lm_head"].astype(jnp.float32)
            return jnp.mean(w, axis=1)

        pipe = LSHSampledPipeline(
            jax.random.PRNGKey(5), corpus.tokens, jax.jit(feature_fn),
            query_fn, LSHPipelineConfig(k=5, l=10, minibatch=16,
                                        refresh_every=50),
            params=params)
        return cfg, corpus, params, pipe

    def test_batches_well_formed(self):
        cfg, corpus, params, pipe = self._setup()
        b = pipe.next_batch()
        assert b["tokens"].shape == (16, 16)
        assert b["targets"].shape == (16, 16)
        assert b["loss_weights"].shape == (16,)
        assert bool(jnp.all(b["loss_weights"] > 0))
        assert float(jnp.mean(b["loss_weights"])) == pytest.approx(1.0,
                                                                   rel=1e-4)

    def test_refresh_changes_index(self):
        cfg, corpus, params, pipe = self._setup()
        before = np.asarray(pipe.index.sorted_codes).copy()
        old_fn = pipe.feature_fn
        pipe.feature_fn = lambda p, t: old_fn(p, t) + jax.random.normal(
            jax.random.PRNGKey(9), (1, cfg.d_model))  # simulate drift
        pipe.refresh()
        after = np.asarray(pipe.index.sorted_codes)
        assert not np.array_equal(before, after)

    def test_trainable_end_to_end_with_weights(self):
        cfg, corpus, params, pipe = self._setup()
        tr = Trainer(cfg, params, Adam(lr=1e-2), iter(pipe.next_batch, None),
                     TrainerConfig(log_every=100, donate=False))
        out = tr.run(30)
        assert all(np.isfinite(out["losses"]))
        assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])


# ---------------------------------------------------------------------------
# gradient compression + accumulation (distributed-optimisation tricks)
# ---------------------------------------------------------------------------

class TestGradCompression:
    def test_quantize_roundtrip_error_bounded(self):
        from repro.optim import compression as gc
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0}
        q = gc.compress(g)
        back = gc.decompress(q, like=g)
        err = float(jnp.max(jnp.abs(back["w"] - g["w"])))
        # int8 block quantisation: error <= scale = max|block| / 127
        assert err <= float(jnp.max(jnp.abs(g["w"]))) / 127 + 1e-6

    def test_wire_bytes_4x_smaller_than_f32(self):
        from repro.optim import compression as gc
        g = {"w": jnp.zeros((4096, 256))}
        q = gc.compress(g)
        assert gc.wire_bytes(q) < 0.3 * g["w"].size * 4

    def test_error_feedback_carries_residual(self):
        from repro.optim import compression as gc
        g = {"w": jnp.full((256,), 1e-4)}  # below one quantisation step
        res = gc.init_error_feedback(g)
        total = jnp.zeros((256,))
        for _ in range(50):
            q, res = gc.compress_with_feedback(g, res)
            total = total + gc.decompress(q)["w"]
        # with feedback, the cumulative transmitted signal tracks 50*g
        np.testing.assert_allclose(np.asarray(total),
                                   np.asarray(g["w"] * 50), rtol=0.05)

    def test_training_with_compression_converges(self, tmp_path):
        cfg = _tiny_cfg()
        corpus = make_token_corpus(0, 256, 16, cfg.vocab)
        tr = Trainer(cfg, init_params(KEY, cfg), Adam(lr=1e-2),
                     uniform_batches(corpus, 8, seed=1),
                     TrainerConfig(log_every=100, grad_compress=True))
        out = tr.run(60)
        assert np.mean(out["losses"][-10:]) < np.mean(out["losses"][:10])


class TestGradAccumulation:
    def test_accum_matches_full_batch(self):
        """grad_accum=4 over batch 16 == one step over the same batch."""
        cfg = _tiny_cfg()
        corpus = make_token_corpus(0, 64, 16, cfg.vocab)
        params = init_params(KEY, cfg)

        def run(accum):
            tr = Trainer(cfg, params, SGD(lr=1e-2),
                         uniform_batches(corpus, 16, seed=3),
                         TrainerConfig(log_every=100, grad_accum=accum,
                                       grad_clip=None, donate=False))
            out = tr.run(5)
            return out["losses"], tr.params

        l1, p1 = run(1)
        l4, p4 = run(4)
        np.testing.assert_allclose(l1, l4, rtol=2e-4, atol=2e-4)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-4)
