"""Streaming corpora: the unified index-mutation API under live traffic.

Pins the contracts the streaming tentpole promises:

  * append/evict parity — a mutated index equals a fresh build over the
    same membership up to the tie-stable order contract (identical
    sorted live codes, identical per-(table, code) bucket membership);
  * unbiasedness over the moving window — E[w·v] tracks the live-window
    mean as rows enter and leave (every 1/(p·N) weight uses live N), in
    the calibrated k=3/l=64 regime of test_sharded_lgd;
  * capacity management — powers-of-2 growth and quarter-occupancy
    compaction, with the live-prefix invariant at every step;
  * checkpoint replay — restore_at(t) truncates + replays the mutation
    log; two restores at the same step draw bit-identical batches,
    including end-to-end through the Trainer's save/restore (the log
    rides in the checkpoint manifest);
  * the deprecation surface — legacy table entry points and legacy
    closure hooks still work but warn.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    EMPTY_CODE,
    IndexMutation,
    LSHParams,
    mutate_index,
)
from repro.core.tables import hash_points
from repro.data.lsh_pipeline import (
    _SHARD_STRIDE,
    LSHPipelineConfig,
    LSHSampledPipeline,
    ShardedLSHPipeline,
)
from repro.train.elastic import rebuild_sharded_pipeline

KEY = jax.random.PRNGKey(0)
VOCAB, DIM = 50, 16
EMBED = jax.random.normal(jax.random.PRNGKey(1), (VOCAB, DIM))
PARAMS = {"embed": EMBED, "q": jnp.ones((DIM,))}
SEQ = 9


def _tokens(n=96, seed=2):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n, SEQ), 0, VOCAB),
        np.int32)


def feature_fn(params, chunk):              # toy params-aware embedding
    return jnp.mean(params["embed"][chunk], axis=1)


def query_fn(params):
    return params["q"]


def _pipe(tokens=None, seed=7, **cfg_kw):
    cfg_kw.setdefault("streaming", True)
    for k, v in dict(k=4, l=8, minibatch=8, refresh_every=0).items():
        cfg_kw.setdefault(k, v)
    cfg = LSHPipelineConfig(**cfg_kw)
    return LSHSampledPipeline(
        jax.random.PRNGKey(seed),
        tokens if tokens is not None else _tokens(),
        feature_fn, query_fn, cfg, params=PARAMS)


def _live_sets(index, n_live):
    """Per-table {code: frozenset(slot ids)} over the live prefix."""
    out = []
    sc = np.asarray(index.sorted_codes)
    od = np.asarray(index.order)
    for t in range(sc.shape[0]):
        live_sc, live_od = sc[t, :n_live], od[t, :n_live]
        out.append({int(code): frozenset(
            live_od[live_sc == code].tolist())
            for code in np.unique(live_sc)})
    return out


def _assert_live_prefix(pipe):
    """Every table: live codes first, sentinel tail after, and the live
    prefix is a permutation of the live slot set."""
    sc = np.asarray(pipe.index.sorted_codes)
    od = np.asarray(pipe.index.order)
    live = set(np.flatnonzero(pipe._live_np).tolist())
    n_live = pipe.n_live
    assert len(live) == n_live
    for t in range(sc.shape[0]):
        dead = sc[t] == np.uint32(EMPTY_CODE)
        assert not dead[:n_live].any()
        assert dead[n_live:].all()
        assert set(od[t, :n_live].tolist()) == live


def _batch_value(tokens_2d):
    """Deterministic per-example value computable from either a batch's
    input tokens or a stored row's input slice."""
    return np.asarray(
        jnp.mean(EMBED[np.asarray(tokens_2d)], axis=(1, 2))) + 2.0


class TestAppendEvictParity:
    def test_append_equals_fresh_build_membership(self):
        pipe = _pipe(_tokens(n=48))
        extra = _tokens(n=16, seed=11)
        gids = pipe.append_rows(extra)
        assert gids.shape == (16,)
        assert pipe.n_live == 64
        _assert_live_prefix(pipe)
        # a fresh pipeline over the concatenated corpus shares the build
        # key (same projections) and assigns the same slots, so the
        # merged index must carry identical bucket membership.
        fresh = _pipe(np.concatenate([_tokens(n=48), extra]))
        assert _live_sets(pipe.index, 64) == _live_sets(fresh.index, 64)

    def test_evict_all_then_append_equals_fresh_build(self):
        """Evicting the whole window then appending a new corpus must
        match a fresh build over that corpus up to the tie-stable order
        contract: identical sorted live codes, identical per-(table,
        code) bucket membership."""
        pipe = _pipe(_tokens(n=32))
        pipe.evict_rows(np.arange(32, dtype=np.int64))
        assert pipe.n_live == 0
        fresh_tokens = _tokens(n=32, seed=23)
        pipe.append_rows(fresh_tokens)
        assert pipe.n_live == 32
        _assert_live_prefix(pipe)
        fresh = _pipe(fresh_tokens)
        np.testing.assert_array_equal(
            np.asarray(pipe.index.sorted_codes)[:, :32],
            np.asarray(fresh.index.sorted_codes)[:, :32])
        # evict-all freed slots 0..31 in order, so the append reuses
        # them in order — slot ids line up with the fresh build's.
        assert _live_sets(pipe.index, 32) == _live_sets(fresh.index, 32)
        np.testing.assert_array_equal(
            np.asarray(pipe.store)[:32], np.asarray(fresh.store)[:32])

    def test_append_then_evict_restores_bucket_membership(self):
        pipe = _pipe(_tokens(n=48))
        before = _live_sets(pipe.index, 48)
        gids = pipe.append_rows(_tokens(n=8, seed=13))
        pipe.evict_rows(gids)
        assert pipe.n_live == 48
        assert _live_sets(pipe.index, 48) == before
        _assert_live_prefix(pipe)

    def test_window_auto_evicts_oldest(self):
        pipe = _pipe(_tokens(n=24), window=24)
        pipe.append_rows(_tokens(n=6, seed=17))
        assert pipe.n_live == 24
        # the 6 oldest arrivals left the window (their slots are
        # reused by the appended rows, so check arrival order)
        assert pipe._arrival[pipe._live_np].min() == 6
        _assert_live_prefix(pipe)


class TestCapacity:
    def test_grow_doubles_capacity(self):
        pipe = _pipe(_tokens(n=60), min_capacity=64)
        assert pipe.capacity == 64
        pipe.append_rows(_tokens(n=8, seed=19))
        assert pipe.capacity == 128 and pipe.n_live == 68
        _assert_live_prefix(pipe)

    def test_compaction_shrinks_capacity(self):
        pipe = _pipe(_tokens(n=60), min_capacity=16)
        assert pipe.capacity == 64
        pipe.evict_rows(np.arange(52, dtype=np.int64))
        assert pipe.n_live == 8
        assert pipe.capacity == 16          # 8 <= 32//4 → halve to 16
        _assert_live_prefix(pipe)
        # draws still work after the slot remap
        b = pipe.next_batch()
        assert b["tokens"].shape == (8, SEQ - 1)


class TestUnbiasedOverWindow:
    @pytest.mark.statistical
    def test_weighted_mean_tracks_moving_window(self):
        """E[w·v] == mean(v) over the LIVE window as it slides: every
        1/(p·N) weight must use the live N.  Calibrated k=3/l=64 regime
        (see test_sharded_lgd.test_sharded_estimator_unbiased)."""
        pipe = _pipe(_tokens(n=64, seed=3), k=3, l=64, minibatch=16,
                     normalize_weights=False, window=64)
        for rnd in range(3):
            pipe.append_rows(_tokens(n=8, seed=100 + rnd))  # slides by 8
            live = np.flatnonzero(pipe._live_np)
            truth = float(np.mean(_batch_value(
                np.asarray(pipe.store)[live][:, :SEQ - 1])))
            es = []
            for _ in range(150):
                b = pipe.next_batch()
                w = np.asarray(b["loss_weights"], np.float64)
                es.append(np.mean(w * _batch_value(b["tokens"])))
            est = float(np.mean(es))
            assert abs(est - truth) / truth < 0.10, (rnd, est, truth)


class TestRestoreReplay:
    def test_restored_pipelines_draw_bit_identical_batches(self):
        """THE acceptance pin: restore-at-step-t is bit-deterministic
        for a streaming pipeline — the mutation log (JSON round-
        tripped, as checkpointed) replays to identical membership,
        identical index, identical batch draws."""
        import json

        pipe = _pipe(_tokens(n=48), window=48, refresh_every=3)
        for _ in range(2):
            pipe.next_batch()
        pipe.append_rows(_tokens(n=6, seed=31))
        for _ in range(3):
            pipe.next_batch()
        gids = pipe.append_rows(_tokens(n=2, seed=37))
        pipe.evict_rows(gids[:1])
        t = pipe._step
        log = json.loads(json.dumps(pipe.mutation_log()))
        live_before = pipe._live_np.copy()

        pipe.restore_at(t)
        np.testing.assert_array_equal(pipe._live_np, live_before)
        expect = [np.asarray(pipe.next_batch()["example_ids"])
                  for _ in range(4)]

        other = _pipe(_tokens(n=48), window=48, refresh_every=3)
        other.load_mutation_log(log)
        other.restore_at(t)
        np.testing.assert_array_equal(other._live_np, live_before)
        np.testing.assert_array_equal(
            np.asarray(other.index.sorted_codes),
            np.asarray(pipe.index.sorted_codes))
        for a in expect:
            np.testing.assert_array_equal(
                a, np.asarray(other.next_batch()["example_ids"]))

    def test_restore_is_idempotent_and_truncates_log(self):
        pipe = _pipe(_tokens(n=32), window=32)
        pipe._step = 5
        pipe.append_rows(_tokens(n=4, seed=41))
        pipe._step = 9
        pipe.append_rows(_tokens(n=4, seed=43))
        pipe.restore_at(7)                   # drops the step-9 append
        assert len(pipe.mutation_log()) == 1
        first = np.asarray(pipe.index.sorted_codes).copy()
        live = pipe._live_np.copy()
        pipe.restore_at(7)
        np.testing.assert_array_equal(
            first, np.asarray(pipe.index.sorted_codes))
        np.testing.assert_array_equal(live, pipe._live_np)

    def test_trainer_checkpoint_carries_mutation_log(self, tmp_path):
        """End-to-end through Trainer.save/restore: the append/evict
        log rides in the checkpoint manifest, and two trainers restored
        from the same checkpoint draw bit-identical batches."""
        from repro.models import ModelConfig, init_params
        from repro.optim import Adam
        from repro.train import Trainer, TrainerConfig

        cfg = ModelConfig(
            name="tiny", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
            d_ff=32, vocab=VOCAB, chunk=16, loss_chunk=16,
            dtype="float32", rope_theta=10000.0, lgd_enabled=True)
        params = init_params(KEY, cfg)

        def ffn(p, chunk):
            return jnp.mean(
                p["embed_group"]["embed"].astype(jnp.float32)[chunk],
                axis=1)

        def qfn(p):
            return jnp.mean(
                p["embed_group"]["lm_head"].astype(jnp.float32), axis=1)

        def mk():
            pipe = LSHSampledPipeline(
                jax.random.PRNGKey(3), _tokens(n=32), ffn, qfn,
                LSHPipelineConfig(k=4, l=6, minibatch=8,
                                  refresh_every=0, window=32),
                params=params)
            tr = Trainer(cfg, params, Adam(lr=1e-2),
                         tcfg=TrainerConfig(log_every=100,
                                            ckpt_dir=str(tmp_path)),
                         sampler=pipe)
            return tr, pipe

        tr, pipe = mk()
        tr.run(3)
        pipe.append_rows(_tokens(n=4, seed=47))
        tr.run(2)
        tr.save()
        tr.finalize()

        tr_a, pipe_a = mk()                  # auto-resumes the newest
        tr_b, pipe_b = mk()
        assert tr_a.step == tr_b.step == tr.step
        assert len(pipe_a.mutation_log()) == 1
        assert pipe_a.n_live == 32           # window held at 32
        for _ in range(3):
            np.testing.assert_array_equal(
                np.asarray(pipe_a.next_batch()["example_ids"]),
                np.asarray(pipe_b.next_batch()["example_ids"]))


class TestShardedStreaming:
    def _pipe(self, n=64, n_shards=2, window=None, **kw):
        for key, v in dict(k=4, l=8, minibatch=16,
                           refresh_every=0).items():
            kw.setdefault(key, v)
        cfg = LSHPipelineConfig(streaming=True, window=window, **kw)
        return ShardedLSHPipeline(
            jax.random.PRNGKey(7), _tokens(n=n), feature_fn, query_fn,
            cfg, n_shards=n_shards, params=PARAMS)

    def test_append_routes_to_least_live_shard(self):
        pipe = self._pipe()
        gids = pipe.append_rows(_tokens(n=4, seed=53))
        shards = np.asarray(gids) // _SHARD_STRIDE
        assert sorted(shards.tolist()) == [0, 0, 1, 1]
        assert [p.n_live for p in pipe.shards] == [34, 34]

    def test_evict_routes_by_stride(self):
        pipe = self._pipe()
        gids = pipe.append_rows(_tokens(n=4, seed=59))
        pipe.evict_rows(gids)
        assert [p.n_live for p in pipe.shards] == [32, 32]
        with pytest.raises(ValueError):
            pipe.evict_rows(np.asarray([10 * _SHARD_STRIDE]))

    def test_window_must_divide_by_shards(self):
        with pytest.raises(ValueError, match="window"):
            self._pipe(window=65)

    def test_mutation_log_restores_via_elastic_rebuild(self):
        pipe = self._pipe(window=64)
        for _ in range(2):
            pipe.next_batch()
        pipe.append_rows(_tokens(n=6, seed=61))
        step = pipe.shards[0]._step
        log = pipe.mutation_log()
        pipe.restore_at(step)                # canonical reference state
        expect = [np.asarray(pipe.next_batch()["example_ids"])
                  for _ in range(3)]
        cfg = LSHPipelineConfig(k=4, l=8, minibatch=16, refresh_every=0,
                                window=64)
        restored = rebuild_sharded_pipeline(
            jax.random.PRNGKey(7), _tokens(n=64), feature_fn, query_fn,
            cfg, step=step, n_shards=2, params=PARAMS, mutation_log=log)
        for a in expect:
            np.testing.assert_array_equal(
                a, np.asarray(restored.next_batch()["example_ids"]))

    def test_log_rejects_shard_count_mismatch(self):
        pipe = self._pipe()
        log = pipe.mutation_log()
        other = self._pipe(n_shards=4, n=64)
        with pytest.raises(ValueError, match="n_shards"):
            other.load_mutation_log(log)

    @pytest.mark.statistical
    def test_weight_composition_uses_live_counts(self):
        """The sharded composer must weight each shard's draws by its
        LIVE count — w·(n_live_s·S/total_live) — not the static row
        count it was built with.  After evicting from one shard only
        (24 vs 32 live), the composed estimate is compared against a
        first-principles reference: per-shard batches drawn from the
        SAME shard objects (same projections, so the finite-L
        calibration bias cancels) composed by hand with the live-count
        formula.  A composer still using static counts would inflate
        shard 0 by 32/24 and miss by ~13%, far outside the noise
        band.  Truth-relative accuracy is pinned only loosely: at
        per-shard N≈24-32 the analytic cp^K collision model carries a
        finite-L calibration offset that is unrelated to streaming
        (the streaming path is bit-identical to the dense sharded
        path over the same membership)."""
        pipe = self._pipe(k=3, l=64, normalize_weights=False)
        gid0 = [int(pipe.shards[0].example_offset + s)
                for s in np.flatnonzero(pipe.shards[0]._live_np)[:8]]
        pipe.evict_rows(np.asarray(gid0, np.int64))
        counts = [p.n_live for p in pipe.shards]
        assert counts == [24, 32]
        total = sum(counts)
        rows = np.concatenate([
            np.asarray(p.store)[np.flatnonzero(p._live_np)][:, :SEQ - 1]
            for p in pipe.shards])
        truth = float(np.mean(_batch_value(rows)))
        comp, ref = [], []
        for _ in range(200):
            b = pipe.next_batch()
            w = np.asarray(b["loss_weights"], np.float64)
            comp.append(np.mean(w * _batch_value(b["tokens"])))
            parts = []
            for p in pipe.shards:
                sb = p.next_batch()
                sw = np.asarray(sb["loss_weights"], np.float64)
                sw = sw * (p.n_live * pipe.n_shards / total)
                parts.append(sw * _batch_value(sb["tokens"]))
            ref.append(np.mean(np.concatenate(parts)))
        comp, ref = np.asarray(comp), np.asarray(ref)
        est, est_ref = float(comp.mean()), float(ref.mean())
        sem = float(np.hypot(comp.std(ddof=1), ref.std(ddof=1))
                    / np.sqrt(len(comp)))
        assert abs(est - est_ref) < 5.0 * sem, (est, est_ref, sem)
        # loose truth sanity: the finite-L calibration offset at this
        # toy geometry stays well under 30%.
        assert abs(est - truth) / truth < 0.30, (est, truth)


class TestDeprecationSurface:
    def test_tables_wrappers_warn_and_match_mutate_index(self):
        from repro.core import build_index, refresh_index, \
            refresh_index_delta

        p = LSHParams(k=4, l=6, dim=8, family="dense")
        x = jax.random.normal(jax.random.PRNGKey(2), (32, 8))
        with pytest.warns(DeprecationWarning, match="build_index"):
            old = build_index(jax.random.PRNGKey(3), x, p)
        new = mutate_index(
            None, IndexMutation("build", key=jax.random.PRNGKey(3),
                                x_aug=x), p)
        np.testing.assert_array_equal(np.asarray(old.sorted_codes),
                                      np.asarray(new.sorted_codes))
        x2 = x + 0.01
        with pytest.warns(DeprecationWarning, match="refresh_index"):
            oldr = refresh_index(None, old, x2, p)
        newr = mutate_index(new, IndexMutation("refresh", x_aug=x2), p)
        np.testing.assert_array_equal(np.asarray(oldr.order),
                                      np.asarray(newr.order))
        ids = jnp.arange(4, dtype=jnp.int32)
        codes = hash_points(x2[:4], old.projections, p)
        with pytest.warns(DeprecationWarning,
                          match="refresh_index_delta"):
            oldd = refresh_index_delta(old, ids, codes)
        newd = mutate_index(new, IndexMutation("delta", ids=ids,
                                               codes=codes))
        np.testing.assert_array_equal(np.asarray(oldd.order),
                                      np.asarray(newd.order))

    def test_legacy_closure_hooks_warn_at_construction(self):
        with pytest.warns(DeprecationWarning, match="legacy closure"):
            LSHSampledPipeline(
                jax.random.PRNGKey(5), _tokens(n=24),
                lambda t: jnp.mean(EMBED[t], axis=1),
                lambda: jnp.ones((DIM,)),
                LSHPipelineConfig(k=4, l=6, minibatch=8,
                                  refresh_every=0))

    def test_sharded_legacy_hooks_warn_once(self):
        with pytest.warns(DeprecationWarning, match="legacy closure") \
                as rec:
            ShardedLSHPipeline(
                jax.random.PRNGKey(5), _tokens(n=24),
                lambda t: jnp.mean(EMBED[t], axis=1),
                lambda: jnp.ones((DIM,)),
                LSHPipelineConfig(k=4, l=6, minibatch=8,
                                  refresh_every=0), n_shards=2)
        dep = [w for w in rec
               if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1

    def test_mutation_api_requires_streaming(self):
        pipe = LSHSampledPipeline(
            jax.random.PRNGKey(5), _tokens(n=24), feature_fn, query_fn,
            LSHPipelineConfig(k=4, l=6, minibatch=8, refresh_every=0),
            params=PARAMS)
        with pytest.raises(ValueError, match="streaming"):
            pipe.append_rows(_tokens(n=2))
        with pytest.raises(ValueError, match="streaming"):
            pipe.evict_rows(np.asarray([0]))

    def test_mutate_entry_point_routes_all_ops(self):
        pipe = _pipe(_tokens(n=32))
        gids = pipe.mutate(IndexMutation("append",
                                         tokens=_tokens(n=2, seed=71)))
        assert gids.shape == (2,)
        pipe.mutate(IndexMutation("evict", ids=gids))
        assert pipe.n_live == 32
        pipe.mutate(IndexMutation("refresh"))
        pipe.mutate(IndexMutation("delta"))
        pipe.mutate(IndexMutation("build"))
        _assert_live_prefix(pipe)
