"""Multi-host elastic LGD: membership protocol, shard adoption, reform.

Three layers, cheapest first:

* PROTOCOL — ``backoff_delay`` determinism, ``shard_adoption_map``,
  ``FileCoord`` barriers/KV, and ``ElasticCluster``'s ladder driven
  in-process over a shared-directory transport (threads as "hosts",
  injected clocks for staleness — no jax.distributed anywhere).
* PIPELINE — ``owned_shards`` partial ownership composes bitwise into
  the full-ownership batch stream, ``adopt_shards`` mid-incident
  equals full ownership bitwise (which carries the E[1/(pN)] = 1
  unbiasedness over from the proven full pipeline), and the
  reshard-vs-mutation-log guard.
* ACCEPTANCE — a real 2-process ``jax.distributed`` CPU run
  (``repro.dist.multihost_worker``) where one process is hard-killed
  mid-training: the survivor must walk healthy → missing-host-degraded
  → reformed, and its post-reform stream must be bit-identical to a
  fresh restore of the same checkpoint.
"""

import json
import os
import socket
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (
    CLUSTER_DEGRADED,
    CLUSTER_HEALTHY,
    CLUSTER_REFORMED,
    ClusterHealthMonitor,
    LSHPipelineConfig,
    ShardedLSHPipeline,
)
from repro.dist.multihost import (
    BarrierTimeout,
    ElasticCluster,
    FileCoord,
    HostLossDetected,
    MultihostConfig,
    backoff_delay,
    claim_reform_writer,
    shard_adoption_map,
)
from repro.testing import DropBarrier, FaultError, ProcKill
from repro.train import Trainer, TrainerConfig
from repro.train.elastic import rebuild_sharded_pipeline

KEY = jax.random.PRNGKey(0)
VOCAB, DIM = 50, 16
EMBED = jax.random.normal(jax.random.PRNGKey(1), (VOCAB, DIM))
PARAMS = {"embed": EMBED, "q": jnp.ones((DIM,))}


def _tokens(n=96, seq=9, seed=3):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (n, seq), 0, VOCAB),
        np.int32)


def feature_fn(params, chunk):
    return jnp.mean(params["embed"][chunk], axis=1)


def query_fn(params):
    return params["q"]


def _pipe(n_shards=2, owned_shards=None, tokens=None, **kw):
    kw.setdefault("refresh_every", 6)
    kw.setdefault("k", 4)
    kw.setdefault("l", 8)
    cfg = LSHPipelineConfig(minibatch=16, normalize_weights=False, **kw)
    return ShardedLSHPipeline(
        jax.random.PRNGKey(7),
        tokens if tokens is not None else _tokens(),
        feature_fn, query_fn, cfg, n_shards=n_shards, params=PARAMS,
        owned_shards=owned_shards)


# ---------------------------------------------------------------------------
# protocol primitives
# ---------------------------------------------------------------------------


class TestBackoffDelay:
    def test_deterministic_and_rank_free(self):
        # the jitter is a pure function of (tag, attempt) — every rank
        # computes the identical sleep, keeping retry attempts aligned
        # across the cluster with zero coordination.
        assert backoff_delay("sync", 3, 0.5) == backoff_delay(
            "sync", 3, 0.5)
        assert backoff_delay("sync", 1, 0.5) != backoff_delay(
            "other", 1, 0.5)

    def test_exponential_envelope(self):
        for a in (1, 2, 3, 4):
            d = backoff_delay("x", a, 0.25)
            lo = 0.25 * 2 ** (a - 1)
            assert lo <= d <= 1.5 * lo

    def test_degenerate_inputs(self):
        assert backoff_delay("x", 0, 1.0) == 0.0
        assert backoff_delay("x", 3, 0.0) == 0.0


class TestShardAdoptionMap:
    def test_identity_when_all_alive(self):
        assert shard_adoption_map(4, [0, 1, 2, 3]) == {
            0: 0, 1: 1, 2: 2, 3: 3}

    def test_orphans_round_robin_over_survivors(self):
        m = shard_adoption_map(4, [0, 2])
        assert m[0] == 0 and m[2] == 2
        assert sorted([m[1], m[3]]) == [0, 2]   # spread, not piled

    def test_deterministic_and_total(self):
        # every process must compute the identical map from the
        # identical membership view — including input-order invariance.
        assert shard_adoption_map(5, [3, 1]) == shard_adoption_map(
            5, [1, 3, 3])
        m = shard_adoption_map(5, [1, 3])
        assert set(m) == set(range(5))
        assert set(m.values()) <= {1, 3}

    def test_no_survivors_raises(self):
        with pytest.raises(ValueError):
            shard_adoption_map(4, [])


class TestFileCoord:
    def test_kv_roundtrip_and_prefix(self, tmp_path):
        c = FileCoord(str(tmp_path), rank=0, num_processes=1)
        c.kv_set("hb/g0/r0", "a")
        c.kv_set("hb/g0/r1", "b")
        c.kv_set("hb/g1/r0", "c")
        got = c.kv_dir("hb/g0/")
        assert got == {"hb/g0/r0": "a", "hb/g0/r1": "b"}
        c.kv_set("hb/g0/r0", "a2")          # overwrite
        assert c.kv_dir("hb/g0/")["hb/g0/r0"] == "a2"

    def test_barrier_passes_when_all_arrive(self, tmp_path):
        coords = [FileCoord(str(tmp_path), r, 3) for r in range(3)]
        errs = []

        def arrive(c):
            try:
                c.barrier("b1", timeout_s=5.0)
            except Exception as e:          # pragma: no cover
                errs.append(e)

        ts = [threading.Thread(target=arrive, args=(c,)) for c in coords]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert not errs

    def test_barrier_timeout_names_missing_ranks(self, tmp_path):
        c = FileCoord(str(tmp_path), rank=0, num_processes=2)
        with pytest.raises(BarrierTimeout, match=r"missing ranks \[1\]"):
            c.barrier("b2", timeout_s=0.2)

    def test_timed_out_barrier_is_poisoned_for_late_arrivals(
            self, tmp_path):
        # JaxCoord semantics: a timed-out barrier id is poisoned.  A
        # slow rank arriving LATE at the abandoned id must fail like
        # its peers did — passing instantly on their stale arrival
        # markers would leave it believing a sync succeeded that
        # everyone else gave up on (divergent membership views).
        a = FileCoord(str(tmp_path), rank=0, num_processes=2)
        b = FileCoord(str(tmp_path), rank=1, num_processes=2)
        with pytest.raises(BarrierTimeout, match="missing ranks"):
            a.barrier("p1", timeout_s=0.2)      # b never arrives
        with pytest.raises(BarrierTimeout, match="poisoned"):
            b.barrier("p1", timeout_s=0.2)      # late arrival fails
        # a fresh id (the retry's attempt suffix) is unaffected
        a2 = FileCoord(str(tmp_path), rank=0, num_processes=1)
        a2.barrier("p2", timeout_s=0.2)


# ---------------------------------------------------------------------------
# cluster ladder (in-process, FileCoord transport, injected clocks)
# ---------------------------------------------------------------------------


def _cluster(tmp_path, rank, nprocs, clock=None, sleep=None, **kw):
    kw.setdefault("barrier_timeout_s", 0.3)
    kw.setdefault("barrier_retries", 1)
    kw.setdefault("barrier_backoff_s", 0.0)
    kw.setdefault("heartbeat_timeout_s", 5.0)
    cfg = MultihostConfig(rank=rank, num_processes=nprocs, **kw)
    coord = FileCoord(str(tmp_path), rank, nprocs)
    return ElasticCluster(cfg, coord, clock=clock or time.time,
                          sleep=sleep or (lambda s: None))


class TestElasticCluster:
    def test_heartbeat_staleness_detects_dead(self, tmp_path):
        now = [100.0]
        a = _cluster(tmp_path, 0, 2, clock=lambda: now[0])
        b = _cluster(tmp_path, 1, 2, clock=lambda: now[0])
        a.heartbeat(1)
        b.heartbeat(1)
        assert a.dead_peers() == []
        now[0] += 10.0                      # b stops beating
        a.heartbeat(2)
        assert a.dead_peers() == [1]

    def test_sync_barrier_both_arrive(self, tmp_path):
        a = _cluster(tmp_path, 0, 2, barrier_timeout_s=5.0)
        b = _cluster(tmp_path, 1, 2, barrier_timeout_s=5.0)
        errs = []

        def go(c):
            try:
                c.sync_barrier("s5")
            except Exception as e:          # pragma: no cover
                errs.append(e)

        ts = [threading.Thread(target=go, args=(c,)) for c in (a, b)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert not errs

    def test_dropped_barrier_heals_within_retries(self, tmp_path):
        # DropBarrier fails rank 0's FIRST arrival; the retry (attempt
        # 2, same id on both ranks) must clear — a transient dropped
        # collective costs one barrier window, not the host.  Real
        # sleeps: the faulting rank must burn the window its peer is
        # stuck waiting in, or the attempt counters desync for good.
        a = _cluster(tmp_path, 0, 2, barrier_timeout_s=0.5,
                     sleep=time.sleep)
        b = _cluster(tmp_path, 1, 2, barrier_timeout_s=0.5,
                     sleep=time.sleep)
        fault = DropBarrier(match="s7", count=1)
        a.set_fault_injector(fault)
        errs = []

        def go(c):
            try:
                c.sync_barrier("s7")
            except Exception as e:          # pragma: no cover
                errs.append(e)

        ts = [threading.Thread(target=go, args=(c,)) for c in (a, b)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert not errs
        assert fault.fired == 1

    def test_exhausted_retries_raise_barrier_timeout(self, tmp_path):
        a = _cluster(tmp_path, 0, 2)
        with pytest.raises(BarrierTimeout, match="after 2 attempt"):
            a.sync_barrier("s9")            # rank 1 never arrives

    def test_classify_failure_walks_the_ladder(self, tmp_path):
        now = [100.0]
        a = _cluster(tmp_path, 0, 2, clock=lambda: now[0])
        b = _cluster(tmp_path, 1, 2, clock=lambda: now[0])
        a.heartbeat(1)
        b.heartbeat(1)
        a.heartbeat(2)          # a observes b's beat while it's fresh
        now[0] += 10.0                      # b dies
        a.heartbeat(15)
        with pytest.raises(BarrierTimeout):
            a.sync_barrier("s15")
        dead = a.classify_failure(15)
        assert dead == [1]
        # the stale beat IDENTIFIED the dead rank (not the
        # everyone-is-lost fallback)
        assert "stale heartbeat" in a.health.transitions[-1][3]
        assert a.alive == {0}
        assert a.generation == 1            # stale beats can't leak in
        assert a.health.state == CLUSTER_DEGRADED
        assert not a.intact
        # deterministic adoption: shard 1 lands on the only survivor
        assert a.shards_to_adopt(2) == [1]
        # a cluster of one barriers trivially from here on
        a.sync_barrier("s20")
        a.note_reformed(20, 1)
        assert a.health.state == CLUSTER_REFORMED
        assert a.summary()["reforms"] == 1

    def test_alive_but_stuck_peer_is_declared_lost(self, tmp_path):
        # every peer still beats, yet the barrier cannot clear past its
        # bounded retries: slow == failed (the ladder's grace is the
        # retry budget, not forever).
        a = _cluster(tmp_path, 0, 2)
        b = _cluster(tmp_path, 1, 2)
        a.heartbeat(5)
        b.heartbeat(5)                      # b beats but never arrives
        with pytest.raises(BarrierTimeout):
            a.sync_barrier("s5")
        dead = a.classify_failure(5)
        assert dead == [1]
        reason = a.health.transitions[-1][3]
        assert "retries exhausted" in reason

    def test_clock_skew_never_fakes_or_masks_a_host_loss(self, tmp_path):
        # b's wall clock runs 50s behind a's (NTP skew far beyond the
        # 5s heartbeat timeout) yet its beats keep ADVANCING — it must
        # stay alive: staleness is timed on the OBSERVER's clock from
        # the moment a NEW beat counter is seen, never by comparing
        # embedded peer wall timestamps.
        now_a = [100.0]
        now_b = [50.0]
        a = _cluster(tmp_path, 0, 2, clock=lambda: now_a[0])
        b = _cluster(tmp_path, 1, 2, clock=lambda: now_b[0])
        for step in range(1, 5):
            a.heartbeat(step)
            b.heartbeat(step)
            assert a.dead_peers() == []
            assert b.dead_peers() == []
            now_a[0] += 1.0
            now_b[0] += 1.0
        # ...and the skew does not MASK a real death either: b stops
        # beating, and 10 observer-seconds later it is stale.
        now_a[0] += 10.0
        now_b[0] += 10.0
        a.heartbeat(9)
        assert a.dead_peers() == [1]

    def test_post_incident_sync_cadence_is_generation_local(
            self, tmp_path):
        # Survivors unwind an incident at DIVERGENT trainer steps; the
        # sync boundaries and barrier names they compute afterwards
        # must come from generation-local counters (reset together by
        # classify_failure) or they time each other out at differently
        # named barriers.  Two incident walks with different local
        # step histories must emit the identical post-incident tag
        # sequence.
        def walk(root, pre_steps):
            now = [100.0]
            a = _cluster(root, 0, 2, clock=lambda: now[0],
                         sync_every=5)
            b = _cluster(root, 1, 2, clock=lambda: now[0],
                         sync_every=5)
            for s in range(1, pre_steps + 1):
                a.heartbeat(s)
                b.heartbeat(s)
            now[0] += 10.0                  # b dies
            a.heartbeat(pre_steps + 1)
            a.classify_failure(pre_steps + 1)
            tags = []
            for s in range(pre_steps + 2, pre_steps + 14):
                a.heartbeat(s)
                if a.at_sync_boundary():
                    tags.append((a.generation, a.next_sync_tag()))
            return tags

        t20 = walk(tmp_path / "w20", pre_steps=20)
        t23 = walk(tmp_path / "w23", pre_steps=23)
        assert t20 and t20 == t23

    def test_exchange_blobs_over_surviving_subset(self, tmp_path):
        # the degraded-mode collective: ranks {0, 2} of a 3-process
        # cluster (rank 1 dead) all-gather raw bytes through the KV
        # store + a barrier over the ALIVE SET ONLY — the dead rank
        # is neither waited on nor read back.
        a = _cluster(tmp_path, 0, 3, barrier_timeout_s=5.0)
        c = _cluster(tmp_path, 2, 3, barrier_timeout_s=5.0)
        for cl in (a, c):
            cl.alive = {0, 2}
            cl.generation = 1
        out, errs = {}, []

        def go(cl, payload):
            try:
                out[cl.rank] = cl.exchange_blobs("avg1", payload)
            except Exception as e:          # pragma: no cover
                errs.append(e)

        ts = [threading.Thread(target=go, args=(a, b"pay-0")),
              threading.Thread(target=go, args=(c, b"pay-2"))]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert not errs
        want = {0: b"pay-0", 2: b"pay-2"}
        assert out == {0: want, 2: want}

    def test_exchange_blobs_missing_survivor_times_out(self, tmp_path):
        # a survivor dying MID-EXCHANGE surfaces as BarrierTimeout —
        # the caller classifies it like any other loss; it never
        # silently averages over a partial set.
        a = _cluster(tmp_path, 0, 3)
        a.alive = {0, 2}
        with pytest.raises(BarrierTimeout):
            a.exchange_blobs("avg1", b"pay-0")

    def test_prockill_fires_on_cluster_step_event(self):
        fault = ProcKill(at_step=7)
        fired = []
        fault_os_exit = os._exit
        try:
            os._exit = lambda code: fired.append(code)
            fault.fire("cluster_step", step=6)
            assert fired == []
            fault.fire("cluster_step", step=7)
            assert fired == [ProcKill.EXIT_CODE]
        finally:
            os._exit = fault_os_exit


class TestClusterHealthMonitor:
    def test_ladder_and_audit_trail(self):
        m = ClusterHealthMonitor()
        assert m.state == CLUSTER_HEALTHY and not m.degraded
        m.note_host_lost(15, [1], "stale heartbeat")
        assert m.state == CLUSTER_DEGRADED and m.degraded
        assert m.lost_hosts == [1]
        m.note_adopted(15, 1, by_rank=0)
        m.note_reformed(20, 1)
        assert m.state == CLUSTER_REFORMED and m.reforms == 1
        s = m.summary()
        assert [t[1:3] for t in s["transitions"]] == [
            (CLUSTER_HEALTHY, CLUSTER_DEGRADED),
            (CLUSTER_DEGRADED, CLUSTER_REFORMED)]
        kinds = [e[1] for e in s["events"]]
        assert kinds == ["host-lost", "shard-adopted"]


class TestClaimReformWriter:
    def test_lowest_survivor_claims_and_peers_abstain(self, tmp_path):
        d = str(tmp_path / "ckpt")
        # min(alive) claims; a non-minimum rank never even writes
        assert claim_reform_writer(d, 1, rank=3, alive=[2, 3]) is False
        assert claim_reform_writer(d, 1, rank=2, alive=[2, 3]) is True
        # idempotent re-claim by the holder
        assert claim_reform_writer(d, 1, rank=2, alive=[2, 3]) is True

    def test_split_brain_tie_breaks_toward_lower_rank(self, tmp_path):
        # symmetric 2-process split-brain: each side declares the
        # other dead, so BOTH are min of their own alive set and both
        # reach the fence at the same generation — the lower rank must
        # win and the higher one must abstain, whichever order the
        # claims land in.
        d = str(tmp_path / "ckpt")
        assert claim_reform_writer(d, 1, rank=1, alive=[1]) is True
        assert claim_reform_writer(d, 1, rank=0, alive=[0]) is True
        assert claim_reform_writer(d, 1, rank=1, alive=[1]) is False

    def test_stale_generation_is_fenced_out(self, tmp_path):
        # a writer from an OLDER membership epoch (e.g. a partitioned
        # host that reformed against a stale view, then thawed) is
        # rejected by the newer claim.
        d = str(tmp_path / "ckpt")
        assert claim_reform_writer(d, 2, rank=1, alive=[1]) is True
        assert claim_reform_writer(d, 1, rank=0, alive=[0]) is False


class TestDegradedParamAverage:
    def test_survivor_subset_average_never_enters_backend_collective(
            self, tmp_path):
        """The HIGH-severity host-loss hang: with >= 3 processes the
        degraded survivors' sync barrier passes over the alive subset,
        but any full-world collective (process_allgather) would then
        hang forever on the dead rank.  The degraded branch must
        average over the KV transport only — this runs it with NO
        jax.distributed runtime at all, which doubles as proof that no
        backend collective is entered."""
        from repro.dist.multihost_worker import _average_params
        a = _cluster(tmp_path, 0, 3, barrier_timeout_s=5.0)
        c = _cluster(tmp_path, 2, 3, barrier_timeout_s=5.0)
        for cl in (a, c):
            cl.alive = {0, 2}               # rank 1 is dead
            cl.generation = 1
            cl.sync_seq = 4                 # same sync tag on both
            assert not cl.intact
        pa = {"w": jnp.arange(4.0), "b": {"x": jnp.ones((2, 3)) * 4.0}}
        pc = {"w": jnp.arange(4.0) * 3.0, "b": {"x": jnp.zeros((2, 3))}}
        out, errs = {}, []

        def go(cl, params):
            try:
                out[cl.rank] = _average_params(params, cl)
            except Exception as e:          # pragma: no cover
                errs.append(e)

        ts = [threading.Thread(target=go, args=(a, pa)),
              threading.Thread(target=go, args=(c, pc))]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert not errs
        for r in (0, 2):
            np.testing.assert_allclose(
                np.asarray(out[r]["w"]), np.arange(4.0) * 2.0)
            np.testing.assert_allclose(
                np.asarray(out[r]["b"]["x"]), np.full((2, 3), 2.0))


# ---------------------------------------------------------------------------
# partial ownership + adoption (the unbiasedness carrier)
# ---------------------------------------------------------------------------


def _cat(batches, key):
    return np.concatenate([np.asarray(b[key]) for b in batches])


class TestOwnedShards:
    def test_per_process_draws_compose_bitwise(self):
        """Process r's sub-batch (owned_shards=[r]) equals rows
        [r·m/S, (r+1)·m/S) of the single-controller global batch,
        bitwise, draw after draw — shard s's stream depends only on
        fold_in(key, s), never on which process owns it."""
        full = _pipe(n_shards=2)
        p0 = _pipe(n_shards=2, owned_shards=[0])
        p1 = _pipe(n_shards=2, owned_shards=[1])
        for _ in range(8):
            g = full.next_batch()
            parts = [p0.next_batch(), p1.next_batch()]
            for k in ("tokens", "targets", "loss_weights",
                      "example_ids"):
                np.testing.assert_array_equal(
                    np.asarray(g[k]), _cat(parts, k), err_msg=k)

    def test_partial_owner_validation(self):
        with pytest.raises(ValueError, match="owned_shards must not"):
            _pipe(n_shards=2, owned_shards=[])
        with pytest.raises(ValueError, match=r"not in \[0, 2\)"):
            _pipe(n_shards=2, owned_shards=[2])
        with pytest.raises(ValueError, match="normalize_weights"):
            ShardedLSHPipeline(
                jax.random.PRNGKey(7), _tokens(), feature_fn, query_fn,
                LSHPipelineConfig(k=4, l=8, minibatch=16,
                                  refresh_every=6),
                n_shards=2, params=PARAMS, owned_shards=[0])
        with pytest.raises(ValueError, match="streaming"):
            _pipe(n_shards=2, owned_shards=[0], window=48,
                  refresh_every=0)

    def test_fault_injector_uses_global_shard_ids(self):
        p1 = _pipe(n_shards=2, owned_shards=[1])
        with pytest.raises(ValueError, match="not owned here"):
            p1.set_fault_injector(DropBarrier(), shard=0)
        p1.set_fault_injector(DropBarrier(), shard=1)   # global id 1


class TestAdoptShards:
    def test_adoption_equals_full_ownership_bitwise(self):
        """Survivor flow: own shard 0, train k draws, adopt shard 1 at
        step k — every later draw must equal the full-ownership
        pipeline's, bitwise.  This transfers E[1/(pN)] = 1 to the
        adopted stream: the weights are byte-identical to the full
        pipeline's, whose unbiasedness is pinned by
        tests/test_sharded_lgd.py::test_sharded_estimator_unbiased."""
        k = 5
        full = _pipe(n_shards=2)
        part = _pipe(n_shards=2, owned_shards=[0])
        for _ in range(k):
            full.next_batch()
            part.next_batch()
        part.adopt_shards([1], step=k)
        assert part.owned == [0, 1]
        for _ in range(6):
            g = full.next_batch()
            a = part.next_batch()
            for key in ("tokens", "targets", "loss_weights",
                        "example_ids"):
                np.testing.assert_array_equal(
                    np.asarray(g[key]), np.asarray(a[key]), err_msg=key)

    @pytest.mark.statistical
    def test_adopted_weights_unbiased(self):
        """E[1/(pN)] = 1 on the adopted (full-ownership-by-one-owner)
        stream, measured in the calibrated k=3, l=64 regime.  The
        expectation in Theorem 1 is over HASH DRAWS, so the average
        runs over index builds (pipeline keys) as well as draws —
        any single build carries an O(10%) finite-L offset (the same
        calibration note as test_sharded_lgd)."""
        tokens = _tokens(n=96, seed=3)
        v = np.asarray(
            jnp.mean(EMBED[tokens[:, :-1]], axis=(1, 2))) + 2.0
        truth = float(v.mean())
        es, ws = [], []
        for seed in range(8):
            pipe = ShardedLSHPipeline(
                jax.random.PRNGKey(seed), tokens, feature_fn, query_fn,
                LSHPipelineConfig(k=3, l=64, minibatch=16,
                                  refresh_every=0,
                                  normalize_weights=False),
                n_shards=2, params=PARAMS, owned_shards=[0])
            pipe.adopt_shards([1], step=0)   # survivor owns everything
            for _ in range(30):
                b = pipe.next_batch()
                w = np.asarray(b["loss_weights"], np.float64)
                es.append(np.mean(w * v[np.asarray(b["example_ids"])]))
                ws.append(w.mean())
        assert abs(np.mean(es) - truth) / truth < 0.05, (
            np.mean(es), truth)
        assert abs(np.mean(ws) - 1.0) < 0.05, np.mean(ws)

    def test_adoption_errors(self):
        part = _pipe(n_shards=2, owned_shards=[0])
        with pytest.raises(ValueError, match="already owned"):
            part.adopt_shards([0], step=0)
        with pytest.raises(ValueError, match=r"not in \[0, 2\)"):
            part.adopt_shards([2], step=0)
        stream = _pipe(n_shards=2, window=48, refresh_every=0)
        with pytest.raises(ValueError, match="static corpus"):
            stream.adopt_shards([1], step=0)


class TestReshardMutationLog:
    def test_shard_count_mismatch_is_actionable(self):
        # checked EARLY — before any O(N) shard build — so the message
        # must carry the remediation (restore on the recorded count).
        with pytest.raises(ValueError, match="recorded shard layout"):
            rebuild_sharded_pipeline(
                jax.random.PRNGKey(7), _tokens(), feature_fn, query_fn,
                LSHPipelineConfig(k=4, l=8, minibatch=16,
                                  refresh_every=0,
                                  normalize_weights=False, window=48),
                step=4, n_shards=1,
                mutation_log={"n_shards": 2, "shards": [[], []]},
                params=PARAMS)

    def test_recorded_shard_count_replays(self):
        pipe = rebuild_sharded_pipeline(
            jax.random.PRNGKey(7), _tokens(), feature_fn, query_fn,
            LSHPipelineConfig(k=4, l=8, minibatch=16, refresh_every=0,
                              normalize_weights=False, window=48),
            step=0, n_shards=2,
            mutation_log={"n_shards": 2, "shards": [[], []]},
            params=PARAMS)
        assert pipe.n_shards == 2
        pipe.next_batch()                   # draws fine post-replay


# ---------------------------------------------------------------------------
# trainer step hook (the cluster attachment point)
# ---------------------------------------------------------------------------


def _lm_cfg():
    from repro.models import ModelConfig
    return ModelConfig(
        name="hook-test", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=VOCAB, chunk=8, loss_chunk=8,
        dtype="float32", rope_theta=10000.0, lgd_enabled=True)


class TestStepHook:
    def _stack(self, hook=None):
        from repro.data import lm_head_query_fn, mean_pool_feature_fn
        from repro.models import init_params
        from repro.optim import Adam
        cfg = _lm_cfg()
        params = init_params(KEY, cfg)
        pipe = ShardedLSHPipeline(
            jax.random.PRNGKey(7), _tokens(seq=9),
            mean_pool_feature_fn(cfg), lm_head_query_fn(),
            LSHPipelineConfig(k=4, l=8, minibatch=16, refresh_every=6,
                              normalize_weights=False),
            n_shards=2, params=params)
        tr = Trainer(cfg, params, Adam(lr=1e-2),
                     tcfg=TrainerConfig(log_every=100, step_hook=hook),
                     resume=False, sampler=pipe)
        return tr, pipe

    def test_hook_called_each_completed_step(self):
        seen = []
        tr, _ = self._stack(hook=lambda t: seen.append(t.step))
        tr.run(5)
        assert seen == [1, 2, 3, 4, 5]

    def test_raising_hook_unwinds_then_realigned_run_matches(self):
        """The incident pattern: a hook raise unwinds run() at a clean
        step boundary; after ``restore_at(step, rebuild=False)``
        realigns the prefetch-desynced counters, the continued run is
        bitwise the uninterrupted run."""
        tr_a, _ = self._stack()
        losses_a = tr_a.run(10)["losses"]

        def hook(t):
            if t.step == 6:
                raise HostLossDetected(6, [1])

        tr_b, pipe_b = self._stack(hook=hook)
        with pytest.raises(HostLossDetected):
            tr_b.run(10)
        assert tr_b.step == 6               # clean boundary
        # the unwound run() had already prefetched batch 6 — realign
        pipe_b.restore_at(tr_b.step, rebuild=False)
        tr_b.tcfg.step_hook = None
        losses_b = tr_b.run(4)["losses"]
        np.testing.assert_allclose(
            losses_a, list(losses_a[:6]) + losses_b, rtol=1e-6)


# ---------------------------------------------------------------------------
# acceptance: real 2-process jax.distributed run, one host killed
# ---------------------------------------------------------------------------


def _free_port():
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


class TestTwoProcessHostLoss:
    def test_survivor_reforms_bit_deterministically(self, tmp_path):
        """Kill rank 1 mid-training.  Rank 0 must: detect the loss and
        go missing-host-degraded; adopt shard 1 (weights stay the
        exact composed w = S/(p·N) form); reform from the newest
        VERIFIED checkpoint on n_shards=1; and draw a post-reform
        stream bit-identical to a fresh restore of that checkpoint in
        THIS process."""
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        ckpt_dir = str(tmp_path / "ckpt")
        coord = f"127.0.0.1:{_free_port()}"
        common = [sys.executable, "-m", "repro.dist.multihost_worker",
                  "--nprocs", "2", "--coordinator", coord,
                  "--ckpt-dir", ckpt_dir, "--steps", "20",
                  "--sync-every", "5", "--ckpt-every", "10",
                  "--degraded-steps", "4", "--post-steps", "6"]
        procs = [subprocess.Popen(
            common + ["--rank", str(r),
                      "--result", str(tmp_path / f"r{r}.json")]
            + (["--kill-at", "12"] if r == 1 else []),
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True) for r in (0, 1)]
        outs = [p.communicate(timeout=560)[0] for p in procs]
        assert procs[1].returncode == ProcKill.EXIT_CODE, outs[1]
        assert procs[0].returncode == 0, outs[0]

        r0 = json.load(open(tmp_path / "r0.json"))
        # the ladder, in order, with the audit trail
        assert r0["incident"]["dead"] == [1]
        assert r0["cluster"]["state"] == CLUSTER_REFORMED
        states = [t[2] for t in r0["cluster"]["transitions"]]
        assert states == [CLUSTER_DEGRADED, CLUSTER_REFORMED]
        assert ["shard 1 adopted by rank 0" in e[2]
                for e in r0["cluster"]["events"]].count(True) == 1
        # degraded draws: full-ownership composed weights, finite and
        # positive (their exact E[1/(pN)] = 1 law is pinned in-process
        # by TestAdoptShards, where averaging over builds is feasible)
        dm = np.asarray(r0["degraded_weight_means"])
        assert dm.shape == (4,) and np.isfinite(dm).all() and (
            dm > 0).all()
        # reform: newest verified checkpoint, surviving shard count,
        # and the survivor (lowest alive rank) holds the writer fence
        assert r0["reform_shards"] == 1
        assert r0["reform_writer"] is True
        assert r0["restore_step"] <= r0["incident"]["step"] + 4
        # bit-determinism across the incident: fresh restore replays
        # the survivor's post-reform stream exactly
        from repro.dist.multihost_worker import replay_post_reform
        rep = replay_post_reform(ckpt_dir, r0["restore_step"],
                                 len(r0["losses_post"]), n_shards=1)
        assert rep["digest"] == r0["post_digest"]
        np.testing.assert_allclose(rep["losses"], r0["losses_post"],
                                   rtol=0, atol=0)
