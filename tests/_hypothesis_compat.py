"""Minimal stand-in for ``hypothesis`` when it is not installed.

The container has no ``hypothesis`` wheel and the repo cannot add
dependencies, so property tests fall back to this shim: each strategy is
a deterministic example generator and ``@given`` expands the cross of a
fixed number of pseudo-random draws (seeded, so failures reproduce).
Only the API surface the test suite uses is implemented: ``given``,
``settings``, ``strategies.integers``, ``strategies.sampled_from``.
"""

from __future__ import annotations

import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # noqa: N801 — mimics the hypothesis module name
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        opts = list(options)
        return _Strategy(lambda rng: rng.choice(opts))


st = strategies


def settings(deadline=None, max_examples: int = 10, **_kw):
    def wrap(fn):
        fn._max_examples = max_examples
        return fn
    return wrap


def given(**strats):
    def wrap(fn):
        # No functools.wraps: pytest follows __wrapped__ when inspecting
        # signatures and would treat the drawn parameters as fixtures.
        def run(*args, **kwargs):
            # @settings sits ABOVE @given, so it stamps the attribute on
            # `run` (read at call time); the inner-fn getattr covers the
            # reversed decorator order.
            n = getattr(run, "_max_examples",
                        getattr(fn, "_max_examples", 10))
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run._max_examples = getattr(fn, "_max_examples", 10)
        return run
    return wrap
