"""Pluggable LSH-family subsystem tests.

Three pillars:

1. SRP PARITY PIN — the family refactor must be behaviour-preserving:
   ``sample``, ``sample_gather_batched`` and ``next_batch_multi`` (at
   multiprobe 0 and 2, plus the quadratic family) are compared against
   ``tests/golden/srp_parity.npz``, generated from the PRE-refactor
   stack (regenerate with ``PYTHONPATH=src python tests/_parity_cases.py``
   — only ever from a commit whose behaviour is the contract).

2. STATISTICAL PROPERTIES per family — empirical collision frequency
   vs the closed-form ``collision_prob`` (chi-square over L tables),
   monotonicity of the MIPS law in the RAW inner product ⟨q, x⟩, and
   E[1/(p·N)] = 1 over index builds for the MIPS family.

3. MIPS ESTIMATOR — ``exact_inclusion_probability`` is family-generic,
   and the importance-weighted minibatch gradient matches the
   full-batch gradient in expectation on an UN-normalised heavy-tailed
   regression (the workload the asymmetric family exists for).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _parity_cases as pc
from _stats import chi2_cap
import repro.core.estimator as E
import repro.core.sampler as S
from repro.core import (
    LGDProblem,
    LSHParams,
    IndexMutation,
    mutate_index,
    compute_codes,
    exact_inclusion_probability,
    full_loss,
    get_family,
    init,
    lgd_step,
    make_projections,
    regression_query,
)
from repro.core.families import FAMILIES, normalize_rows
from repro.core.lgd import preprocess_regression_mips, squared_loss_grad
from repro.optim import SGD

KEY = jax.random.PRNGKey(0)


def _build_index(key, x_aug, p, **kw):
    return mutate_index(
        None, IndexMutation("build", key=key, x_aug=x_aug), p, **kw)


# ---------------------------------------------------------------------------
# 1. SRP parity pin
# ---------------------------------------------------------------------------

class TestSRPParity:
    """The refactored stack must reproduce the pre-family golden outputs."""

    @pytest.fixture(scope="class")
    def golden(self):
        assert os.path.exists(pc.GOLDEN), (
            "golden parity file missing; regenerate ONLY from a commit "
            "whose behaviour is the contract: "
            "PYTHONPATH=src python tests/_parity_cases.py")
        return dict(np.load(pc.GOLDEN))

    def _check(self, golden, fresh, prefix):
        for k, f in fresh.items():
            g = golden[f"{prefix}_{k}"]
            f = np.asarray(f)
            assert g.shape == f.shape, (k, g.shape, f.shape)
            if g.dtype.kind in "iub":
                np.testing.assert_array_equal(g, f, err_msg=k)
            else:
                # float outputs: tight tolerance (golden may come from a
                # different host than CI)
                np.testing.assert_allclose(g, f, rtol=1e-5, atol=1e-7,
                                           err_msg=k)

    @pytest.mark.parametrize("mp", [0, 2])
    def test_sample_pinned(self, golden, mp):
        self._check(golden, pc.sample_case(mp), f"sample_mp{mp}")

    @pytest.mark.parametrize("mp", [0, 2])
    def test_quadratic_sample_pinned(self, golden, mp):
        self._check(golden, pc.quadratic_sample_case(mp), f"quad_mp{mp}")

    @pytest.mark.parametrize("mp", [0, 2])
    def test_sample_gather_batched_pinned(self, golden, mp):
        self._check(golden, pc.gather_case(mp), f"gather_mp{mp}")

    @pytest.mark.parametrize("mp", [0, 2])
    def test_pipeline_next_batch_multi_pinned(self, golden, mp):
        self._check(golden, pc.pipeline_case(mp), f"pipe_mp{mp}")


# ---------------------------------------------------------------------------
# 2. family contract + statistical properties
# ---------------------------------------------------------------------------

class TestFamilyContract:
    def test_registry(self):
        assert get_family("srp") is get_family("dense")
        assert get_family("mips").asymmetric
        assert not get_family("dense").asymmetric
        assert get_family("quadratic").proj_kind == "quadratic"
        with pytest.raises(ValueError, match="unknown LSH family"):
            get_family("minhash")
        with pytest.raises(ValueError, match="unknown LSH family"):
            LSHParams(k=4, l=2, dim=8, family="minhash")

    def test_aug_dim_and_code_width(self):
        for name, fam in FAMILIES.items():
            # banded families widen the packed code by their band bits
            # (tag above the K sign bits) and add a band coordinate
            band_bits = (fam.num_bands() - 1).bit_length()
            assert fam.code_width(7) == 7 + band_bits, name
            if fam.num_bands() > 1:
                assert fam.aug_dim(10) == 12, name
            elif fam.asymmetric:
                assert fam.aug_dim(10) == 11, name
            else:
                assert fam.aug_dim(10) == 10, name

    def test_mips_augmented_geometry(self):
        """Data rows unit-norm; query unit-norm with zero tail; the
        Simple-LSH identity <S(x), Q(q)> = <x, q>/(M |q|)."""
        fam = get_family("mips")
        x = 5.0 * jax.random.normal(jax.random.PRNGKey(1), (64, 7))
        q = jax.random.normal(jax.random.PRNGKey(2), (7,))
        xa = fam.augment_data(x)
        qa = fam.augment_query(q)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(xa), axis=-1), 1.0, atol=1e-5)
        assert float(qa[-1]) == 0.0
        np.testing.assert_allclose(float(jnp.linalg.norm(qa)), 1.0,
                                   atol=1e-6)
        m = float(fam.data_scale(x))
        ip = np.asarray(jnp.sum(xa * qa, axis=-1))
        expected = np.asarray(x @ q) / (m * float(jnp.linalg.norm(q)))
        np.testing.assert_allclose(ip, expected, rtol=1e-4, atol=1e-6)

    def test_mips_scale_pinning(self):
        """Subset re-augmentation at the pinned scale matches the full
        build's rows — the delta-refresh consistency contract."""
        fam = get_family("mips")
        x = jax.random.normal(jax.random.PRNGKey(3), (32, 5)) * 3.0
        scale = fam.data_scale(x)
        full = fam.augment_data(x, scale=scale)
        sub = fam.augment_data(x[10:20], scale=scale)
        np.testing.assert_array_equal(np.asarray(full[10:20]),
                                      np.asarray(sub))

    def test_mips_overscale_rows_clamp_not_nan(self):
        """Rows whose norm exceeds the pinned M (drifted features) clamp
        the tail coordinate at 0 — finite, and cp stays exact."""
        fam = get_family("mips")
        x = jnp.ones((4, 3))
        big = fam.augment_data(10.0 * x, scale=jnp.asarray(1.0))
        assert bool(jnp.all(jnp.isfinite(big)))
        np.testing.assert_allclose(np.asarray(big[:, -1]), 0.0)

    def test_mips_collision_prob_monotone_in_inner_product(self):
        """cp must be strictly increasing in the RAW inner product
        <q, x> — the property that lets un-normalised corpora sample the
        paper's weight directly."""
        fam = get_family("mips")
        d = 6
        q = jax.random.normal(jax.random.PRNGKey(4), (d,))
        # points with very different norms AND angles
        x = jax.random.normal(jax.random.PRNGKey(5), (256, d)) * \
            jnp.exp(jax.random.normal(jax.random.PRNGKey(6), (256, 1)))
        xa = fam.augment_data(x)
        qa = fam.augment_query(q)
        cp = np.asarray(fam.collision_prob(xa, qa))
        ip = np.asarray(x @ q)
        order = np.argsort(ip)
        assert np.all(np.diff(cp[order]) >= -1e-6), \
            "cp not monotone in <q, x>"
        # strictly increasing across the spread (not constant)
        assert cp[order][-1] - cp[order][0] > 0.1

    def test_probe_class_probs_default(self):
        fam = get_family("dense")
        cp = jnp.asarray(0.7)
        rs = jnp.asarray([0.0, 1.0, 2.0])
        got = np.asarray(fam.probe_class_probs(cp, 5, rs))
        want = 0.7 ** (5 - np.array([0, 1, 2.0])) * 0.3 ** np.array(
            [0, 1, 2.0])
        np.testing.assert_allclose(got, want, rtol=1e-6)


def _code_match_freq(fam_name, x_aug, q_aug, k, l, key):
    """Fraction of the L tables where each point's K-bit code equals the
    query's — the empirical per-table collision frequency."""
    p = LSHParams(k=k, l=l, dim=x_aug.shape[-1], family=fam_name)
    proj = make_projections(key, p)
    quad = get_family(fam_name).proj_kind == "quadratic"
    cx = compute_codes(x_aug, proj, k=k, l=l, quadratic=quad)   # (n, L)
    cq = compute_codes(q_aug, proj, k=k, l=l, quadratic=quad)   # (L,)
    return np.asarray(jnp.mean((cx == cq[None]).astype(jnp.float32),
                               axis=1))


class TestCollisionLaw:
    """Empirical per-table collision frequency vs the closed form, per
    family: chi-square over points with L tables as Bernoulli trials."""

    @pytest.mark.statistical
    @pytest.mark.parametrize("fam_name", ["dense", "quadratic", "mips"])
    def test_empirical_matches_closed_form(self, fam_name):
        fam = get_family(fam_name)
        k, l, n, d = 3, 1500, 24, 8
        kx, kq, kp = jax.random.split(jax.random.PRNGKey(7), 3)
        x = jax.random.normal(kx, (n, d))
        if fam_name == "mips":
            x = x * jnp.exp(jax.random.normal(jax.random.fold_in(kx, 1),
                                              (n, 1)))   # spread norms
        q = jax.random.normal(kq, (d,))
        x_aug = fam.augment_data(x)
        q_aug = fam.augment_query(q)
        cp = np.asarray(fam.collision_prob(x_aug, q_aug))
        expect = cp ** k                                   # full-code match
        freq = _code_match_freq(fam_name, x_aug, q_aug, k, l, kp)
        # chi-square: sum over points of (O-E)^2/Var, Var = L p(1-p).
        # keep cells with non-degenerate expectation
        keep = (expect > 0.005) & (expect < 0.995)
        assert keep.sum() >= 10, "collision-law regime degenerate"
        obs, exp = freq[keep] * l, expect[keep] * l
        chi2 = float(np.sum((obs - exp) ** 2 /
                            (l * expect[keep] * (1 - expect[keep]))))
        ncell = int(keep.sum())
        assert chi2 < chi2_cap(ncell), (
            f"{fam_name}: chi2 {chi2:.1f} vs {ncell} cells — empirical "
            "collision frequency disagrees with collision_prob")

    @pytest.mark.statistical
    def test_mips_unit_inverse_probability_over_builds(self):
        """E[1/(p·N)] = 1 for MIPS Algorithm-1 samples, expectation over
        index builds AND draws (the unbiasedness identity the importance
        weights rest on).

        CALIBRATION: the populated-bucket regime (moderate norm spread,
        small K, every table bucket non-empty so l == 1) — where the
        paper's (1-q)^(l-1) miss factor is exact.  Extreme norm tails
        concentrate Simple-LSH-augmented points near the pole
        [0,..,0,1]; probed buckets are then often empty with CORRELATED
        occupancy and the independence approximation behind the miss
        factor degrades (measured: E[1/(pN)] ~ 0.55 at exp(0.8·N) log-
        normal norms) — the known Simple-LSH boundary, documented in
        docs/ARCHITECTURE.md.  The ``mips_banded`` family closes that
        boundary (tests/test_norm_ranging.py pins both sides)."""
        n, d = 400, 6
        kx, kn, kq = jax.random.split(jax.random.PRNGKey(8), 3)
        dirs = normalize_rows(jax.random.normal(kx, (n, d)))
        norms = jax.random.uniform(kn, (n, 1), minval=0.5,
                                   maxval=1.0) * 4.0
        x = dirs * norms               # un-normalised, 2x norm spread
        fam = get_family("mips")
        x_aug = fam.augment_data(x)
        q = fam.augment_query(jax.random.normal(kq, (d,)))
        p = LSHParams(k=3, l=24, dim=d + 1, family="mips")

        def per_build(key):
            kb, ks = jax.random.split(key)
            index = _build_index(kb, x_aug, p)
            res = S.sample(ks, index, x_aug, q, p, m=1000)
            return (jnp.mean(1.0 / (res.probs * n)),
                    jnp.mean(res.n_probes.astype(jnp.float32)))

        keys = jax.random.split(jax.random.PRNGKey(11), 24)
        means, mean_l = jax.lax.map(per_build, keys)
        means = np.asarray(means)
        # regime guard: buckets essentially always populated (the
        # exactness precondition; rare per-build empties are fine)
        assert float(np.mean(np.asarray(mean_l))) < 1.05, "regime drifted"
        grand = float(means.mean())
        # per-build sd ~0.20 -> mean_band(0.20, 24) ~ 0.12 (3-sigma)
        assert abs(grand - 1.0) < 0.12, (
            f"E[1/(pN)] = {grand:.3f} != 1 for MIPS (per-build sd "
            f"{means.std():.3f})")


# ---------------------------------------------------------------------------
# 3. MIPS estimator: family-generic inclusion probs + unbiasedness
# ---------------------------------------------------------------------------

class TestMIPSEstimator:
    def test_exact_inclusion_probability_family_generic(self):
        """For every family, single-probe inclusion = cp^K, multiprobe =
        sum of the family's probe-class probabilities — evaluated via
        the family's OWN closed form."""
        d = 6
        x = jax.random.normal(jax.random.PRNGKey(12), (40, d)) * 2.0
        q = jax.random.normal(jax.random.PRNGKey(13), (d,))
        for fam_name in ("dense", "quadratic", "mips"):
            fam = get_family(fam_name)
            xa, qa = fam.augment_data(x), fam.augment_query(q)
            p = LSHParams(k=5, l=4, dim=xa.shape[-1], family=fam_name)
            cp = np.asarray(fam.collision_prob(xa, qa))
            got = np.asarray(exact_inclusion_probability(xa, qa, p))
            np.testing.assert_allclose(got, cp ** 5, rtol=1e-5,
                                       err_msg=fam_name)
            got2 = np.asarray(
                exact_inclusion_probability(xa, qa, p, multiprobe=2))
            want2 = cp ** 5 + 2 * cp ** 4 * (1 - cp)   # masks r = 0,1,1
            np.testing.assert_allclose(got2, want2, rtol=1e-5,
                                       err_msg=fam_name)

    @pytest.mark.statistical
    def test_mips_estimator_unbiased_unnormalized_heavy_tail(self):
        """Importance-weighted minibatch gradient == full-batch gradient
        in expectation on an UN-normalised heavy-tailed regression — the
        no-normalisation workload the MIPS family unlocks."""
        n, d = 400, 8
        kx, kt, kn, knn = jax.random.split(jax.random.PRNGKey(14), 4)
        # un-normalised rows (2x norm spread) + one-sided heavy-tailed
        # residuals — the calibrated populated-bucket regime (see
        # test_mips_unit_inverse_probability_over_builds)
        dirs = normalize_rows(jax.random.normal(kx, (n, d)))
        x = dirs * (jax.random.uniform(kn, (n, 1), minval=0.5,
                                       maxval=1.0) * 3.0)
        y = x @ jax.random.normal(kt, (d,)) - \
            0.5 * jax.random.pareto(knn, 2.5, (n,))
        fam = get_family("mips")
        xt, yt, x_aug = preprocess_regression_mips(x, y, fam)
        p = LSHParams(k=3, l=16, dim=d + 2, family="mips")
        theta = 0.1 * jax.random.normal(jax.random.PRNGKey(15), (d,))
        q = fam.augment_query(regression_query(theta))
        full_grad = jnp.mean(
            jax.vmap(lambda a, b: squared_loss_grad(theta, a, b))(xt, yt),
            0)

        def per_build(key):
            kb, ks = jax.random.split(key)
            index = _build_index(kb, x_aug, p)
            res = S.sample(ks, index, x_aug, q, p, m=400)
            return E.lgd_gradient(squared_loss_grad, theta,
                                  xt[res.indices], yt[res.indices], res, n)

        keys = jax.random.split(jax.random.PRNGKey(16), 30)
        grand = jnp.mean(jax.lax.map(per_build, keys), axis=0)
        rel = float(jnp.linalg.norm(grand - full_grad) /
                    jnp.linalg.norm(full_grad))
        assert rel < 0.25, f"MIPS estimator biased: rel err {rel}"

    @pytest.mark.statistical
    def test_mips_lgd_training_decreases_loss(self):
        """End-to-end: MIPS LGD trains on un-normalised data."""
        n, d = 1000, 10
        kx, ky, kt = jax.random.split(jax.random.PRNGKey(17), 3)
        x = jax.random.normal(kx, (n, d)) * \
            (1.0 + jax.random.pareto(kt, 3.0, (n, 1)))
        y = x @ jax.random.normal(ky, (d,)) + \
            0.1 * jax.random.normal(jax.random.fold_in(ky, 1), (n,))
        prob = LGDProblem(
            kind="regression",
            lsh=LSHParams(k=5, l=20, dim=d + 2, family="mips"),
            minibatch=8, p_floor=1e-7)
        opt = SGD(lr=1e-3)
        state, xt, yt, x_aug = init(jax.random.PRNGKey(18), prob, x, y,
                                    opt)
        loss0 = float(full_loss(state.theta, xt, yt, prob))
        s = state
        for i in range(300):
            s, m = lgd_step(jax.random.fold_in(KEY, 7_000 + i), s, xt, yt,
                            x_aug, prob, opt)
        loss1 = float(full_loss(s.theta, xt, yt, prob))
        assert np.isfinite(loss1) and loss1 < 0.5 * loss0, (loss0, loss1)


# ---------------------------------------------------------------------------
# pipeline-level family plumbing
# ---------------------------------------------------------------------------

class TestPipelineFamilies:
    def _pipe(self, family, **cfg_kw):
        from repro.data import LSHPipelineConfig, LSHSampledPipeline

        kt, kq, kp = jax.random.split(jax.random.PRNGKey(19), 3)
        tokens = np.asarray(jax.random.randint(kt, (96, 17), 0, 50,
                                               dtype=jnp.int32))
        qfix = jax.random.normal(kq, (4,))

        def feat(_p, tokens):
            t = tokens.astype(jnp.float32)
            base = jnp.stack([jnp.mean(t, 1), jnp.std(t, 1),
                              jnp.mean(jnp.sin(t), 1),
                              jnp.mean(jnp.cos(t), 1)], -1)
            return base * (1.0 + jnp.mean(t, 1)[:, None])  # spread norms

        from repro.data import LSHPipelineConfig as C
        return LSHSampledPipeline(
            kp, tokens, feat, lambda _p: qfix,
            C(k=5, l=6, minibatch=8, refresh_every=0, family=family,
              **cfg_kw), params=())

    def test_mips_pipeline_dims_and_weights(self):
        pipe = self._pipe("mips")
        assert pipe.lsh.dim == pipe.features.shape[-1]
        assert pipe.lsh.family == "mips"
        assert pipe._feat_scale is not None
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(pipe.features), axis=-1), 1.0,
            atol=1e-5)
        b = pipe.next_batch()
        assert np.isfinite(np.asarray(b["loss_weights"])).all()
        np.testing.assert_allclose(
            float(np.mean(np.asarray(b["loss_weights"]))), 1.0, rtol=1e-5)

    def test_srp_pipeline_unchanged_lsh_family(self):
        pipe = self._pipe("srp")
        assert pipe.lsh.family == "dense"
        assert pipe._feat_scale is None

    def test_mips_delta_refresh_reuses_scale(self):
        pipe = self._pipe("mips", refresh_mode="delta", drift_frac=0.0)
        scale0 = float(pipe._feat_scale)
        for _ in range(4):
            pipe.next_batch()
        pipe.refresh()                    # delta: pinned scale
        assert float(pipe._feat_scale) == scale0
        pipe.refresh(full=True)           # full: re-derives (same params
        assert float(pipe._feat_scale) == scale0   # -> same features)

    def test_unknown_family_rejected(self):
        from repro.data import LSHPipelineConfig
        with pytest.raises(ValueError, match="unknown LSH family"):
            LSHPipelineConfig(family="minhash")

    def test_mips_restore_determinism(self):
        """Two MIPS pipelines restored at the same step draw identical
        batches — the family does not break the restore contract."""
        a = self._pipe("mips")
        b = self._pipe("mips")
        for _ in range(3):
            a.next_batch()
        a.restore_at(1)
        b.restore_at(1)
        ba, bb = a.next_batch(), b.next_batch()
        for k in ba:
            np.testing.assert_array_equal(np.asarray(ba[k]),
                                          np.asarray(bb[k]), err_msg=k)


def test_normalize_rows_guard():
    z = jnp.zeros((2, 3))
    out = np.asarray(normalize_rows(z))
    assert np.isfinite(out).all()
