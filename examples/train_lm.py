"""End-to-end driver: train a decoder LM with the LGD-sampled data pipeline.

Presets:
  demo  (default)  ~3M params, a few hundred steps on CPU in minutes —
                   compares the LSH-sampled pipeline against uniform.
  100m             ~100M-param config (d=768, 12L) for a real host/TPU;
                   identical code path, bigger numbers.

Sampler (``--sampler {uniform,lgd}``):
  uniform          i.i.d. uniform batches (the SGD baseline).
  lgd              the paper's LSH-sampled adaptive batches: example
                   features (pooled last-layer reps) are hashed into
                   per-shard LSH indexes; each step queries with the
                   output-layer direction and draws Algorithm-1 samples,
                   de-biased by 1/(p_i N) importance weights inside the
                   jitted loss.  Batches are DEVICE-RESIDENT: the token
                   store is uploaded once and each draw is a single
                   compiled sample->gather->weight call — watch the
                   ``sampler`` fraction in the progress line sit near
                   zero.  The periodic index refresh runs on a host
                   thread, double-buffered, so re-hashing overlaps
                   device compute.

Refresh mode (``--refresh-mode {full,delta}``):
  full             re-embed + re-hash the whole corpus every
                   ``refresh_every`` steps.
  delta            re-embed/re-hash only the examples VISITED since the
                   last refresh plus a drift-sampled remainder, merged
                   into the sorted index through the previous order —
                   refresh cost scales with drift, not corpus size
                   (benchmarks/run.py tab_refresh_cost quantifies it).

Sharded-index contract (``--shards S``): the corpus is split into S
contiguous equal shards (one per data-parallel group at scale — S
defaults to 1 on a single host); each shard owns its own LSH index and
contributes minibatch/S samples per global batch, weighted so the batch
mean equals the average of per-shard unbiased estimates (see
``repro/data/lsh_pipeline.py``).  On an elastic restart with a different
S, ``Trainer.restore`` rebuilds all per-shard indexes deterministically
from the restored params (``repro/train/elastic.py``).

Optimizer (``--optimizer {sgd,momentum,adagrad,adam}``): LGD only
replaces the gradient ESTIMATOR — the 1/(p·N) weights are applied
inside the jitted loss, so any update rule's moments accumulate the
unbiased estimate unchanged (gated end-to-end by
``benchmarks/run.py tab_optimizers``).

Multi-probe (``--multiprobe K``): walk K extra Hamming-ball probe
codes per table before giving up on it — empty buckets resolve to
probability-corrected near-bucket samples instead of uniform
fallbacks (watch the ``fallback`` column drop on skewed corpora).

LSH family (``--family {srp,mips}``): ``srp`` row-normalises the
pooled feature embeddings so cosine proxies the inner product (the
paper's BERT recipe); ``mips`` hashes them UN-normalised through the
asymmetric Simple-LSH augmentation (``repro/core/families/mips.py``)
— collision probability monotone in the raw inner product, so feature
norms carry sampling signal.  Same fused kernels either way.

Head (``--head {full,lsh}``): ``full`` pays the O(V·d)-per-token
softmax normaliser; ``lsh`` trains through the LSH-SAMPLED head
(``repro/models/sampled_softmax.py``): a MIPS index over the lm_head
rows is probed with each token's hidden state, the normaliser is
estimated from ``n_samples`` Algorithm-1 negatives with exact
inclusion probabilities (E[Zhat] = Z), and the index delta-refreshes
every ``refresh_every`` OPTIMIZER steps as the head trains — the
index-over-params twin of the data pipeline.  The eval line always
uses the exact full-vocab loss, so you can watch sampled training
track it.  ``--head lsh`` composes with ``--sampler uniform`` (the
LGD data sampler owns the batch stream in lgd mode).

Run:  PYTHONPATH=src python examples/train_lm.py [--preset demo]
          [--steps 200] [--sampler lgd] [--shards 2] [--ckpt /tmp/lm_ckpt]
          [--optimizer adam] [--multiprobe 2] [--family mips]
          [--head lsh]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import (
    LSHPipelineConfig, ShardedLSHPipeline, lm_head_query_fn,
    make_token_corpus, mean_pool_feature_fn, uniform_batches,
)
from repro.models import (
    LMHeadIndex, ModelConfig, SampledSoftmaxConfig, init_params, loss,
    make_sampled_loss,
)
from repro.optim import make_optimizer, schedules
from repro.train import Trainer, TrainerConfig

PRESETS = {
    "demo": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                 d_ff=512, vocab=1024, seq=64, corpus=4096, batch=16),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab=32768, seq=512, corpus=100_000,
                 batch=32),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--sampler", default="lgd", choices=["uniform", "lgd"],
                    help="uniform batches vs LSH-sampled LGD batches")
    ap.add_argument("--uniform", action="store_true",
                    help="deprecated alias for --sampler uniform")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard-by-example LSH index count (one per DP "
                         "group at scale); must divide the batch size")
    ap.add_argument("--refresh-mode", default="full",
                    choices=["full", "delta"],
                    help="full: re-hash the whole corpus each refresh; "
                         "delta: only visited + drift-sampled rows")
    ap.add_argument("--optimizer", default="adam",
                    choices=["sgd", "momentum", "adagrad", "adam"],
                    help="update rule; the LGD sampler composes with any "
                         "of them (importance weights enter the loss, so "
                         "moments accumulate the unbiased estimate)")
    ap.add_argument("--multiprobe", type=int, default=0,
                    help="extra Hamming-ball probe codes per table (0 = "
                         "single-probe): empty buckets resolve to "
                         "probability-corrected near-bucket samples "
                         "instead of uniform fallbacks")
    ap.add_argument("--family", default="srp", choices=["srp", "mips", "mips_banded"],
                    help="LSH family: srp = row-normalised features + "
                         "cosine SimHash; mips = un-normalised features "
                         "through the asymmetric Simple-LSH augmentation; "
                         "mips_banded = norm-ranged Simple-LSH (exact "
                         "weights at heavy-tailed feature norms)")
    ap.add_argument("--head", default="full", choices=["full", "lsh"],
                    help="full: exact O(V) softmax normaliser; lsh: "
                         "LSH-sampled normaliser over a MIPS index of "
                         "the lm_head rows, delta-refreshed by step")
    ap.add_argument("--head-refresh-every", type=int, default=25,
                    help="optimizer steps between head-index refreshes "
                         "(--head lsh)")
    ap.add_argument("--head-samples", type=int, default=64,
                    help="LSH-sampled negatives per token (--head lsh)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    if args.uniform:
        args.sampler = "uniform"
    if args.head == "lsh" and args.sampler == "lgd":
        ap.error("--head lsh composes with --sampler uniform (the LGD "
                 "data sampler owns the batch stream in lgd mode)")
    p = PRESETS[args.preset]

    cfg = ModelConfig(
        name=f"lm-{args.preset}", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"], vocab=p["vocab"],
        chunk=64, loss_chunk=128, dtype="float32", rope_theta=10000.0,
        lgd_enabled=args.sampler == "lgd")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params | sampler: {args.sampler}"
          f" | head: {args.head} | optimizer: {args.optimizer}"
          + (f" | shards: {args.shards} | multiprobe: {args.multiprobe}"
             f" | family: {args.family}"
             if cfg.lgd_enabled else ""))

    corpus = make_token_corpus(1, p["corpus"], p["seq"], cfg.vocab,
                               hard_frac=0.1)

    sampler = batches = None
    if cfg.lgd_enabled:
        sampler = ShardedLSHPipeline(
            jax.random.PRNGKey(2), corpus.tokens,
            mean_pool_feature_fn(cfg), lm_head_query_fn(),
            LSHPipelineConfig(k=cfg.lgd_k, l=cfg.lgd_l,
                              minibatch=p["batch"],
                              refresh_every=cfg.lgd_refresh_every,
                              refresh_async=True,
                              refresh_mode=args.refresh_mode,
                              multiprobe=args.multiprobe,
                              family=args.family),
            n_shards=args.shards, params=params)
    else:
        batches = uniform_batches(corpus, p["batch"], seed=3)

    head = loss_fn = step_hook = None
    if args.head == "lsh":
        # keep k in the populated-bucket regime at this preset's V
        # (occupancy ~ V / 2^k stays >> 1) so the sampled normaliser
        # sits inside the family's calibrated-unbiasedness boundary.
        scfg = SampledSoftmaxConfig(
            k=min(7, max(3, cfg.vocab.bit_length() - 6)), l=8,
            n_samples=args.head_samples, multiprobe=2,
            refresh_every=args.head_refresh_every, refresh_mode="delta")
        head = LMHeadIndex(params, cfg, scfg)
        batches = head.wrap_batches(batches)
        loss_fn = make_sampled_loss(cfg, scfg)
        step_hook = head.step_hook
        print(f"head index: {head.index.n_points} rows x "
              f"{head.index.n_tables} tables | m={scfg.n_samples} "
              f"negatives/token | refresh every {scfg.refresh_every} steps")

    peak = 3e-3 if args.optimizer == "adam" else 3e-2
    tr = Trainer(
        cfg, params,
        make_optimizer(args.optimizer,
                       schedules.warmup_cosine(peak, 20, args.steps)),
        batches,
        TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=100, log_every=20,
                      donate=not cfg.lgd_enabled and args.head != "lsh",
                      step_hook=step_hook),
        sampler=sampler, loss_fn=loss_fn)

    eval_batch = {"tokens": jnp.asarray(corpus.tokens[:128, :-1]),
                  "targets": jnp.asarray(corpus.tokens[:128, 1:])}
    eval_fn = jax.jit(lambda prm: loss(prm, cfg, eval_batch))
    for chunk in range(0, args.steps, 50):
        n = min(50, args.steps - chunk)
        d0, w0 = tr.data_seconds, time.perf_counter()
        tr.run(n)
        wall = time.perf_counter() - w0
        # steps/sec + the fraction of wall time blocked on batch draws:
        # the device-resident data path shows up as sampler -> ~0.
        sampler_frac = (tr.data_seconds - d0) / max(wall, 1e-12)
        last = tr.metrics_history[-1] if tr.metrics_history else {}
        fb = (f"  fallback {sampler.sampler_stats()['fallback_rate']:5.1%}"
              if sampler is not None else "")
        print(f"step {tr.step:5d}  train {last.get('loss', float('nan')):.4f}"
              f"  eval {float(eval_fn(tr.params)):.4f}"
              f"  steps/s {n / max(wall, 1e-12):6.2f}"
              f"  sampler {sampler_frac:5.1%}{fb}"
              f"  stragglers {tr.straggler_steps}")
    tr.finalize()


if __name__ == "__main__":
    main()
