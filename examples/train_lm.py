"""End-to-end driver: train a decoder LM with the LGD-sampled data pipeline.

Presets:
  demo  (default)  ~3M params, a few hundred steps on CPU in minutes —
                   compares the LSH-sampled pipeline against uniform.
  100m             ~100M-param config (d=768, 12L) for a real host/TPU;
                   identical code path, bigger numbers.

Run:  PYTHONPATH=src python examples/train_lm.py [--preset demo]
          [--steps 200] [--uniform] [--ckpt /tmp/lm_ckpt]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.data import (
    LSHPipelineConfig, LSHSampledPipeline, make_token_corpus,
    uniform_batches,
)
from repro.models import ModelConfig, forward, init_params, loss
from repro.optim import Adam, schedules
from repro.train import Trainer, TrainerConfig

PRESETS = {
    "demo": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                 d_ff=512, vocab=1024, seq=64, corpus=4096, batch=16),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab=32768, seq=512, corpus=100_000,
                 batch=32),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--uniform", action="store_true",
                    help="disable LGD sampling (baseline)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ModelConfig(
        name=f"lm-{args.preset}", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"], vocab=p["vocab"],
        chunk=64, loss_chunk=128, dtype="float32", rope_theta=10000.0,
        lgd_enabled=not args.uniform)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params | LGD sampling: "
          f"{cfg.lgd_enabled}")

    corpus = make_token_corpus(1, p["corpus"], p["seq"], cfg.vocab,
                               hard_frac=0.1)
    holder = {}

    if cfg.lgd_enabled:
        def feature_fn(tokens):
            prm = holder.get("trainer").params if "trainer" in holder \
                else params
            h = forward(prm, cfg, {"tokens": tokens})
            return jnp.mean(h.astype(jnp.float32), axis=1)

        def query_fn():
            prm = holder.get("trainer").params if "trainer" in holder \
                else params
            w = prm["embed_group"]["lm_head"].astype(jnp.float32)
            return jnp.mean(w, axis=1)

        pipe = LSHSampledPipeline(
            jax.random.PRNGKey(2), corpus.tokens, jax.jit(feature_fn),
            query_fn,
            LSHPipelineConfig(k=cfg.lgd_k, l=cfg.lgd_l,
                              minibatch=p["batch"],
                              refresh_every=cfg.lgd_refresh_every))
        batches = iter(pipe.next_batch, None)
    else:
        batches = uniform_batches(corpus, p["batch"], seed=3)

    tr = Trainer(
        cfg, params,
        Adam(lr=schedules.warmup_cosine(3e-3, 20, args.steps)),
        batches,
        TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=100, log_every=20,
                      donate=not cfg.lgd_enabled))
    holder["trainer"] = tr

    eval_batch = {"tokens": jnp.asarray(corpus.tokens[:128, :-1]),
                  "targets": jnp.asarray(corpus.tokens[:128, 1:])}
    eval_fn = jax.jit(lambda prm: loss(prm, cfg, eval_batch))
    for chunk in range(0, args.steps, 50):
        tr.run(min(50, args.steps - chunk))
        print(f"step {tr.step:5d}  train {tr.metrics_history[-1]['loss']:.4f}"
              f"  eval {float(eval_fn(tr.params)):.4f}"
              f"  stragglers {tr.straggler_steps}")
    tr.finalize()


if __name__ == "__main__":
    main()
