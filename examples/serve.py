"""Batched serving example: prefill a batch of prompts, decode new tokens.

Exercises the same prefill/decode_step paths the dry-run lowers for the
decode_32k / long_500k cells (KV cache for attention archs, O(1) state
for SSM archs).

Head (``--head {full,lsh}``):
  full   the baseline O(V·d)-per-token head: full logits matmul + argmax.
  lsh    the LSH-shortlisted head (``repro/models/sampled_softmax.py``):
         a MIPS index over the lm_head rows is probed with the decode
         hidden state, up to ``shortlist_per_table`` candidates are
         gathered per (probe, table) pair and the argmax runs over that
         static shortlist only — O(J·L·c·d) per token.  Approximate:
         when no probed bucket holds the true argmax the emitted token
         differs from ``--head full`` (the bias boundary documented in
         docs/ARCHITECTURE.md; recall@k is pinned in tests and gated by
         ``benchmarks/run.py tab_softmax``).

Timing is reported PER PHASE — prefill seconds and the decode p10/p50
ms/token over the per-step latencies (p10 ≈ the steady-state floor once
compilation and cache effects settle; the first, compile-carrying step
is timed separately) — so the shortlist head has a comparable baseline.

Run:  PYTHONPATH=src python examples/serve.py [--arch zamba2_1_2b]
          [--new-tokens 32] [--head lsh]
(uses the arch's SMOKE config so it runs on CPU).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import (
    LMHeadIndex, SampledSoftmaxConfig, decode_step, init_cache, init_params,
    lsh_decode_step, prefill,
)


def _percentiles(ms):
    """(p10, p50) of per-step latencies, excluding the compile step."""
    steady = ms[1:] if len(ms) > 1 else ms
    return (float(np.percentile(steady, 10)),
            float(np.percentile(steady, 50)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2_1_2b",
                    choices=configs.all_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--head", default="full", choices=["full", "lsh"],
                    help="full: O(V) logits matmul per token; lsh: "
                         "LSH-shortlisted argmax over probed candidates")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    b, s = args.batch, args.prompt_len
    max_len = s + args.new_tokens

    batch = {}
    if cfg.frontend == "embed_stub":
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    if "cross_attn" in cfg.block_pattern:
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model))

    head = None
    if args.head == "lsh":
        # Decode wants RECALL, not exact probabilities, so the shortlist
        # runs on the norm-ranged (banded) MIPS index: one global
        # Simple-LSH scale caps an exact-match query's per-table
        # collision at cos ~ ||x||/M (measured recall ~0.5 on an init
        # head); per-band scales restore it (~0.98 — see
        # benchmarks/run.py tab_softmax).  k sized so each band's mean
        # bucket occupancy stays within shortlist_per_table.
        from repro.core.families import get_family
        fam = get_family("mips_banded")
        band_rows = max(1, cfg.vocab // fam.num_bands())
        scfg = SampledSoftmaxConfig(
            family="mips_banded",
            k=max(3, band_rows.bit_length() - 3),
            l=8, multiprobe=2, shortlist_per_table=8)
        head = LMHeadIndex(params, cfg, scfg)
        n_cand = (fam.num_bands() * (1 + scfg.multiprobe) * scfg.l
                  * scfg.shortlist_per_table)
        print(f"[{cfg.name}] head=lsh: {head.index.n_points} rows x "
              f"{head.index.n_tables} tables, "
              f"shortlist {n_cand}/{cfg.vocab} candidates/token")

    cache = init_cache(cfg, b, max_len)
    t0 = time.perf_counter()
    h, cache = prefill(params, cfg, batch, cache)
    jax.block_until_ready(h)
    prefill_s = time.perf_counter() - t0
    print(f"[{cfg.name}] prefill {b}x{s}: {prefill_s:.2f}s")

    if args.head == "lsh":
        scfg_ = head.scfg
        step_fn = jax.jit(
            lambda prm, st, c, idx: lsh_decode_step(prm, cfg, scfg_, st, c,
                                                    idx))
    else:
        def _full_step(prm, st, c):
            logits, c2 = decode_step(prm, cfg, st, c)
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32), c2
        step_fn = jax.jit(_full_step)

    tok = jnp.zeros((b, 1), jnp.int32)
    emb = jnp.zeros((b, 1, cfg.d_model))
    generated = []
    step_ms = []
    t_loop = time.perf_counter()
    for t in range(args.new_tokens):
        step = {"positions": jnp.full((b, 1), s + t, jnp.int32)}
        if cfg.frontend == "embed_stub":
            step["embeds"] = emb
        else:
            step["tokens"] = tok
        if "cross_attn" in cfg.block_pattern:
            step["image_embeds"] = batch["image_embeds"]
        t0 = time.perf_counter()
        if args.head == "lsh":
            tok, cache = step_fn(params, step, cache, head.index)
        else:
            tok, cache = step_fn(params, step, cache)
        jax.block_until_ready(tok)
        step_ms.append((time.perf_counter() - t0) * 1e3)
        if cfg.frontend == "embed_stub":
            emb = jax.random.normal(jax.random.fold_in(key, t),
                                    (b, 1, cfg.d_model))
        generated.append(tok[:, 0])
    dt = time.perf_counter() - t_loop
    toks = jnp.stack(generated, axis=1)
    p10, p50 = _percentiles(step_ms)
    print(f"[{cfg.name}] decode head={args.head}: p10 {p10:.2f} ms/token  "
          f"p50 {p50:.2f} ms/token  (compile step {step_ms[0]:.1f} ms)")
    print(f"decoded {args.new_tokens} tokens/seq in {dt:.2f}s "
          f"({b*args.new_tokens/dt:.1f} tok/s); sample row: "
          f"{toks[0][:12].tolist()}")


if __name__ == "__main__":
    main()
