"""Batched serving example: prefill a batch of prompts, decode new tokens.

Exercises the same prefill/decode_step paths the dry-run lowers for the
decode_32k / long_500k cells (KV cache for attention archs, O(1) state
for SSM archs).

Run:  PYTHONPATH=src python examples/serve.py [--arch zamba2_1_2b]
          [--new-tokens 32]
(uses the arch's SMOKE config so it runs on CPU).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import decode_step, init_cache, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2_1_2b",
                    choices=configs.all_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    b, s = args.batch, args.prompt_len
    max_len = s + args.new_tokens

    batch = {}
    if cfg.frontend == "embed_stub":
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    if "cross_attn" in cfg.block_pattern:
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model))

    cache = init_cache(cfg, b, max_len)
    t0 = time.perf_counter()
    h, cache = prefill(params, cfg, batch, cache)
    print(f"[{cfg.name}] prefill {b}x{s}: {time.perf_counter()-t0:.2f}s")

    step_fn = jax.jit(lambda prm, st, c: decode_step(prm, cfg, st, c))
    tok = jnp.zeros((b, 1), jnp.int32)
    emb = jnp.zeros((b, 1, cfg.d_model))
    generated = []
    t0 = time.perf_counter()
    for t in range(args.new_tokens):
        step = {"positions": jnp.full((b, 1), s + t, jnp.int32)}
        if cfg.frontend == "embed_stub":
            step["embeds"] = emb
        else:
            step["tokens"] = tok
        if "cross_attn" in cfg.block_pattern:
            step["image_embeds"] = batch["image_embeds"]
        logits, cache = decode_step(params, cfg, step, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        if cfg.frontend == "embed_stub":
            emb = jax.random.normal(jax.random.fold_in(key, t),
                                    (b, 1, cfg.d_model))
        generated.append(tok[:, 0])
    dt = time.perf_counter() - t0
    toks = jnp.stack(generated, axis=1)
    print(f"decoded {args.new_tokens} tokens/seq in {dt:.2f}s "
          f"({b*args.new_tokens/dt:.1f} tok/s); sample row: "
          f"{toks[0][:12].tolist()}")


if __name__ == "__main__":
    main()
