"""Fault-tolerance demo: train, crash, restart — then rescale the mesh.

  1. trains 60 steps, checkpointing every 20
  2. simulates a node failure (trainer object dropped on the floor)
  3. a fresh Trainer resumes from step 60 deterministically
  4. the checkpoint is then restored onto a DIFFERENT mesh shape
     (elastic rescale path used when hosts join/leave)

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import tempfile

import jax
import numpy as np

from repro.data import make_token_corpus, uniform_batches
from repro.models import ModelConfig, init_params
from repro.optim import Adam
from repro.train import Trainer, TrainerConfig, checkpoint as ckpt
from repro.train.elastic import rescale_plan, restore_latest_valid_on_mesh


def main():
    cfg = ModelConfig(name="elastic-demo", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                      chunk=16, loss_chunk=32, dtype="float32",
                      rope_theta=10000.0)
    corpus = make_token_corpus(0, 512, 32, cfg.vocab)
    key = jax.random.PRNGKey(0)

    with tempfile.TemporaryDirectory() as d:
        def fresh(resume):
            return Trainer(cfg, init_params(key, cfg), Adam(lr=1e-2),
                           uniform_batches(corpus, 8, seed=1),
                           TrainerConfig(ckpt_dir=d, ckpt_every=20,
                                         log_every=20),
                           resume=resume)

        t1 = fresh(resume=False)
        t1.run(60)
        t1.finalize()
        print(f"phase 1: trained to step {t1.step}, "
              f"latest ckpt = step {ckpt.latest_step(d)}")
        loss_before_crash = t1.metrics_history[-1]["loss"]
        del t1  # << node failure

        t2 = fresh(resume=True)
        print(f"phase 2: restarted at step {t2.step} (auto-resume)")
        t2.run(40)
        t2.finalize()
        print(f"phase 2: continued to step {t2.step}, "
              f"loss {t2.metrics_history[-1]['loss']:.4f} "
              f"(pre-crash {loss_before_crash:.4f})")

        # elastic rescale: restore the same checkpoint onto a 1-device
        # host mesh with proper shardings (on a fleet: the new pod count)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        template = {"params": init_params(key, cfg),
                    "opt_state": Adam(lr=1e-2).init(init_params(key, cfg))}
        # integrity-checked selection: a checkpoint truncated by the
        # "failure" would be skipped for the newest VALID one
        step_v, state, extra = restore_latest_valid_on_mesh(
            d, template, mesh)
        n = sum(x.size for x in jax.tree.leaves(state["params"]))
        print(f"phase 3: restored step {extra['step']} onto mesh "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"({n/1e6:.2f}M params resharded)")
        print("rescale plan 256->512 chips:",
              rescale_plan(256, 512, global_batch=256))


if __name__ == "__main__":
    main()
