"""Quickstart: LGD (LSH-sampled SGD) vs plain SGD on least squares.

Reproduces the paper's core experiment in ~30s on CPU:
  1. build hash tables over [x_i, y_i]  (one-time cost)
  2. per step: hash-lookup sample -> unbiased gradient -> SGD update
  3. compare convergence against uniform-sampling SGD

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    LGDProblem, LSHParams, full_loss, init, lgd_step, sgd_step,
)
from repro.data import make_regression
from repro.optim import SGD


def main():
    key = jax.random.PRNGKey(0)
    ds = make_regression(key, "yearmsd-like", n_train=8000, d=90,
                         noise="pareto")
    problem = LGDProblem(
        kind="regression",
        lsh=LSHParams(k=5, l=100, dim=91, family="quadratic"),
        minibatch=16,
    )
    opt = SGD(lr=5e-2)
    state, xt, yt, x_aug = init(key, problem, ds.x_train, ds.y_train, opt)
    print(f"dataset: {ds.x_train.shape}, hash tables: "
          f"{state.index.sorted_codes.shape} (K={problem.lsh.k}, "
          f"L={problem.lsh.l})")

    s_lgd = s_sgd = state
    for step in range(601):
        k = jax.random.fold_in(key, step)
        s_lgd, m = lgd_step(k, s_lgd, xt, yt, x_aug, problem, opt)
        s_sgd, _ = sgd_step(k, s_sgd, xt, yt, problem, opt)
        if step % 100 == 0:
            print(f"step {step:4d}  "
                  f"LGD loss {float(full_loss(s_lgd.theta, xt, yt, problem)):.4f}  "
                  f"SGD loss {float(full_loss(s_sgd.theta, xt, yt, problem)):.4f}  "
                  f"(bucket={float(m['bucket_size_mean']):.0f}, "
                  f"probes={float(m['n_probes_mean']):.1f})")


if __name__ == "__main__":
    main()
