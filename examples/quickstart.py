"""Quickstart: LGD (LSH-sampled gradient descent) vs plain SGD on least squares.

Reproduces the paper's core experiment on CPU:
  1. build hash tables over [x_i, y_i]  (one-time cost)
  2. per step: hash-lookup sample -> unbiased gradient -> optimiser update
  3. compare convergence against uniform-sampling SGD

The gradient ESTIMATOR is what LGD replaces, so any first-order
optimiser plugs in underneath (``--optimizer {sgd,momentum,adagrad,
adam}``), and ``--multiprobe`` turns on Hamming-ball multi-probe
querying (empty buckets resolve to probability-corrected neighbour
buckets instead of uniform fallbacks).

The hash family is pluggable (``--family {quadratic,srp,mips}``):
``quadratic`` (default) matches |<q,x>| exactly via the implicit
squared expansion; ``srp`` is plain cosine SimHash on the normalised
rows; ``mips`` demonstrates the asymmetric Simple-LSH family — the
same data WITHOUT the unit-norm preprocessing restriction, hashed
through the [x/M, sqrt(1-||x/M||^2)] augmentation.

Run:  PYTHONPATH=src python examples/quickstart.py [--steps 600]
          [--optimizer sgd] [--multiprobe 2] [--family mips]
"""

import argparse

import jax

from repro.core import (
    LGDProblem, LSHParams, full_loss, get_family, init, lgd_step,
    sgd_step,
)
from repro.data import make_regression
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600,
                    help="training steps (600 reproduces the paper curve; "
                         "use ~60 for a smoke run)")
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "momentum", "adagrad", "adam"],
                    help="optimiser under BOTH estimators (LGD only "
                         "replaces the gradient estimate)")
    ap.add_argument("--multiprobe", type=int, default=0,
                    help="extra Hamming-ball probe codes per table")
    ap.add_argument("--family", default="quadratic",
                    choices=["quadratic", "srp", "mips", "mips_banded"],
                    help="LSH family (core.families registry): quadratic "
                         "matches |<q,x>|; srp is cosine SimHash; mips is "
                         "the asymmetric no-normalisation Simple-LSH; "
                         "mips_banded adds norm-ranged banding for "
                         "heavy-tailed norms")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    ds = make_regression(key, "yearmsd-like", n_train=8000, d=90,
                         noise="pareto")
    # augmented-vector dim: the family owns it ([x, y] is d+1 = 91;
    # asymmetric families append their extra coordinates on top)
    dim = get_family(args.family).aug_dim(91)
    problem = LGDProblem(
        kind="regression",
        lsh=LSHParams(k=5, l=100, dim=dim, family=args.family),
        minibatch=16,
        multiprobe=args.multiprobe,
        # the MIPS families train on UN-normalised rows: bound the
        # rare tiny-p draws
        p_floor=1e-7 if args.family in ("mips", "mips_banded") else 0.0,
    )
    lr = 5e-2 if args.optimizer != "adam" else 5e-3
    if args.family in ("mips", "mips_banded"):
        # un-normalised rows: ||x_i||^2 ~ d instead of 1, so the
        # quadratic loss curvature (and the stable LR) scales by ~1/d
        lr /= ds.x_train.shape[1]
    opt = make_optimizer(args.optimizer, lr)
    state, xt, yt, x_aug = init(key, problem, ds.x_train, ds.y_train, opt)
    print(f"dataset: {ds.x_train.shape}, hash tables: "
          f"{state.index.sorted_codes.shape} (K={problem.lsh.k}, "
          f"L={problem.lsh.l}), family: {args.family}, "
          f"optimizer: {args.optimizer}")

    s_lgd = s_sgd = state
    for step in range(args.steps + 1):
        k = jax.random.fold_in(key, step)
        s_lgd, m = lgd_step(k, s_lgd, xt, yt, x_aug, problem, opt)
        s_sgd, _ = sgd_step(k, s_sgd, xt, yt, problem, opt)
        if step % max(args.steps // 6, 1) == 0:
            print(f"step {step:4d}  "
                  f"LGD loss {float(full_loss(s_lgd.theta, xt, yt, problem)):.4f}  "
                  f"SGD loss {float(full_loss(s_sgd.theta, xt, yt, problem)):.4f}  "
                  f"(bucket={float(m['bucket_size_mean']):.0f}, "
                  f"probes={float(m['n_probes_mean']):.1f}, "
                  f"fallback={float(m['fallback_frac']):.2f})")


if __name__ == "__main__":
    main()
