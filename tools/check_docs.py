"""Documentation gate: link checker + documented-command execution.

Two checks over ``README.md`` and every markdown file under ``docs/``:

1. LINK CHECK — every relative markdown link ``[text](target)`` must
   resolve to an existing file (anchors are stripped; ``http(s)://``
   and ``mailto:`` links are skipped — CI must not flake on the
   network).  Targets resolve relative to the file that contains them,
   with a repo-root fallback for absolute-style paths.

2. SNIPPET EXECUTION — fenced shell blocks tagged with an HTML comment
   ``<!-- ci:run -->`` on the line directly above the fence are
   executed line by line (comments and blank lines skipped) from the
   repo root with ``PYTHONPATH=src``.  A non-zero exit fails the gate,
   so the documented quickstart invocations cannot rot.  Keep tagged
   snippets CPU-quick (< ~2 min): they run in the CI ``docs`` job.

Usage:  python tools/check_docs.py  (exit 0 = docs are healthy)
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
RUN_TAG = "<!-- ci:run -->"


def md_files():
    files = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        for base, _, names in os.walk(docs):
            files.extend(os.path.join(base, n) for n in sorted(names)
                         if n.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def strip_code_blocks(text: str) -> str:
    """Drop fenced code blocks so links are only checked in prose."""
    return re.sub(r"```.*?```", "", text, flags=re.S)


def check_links(path: str) -> list:
    failures = []
    with open(path) as f:
        text = f.read()
    for target in LINK_RE.findall(strip_code_blocks(text)):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        cand = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        cand_root = os.path.normpath(os.path.join(ROOT, rel.lstrip("/")))
        if not (os.path.exists(cand) or os.path.exists(cand_root)):
            failures.append(
                f"{os.path.relpath(path, ROOT)}: broken link -> {target}")
    return failures


def tagged_snippets(path: str) -> list:
    """Fenced sh blocks directly preceded by the ci:run tag."""
    snippets = []
    with open(path) as f:
        lines = f.read().splitlines()
    i = 0
    while i < len(lines):
        if lines[i].strip() == RUN_TAG:
            j = i + 1
            if j < len(lines) and lines[j].startswith("```"):
                k = j + 1
                block = []
                while k < len(lines) and not lines[k].startswith("```"):
                    block.append(lines[k])
                    k += 1
                snippets.append((i + 1, block))
                i = k
        i += 1
    return snippets


def run_snippets(path: str) -> list:
    failures = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    for lineno, block in tagged_snippets(path):
        for cmd in block:
            cmd = cmd.strip()
            if not cmd or cmd.startswith("#"):
                continue
            print(f"[ci:run] {os.path.relpath(path, ROOT)}:{lineno}: {cmd}",
                  flush=True)
            proc = subprocess.run(cmd, shell=True, cwd=ROOT, env=env,
                                  timeout=600)
            if proc.returncode != 0:
                failures.append(
                    f"{os.path.relpath(path, ROOT)}:{lineno}: documented "
                    f"command failed (exit {proc.returncode}): {cmd}")
    return failures


def main() -> int:
    failures = []
    files = md_files()
    print(f"checking {len(files)} markdown file(s)")
    for path in files:
        failures.extend(check_links(path))
    n_snip = sum(len(tagged_snippets(p)) for p in files)
    print(f"link check done; executing {n_snip} tagged snippet(s)")
    for path in files:
        failures.extend(run_snippets(path))
    for msg in failures:
        print(f"::error::{msg}")
    if failures:
        return 1
    print("docs gate: all links resolve, all documented commands run")
    return 0


if __name__ == "__main__":
    sys.exit(main())
