"""Chaos drill CLI: inject one fault into a short CPU LGD run and
report the self-healing story.

Each drill trains a tiny LM with the full Trainer + ShardedLSHPipeline
stack while one deterministic fault from ``repro.testing.faults``
fires, then checks the survival contract: the run completes, the loss
falls, and the health/skip bookkeeping recorded what happened.  Exit 0
means the stack healed; exit 1 prints which guarantee broke.

Usage:
    PYTHONPATH=src python tools/chaos.py --fault refresh-raise
    PYTHONPATH=src python tools/chaos.py --fault all --steps 60

Faults: refresh-raise | refresh-hang | ckpt-truncate | nan-grad |
        none | all
"""

from __future__ import annotations

import argparse
import logging
import sys
import tempfile

import jax
import numpy as np

from repro.data import (
    HealthConfig,
    LSHPipelineConfig,
    ShardedLSHPipeline,
    make_token_corpus,
    mean_pool_feature_fn,
    lm_head_query_fn,
)
from repro.models import ModelConfig, init_params
from repro.optim import Adam
from repro.testing import NanLossWeights, RefreshHang, RefreshRaise, \
    truncate_arrays
from repro.train import Trainer, TrainerConfig, checkpoint as ckpt

FAULTS = ("refresh-raise", "refresh-hang", "ckpt-truncate", "nan-grad",
          "none")


def _cfg():
    return ModelConfig(
        name="chaos-drill", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=64, chunk=16, loss_chunk=16,
        dtype="float32", rope_theta=10000.0, lgd_enabled=True)


def _stack(cfg, corpus, params, ckpt_dir=None, **pipe_kw):
    pipe_kw.setdefault("health", HealthConfig(fallback_spike=1.1))
    sampler = ShardedLSHPipeline(
        jax.random.PRNGKey(12), corpus.tokens, mean_pool_feature_fn(cfg),
        lm_head_query_fn(),
        LSHPipelineConfig(k=5, l=10, minibatch=16, refresh_every=10,
                          refresh_async=True, refresh_backoff=0.0,
                          **pipe_kw),
        n_shards=2, params=params)
    tcfg = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=10, log_every=10,
                         rollback_after=3)
    return sampler, tcfg


def drill(fault: str, steps: int) -> dict:
    cfg = _cfg()
    corpus = make_token_corpus(11, 256, 16, cfg.vocab, hard_frac=0.15)
    params = init_params(jax.random.PRNGKey(0), cfg)

    with tempfile.TemporaryDirectory() as d:
        if fault == "ckpt-truncate":
            sampler, tcfg = _stack(cfg, corpus, params, ckpt_dir=d)
            t1 = Trainer(cfg, params, Adam(lr=1e-2), tcfg=tcfg,
                         resume=False, sampler=sampler)
            out1 = t1.run(steps // 2)
            t1.finalize()
            truncate_arrays(d, t1.step)          # corrupt the newest
            sampler2, tcfg2 = _stack(cfg, corpus,
                                     init_params(jax.random.PRNGKey(0),
                                                 cfg), ckpt_dir=d)
            tr = Trainer(cfg, init_params(jax.random.PRNGKey(0), cfg),
                         Adam(lr=1e-2), tcfg=tcfg2, resume=True,
                         sampler=sampler2)
            resumed_at = tr.step
            out = tr.run(steps - tr.step)
            tr.finalize()
            losses = out1["losses"][:resumed_at] + out["losses"]
            sampler = sampler2
        else:
            injector = None
            pipe_kw = {}
            if fault == "refresh-raise":
                injector = RefreshRaise(cycles=3)
                pipe_kw = {"refresh_retries": 1}
            elif fault == "refresh-hang":
                injector = RefreshHang(seconds=5.0, cycles=1)
                pipe_kw = {"refresh_retries": 0, "refresh_timeout": 0.25}
            sampler, tcfg = _stack(cfg, corpus, params, ckpt_dir=d,
                                   **pipe_kw)
            if injector is not None:
                sampler.set_fault_injector(injector, shard=0)
            if fault == "nan-grad":
                sampler = NanLossWeights(sampler, at_step=steps // 3,
                                         count=2)
            tr = Trainer(cfg, params, Adam(lr=1e-2), tcfg=tcfg,
                         resume=False, sampler=sampler)
            out = tr.run(steps)
            tr.finalize()
            losses = out["losses"]

        finite = [l for l in losses if np.isfinite(l)]
        report = {
            "fault": fault,
            "steps": len(losses),
            "loss_head": float(np.mean(finite[:5])),
            "loss_tail": float(np.mean(finite[-5:])),
            "skipped_steps": tr.skipped_steps,
            "rollbacks": tr.rollbacks,
            "health": sampler.health_state(),
            "transitions": sampler.health_summary()["transitions"],
            "valid_ckpt": ckpt.latest_valid_step(d),
        }
        report["survived"] = (
            len(losses) == steps
            and np.isfinite(report["loss_tail"])
            and report["loss_tail"] < report["loss_head"])
        return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fault", default="all",
                    choices=FAULTS + ("all",))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="show the health log as faults fire")
    args = ap.parse_args(argv)
    if not args.verbose:
        logging.disable(logging.WARNING)

    faults = list(FAULTS) if args.fault == "all" else [args.fault]
    failed = []
    for f in faults:
        r = drill(f, args.steps)
        verdict = "SURVIVED" if r["survived"] else "DIED"
        print(f"[{verdict}] {f:14s} loss {r['loss_head']:.3f} -> "
              f"{r['loss_tail']:.3f}  skipped={r['skipped_steps']} "
              f"rollbacks={r['rollbacks']} health={r['health']}")
        for t in r["transitions"]:
            print(f"    transition: {t}")
        if not r["survived"]:
            failed.append(f)
    if failed:
        print(f"FAILED drills: {', '.join(failed)}")
        return 1
    print(f"all {len(faults)} drill(s) survived")
    return 0


if __name__ == "__main__":
    sys.exit(main())
