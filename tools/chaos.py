"""Chaos drill CLI: inject one fault into a short CPU LGD run and
report the self-healing story.

Each drill trains a tiny LM with the full Trainer + ShardedLSHPipeline
stack while one deterministic fault from ``repro.testing.faults``
fires, then checks the survival contract: the run completes, the loss
falls, and the health/skip bookkeeping recorded what happened.  Exit 0
means the stack healed; exit 1 prints which guarantee broke.

Usage:
    PYTHONPATH=src python tools/chaos.py --fault refresh-raise
    PYTHONPATH=src python tools/chaos.py --fault all --steps 60
    PYTHONPATH=src python tools/chaos.py --drill host-loss

Faults: refresh-raise | refresh-hang | ckpt-truncate | nan-grad |
        none | all

The ``host-loss`` drill is the multi-process one: it spawns a real
2-process ``jax.distributed`` run (``repro.dist.multihost_worker``),
hard-kills one process mid-training, and checks the survivor walked
the whole elastic ladder — adopted the dead host's shard, reformed
from the newest verified checkpoint, and produced a post-reform batch
stream BIT-IDENTICAL to a fresh restore of the same checkpoint.  It
is excluded from ``--fault all`` (it costs minutes, and CI runs it in
its own job).
"""

from __future__ import annotations

import argparse
import json
import logging
import os
import socket
import subprocess
import sys
import tempfile

import jax
import numpy as np

from repro.data import (
    HealthConfig,
    LSHPipelineConfig,
    ShardedLSHPipeline,
    make_token_corpus,
    mean_pool_feature_fn,
    lm_head_query_fn,
)
from repro.models import ModelConfig, init_params
from repro.optim import Adam
from repro.testing import NanLossWeights, RefreshHang, RefreshRaise, \
    truncate_arrays
from repro.train import Trainer, TrainerConfig, checkpoint as ckpt

FAULTS = ("refresh-raise", "refresh-hang", "ckpt-truncate", "nan-grad",
          "none")


def _cfg():
    return ModelConfig(
        name="chaos-drill", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=64, chunk=16, loss_chunk=16,
        dtype="float32", rope_theta=10000.0, lgd_enabled=True)


def _stack(cfg, corpus, params, ckpt_dir=None, **pipe_kw):
    pipe_kw.setdefault("health", HealthConfig(fallback_spike=1.1))
    sampler = ShardedLSHPipeline(
        jax.random.PRNGKey(12), corpus.tokens, mean_pool_feature_fn(cfg),
        lm_head_query_fn(),
        LSHPipelineConfig(k=5, l=10, minibatch=16, refresh_every=10,
                          refresh_async=True, refresh_backoff=0.0,
                          **pipe_kw),
        n_shards=2, params=params)
    tcfg = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=10, log_every=10,
                         rollback_after=3)
    return sampler, tcfg


def drill(fault: str, steps: int) -> dict:
    cfg = _cfg()
    corpus = make_token_corpus(11, 256, 16, cfg.vocab, hard_frac=0.15)
    params = init_params(jax.random.PRNGKey(0), cfg)

    with tempfile.TemporaryDirectory() as d:
        if fault == "ckpt-truncate":
            sampler, tcfg = _stack(cfg, corpus, params, ckpt_dir=d)
            t1 = Trainer(cfg, params, Adam(lr=1e-2), tcfg=tcfg,
                         resume=False, sampler=sampler)
            out1 = t1.run(steps // 2)
            t1.finalize()
            truncate_arrays(d, t1.step)          # corrupt the newest
            sampler2, tcfg2 = _stack(cfg, corpus,
                                     init_params(jax.random.PRNGKey(0),
                                                 cfg), ckpt_dir=d)
            tr = Trainer(cfg, init_params(jax.random.PRNGKey(0), cfg),
                         Adam(lr=1e-2), tcfg=tcfg2, resume=True,
                         sampler=sampler2)
            resumed_at = tr.step
            out = tr.run(steps - tr.step)
            tr.finalize()
            losses = out1["losses"][:resumed_at] + out["losses"]
            sampler = sampler2
        else:
            injector = None
            pipe_kw = {}
            if fault == "refresh-raise":
                injector = RefreshRaise(cycles=3)
                pipe_kw = {"refresh_retries": 1}
            elif fault == "refresh-hang":
                injector = RefreshHang(seconds=5.0, cycles=1)
                pipe_kw = {"refresh_retries": 0, "refresh_timeout": 0.25}
            sampler, tcfg = _stack(cfg, corpus, params, ckpt_dir=d,
                                   **pipe_kw)
            if injector is not None:
                sampler.set_fault_injector(injector, shard=0)
            if fault == "nan-grad":
                sampler = NanLossWeights(sampler, at_step=steps // 3,
                                         count=2)
            tr = Trainer(cfg, params, Adam(lr=1e-2), tcfg=tcfg,
                         resume=False, sampler=sampler)
            out = tr.run(steps)
            tr.finalize()
            losses = out["losses"]

        finite = [l for l in losses if np.isfinite(l)]
        report = {
            "fault": fault,
            "steps": len(losses),
            "loss_head": float(np.mean(finite[:5])),
            "loss_tail": float(np.mean(finite[-5:])),
            "skipped_steps": tr.skipped_steps,
            "rollbacks": tr.rollbacks,
            "health": sampler.health_state(),
            "transitions": sampler.health_summary()["transitions"],
            "valid_ckpt": ckpt.latest_valid_step(d),
        }
        report["survived"] = (
            len(losses) == steps
            and np.isfinite(report["loss_tail"])
            and report["loss_tail"] < report["loss_head"])
        return report


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def drill_host_loss(steps: int, verbose: bool = False) -> dict:
    """The multi-process drill: 2 real OS processes, one dies.

    Spawns two ``multihost_worker`` processes over a local
    ``jax.distributed`` coordinator, arms ``ProcKill`` on rank 1, and
    verifies the survival contract end to end:

      * rank 1 exits with the injected death code (it really died);
      * rank 0 detected the loss, adopted shard 1, ran degraded, and
        REFORMED from the newest verified checkpoint on 1 shard;
      * the post-reform stream digest matches a fresh restore of the
        same checkpoint in THIS process (``replay_post_reform``) —
        bit-determinism across the incident.
    """
    from repro.dist.multihost_worker import replay_post_reform
    from repro.testing import ProcKill

    steps = max(steps, 25)               # room for ckpt + sync + kill
    with tempfile.TemporaryDirectory() as d:
        coord = f"127.0.0.1:{_free_port()}"
        common = [sys.executable, "-m", "repro.dist.multihost_worker",
                  "--nprocs", "2", "--coordinator", coord,
                  "--ckpt-dir", os.path.join(d, "ckpt"),
                  "--steps", str(steps), "--sync-every", "5",
                  "--ckpt-every", "10"]
        env = dict(os.environ, JAX_PLATFORMS="cpu")
        procs = [subprocess.Popen(
            common + ["--rank", str(r),
                      "--result", os.path.join(d, f"r{r}.json")]
            + (["--kill-at", "12"] if r == 1 else []),
            env=env,
            stdout=None if verbose else subprocess.DEVNULL,
            stderr=None if verbose else subprocess.DEVNULL,
        ) for r in (0, 1)]
        rcs = [p.wait(timeout=600) for p in procs]

        report = {"fault": "host-loss", "steps": steps,
                  "exit_codes": rcs, "survived": False}
        res_path = os.path.join(d, "r0.json")
        if rcs[0] != 0 or rcs[1] != ProcKill.EXIT_CODE or \
                not os.path.exists(res_path):
            return report
        r0 = json.load(open(res_path))
        report.update(
            incident=r0.get("incident"),
            restore_step=r0.get("restore_step"),
            reform_shards=r0.get("reform_shards"),
            health=r0["cluster"]["state"],
            transitions=r0["cluster"]["transitions"],
        )
        rep = replay_post_reform(
            os.path.join(d, "ckpt"), r0["restore_step"],
            len(r0["losses_post"]), n_shards=r0["reform_shards"])
        report["digest_match"] = rep["digest"] == r0["post_digest"]
        report["survived"] = (
            r0.get("incident") is not None
            and r0["cluster"]["state"] == "reformed"
            and r0["reform_shards"] == 1
            and report["digest_match"]
            and all(np.isfinite(r0["losses_post"])))
        return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fault", default="all",
                    choices=FAULTS + ("all",))
    ap.add_argument("--drill", default=None, choices=("host-loss",),
                    help="multi-process drill (separate from --fault)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="show the health log as faults fire")
    args = ap.parse_args(argv)
    if not args.verbose:
        logging.disable(logging.WARNING)

    if args.drill == "host-loss":
        r = drill_host_loss(args.steps, verbose=args.verbose)
        verdict = "SURVIVED" if r["survived"] else "DIED"
        print(f"[{verdict}] host-loss exit_codes={r['exit_codes']} "
              f"incident={r.get('incident')} "
              f"reform_shards={r.get('reform_shards')} "
              f"digest_match={r.get('digest_match')} "
              f"health={r.get('health')}")
        for t in r.get("transitions", []):
            print(f"    transition: {t}")
        return 0 if r["survived"] else 1

    faults = list(FAULTS) if args.fault == "all" else [args.fault]
    failed = []
    for f in faults:
        r = drill(f, args.steps)
        verdict = "SURVIVED" if r["survived"] else "DIED"
        print(f"[{verdict}] {f:14s} loss {r['loss_head']:.3f} -> "
              f"{r['loss_tail']:.3f}  skipped={r['skipped_steps']} "
              f"rollbacks={r['rollbacks']} health={r['health']}")
        for t in r["transitions"]:
            print(f"    transition: {t}")
        if not r["survived"]:
            failed.append(f)
    if failed:
        print(f"FAILED drills: {', '.join(failed)}")
        return 1
    print(f"all {len(faults)} drill(s) survived")
    return 0


if __name__ == "__main__":
    sys.exit(main())
