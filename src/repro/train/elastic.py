"""Elastic scaling: restore a checkpoint onto a different mesh.

The checkpoint format is mesh-agnostic (host numpy per leaf), so scaling
a job up/down is: build the new mesh, recompute the parameter shardings
for it, and restore with reshard-on-load.  The same path handles node
failure (restart on the surviving smaller mesh) and scale-up.

LGD shard-by-example state is NOT checkpointed: per-shard LSH indexes
are a pure function of (pipeline key, corpus shard, restored params,
restored step), so an elastic restart — including one that CHANGES the
mesh shape and hence the shard count — rebuilds them with
``rebuild_sharded_pipeline``.  The rebuild is bit-deterministic (fold_in
key streams + canonical fresh argsort; see
``LSHSampledPipeline.restore_at``), so two restores of the same
checkpoint onto the same mesh draw identical batch sequences.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from repro.dist.sharding import data_axis_size, tree_param_shardings
from . import checkpoint as ckpt


def restore_on_mesh(
    ckpt_dir: str,
    step: int,
    template: Any,
    mesh,
) -> tuple:
    """Restore ``template``-structured state onto ``mesh`` (any shape)."""
    shardings = tree_param_shardings(template, mesh) if mesh else None
    return ckpt.restore(ckpt_dir, step, template, shardings)


def restore_latest_valid_on_mesh(
    ckpt_dir: str,
    template: Any,
    mesh,
) -> tuple:
    """Elastic restart entry point: restore the newest checkpoint that
    passes ``verify()`` onto ``mesh``.

    The node-failure scenario this serves is exactly the one where the
    newest checkpoint is most likely truncated (the writer died mid-
    save), so the elastic path defaults to integrity-checked selection.
    Returns ``(step, state, extra)``; raises FileNotFoundError when no
    valid checkpoint exists.
    """
    step = ckpt.latest_valid_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(
            f"no valid checkpoint under {ckpt_dir!r}")
    state, extra = restore_on_mesh(ckpt_dir, step, template, mesh)
    return step, state, extra


def rebuild_sharded_pipeline(
    key: jax.Array,
    tokens,
    feature_fn: Callable,
    query_fn: Callable,
    config,
    step: int,
    *,
    n_shards: Optional[int] = None,
    mesh=None,
    params: Any = None,
    feature_batch: int = 512,
    mutation_log: Any = None,
    owned_shards=None,
):
    """Reshard-on-restore for the LGD pipeline: rebuild per-shard indexes.

    ``n_shards`` defaults to the data-parallel degree of ``mesh`` — the
    shard count follows the restored mesh shape, so a job that comes
    back on fewer (or more) hosts re-partitions the corpus to match.
    ``params`` should be the RESTORED model params: features are
    re-embedded from them, matching the paper's periodic-refresh
    semantics (the pre-failure features were at most one refresh period
    fresher).  Calling this twice with the same arguments yields
    bitwise-identical indexes and batch sequences.

    ``mutation_log``: a streaming pipeline's checkpointed append/evict
    log (checkpoint ``extra["mutation_log"]``); replayed by
    ``restore_at`` so the restored windows hold the checkpointed
    membership.  Streaming logs record their shard routing, so they
    restore only onto the SAME ``n_shards`` — checked EARLY here, see
    below; ``tokens`` must be the original construction-time corpus,
    not the mutated window.

    ``owned_shards``: restrict the rebuild to a subset of shard ids
    (multi-controller restore: each process rebuilds only the shards it
    owns — see ``ShardedLSHPipeline``; static corpora only — the
    sharded streaming weight composition needs every shard's live
    count).  A host-loss reform on a STREAMING run therefore keeps the
    recorded ``n_shards`` with one process owning all of them
    (``owned_shards=None``); a static-corpus reform is free to
    re-partition (``n_shards=<survivors>``) instead.
    """
    from repro.data.lsh_pipeline import ShardedLSHPipeline

    if n_shards is None:
        n_shards = data_axis_size(mesh) if mesh is not None else 1
    if isinstance(mutation_log, dict) and "n_shards" in mutation_log:
        logged = int(mutation_log["n_shards"])
        if logged != n_shards:
            # fail BEFORE the O(N) shard builds, with the remediation:
            # logged append/evict entries are routed by the recorded
            # shard bounds (global ids encode their owning shard, and
            # window eviction order is shard-local), so replaying them
            # under different bounds would silently change the restored
            # membership — there is no canonical re-routing.
            raise ValueError(
                f"streaming mutation log was recorded under n_shards="
                f"{logged} but this rebuild targets n_shards="
                f"{n_shards}: logged append/evict entries only replay "
                f"on the recorded shard layout.  Restore with "
                f"n_shards={logged} (one surviving process owns every "
                f"recorded shard), or rebuild the window from the "
                f"upstream source instead of the log.")
    pipe = ShardedLSHPipeline(
        key, tokens, feature_fn, query_fn, config, n_shards=n_shards,
        feature_batch=feature_batch, params=params, mesh=mesh,
        owned_shards=owned_shards)
    if mutation_log is not None:
        pipe.load_mutation_log(mutation_log)
    # the constructor just built every index from the restored params
    # and build keys — bitwise what restore_at would rebuild — so only
    # the counters need rewinding (skips a second O(N) corpus embed).
    # Shards whose replayed mutation log is non-empty rebuild anyway
    # (restore_at forces it: replayed membership != constructor state).
    pipe.restore_at(step, rebuild=False)
    return pipe


def rescale_plan(old_devices: int, new_devices: int,
                 global_batch: int) -> dict:
    """Policy for elastic rescale: keep the GLOBAL batch fixed so the
    optimisation trajectory is unchanged; per-device batch and gradient
    accumulation adjust.

    Invariants (asserted):
      * ``per_device_batch_new * new_devices * grad_accum_steps ==
        global_batch`` — the plan is exactly consistent with the fixed
        global batch (no silent rounding);
      * ``per_device_batch_new <= per_device_batch_old`` — accumulation
        GROWS when devices shrink, so a scale-DOWN never asks a device
        for more memory than it already proved it has.  Scale-up needs
        no accumulation (``grad_accum_steps == 1``).

    Raises ``ValueError`` when ``global_batch`` does not divide over
    ``new_devices`` — SPMD devices step in lockstep on equal slices, so
    an indivisible batch cannot be kept fixed; the caller must pick a
    dividing device count or change the batch explicitly.
    """
    if old_devices <= 0 or new_devices <= 0:
        raise ValueError(
            f"device counts must be positive, got old={old_devices} "
            f"new={new_devices}")
    if global_batch % new_devices != 0:
        raise ValueError(
            f"global_batch={global_batch} does not divide over "
            f"new_devices={new_devices}; elastic rescale keeps the "
            f"global batch fixed, so restore on a device count that "
            f"divides it (or change the batch explicitly)")
    micro = global_batch // new_devices       # rows/device per optimiser step
    per_old = max(global_batch // old_devices, 1)
    # smallest accumulation depth that (a) caps the per-device batch at
    # the old one and (b) divides the per-device rows exactly.
    target = -(-micro // per_old)
    accum = next(a for a in range(target, micro + 1) if micro % a == 0)
    plan = {
        "old_devices": old_devices,
        "new_devices": new_devices,
        "global_batch": global_batch,
        "per_device_batch_old": per_old,
        "per_device_batch_new": micro // accum,
        "grad_accum_steps": accum,
    }
    assert (plan["per_device_batch_new"] * new_devices
            * plan["grad_accum_steps"] == global_batch), plan
    return plan
