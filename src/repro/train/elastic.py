"""Elastic scaling: restore a checkpoint onto a different mesh.

The checkpoint format is mesh-agnostic (host numpy per leaf), so scaling
a job up/down is: build the new mesh, recompute the parameter shardings
for it, and restore with reshard-on-load.  The same path handles node
failure (restart on the surviving smaller mesh) and scale-up.
"""

from __future__ import annotations

from typing import Any, Optional

import jax

from repro.dist.sharding import tree_param_shardings
from . import checkpoint as ckpt


def restore_on_mesh(
    ckpt_dir: str,
    step: int,
    template: Any,
    mesh,
) -> tuple:
    """Restore ``template``-structured state onto ``mesh`` (any shape)."""
    shardings = tree_param_shardings(template, mesh) if mesh else None
    return ckpt.restore(ckpt_dir, step, template, shardings)


def rescale_plan(old_devices: int, new_devices: int,
                 global_batch: int) -> dict:
    """Policy for elastic rescale: keep the GLOBAL batch fixed so the
    optimisation trajectory is unchanged; per-device batch adjusts."""
    assert global_batch % new_devices == 0 or new_devices % 2 == 0
    return {
        "old_devices": old_devices,
        "new_devices": new_devices,
        "global_batch": global_batch,
        "per_device_batch_old": global_batch // max(old_devices, 1),
        "per_device_batch_new": max(global_batch // new_devices, 1),
        "grad_accum_steps": max(1, new_devices // global_batch),
    }
