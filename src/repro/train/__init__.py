from . import checkpoint, elastic  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
