"""Fault-tolerant checkpointing: path-keyed npz shards + atomic manifest.

Design for 1000+ nodes (documented; exercised single-host here):
  * every leaf is saved under its tree path, so restore is structural —
    a checkpoint written on one mesh restores onto ANY mesh/device count
    (elastic scaling): leaves are loaded on host then device_put with the
    TARGET sharding.
  * writes go to ``<dir>.tmp`` then os.rename -> crash-safe (a killed
    writer never corrupts the latest checkpoint).
  * ``save_async`` offloads serialisation to a thread after device_get,
    keeping the accelerator busy (overlap checkpoint I/O with compute).
  * on a real fleet each host writes only its addressable shards; here a
    single host owns everything, but the format (per-leaf files keyed by
    path) is the multi-writer-safe layout.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _path_str(kp) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)


def _sanitize(p: str) -> str:
    return re.sub(r"[^\w./-]", "_", p).replace("/", "__")


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None):
    """Synchronous atomic checkpoint of an arbitrary pytree."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    arrays = {}
    for kp, v in flat:
        path = _path_str(kp)
        key = _sanitize(path)
        arrays[key] = np.asarray(jax.device_get(v))
        manifest["leaves"].append({
            "path": path, "key": key,
            "shape": list(arrays[key].shape),
            "dtype": str(arrays[key].dtype),
        })
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Overlap checkpoint serialisation with training compute."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, ckpt_dir: str, step: int, tree: Any,
             extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.device_get(tree)   # snapshot before training mutates

        def _write():
            save(ckpt_dir, step, host_tree, extra)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: Any,
            shardings: Any = None) -> tuple:
    """Restore into the structure of ``template`` (values ignored).

    ``shardings``: optional pytree of NamedSharding for the TARGET mesh —
    this is the elastic-rescale path: a checkpoint from a 256-chip run
    restores onto 512 chips (or a single CPU) by resharding on load.
    Returns (tree, extra_dict).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    by_path = {leaf["path"]: data[leaf["key"]]
               for leaf in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    flat_shard = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (kp, tmpl), shard in zip(flat, flat_shard):
        path = _path_str(kp)
        if path not in by_path:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = by_path[path]
        want = tuple(getattr(tmpl, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"{path}: checkpoint shape {arr.shape} != template {want}")
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        treedef, leaves), manifest.get("extra", {})


def keep_last(ckpt_dir: str, n: int = 3):
    """Garbage-collect old checkpoints, keep the newest n."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(m.group(1))
        for name in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", name)))
    for s in steps[:-n]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
