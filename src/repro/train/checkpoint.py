"""Fault-tolerant checkpointing: path-keyed npz shards + atomic manifest.

Design for 1000+ nodes (documented; exercised single-host here):
  * every leaf is saved under its tree path, so restore is structural —
    a checkpoint written on one mesh restores onto ANY mesh/device count
    (elastic scaling): leaves are loaded on host then device_put with the
    TARGET sharding.
  * writes go to ``<dir>.tmp`` then os.rename -> crash-safe (a killed
    writer never corrupts the latest checkpoint).
  * ``save_async`` offloads serialisation to a thread after device_get,
    keeping the accelerator busy (overlap checkpoint I/O with compute).
  * on a real fleet each host writes only its addressable shards; here a
    single host owns everything, but the format (per-leaf files keyed by
    path) is the multi-writer-safe layout.

INTEGRITY (the self-healing contract): every leaf records a CRC32 of
its raw bytes in the manifest, and the manifest itself carries a
self-checksum (SHA-256 over its canonical JSON minus the checksum
field).  ``verify()`` re-derives both and structurally cross-checks the
npz against the manifest, so a truncated ``arrays.npz``, a deleted
leaf, or a flipped byte in ``manifest.json`` all turn the checkpoint
INVALID instead of silently corrupting a resume.  ``latest_valid_step``
walks steps newest-first and returns the first checkpoint that passes
``verify()`` — the restore path's fallback to the newest GOOD state.
``latest_step`` (existence check only) is retained for callers that
want the cheap answer.

Failure hygiene:
  * ``AsyncCheckpointer`` captures its worker thread's exception in a
    box and re-raises it at the next ``save()``/``wait()`` — a failed
    background write can make AT MOST one further training step before
    it surfaces, mirroring the refresh-thread error box in
    ``repro.data.lsh_pipeline``.
  * a writer killed mid-``save`` leaves ``step_*.tmp`` behind;
    ``keep_last`` garbage-collects any ``.tmp`` not newer than the
    newest COMPLETED checkpoint (an in-flight async write is always for
    a strictly newer step), and ``save`` logs when it clobbers one.
"""

from __future__ import annotations

import hashlib
import json
import logging
import os
import re
import shutil
import threading
import zlib
from typing import Any, Optional, Tuple

import jax
import numpy as np

log = logging.getLogger("repro.checkpoint")

MANIFEST_VERSION = 2


def _path_str(kp) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)


def _sanitize(p: str) -> str:
    return re.sub(r"[^\w./-]", "_", p).replace("/", "__")


def _json_default(o):
    """np scalars/arrays in ``extra`` (e.g. a streaming pipeline's
    mutation log assembled from np ints) serialise as their Python
    equivalents instead of raising."""
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON serialisable: {type(o)!r}")


def _manifest_digest(manifest: dict) -> str:
    """SHA-256 over the canonical JSON of everything but the checksum
    field itself — a flipped byte anywhere in the manifest (paths, crcs,
    shapes, extra) changes this digest."""
    body = {k: v for k, v in manifest.items() if k != "checksum"}
    blob = json.dumps(body, sort_keys=True, separators=(",", ":"),
                      default=_json_default)
    return hashlib.sha256(blob.encode()).hexdigest()


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None):
    """Synchronous atomic checkpoint of an arbitrary pytree."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        # a previous writer died mid-save (or an overwrite): not an
        # error, but worth a trace — keep_last GCs these when orphaned.
        log.warning("checkpoint save: clobbering stale %s", tmp)
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"version": MANIFEST_VERSION, "step": step, "leaves": [],
                "extra": extra or {}}
    arrays = {}
    for kp, v in flat:
        path = _path_str(kp)
        key = _sanitize(path)
        arr = np.asarray(jax.device_get(v))
        arrays[key] = arr
        manifest["leaves"].append({
            "path": path, "key": key,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        })
    manifest["checksum"] = _manifest_digest(manifest)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, default=_json_default)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def verify(ckpt_dir: str, step: int) -> Tuple[bool, str]:
    """Integrity check of one checkpoint: (ok, reason).

    Validates, in order: manifest parses as JSON; manifest self-checksum
    matches (byte flips anywhere in the manifest); ``arrays.npz`` loads
    (truncation corrupts the zip central directory); every manifest leaf
    exists in the npz with the recorded shape/dtype; every leaf's CRC32
    matches the recorded one (bit flips in array data).  Legacy
    (version-1) manifests without checksums pass the structural checks
    only.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    mpath = os.path.join(d, "manifest.json")
    apath = os.path.join(d, "arrays.npz")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        return False, f"manifest unreadable: {e}"
    if not isinstance(manifest, dict) or "leaves" not in manifest:
        return False, "manifest malformed: no leaves"
    if "checksum" in manifest and \
            manifest["checksum"] != _manifest_digest(manifest):
        return False, "manifest self-checksum mismatch"
    try:
        data = np.load(apath)
        keys = set(data.files)
    except Exception as e:   # truncated zip raises various error types
        return False, f"arrays.npz unreadable: {e}"
    try:
        for leaf in manifest["leaves"]:
            key = leaf["key"]
            if key not in keys:
                return False, f"leaf missing from arrays.npz: {leaf['path']}"
            try:
                arr = data[key]
            except Exception as e:   # per-member truncation/corruption
                return False, f"leaf unreadable: {leaf['path']}: {e}"
            if list(arr.shape) != list(leaf["shape"]):
                return False, (f"leaf shape mismatch: {leaf['path']} "
                               f"{list(arr.shape)} != {leaf['shape']}")
            if str(arr.dtype) != leaf["dtype"]:
                return False, (f"leaf dtype mismatch: {leaf['path']} "
                               f"{arr.dtype} != {leaf['dtype']}")
            if "crc32" in leaf and zlib.crc32(
                    np.ascontiguousarray(arr).tobytes()) != leaf["crc32"]:
                return False, f"leaf crc mismatch: {leaf['path']}"
    finally:
        data.close()
    return True, "ok"


class AsyncCheckpointer:
    """Overlap checkpoint serialisation with training compute.

    A write-thread failure is captured in an error box and re-raised at
    the NEXT ``save()`` or ``wait()`` — it cannot be silently swallowed,
    and it surfaces at most one checkpoint interval after it happened.
    """

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, ckpt_dir: str, step: int, tree: Any,
             extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.device_get(tree)   # snapshot before training mutates

        def _write():
            try:
                save(ckpt_dir, step, host_tree, extra)
            except BaseException as e:     # boxed; re-raised at next call
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                "async checkpoint write failed") from err


def _completed_steps(ckpt_dir: str) -> list:
    return sorted(
        int(m.group(1))
        for name in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", name))
        and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")))


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest checkpoint step by EXISTENCE only (no integrity check)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = _completed_steps(ckpt_dir)
    return max(steps) if steps else None


def latest_valid_step(ckpt_dir: str) -> Optional[int]:
    """Newest checkpoint that passes ``verify()``.

    Walks steps newest-first, skipping corrupt/truncated checkpoints
    (each skip is logged with the verify reason) — the restore path's
    guarantee that a bad newest checkpoint degrades resume by one
    interval instead of bricking it.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    for s in sorted(_completed_steps(ckpt_dir), reverse=True):
        ok, reason = verify(ckpt_dir, s)
        if ok:
            return s
        log.warning("checkpoint step %d failed verify (%s); skipping",
                    s, reason)
    return None


def restore(ckpt_dir: str, step: int, template: Any,
            shardings: Any = None) -> tuple:
    """Restore into the structure of ``template`` (values ignored).

    ``shardings``: optional pytree of NamedSharding for the TARGET mesh —
    this is the elastic-rescale path: a checkpoint from a 256-chip run
    restores onto 512 chips (or a single CPU) by resharding on load.
    Returns (tree, extra_dict).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    by_path = {leaf["path"]: data[leaf["key"]]
               for leaf in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    flat_shard = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (kp, tmpl), shard in zip(flat, flat_shard):
        path = _path_str(kp)
        if path not in by_path:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = by_path[path]
        want = tuple(getattr(tmpl, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"{path}: checkpoint shape {arr.shape} != template {want}")
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        treedef, leaves), manifest.get("extra", {})


def discard_after(ckpt_dir: str, step: int):
    """Delete every checkpoint (and ``.tmp``) for steps > ``step``.

    Called by the restore path: once a run resumes at ``step``, newer
    checkpoints on disk belong to an ABANDONED timeline (a corrupt
    newest that verify() skipped, or the poisoned future a rollback
    rewound past).  Leaving them would shadow the resumed run's own
    writes and break ``keep_last``'s invariant that an in-flight async
    ``.tmp`` is always strictly newer than every completed step.
    """
    if not os.path.isdir(ckpt_dir):
        return
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)(\.tmp)?", name)
        if m and int(m.group(1)) > step:
            log.warning("discarding abandoned-timeline checkpoint %s "
                        "(resumed at step %d)", name, step)
            shutil.rmtree(os.path.join(ckpt_dir, name),
                          ignore_errors=True)


def keep_last(ckpt_dir: str, n: int = 3):
    """Garbage-collect old checkpoints, keep the newest n.

    Also reaps orphaned ``step_*.tmp`` dirs left by writers killed
    mid-``save``: any ``.tmp`` not strictly newer than the newest
    COMPLETED checkpoint is an orphan (an in-flight async write is
    always for a newer step than every completed one).
    """
    if not os.path.isdir(ckpt_dir):
        return
    all_steps = sorted(
        int(m.group(1))
        for name in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", name)))
    for s in all_steps[:-n]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    completed = _completed_steps(ckpt_dir)
    newest = completed[-1] if completed else -1
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)\.tmp", name)
        if m and int(m.group(1)) <= newest:
            log.warning("checkpoint GC: removing orphaned %s", name)
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
