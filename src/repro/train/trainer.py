"""Training loop: LGD-sampled or uniform pipeline, checkpoint/restart,
metrics, and the distributed-runtime policies that matter at fleet scale.

Fault-tolerance contract:
  * checkpoint every ``ckpt_every`` steps (async, atomic) including
    optimiser state, data-pipeline step counter and PRNG key -> a
    restarted job resumes bit-deterministically (same batch sequence).
  * ``Trainer(..., resume=True)`` picks up the latest step automatically.
  * on a real fleet, a failed host triggers a restart from the latest
    checkpoint on the surviving mesh (see train/elastic.py for the
    reshard-on-restore path, exercised in tests by mesh-shape changes).
  * resume picks the newest checkpoint that passes ``verify()``
    (``latest_valid_step``), so a corrupt/truncated newest checkpoint
    costs one interval, not the run.
  * non-finite loss/grad-norm steps apply NO update (``skip_nonfinite``;
    counted in ``skipped_steps``); ``rollback_after`` consecutive bad
    steps trigger a rollback to the newest verified checkpoint (sampler
    mode).  See docs/ARCHITECTURE.md "Failure model".

Straggler mitigation (documented policy, host-side): per-step wall-time
is tracked with an EWMA; steps exceeding ``straggler_factor`` x EWMA are
counted and surfaced in metrics — on a fleet this signal feeds the
controller that evicts/replaces slow hosts.  Data loading is
double-buffered (next batch prepared while the step runs) so host-side
sampling (the LGD hash lookups) overlaps device compute.

ADAPTIVE OPTIMIZERS under LGD: the sampler composes with ANY
``repro.optim`` optimiser (Adam, AdaGrad, momentum-SGD, ...) because
the importance weights enter the LOSS, not the update rule: the jitted
loss multiplies per-example losses by 1/(p_i N), so the gradient the
optimiser receives IS the unbiased estimate of the full-batch gradient
— Adam's first/second moments and AdaGrad's accumulator are then
running statistics OF that estimate (weights applied strictly before
moment accumulation).  First moments therefore track the true mean
gradient: E[m_1] = (1-b1) * full-batch grad (pinned by
tests/test_optim_lgd.py against full-batch moments).  Second moments
accumulate E[g_est^2] >= E[g_est]^2 — the correct Adam/AdaGrad
semantics for any stochastic estimator; nothing in the update rule
needs to know the batch was adaptively sampled.

LGD sampler hook: pass ``sampler=`` (an ``LSHSampledPipeline`` /
``ShardedLSHPipeline``) instead of ``batches``.  The trainer then
  * draws batches from ``sampler.next_batch`` — importance weights
    1/(p_i N) ride in ``batch["loss_weights"]`` and are applied INSIDE
    the jitted loss (``models.layers.chunked_cross_entropy``), keeping
    the adaptive-sampling gradient unbiased.  Batches arrive as DEVICE
    arrays (the pipeline's sample->gather->weight program runs on
    device against its resident token store), so drawing costs only the
    dispatch of one compiled call — there is no host-side batch
    assembly or re-upload anywhere in the loop;
  * pushes fresh params via ``sampler.set_params`` after every step, so
    queries track the live model and the periodic index refresh (which
    the pipeline runs on a host thread, double-buffered) re-embeds from
    near-current params while the device step runs;
  * forces ``donate=False`` (the sampler's feature/query closures read
    live param buffers) and, on restore, rewinds the sampler with
    ``restore_at(step)`` instead of replaying consumed batches.

Sampler-overhead accounting: the host-blocking time spent drawing every
batch is accumulated in ``data_seconds`` (total loop wall time in
``loop_seconds``); ``sampler_overhead`` is their ratio and per-entry
``metrics_history`` carries ``data_dt`` (the LAST draw's host-blocking
seconds, per-step like ``dt``) — the number the device-resident data
path is meant to drive toward zero.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, loss as lm_loss
from repro.optim import apply_updates
from . import checkpoint as ckpt


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    grad_clip: Optional[float] = 1.0
    # donate params/opt_state buffers to the step (halves peak HBM).
    # Disable when an LGD pipeline holds references to live params
    # (its feature/query closures would read donated buffers).
    donate: bool = True
    # micro-batching: split each batch into N equal slices along dim 0 and
    # accumulate gradients — decouples the optimisation batch size from
    # per-device memory (used by elastic rescale to keep global batch
    # fixed when devices shrink).
    grad_accum: int = 1
    # int8 gradient compression with error feedback on the DP all-reduce
    # path (see optim/compression.py); quantisation happens inside the
    # step so the wire-crossing tree is 4x smaller than bf16.
    grad_compress: bool = False
    # -- self-healing guards (docs/ARCHITECTURE.md: failure model) --
    # a step whose loss or grad-norm is non-finite applies NO update
    # (params/opt_state/ef_residual selected unchanged inside the jitted
    # step); the batch is still consumed and ``step`` still advances, so
    # the data stream stays aligned with the step counter and restore
    # determinism holds.  Counted in ``skipped_steps``.
    skip_nonfinite: bool = True
    # after this many CONSECUTIVE skipped steps, roll back to the newest
    # checkpoint that passes verify() (sampler mode only — a plain batch
    # iterator cannot be rewound).  0 disables rollback.
    rollback_after: int = 5
    # lifetime cap on rollbacks (a persistent NaN source must not pin
    # the run in a restore loop forever).
    max_rollbacks: int = 3
    # called with the Trainer after every COMPLETED step (post-update,
    # post-checkpoint) — the multi-host deployment's attachment point
    # for heartbeats, membership barriers and cross-process parameter
    # averaging (repro.dist.multihost).  The hook may mutate
    # ``trainer.params`` (push the result via ``trainer.sampler
    # .set_params`` too) and may raise to unwind ``run()`` at a clean
    # step boundary: params/opt_state/step are consistent, and a later
    # ``restore_at`` realigns the data stream.
    step_hook: Optional[Callable] = None


class Trainer:
    """Training loop with LGD-sampler, checkpoint and metrics hooks.

    Args:
      cfg: model config (defines the default LM loss).
      params: initial parameter pytree.
      optimizer: any ``repro.optim`` optimiser (``init``/``update``
        interface) — Adam, AdaGrad, momentum-SGD, Adafactor, ...;
        with ``sampler=`` the importance-weighted gradient estimate
        feeds its moment accumulators unchanged (module docstring).
      batches: iterator of batch dicts (uniform-sampling mode);
        mutually exclusive with ``sampler``.
      tcfg: loop policy knobs (checkpointing, clipping, accumulation).
      resume: auto-restore the latest checkpoint in ``tcfg.ckpt_dir``.
      loss_fn: optional ``loss_fn(params, batch)`` override.
      sampler: an ``LSHSampledPipeline``/``ShardedLSHPipeline`` — the
        LGD adaptive-sampling mode (forces ``donate=False``; pushes
        live params via ``set_params`` each step; ``restore_at`` on
        checkpoint restore).

    Determinism: with a sampler, restoring at step t replays the exact
    batch sequence of a run that reached step t (fold_in key streams —
    see ``repro.data.lsh_pipeline``); with ``batches``, restore skips
    already-consumed batches, so the iterator must be re-creatable.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        optimizer,
        batches: Optional[Iterator[Dict[str, jax.Array]]] = None,
        tcfg: TrainerConfig = TrainerConfig(),
        resume: bool = True,
        loss_fn: Optional[Callable] = None,
        sampler=None,
    ):
        if (batches is None) == (sampler is None):
            raise ValueError("pass exactly one of batches= or sampler=")
        self._sampler = sampler
        if sampler is not None:
            if hasattr(sampler, "set_params"):
                sampler.set_params(params)
            batches = iter(sampler.next_batch, None)
            if tcfg.donate:
                # sampler closures read live param buffers; donating
                # them to the step would hand the sampler freed memory.
                tcfg = dataclasses.replace(tcfg, donate=False)
        self.cfg = cfg
        self.optimizer = optimizer
        self.batches = batches
        self.tcfg = tcfg
        self.params = params
        self.opt_state = optimizer.init(params)
        self.step = 0
        self.metrics_history = []
        self._ckpt = ckpt.AsyncCheckpointer()
        self._ewma_dt = None
        self.straggler_steps = 0
        self.skipped_steps = 0      # non-finite steps (no update applied)
        self.rollbacks = 0          # checkpoint rollbacks taken
        self._bad_streak = 0        # consecutive skipped steps
        self.data_seconds = 0.0     # host-blocking batch-draw time (total)
        self.loop_seconds = 0.0     # total run() wall time
        self._last_draw_dt = 0.0    # host-blocking time of the last draw
        loss_fn = loss_fn or (lambda p, b: lm_loss(p, cfg, b))

        clip = tcfg.grad_clip
        accum = max(tcfg.grad_accum, 1)
        compress_on = tcfg.grad_compress
        if compress_on:
            from repro.optim import compression as _gc
            self._ef_residual = _gc.init_error_feedback(params)

        def grads_of(params, batch):
            if accum == 1:
                return jax.value_and_grad(loss_fn)(params, batch)

            def micro(i):
                mb = jax.tree.map(
                    lambda x: x.reshape(
                        (accum, x.shape[0] // accum) + x.shape[1:])[i]
                    if hasattr(x, "shape") and x.ndim >= 1 else x, batch)
                return jax.value_and_grad(loss_fn)(params, mb)

            def body(carry, i):
                l_acc, g_acc = carry
                l, g = micro(i)
                return (l_acc + l,
                        jax.tree.map(jnp.add, g_acc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (l, g), _ = jax.lax.scan(
                body, (jnp.zeros(()), zeros), jnp.arange(accum))
            scale = 1.0 / accum
            return l * scale, jax.tree.map(lambda x: x * scale, g)

        guard = tcfg.skip_nonfinite

        def train_step(params, opt_state, batch, ef_residual=None):
            l, grads = grads_of(params, batch)
            old_ef = ef_residual
            if compress_on:
                from repro.optim import compression as _gc
                # this quantised tree is what crosses the DP links
                qtree, ef_residual = _gc.compress_with_feedback(
                    grads, ef_residual)
                grads = _gc.decompress(qtree, like=grads)
            if clip is not None or guard:
                # a single NaN/Inf anywhere in the gradient tree
                # propagates into this norm, so isfinite(gnorm) is a
                # whole-tree finiteness check.
                gnorm = jnp.sqrt(sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads)))
            else:
                gnorm = jnp.zeros(())
            if clip is not None:
                scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
                grads = jax.tree.map(
                    lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                    grads)
            updates, new_opt = optimizer.update(grads, opt_state, params)
            new_params = apply_updates(params, updates)
            if guard:
                # branchless skip: a non-finite loss or grad-norm keeps
                # params/opt_state/error-feedback EXACTLY as they were
                # (the where selects the old buffers) — the poisoned
                # gradients never reach the optimiser's moments.
                ok = jnp.isfinite(l) & jnp.isfinite(gnorm)
                sel = lambda n, o: jnp.where(ok, n, o)  # noqa: E731
                new_params = jax.tree.map(sel, new_params, params)
                new_opt = jax.tree.map(sel, new_opt, opt_state)
                if compress_on:
                    ef_residual = jax.tree.map(sel, ef_residual, old_ef)
            else:
                ok = jnp.array(True)
            return new_params, new_opt, l, gnorm, ef_residual, ok

        self._step_fn = jax.jit(
            train_step, donate_argnums=(0, 1) if tcfg.donate else ())

        if resume and tcfg.ckpt_dir:
            # resume from the newest checkpoint that passes verify() —
            # a corrupt/truncated newest checkpoint costs one interval,
            # not the run.
            last = ckpt.latest_valid_step(tcfg.ckpt_dir)
            if last is not None:
                self.restore(last)

    # -- checkpoint ----------------------------------------------------------

    def _state_tree(self):
        return {"params": self.params, "opt_state": self.opt_state}

    def save(self):
        if not self.tcfg.ckpt_dir:
            return
        extra = {"step": self.step}
        if self._sampler is not None and \
                getattr(self._sampler, "streaming", False) and \
                hasattr(self._sampler, "mutation_log"):
            # streaming pipelines: the explicit append/evict log rides
            # in the manifest so a restore can replay membership and
            # keep restored-at-step bit-determinism (lsh_pipeline
            # module docstring, STREAMING CORPORA).
            extra["mutation_log"] = self._sampler.mutation_log()
        self._ckpt.save(
            self.tcfg.ckpt_dir, self.step, self._state_tree(),
            extra=extra)
        ckpt.keep_last(self.tcfg.ckpt_dir, self.tcfg.keep_ckpts)

    def restore(self, step: int):
        tmpl = self._state_tree()
        tree, extra = ckpt.restore(self.tcfg.ckpt_dir, step, tmpl)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.step = extra.get("step", step)
        # checkpoints newer than the restore point are an abandoned
        # timeline (corrupt newest, or a rolled-back poisoned future) —
        # drop them so the resumed run's own writes are authoritative.
        ckpt.discard_after(self.tcfg.ckpt_dir, self.step)
        if self._sampler is not None and hasattr(self._sampler,
                                                 "restore_at"):
            # rebuild the sampler's index from the restored params and
            # rewind its key streams — O(refresh) instead of O(steps),
            # and bit-deterministic across restores.
            if hasattr(self._sampler, "set_params"):
                self._sampler.set_params(self.params)
            if "mutation_log" in extra and \
                    hasattr(self._sampler, "load_mutation_log"):
                # restore the streaming membership history first;
                # restore_at replays it before the canonical rebuild.
                self._sampler.load_mutation_log(extra["mutation_log"])
            self._sampler.restore_at(self.step)
        else:
            # deterministic data resume: skip already-consumed batches
            for i in range(self.step):
                try:
                    next(self.batches)
                except StopIteration:
                    raise RuntimeError(
                        f"batch iterator exhausted after {i} batches "
                        f"while skipping to checkpoint step {self.step} "
                        f"— the iterator is shorter than the checkpoint "
                        f"(it must be re-creatable past the restore "
                        f"point)") from None

    def _rollback(self) -> bool:
        """Roll back to the newest VERIFIED checkpoint after a streak of
        non-finite steps (sampler mode only — ``restore_at`` rewinds the
        data stream; a plain iterator cannot).  Returns True on success.
        """
        try:
            self._ckpt.wait()           # surface a boxed async failure
        except RuntimeError:
            pass                        # the write failed; disk may still
            #                             hold an older valid checkpoint
        step_v = ckpt.latest_valid_step(self.tcfg.ckpt_dir)
        if step_v is None:
            return False
        prev = self.step
        self.restore(step_v)
        self.rollbacks += 1
        self._bad_streak = 0
        self.metrics_history.append({
            "step": self.step, "event": "rollback",
            "from_step": prev, "to_step": step_v,
            "skipped_steps": self.skipped_steps,
        })
        return True

    def finalize(self):
        self._ckpt.wait()
        if self._sampler is not None and hasattr(self._sampler, "finalize"):
            self._sampler.finalize()

    # -- loop ----------------------------------------------------------------

    @property
    def sampler(self):
        """The LGD sampler this trainer drives (None in batches mode) —
        exposed for step hooks that mutate params and must push them."""
        return self._sampler

    @property
    def sampler_overhead(self) -> float:
        """Fraction of loop wall time spent blocked on batch draws."""
        return self.data_seconds / max(self.loop_seconds, 1e-12)

    def _draw(self):
        t0 = time.time()
        try:
            return next(self.batches)
        finally:
            self._last_draw_dt = time.time() - t0
            self.data_seconds += self._last_draw_dt

    def run(self, n_steps: int) -> Dict[str, list]:
        losses = []
        if n_steps <= 0:
            # never touch the data stream: batch k must train step k,
            # and a no-op run() must not tick the sampler's key stream.
            return {"losses": losses}
        target = self.step + n_steps
        t_loop = time.time()
        try:
            next_batch = self._draw()            # double buffering
        except StopIteration:
            # an empty/exhausted iterator on the FIRST draw is a clean
            # no-op run, not a crash (satellite: bare StopIteration).
            self.loop_seconds += time.time() - t_loop
            return {"losses": losses}
        while self.step < target:
            t0 = time.time()
            batch = next_batch
            self.params, self.opt_state, l, gnorm, ef, ok = self._step_fn(
                self.params, self.opt_state, batch,
                getattr(self, "_ef_residual", None))
            if ef is not None:
                self._ef_residual = ef
            ok = bool(ok) if self.tcfg.skip_nonfinite else True
            if ok:
                self._bad_streak = 0
            else:
                self.skipped_steps += 1
                self._bad_streak += 1
            if self._sampler is not None and \
                    hasattr(self._sampler, "note_loss"):
                # feed the degradation ladder: a non-finite streak sends
                # the pipeline to uniform-fallback (weights un-poisoned
                # by construction).
                self._sampler.note_loss(ok)
            if not ok and self.tcfg.rollback_after > 0 and \
                    self._bad_streak >= self.tcfg.rollback_after and \
                    self._sampler is not None and self.tcfg.ckpt_dir and \
                    self.rollbacks < self.tcfg.max_rollbacks:
                if self._rollback():
                    # the prefetched batch belongs to the abandoned
                    # stream position; re-draw at the rolled-back step.
                    next_batch = self._draw()
                    continue
            if self._sampler is not None and \
                    hasattr(self._sampler, "set_params"):
                # point the sampler at the post-step params (async jax
                # values — sampling ops just enqueue behind the step)
                # BEFORE drawing the next batch, so its query reflects
                # the live model.
                self._sampler.set_params(self.params)
                # the draw's query depends on the step's output params,
                # and dispatching on a pending input blocks on backends
                # without cross-dependency async (CPU) — sync the loss
                # first so data_seconds measures the DRAW, not the
                # in-flight step it would otherwise absorb.
                l = float(l)
            if self.step + 1 < target:
                # prefetch ONLY if another step will run: batch k must
                # train step k, never be thrown away at loop exit —
                # otherwise chunked run() calls desync the data stream
                # from self.step and restore-at-step resume diverges.
                try:
                    next_batch = self._draw()        # overlap device step
                except StopIteration:
                    next_batch = None
            else:
                next_batch = None
            l = float(l)
            dt = time.time() - t0
            self._ewma_dt = dt if self._ewma_dt is None else \
                0.9 * self._ewma_dt + 0.1 * dt
            if dt > self.tcfg.straggler_factor * self._ewma_dt:
                self.straggler_steps += 1
            self.step += 1
            losses.append(l)
            if self.step % self.tcfg.log_every == 0:
                entry = {
                    "step": self.step, "loss": l,
                    "grad_norm": float(gnorm), "dt": dt,
                    "data_dt": self._last_draw_dt,
                    "stragglers": self.straggler_steps,
                    "skipped_steps": self.skipped_steps,
                    "rollbacks": self.rollbacks,
                }
                if self._sampler is not None and \
                        hasattr(self._sampler, "sampler_stats"):
                    # device-sync'd read, so only at log cadence
                    st = self._sampler.sampler_stats()
                    entry["fallback_rate"] = st["fallback_rate"]
                    entry["primary_miss_rate"] = st["primary_miss_rate"]
                if self._sampler is not None and \
                        hasattr(self._sampler, "check_health"):
                    # feeds the batch fallback rate into the ladder and
                    # reports the state (syncs; log cadence only)
                    entry["health"] = self._sampler.check_health()
                    hs = self._sampler.health_summary()
                    entry["health_transitions"] = hs["transitions"]
                self.metrics_history.append(entry)
            if self.tcfg.ckpt_dir and \
                    self.step % self.tcfg.ckpt_every == 0:
                self.save()
            if self.tcfg.step_hook is not None:
                # cluster attachment point — may mutate params or raise
                # (e.g. HostLossDetected) at this clean step boundary.
                self.tcfg.step_hook(self)
            if next_batch is None:
                break
        self.loop_seconds += time.time() - t_loop
        return {"losses": losses}
