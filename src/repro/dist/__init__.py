from .sharding import (  # noqa: F401
    batch_sharding,
    current_mesh,
    logical,
    param_spec,
    tree_param_shardings,
    use_mesh,
)
