from .sharding import (  # noqa: F401
    batch_sharding,
    current_mesh,
    host_local_mesh,
    logical,
    param_spec,
    tree_param_shardings,
    use_mesh,
)
from .multihost import (  # noqa: F401
    BarrierTimeout,
    ClusterError,
    ElasticCluster,
    FileCoord,
    HostLossDetected,
    MultihostConfig,
    backoff_delay,
    shard_adoption_map,
)
