"""Sharding rules: logical activation axes + per-parameter placement.

One place owns the mesh mapping so models never name physical axes:

* ``logical(x, *axes)`` annotates activations with *logical* axis names
  ("batch", "seq", "heads", "ff", "vocab", "experts") that resolve to
  physical mesh axes under ``use_mesh``; outside a mesh context it is a
  no-op, so every model runs unsharded on a laptop unchanged.
* ``param_spec(path, shape, mesh)`` assigns a PartitionSpec to one
  parameter from its tree path and shape: tensor-parallel over heads /
  experts / vocab on the ``model`` axis, FSDP over the feature dim on the
  ``data`` axis (``("pod", "data")`` on multi-pod meshes), norms and any
  indivisible dim replicated.  Parameters stacked over layers
  (``blocks/...``) keep their leading layer dim unsharded — it is the
  scan axis.
* ``tree_param_shardings`` maps ``param_spec`` over a whole params (or
  eval_shape) pytree; ``batch_sharding`` shards batch dim 0 over the
  data axes.

Only ``mesh.shape`` / ``mesh.axis_names`` are touched, so tests can pass
stub meshes without building devices.
"""

from __future__ import annotations

import contextlib
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# mesh context
# ---------------------------------------------------------------------------

_MESH: list = []   # stack of active meshes


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for ``logical`` constraints within the block."""
    _MESH.append(mesh)
    try:
        yield mesh
    finally:
        _MESH.pop()


def current_mesh():
    return _MESH[-1] if _MESH else None


# ---------------------------------------------------------------------------
# axis resolution
# ---------------------------------------------------------------------------

def _data_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _axis_size(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def data_axis_size(mesh) -> int:
    """Total data-parallel degree of ``mesh`` (pod x data on multi-pod)."""
    return _axis_size(mesh, _data_axes(mesh))


def example_shard_bounds(n: int, shard_id: int, n_shards: int):
    """Contiguous [lo, hi) bounds of corpus shard ``shard_id``.

    Balanced split (sizes differ by at most 1, remainder to the lowest
    ids).  This is the shard-by-example contract for LGD scale-out: DP
    group s builds/refreshes/queries ONLY the LSH index of examples
    [lo, hi) — see ``repro/data/lsh_pipeline.ShardedLSHPipeline`` for
    how per-shard importance weights compose into an unbiased global
    estimator under the DP all-reduce.
    """
    if not (0 <= shard_id < n_shards):
        raise ValueError(f"shard_id {shard_id} not in [0, {n_shards})")
    base, rem = divmod(n, n_shards)
    lo = shard_id * base + min(shard_id, rem)
    hi = lo + base + (1 if shard_id < rem else 0)
    return lo, hi


# logical activation axis -> physical mesh axis ("batch" -> the data axes,
# model-parallel dims -> "model"; "seq" is the sequence-parallel residual
# sharding, also over "model").
_LOGICAL = {
    "batch": _data_axes,
    "seq": lambda mesh: "model",
    "heads": lambda mesh: "model",
    "ff": lambda mesh: "model",
    "vocab": lambda mesh: "model",
    "experts": lambda mesh: "model",
}


def logical(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op meshless)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    spec = []
    for dim, name in zip(x.shape, axes):
        phys = _LOGICAL[name](mesh) if name is not None else None
        if phys is not None and dim % _axis_size(mesh, phys) != 0:
            phys = None
        spec.append(phys)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# parameter placement
# ---------------------------------------------------------------------------

def _divisible(mesh, axes, dim: int):
    if axes is None or dim % _axis_size(mesh, axes) != 0:
        return None
    return axes


def param_spec(path: str, shape: tuple, mesh) -> P:
    """PartitionSpec for the parameter at ``path`` with ``shape``.

    Rules (each sharded dim must divide its axes, else replicated):
      embed (V, d)            -> (model, data)     vocab TP + embed FSDP
      lm_head (d, V)          -> (data, model)
      experts_* (E, d, ff)    -> (model, data, -)  expert TP + d FSDP
      wq/wk/wv (d, H, Dh)     -> (data, model, -)  head TP + d FSDP
      wo (H, Dh, d)           -> (model, -, data)
      generic 2-D (din, dout) -> (data, model)     FSDP + output TP
      norms / 1-D             -> replicated
    ``blocks/...`` parameters are stacked over layers: the leading layer
    dim is the scan axis and stays unsharded.
    """
    parts = path.split("/")
    leaf = parts[-1]
    data = _data_axes(mesh)

    stacked = parts[0] == "blocks"
    core = shape[1:] if stacked else shape

    if "norm" in parts or leaf in ("scale", "bias") or len(core) < 2:
        spec = [None] * len(core)
    elif leaf == "embed":
        spec = ["model", data]
    elif leaf == "lm_head":
        spec = [data, "model"]
    elif "experts" in leaf:
        spec = ["model", data] + [None] * (len(core) - 2)
    elif leaf in ("wq", "wk", "wv") and len(core) == 3:
        spec = [data, "model", None]
    elif leaf == "wo" and len(core) == 3:
        spec = ["model", None, data]
    elif len(core) == 2:
        spec = [data, "model"]
    else:
        spec = [None] * len(core)

    spec = [_divisible(mesh, s, d) for s, d in zip(spec, core)]
    if stacked:
        spec = [None] + spec
    return P(*spec)


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def tree_param_shardings(params: Any, mesh):
    """NamedSharding for every leaf of a params (or eval_shape) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: NamedSharding(mesh, param_spec(_path_str(kp), x.shape,
                                                     mesh)),
        params,
    )


def batch_sharding(mesh):
    """Batch tensors: dim 0 over the data axes, rest replicated."""
    return NamedSharding(mesh, P(_data_axes(mesh)))


def host_local_mesh(axis_names=("data", "model")):
    """Mesh over THIS process's addressable devices — the surviving
    mesh of a multi-controller deployment after peers are gone.

    The elastic reform path (``repro.dist.multihost``) restores the
    newest verified checkpoint onto whatever devices the survivor still
    addresses; a global mesh would hang on dead hosts' devices, so the
    reform must shard over ``jax.local_devices()`` only.  Returns None
    when a single local device leaves nothing to shard over (callers
    pass ``mesh=None`` downstream — the unsharded path).
    """
    devs = jax.local_devices()
    if len(devs) < 2:
        return None
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devs).reshape(len(devs), 1), axis_names)


# ---------------------------------------------------------------------------
# device-resident example stores (LGD shard-by-example)
# ---------------------------------------------------------------------------

def shard_store_device(mesh, shard_id: int, n_shards: int):
    """Placement for corpus shard ``shard_id``'s token/feature store.

    The LGD pipeline uploads each shard's example store ONCE at build
    time; all per-step sampling, gathering and weighting then runs where
    the data lives — no host round-trip.  Under a single-controller mesh
    the store must be committed MESH-WIDE (replicated): the feature/query
    hooks take the model params, which are sharded across the whole
    mesh, and jit refuses inputs committed to mismatched device sets —
    a store pinned to one device cannot feed a mesh-spanning embed.
    (True per-DP-group residency is the multi-controller deployment,
    where each process only constructs its own shard's pipeline and the
    store never leaves the group's hosts; ``shard_id``/``n_shards``
    stay in the signature for that path.)  Returns None without a mesh
    (single-device hosts: the default device is the only choice).
    """
    del shard_id, n_shards
    if mesh is None:
        return None
    return NamedSharding(mesh, P())


def compose_sharded_batch(parts, mesh):
    """Assemble per-shard sub-batches into one global batch — on device.

    ``parts``: equal-length dim-0 slices, part s committed to shard s's
    device (see ``shard_store_device``).  The composed array is exactly
    the concatenation under ``batch_sharding(mesh)``, built with
    ``jax.make_array_from_single_device_arrays`` so a part that already
    sits on its DP group's device is adopted ZERO-COPY; the only
    transfers are device-to-device (model-axis replicas, or shard counts
    that do not match the data-parallel degree).  No host numpy anywhere.
    """
    sh = batch_sharding(mesh)
    rows = sum(p.shape[0] for p in parts)
    shape = (rows,) + tuple(parts[0].shape[1:])
    per = rows // len(parts)

    def pieces(start, stop):
        out, s = [], start // per
        while start < stop:
            take = min(stop, (s + 1) * per) - start
            out.append(parts[s][start - s * per:start - s * per + take])
            start, s = start + take, s + 1
        return out

    arrs = []
    for dev, idx in sh.addressable_devices_indices_map(shape).items():
        start = idx[0].start or 0
        stop = idx[0].stop if idx[0].stop is not None else rows
        ps = [jax.device_put(x, dev) for x in pieces(start, stop)]
        arrs.append(ps[0] if len(ps) == 1 else jax.numpy.concatenate(ps))
    return jax.make_array_from_single_device_arrays(shape, sh, arrs)
