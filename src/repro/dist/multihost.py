"""Multi-host elastic LGD: membership, liveness and reform protocol.

The multi-controller deployment of the sharded LGD pipeline (JAX
multi-process SPMD model): process r owns corpus shard r and its LSH
index (``ShardedLSHPipeline(..., owned_shards=[r])``) — embedding,
hashing and refresh all stay process-local, and only batch shards +
gradients/parameters cross the interconnect.  This module owns the
ROBUSTNESS layer that makes that deployment survive a lost host:

* HEARTBEATS — every process publishes ``hb/g<generation>/r<rank>``
  beats through the coordination service's KV store (or a shared
  filesystem, ``FileCoord``).  Staleness is judged OBSERVER-SIDE: each
  process stamps, on its OWN clock, the moment it sees a peer's beat
  counter advance, and a peer is dead when no NEW beat has been seen
  for ``heartbeat_timeout_s``.  Peer wall timestamps are never compared
  across hosts, so NTP skew can neither fake nor mask a host loss.
* BARRIER-GUARDED COLLECTIVES — cross-process collectives (parameter
  averaging, gradient all-reduce) are only ever entered behind a passed
  ``sync_barrier``: a barrier with a dead peer FAILS FAST with
  DEADLINE_EXCEEDED after ``barrier_timeout_s`` (verified against the
  JAX coordination service), where a collective with a dead peer would
  hang forever.  Barriers retry ``barrier_retries`` times with the same
  deterministic-jitter exponential backoff as the pipeline's refresh
  retries (``backoff_delay``), so a HUNG-but-alive host (dropped
  collective, GC pause) gets bounded grace before being treated as
  lost — per the ladder, a host slow past the retry budget IS a failed
  host.  After a loss the ``jax.distributed`` world STILL CONTAINS the
  dead rank, so backend collectives are off the table for the rest of
  the process's life; degraded survivors all-gather through the
  coordination plane instead (``ElasticCluster.exchange_blobs``).
* MEMBERSHIP GENERATIONS — every detected loss bumps ``generation``;
  heartbeat keys are generation-scoped so a re-formed cluster never
  reads a dead generation's beats.
* THE LADDER (``repro.data.health.ClusterHealthMonitor``):

      healthy ──barrier timeout + stale beat──▶ missing-host-degraded
      missing-host-degraded ──reform──────────▶ reformed

  Mid-incident the survivors ADOPT the lost shards
  (``ShardedLSHPipeline.adopt_shards`` — same shard count, same
  bounds, so w = S/(p·N) stays exactly unbiased) and keep training
  process-locally; the full REFORM then restores the newest verified
  checkpoint (``restore_latest_valid_on_mesh``) and rebuilds the
  pipeline with the surviving shard count
  (``rebuild_sharded_pipeline``) — bit-identical to a fresh restore on
  the same mesh.

* CLEAN DETACH — after an incident the JAX distributed runtime's
  shutdown barrier can never pass (the dead peer will not arrive) and
  aborts the interpreter; ``finalize_and_exit`` hard-exits the
  survivor once results are flushed.  Only use a normal interpreter
  exit while the full cluster is intact.

Coordinator loss (rank 0 by default) takes the coordination service
with it — survivors cannot barrier or read beats, which on this ladder
means the JOB restarts from the newest verified checkpoint rather than
reforming in place; the non-coordinator loss is the elastic path.

``ElasticCluster`` is transport-agnostic: it talks to a tiny KV+barrier
interface implemented by ``JaxCoord`` (the ``jax.distributed``
coordination service) and ``FileCoord`` (a shared directory — unit
tests exercise the whole protocol in-process with threads, no JAX
runtime anywhere).  See ``repro.dist.multihost_worker`` for the
runnable training worker and docs/ARCHITECTURE.md "Multi-host
deployment & failure model" for the full sequence diagram.
"""

from __future__ import annotations

import base64
import dataclasses
import json
import os
import sys
import tempfile
import time
import zlib
from typing import Dict, List, Optional, Sequence

from repro.data.health import ClusterHealthMonitor


class ClusterError(RuntimeError):
    """Coordination-service failure that is not a plain barrier
    timeout (lost coordinator, poisoned client, ...)."""


class BarrierTimeout(ClusterError):
    """A sync barrier did not clear within its bounded retries."""


class HostLossDetected(RuntimeError):
    """Raised (typically out of a trainer ``step_hook``) when the
    membership protocol declares peers lost; carries the incident."""

    def __init__(self, step: int, dead: Sequence[int]):
        self.step = int(step)
        self.dead = sorted(int(r) for r in dead)
        super().__init__(
            f"host loss at step {self.step}: dead ranks {self.dead}")


def backoff_delay(tag: str, attempt: int, base: float) -> float:
    """Exponential backoff with DETERMINISTIC jitter (PR 6 contract,
    shared with ``LSHSampledPipeline._sleep_backoff``): the jitter is a
    pure CRC32 function of ``(tag, attempt)`` — NOT of the rank — so
    every process sleeps identically and retry attempts stay aligned
    across the cluster without any extra coordination."""
    if base <= 0 or attempt <= 0:
        return 0.0
    j = (zlib.crc32(f"{tag}:{attempt}".encode()) % 1000) / 1000.0
    return base * (2 ** (attempt - 1)) * (1.0 + 0.5 * j)


def shard_adoption_map(n_shards: int, alive: Sequence[int]
                       ) -> Dict[int, int]:
    """Deterministic owner map after a loss: shard s stays with rank s
    when alive, otherwise round-robins over the sorted survivors —
    every process computes the identical map from the identical
    membership view, no election needed."""
    alive = sorted(set(int(r) for r in alive))
    if not alive:
        raise ValueError("no surviving ranks to adopt shards")
    out: Dict[int, int] = {}
    orphan = 0
    for s in range(n_shards):
        if s in alive:
            out[s] = s
        else:
            out[s] = alive[orphan % len(alive)]
            orphan += 1
    return out


@dataclasses.dataclass
class MultihostConfig:
    """Knobs of the elastic membership protocol."""

    rank: int = 0
    num_processes: int = 1
    coordinator: str = ""            # "host:port" (jax.distributed)
    # steps between heartbeat publications (every step by default —
    # one small KV write, off the device path).
    heartbeat_every: int = 1
    # a peer whose last beat is older than this is DEAD (wall seconds).
    heartbeat_timeout_s: float = 10.0
    # one barrier attempt's timeout; total grace for a slow host is
    # roughly barrier_timeout_s * (1 + barrier_retries) + backoffs.
    barrier_timeout_s: float = 5.0
    barrier_retries: int = 2
    barrier_backoff_s: float = 0.25
    # steps between barrier-guarded parameter syncs in the worker.
    sync_every: int = 5


def initialize(cfg: MultihostConfig):
    """``jax.distributed.initialize`` wrapper for the CPU/gloo path.

    Multi-process CPU collectives need the gloo implementation
    selected BEFORE the backend initialises (the default CPU client
    refuses cross-process computations); TPU/GPU ignore the setting.
    Safe to call once per process; no-op when num_processes == 1.
    """
    if cfg.num_processes <= 1:
        return
    import jax

    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):     # non-CPU builds / old jax
        pass
    jax.distributed.initialize(
        coordinator_address=cfg.coordinator,
        num_processes=cfg.num_processes,
        process_id=cfg.rank)


def jax_coord_client():
    """The live coordination-service client, or None outside a
    ``jax.distributed`` session."""
    try:
        from jax._src.distributed import global_state
    except ImportError:                       # pragma: no cover
        return None
    return getattr(global_state, "client", None)


class JaxCoord:
    """KV + barrier transport over the JAX coordination service."""

    def __init__(self, client=None):
        self.client = client if client is not None else jax_coord_client()
        if self.client is None:
            raise ClusterError(
                "no jax.distributed coordination client — call "
                "repro.dist.multihost.initialize first")

    def kv_set(self, key: str, value: str):
        try:
            self.client.key_value_set(key, value, allow_overwrite=True)
        except Exception as e:                # XlaRuntimeError etc.
            raise ClusterError(f"kv_set({key!r}) failed: {e}") from e

    def kv_dir(self, prefix: str) -> Dict[str, str]:
        try:
            items = self.client.key_value_dir_get(prefix)
        except Exception as e:
            raise ClusterError(f"kv_dir({prefix!r}) failed: {e}") from e
        return {k: v for k, v in items}

    def barrier(self, name: str, timeout_s: float,
                ranks: Optional[Sequence[int]] = None):
        procs = None if ranks is None else sorted(int(r) for r in ranks)
        try:
            self.client.wait_at_barrier(
                name, int(timeout_s * 1000), procs)
        except Exception as e:
            msg = str(e)
            if "DEADLINE_EXCEEDED" in msg or "timed out" in msg.lower():
                raise BarrierTimeout(
                    f"barrier {name!r} timed out after {timeout_s}s: "
                    f"{msg}") from e
            raise ClusterError(
                f"barrier {name!r} failed: {msg}") from e


class NullCoord:
    """Transport for a cluster of ONE: no peers, so every KV write is
    unread, and every barrier passes trivially."""

    def kv_set(self, key: str, value: str):
        pass

    def kv_dir(self, prefix: str) -> Dict[str, str]:
        return {}

    def barrier(self, name: str, timeout_s: float,
                ranks: Optional[Sequence[int]] = None):
        pass


class FileCoord:
    """KV + barrier transport over a shared directory.

    The same wire contract as ``JaxCoord`` on plain files: KV entries
    are atomic tmp+rename writes under ``root/kv/<key>``; a barrier is
    an arrival marker per rank under ``root/barriers/<name>/`` polled
    until every expected rank has arrived.  Used by the in-process unit
    tests (threads share one tmpdir) and usable as a real transport on
    any shared filesystem.  Liveness matches ``JaxCoord``, including
    the poisoning of timed-out ids: a rank that times out drops a
    ``FAILED`` tombstone into the barrier dir, so a slow rank arriving
    LATE at an abandoned attempt fails like its peers did instead of
    passing instantly on their stale markers (which would leave it
    believing a sync succeeded that everyone else gave up on —
    divergent membership views).
    """

    def __init__(self, root: str, rank: int, num_processes: int,
                 poll_s: float = 0.01):
        self.root = root
        self.rank = int(rank)
        self.num_processes = int(num_processes)
        self.poll_s = poll_s
        os.makedirs(os.path.join(root, "kv"), exist_ok=True)
        os.makedirs(os.path.join(root, "barriers"), exist_ok=True)

    def _kv_path(self, key: str) -> str:
        safe = key.replace("/", "__")
        return os.path.join(self.root, "kv", safe)

    def kv_set(self, key: str, value: str):
        path = self._kv_path(key)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path))
        with os.fdopen(fd, "w") as f:
            f.write(value)
        os.replace(tmp, path)

    def kv_dir(self, prefix: str) -> Dict[str, str]:
        safe = prefix.replace("/", "__")
        kv = os.path.join(self.root, "kv")
        out = {}
        for name in os.listdir(kv):
            if name.startswith(safe):
                try:
                    with open(os.path.join(kv, name)) as f:
                        out[name.replace("__", "/")] = f.read()
                except OSError:
                    continue                  # mid-rename race
        return out

    def barrier(self, name: str, timeout_s: float,
                ranks: Optional[Sequence[int]] = None):
        ranks = list(range(self.num_processes)) if ranks is None \
            else sorted(int(r) for r in ranks)
        d = os.path.join(self.root, "barriers", name.replace("/", "__"))
        os.makedirs(d, exist_ok=True)
        poison = os.path.join(d, "FAILED")
        if os.path.exists(poison):
            # a peer already timed this id out and moved on — a late
            # arrival must fail too (JaxCoord poisons timed-out ids).
            raise BarrierTimeout(
                f"barrier {name!r} was poisoned by a peer's timeout")
        with open(os.path.join(d, f"r{self.rank}"), "w") as f:
            f.write("1")
        deadline = time.monotonic() + timeout_s
        while True:
            if os.path.exists(poison):
                raise BarrierTimeout(
                    f"barrier {name!r} was poisoned by a peer's "
                    f"timeout")
            if all(os.path.exists(os.path.join(d, f"r{r}"))
                   for r in ranks):
                return
            if time.monotonic() >= deadline:
                # tombstone FIRST, then raise: whoever arrives after
                # this instant sees a failed attempt, not our stale
                # arrival markers.
                tmp = os.path.join(d, f".failed.r{self.rank}")
                with open(tmp, "w") as f:
                    f.write(f"r{self.rank}")
                os.replace(tmp, poison)
                missing = [r for r in ranks if not os.path.exists(
                    os.path.join(d, f"r{r}"))]
                raise BarrierTimeout(
                    f"barrier {name!r} timed out after {timeout_s}s "
                    f"(missing ranks {missing})")
            time.sleep(self.poll_s)


class ElasticCluster:
    """Membership + liveness for one process of a multi-host LGD run.

    Wraps a coordination transport (``JaxCoord``/``FileCoord``) with
    the elastic protocol: generation-scoped heartbeats, retrying
    barriers, failure classification and the deterministic adoption
    map.  Detection policy (both legs required before declaring a peer
    dead is WRONG — either suffices, they cover different faults):

    * a ``sync_barrier`` that exhausts its bounded retries flags the
      incident (covers hung/slow/partitioned hosts that still beat);
    * stale heartbeats then IDENTIFY the dead ranks (covers crashed
      hosts precisely); when every absent peer still beats, the
      barrier-blocking peers are treated as lost anyway — a host slow
      past the retry budget is a failed host.

    All state transitions land in ``health`` (the cluster ladder) so
    the incident history is auditable like the per-pipeline ladder.
    """

    def __init__(self, cfg: MultihostConfig, coord,
                 clock=time.time, sleep=time.sleep):
        self.cfg = cfg
        self.coord = coord
        self.rank = cfg.rank
        self.generation = 0
        self.alive = set(range(cfg.num_processes))
        self.health = ClusterHealthMonitor()
        self.fault_injector = None
        self._beat = 0
        # rank -> (last beat counter seen, OBSERVER clock when it was
        # first seen) — staleness never reads a peer's wall timestamp.
        self._last_seen: Dict[int, tuple] = {}
        # generation-LOCAL counters: survivors unwind an incident at
        # divergent trainer steps, so sync cadence and barrier names
        # must come from state every survivor resets together.
        self._steps_in_gen = 0
        self.sync_seq = 0
        self._clock = clock
        self._sleep = sleep

    # -- faults --------------------------------------------------------------

    def set_fault_injector(self, injector):
        """``repro.testing.faults`` port: fires ``cluster_step`` every
        heartbeat and ``sync_barrier`` before every barrier arrival."""
        self.fault_injector = injector

    def _fault(self, event: str, **info):
        if self.fault_injector is not None:
            self.fault_injector.fire(event, **info)

    # -- heartbeats ----------------------------------------------------------

    def heartbeat(self, step: int):
        """Publish this process's beat (generation-scoped) and refresh
        the observer-side view of every peer's."""
        self._fault("cluster_step", step=step, rank=self.rank)
        self._steps_in_gen += 1
        if step % max(self.cfg.heartbeat_every, 1) != 0:
            return
        self._beat += 1
        self.coord.kv_set(
            f"hb/g{self.generation}/r{self.rank}",
            json.dumps({"beat": self._beat, "step": int(step),
                        "t": self._clock()}))
        self.observe_peers()

    def peer_beats(self) -> Dict[int, dict]:
        """Latest published beat per rank in the current generation."""
        out = {}
        for key, val in self.coord.kv_dir(
                f"hb/g{self.generation}/").items():
            try:
                rank = int(key.rsplit("r", 1)[-1])
                out[rank] = json.loads(val)
            except (ValueError, json.JSONDecodeError):
                continue
        return out

    def observe_peers(self) -> Dict[int, dict]:
        """Refresh the observer-side receive stamps: a peer's staleness
        clock resets only when its BEAT COUNTER advances, timed on THIS
        process's clock.  Peer wall timestamps are never compared across
        hosts — clock skew of any size can neither fake a host loss nor
        mask one."""
        beats = self.peer_beats()
        now = self._clock()
        for r, b in beats.items():
            beat = int(b.get("beat", 0))
            prev = self._last_seen.get(r)
            if prev is None or beat > prev[0]:
                self._last_seen[r] = (beat, now)
        return beats

    def dead_peers(self) -> List[int]:
        """Alive-set ranks with no fresh beat: never observed in this
        generation, or whose beat counter has not advanced within
        ``heartbeat_timeout_s`` of observer-local time."""
        self.observe_peers()
        now = self._clock()
        dead = []
        for r in sorted(self.alive):
            if r == self.rank:
                continue
            seen = self._last_seen.get(r)
            if seen is None or \
                    now - seen[1] > self.cfg.heartbeat_timeout_s:
                dead.append(r)
        return dead

    # -- barriers ------------------------------------------------------------

    def sync_barrier(self, name: str):
        """Collective guard: every alive rank must arrive.

        Retries with attempt-suffixed barrier ids (a timed-out id is
        poisoned on the coordination service, and late arrivals at a
        passed id would race) and the deterministic-jitter backoff —
        keyed by ``(name, attempt)`` only, so all ranks sleep the same
        and re-converge on the same attempt id.  Raises
        ``BarrierTimeout`` when the retry budget is exhausted; the
        caller then runs ``classify_failure``.
        """
        ranks = sorted(self.alive)
        if ranks == [self.rank]:
            return                            # a cluster of one
        attempts = self.cfg.barrier_retries + 1
        last: Optional[Exception] = None
        for attempt in range(1, attempts + 1):
            try:
                self._fault("sync_barrier", name=name, attempt=attempt,
                            rank=self.rank)
                self.coord.barrier(
                    f"g{self.generation}/{name}/a{attempt}",
                    self.cfg.barrier_timeout_s, ranks)
                return
            except BarrierTimeout as e:
                last = e                      # waited the full window
            except Exception as e:            # FaultError / transport
                last = e
                if attempt < attempts:
                    # this rank FAILED TO ARRIVE (dropped collective)
                    # while its peers sit in the attempt's window until
                    # its timeout — burn the same window locally, or
                    # the retry counters desync by one attempt and the
                    # ranks never meet at the same barrier id again.
                    self._sleep(self.cfg.barrier_timeout_s)
            if attempt < attempts:
                self._sleep(backoff_delay(
                    name, attempt, self.cfg.barrier_backoff_s))
        raise BarrierTimeout(
            f"sync barrier {name!r} failed after {attempts} "
            f"attempt(s): {last}")

    def at_sync_boundary(self) -> bool:
        """True when the GENERATION-LOCAL step counter crosses a
        ``sync_every`` boundary.  Survivors unwind an incident at
        divergent trainer steps; counting hook steps within the
        generation (reset together by ``classify_failure``) keeps their
        cadence aligned so they keep meeting at the same barriers."""
        return (self._steps_in_gen > 0 and
                self._steps_in_gen % max(self.cfg.sync_every, 1) == 0)

    def next_sync_tag(self) -> str:
        """Survivor-agreed name for the next parameter sync: a
        per-generation sequence number, NOT the local trainer step —
        post-incident trainer steps diverge across survivors, and
        step-named barriers would time each other out and cascade into
        repeated false host-loss classifications."""
        self.sync_seq += 1
        return f"q{self.sync_seq}"

    def exchange_blobs(self, tag: str, payload: bytes
                       ) -> Dict[int, bytes]:
        """All-gather raw bytes across the CURRENT alive set through
        the coordination KV store (publish → survivor barrier → read).

        This is the degraded-mode collective: after a host loss the
        ``jax.distributed`` world still contains the dead rank, so any
        backend collective (``process_allgather`` & co.) would hang
        forever; the surviving subset exchanges through the
        coordination plane instead.  Keys are generation- and
        tag-scoped, so epochs never mix and a tag is never reused
        within one.  Raises ``BarrierTimeout`` if a survivor dies
        mid-exchange and ``ClusterError`` if a blob is missing after
        the barrier passed."""
        prefix = f"xg/g{self.generation}/{tag}/"
        self.coord.kv_set(prefix + f"r{self.rank}",
                          base64.b64encode(payload).decode("ascii"))
        self.sync_barrier(f"xg-{tag}")
        out: Dict[int, bytes] = {}
        for key, val in self.coord.kv_dir(prefix).items():
            try:
                r = int(key.rsplit("r", 1)[-1])
            except ValueError:
                continue
            if r in self.alive:
                out[r] = base64.b64decode(val)
        missing = sorted(set(self.alive) - set(out))
        if missing:
            raise ClusterError(
                f"exchange {tag!r}: blobs missing from ranks "
                f"{missing} after the barrier passed")
        return out

    # -- membership ----------------------------------------------------------

    def classify_failure(self, step: int) -> List[int]:
        """Declare the incident after a failed ``sync_barrier``: remove
        the dead ranks from the membership, bump the generation (stale
        beats can never leak into the new epoch) and move the ladder to
        missing-host-degraded.  Returns the dead ranks."""
        dead = self.dead_peers()
        reason = "stale heartbeat"
        if not dead:
            # every peer still beats, yet the barrier cannot clear past
            # its bounded retries: slow/partitioned == failed.
            dead = sorted(self.alive - {self.rank})
            reason = "barrier retries exhausted (host alive but stuck)"
        for r in dead:
            self.alive.discard(r)
        self.generation += 1
        # generation-local state restarts with the epoch: stale stamps
        # must not outlive the membership view they described, and the
        # survivors' sync cadence/naming re-aligns from zero.
        self._last_seen.clear()
        self._steps_in_gen = 0
        self.sync_seq = 0
        self.health.note_host_lost(step, dead, reason)
        return dead

    def adoption_map(self, n_shards: Optional[int] = None
                     ) -> Dict[int, int]:
        n = self.cfg.num_processes if n_shards is None else n_shards
        return shard_adoption_map(n, self.alive)

    def shards_to_adopt(self, n_shards: Optional[int] = None
                        ) -> List[int]:
        """Shard ids THIS rank must adopt under the deterministic map
        (beyond its own shard)."""
        return sorted(s for s, r in self.adoption_map(n_shards).items()
                      if r == self.rank and s != self.rank)

    def note_adopted(self, step: int, shards: Sequence[int]):
        for s in shards:
            self.health.note_adopted(step, int(s), self.rank)

    def note_reformed(self, step: int, n_shards: int):
        self.health.note_reformed(step, n_shards)

    @property
    def intact(self) -> bool:
        return len(self.alive) == self.cfg.num_processes

    def summary(self) -> dict:
        return {
            "rank": self.rank,
            "generation": self.generation,
            "alive": sorted(self.alive),
            **self.health.summary(),
        }


def claim_reform_writer(ckpt_dir: str, generation: int, rank: int,
                        alive: Sequence[int]) -> bool:
    """Single-writer election + generation fence for the reform path.

    Exactly ONE survivor may write checkpoints (and ``discard_after``)
    into the shared directory after a reform; concurrent writers would
    race tmp+rename saves and each other's ``discard_after``,
    corrupting the checkpoint history.  The writer is the LOWEST
    surviving rank — deterministically computable from the membership
    view, no election traffic.

    That alone is not enough under a symmetric split-brain (the
    slow-is-failed policy makes both sides of a partition declare each
    other dead, so BOTH become the minimum of their own alive set), so
    the claim is additionally fenced through an atomically-renamed
    marker in the checkpoint dir: a HIGHER generation beats a lower
    one (a stale writer from an older epoch is rejected), and ties
    break toward the lower rank.  The fence is best-effort — rename
    races have a window on real shared filesystems — but a losing or
    stale claimant that observes the fence abstains instead of
    writing.
    """
    alive = sorted(set(int(r) for r in alive))
    if not alive or int(rank) != alive[0]:
        return False
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, "reform_writer.json")
    mine = {"generation": int(generation), "rank": int(rank)}

    def read():
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError, ValueError):
            return None

    def priority(claim):                  # higher tuple wins the fence
        return (claim["generation"], -claim["rank"])

    for _ in range(3):
        cur = read()
        if cur is not None:
            if priority(cur) > priority(mine):
                return False
            if cur == mine:
                return True
        fd, tmp = tempfile.mkstemp(dir=ckpt_dir)
        with os.fdopen(fd, "w") as f:
            json.dump(mine, f)
        os.replace(tmp, path)
        time.sleep(0.05)                  # let a racing rename land
    return read() == mine


def finalize_and_exit(cluster: Optional[ElasticCluster], code: int = 0):
    """Exit a multihost worker safely.

    With the cluster INTACT, the normal interpreter exit is fine — the
    JAX distributed runtime's shutdown barrier has every participant.
    After an incident that barrier can NEVER pass (the dead peer will
    not arrive) and the runtime ABORTS the process from its atexit
    hook; the survivor must detach with ``os._exit`` once its results
    are flushed (verified against jax 0.4.37's shutdown path).
    """
    sys.stdout.flush()
    sys.stderr.flush()
    if cluster is not None and not cluster.intact:
        os._exit(code)
    sys.exit(code)
