"""One process of a multi-host elastic LGD run (+ the replay harness).

Runnable worker for the multi-controller deployment: process r owns
corpus shard r (``ShardedLSHPipeline(..., owned_shards=[r])``),
hashes/refreshes locally, and crosses the interconnect only for the
barrier-guarded parameter average every ``sync_every`` steps.  The
full elastic story, end to end in one process's life:

  1. HEALTHY — train on the local shard's draws; heartbeat each step;
     at sync boundaries pass ``sync_barrier`` then average params
     across processes (``process_allgather`` → host mean → fresh
     process-LOCAL arrays, so the params never stay committed to a
     mesh that includes peers that may die).
  2. INCIDENT — a sync barrier exhausts its retries; the step hook
     classifies the failure (stale heartbeats name the dead) and
     raises ``HostLossDetected``, unwinding ``Trainer.run`` at a clean
     step boundary.
  3. DEGRADED — the survivors ADOPT the lost shards
     (``adopt_shards``: same shard count and bounds, so batch weights
     keep the exact w = S/(p·N) form and E[mean w] = 1 mid-incident)
     and keep training; with more than one survivor the parameter
     sync continues over the coordination KV store
     (``exchange_blobs`` — the jax.distributed world still contains
     the dead rank, so backend collectives would hang forever), at a
     cadence/naming keyed by generation-local counters so survivors
     that unwound at divergent steps still meet.
  4. REFORM — restore the newest verified checkpoint
     (``restore_latest_valid_on_mesh``) and rebuild the pipeline with
     the surviving shard count (``rebuild_sharded_pipeline``,
     n_shards = survivors); ONE fenced writer (the lowest surviving
     rank, ``claim_reform_writer``) owns the shared checkpoint dir
     from here; the post-reform batch stream is bit-identical to a
     fresh restore of the same checkpoint (``replay_post_reform``
     below recomputes the digest to prove it).
  5. DETACH — results flushed, ``finalize_and_exit`` hard-exits (the
     distributed runtime's shutdown barrier can never pass once a peer
     is dead).

The tiny model/corpus mirror ``tools/chaos.py`` so a 2-process CPU run
finishes in CI seconds.  Faults are the deterministic injectors from
``repro.testing`` (``ProcKill``/``ProcHang``/``DropBarrier``), armed
per-rank from the command line.

Usage (one line per process, shared coordinator address):

    PYTHONPATH=src python -m repro.dist.multihost_worker \\
        --rank 0 --nprocs 2 --coordinator 127.0.0.1:9876 \\
        --ckpt-dir /tmp/mh/ckpt --result /tmp/mh/r0.json
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from typing import Any, Dict, List, Optional

import numpy as np

from .multihost import (
    BarrierTimeout,
    ElasticCluster,
    HostLossDetected,
    JaxCoord,
    MultihostConfig,
    NullCoord,
    claim_reform_writer,
    finalize_and_exit,
    initialize,
)

# deterministic tiny-stack constants, shared by the worker AND the
# replay harness — the reform digest is only meaningful because both
# rebuild from the identical (key, corpus, config) triple.
PIPE_KEY_SEED = 12
PARAM_KEY_SEED = 0
CORPUS = dict(seed=11, n_examples=256, seq_len=16, hard_frac=0.15)
LR = 1e-2


def model_cfg():
    from repro.models import ModelConfig
    return ModelConfig(
        name="multihost-worker", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=64, chunk=16, loss_chunk=16,
        dtype="float32", rope_theta=10000.0, lgd_enabled=True)


def pipe_cfg():
    from repro.data import LSHPipelineConfig
    # synchronous refresh: the elastic protocol is the thing under
    # test, and async refresh threads would outlive an os._exit drill.
    # RAW w = S/(p·N) weights (no mean-1 normalisation): a partial
    # owner never sees the global batch, and the unbiasedness check
    # E[mean w] = 1 is only meaningful on unnormalised weights.
    return LSHPipelineConfig(k=5, l=10, minibatch=16, refresh_every=10,
                             refresh_async=False, refresh_backoff=0.0,
                             normalize_weights=False)


def build_pipeline(params, n_shards: int,
                   owned_shards: Optional[List[int]] = None):
    """The deterministic worker pipeline (any shard layout): same key,
    corpus and config on every process, so shard s's draw stream is
    identical whichever process owns it."""
    import jax
    from repro.data import (
        ShardedLSHPipeline, lm_head_query_fn, make_token_corpus,
        mean_pool_feature_fn)
    cfg = model_cfg()
    corpus = make_token_corpus(CORPUS["seed"], CORPUS["n_examples"],
                               CORPUS["seq_len"], cfg.vocab,
                               hard_frac=CORPUS["hard_frac"])
    return ShardedLSHPipeline(
        jax.random.PRNGKey(PIPE_KEY_SEED), corpus.tokens,
        mean_pool_feature_fn(cfg), lm_head_query_fn(), pipe_cfg(),
        n_shards=n_shards, params=params, owned_shards=owned_shards)


class RecordBatches:
    """Sampler proxy recording every draw's (example_ids, loss_weights)
    — the raw material for the unbiasedness check (mean weight per
    batch) and the bit-determinism digest.  Full sampler surface
    delegates to the wrapped pipeline."""

    def __init__(self, inner):
        self._inner = inner
        self.records: List[tuple] = []     # (ids bytes, weights bytes)
        self.weight_means: List[float] = []

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def next_batch(self, *args, **kwargs):
        b = self._inner.next_batch(*args, **kwargs)
        ids = np.asarray(b["example_ids"], np.int64)
        w = np.asarray(b["loss_weights"], np.float32)
        self.records.append((ids.tobytes(), w.tobytes()))
        self.weight_means.append(float(w.mean()))
        return b


def batch_digest(records) -> str:
    """Order-sensitive digest over recorded draws: two streams agree
    iff every batch's ids AND weights agree bitwise, in order."""
    h = hashlib.sha256()
    for ids_bytes, w_bytes in records:
        h.update(ids_bytes)
        h.update(w_bytes)
    return h.hexdigest()


def _average_params(params, cluster: ElasticCluster):
    """Cross-process parameter average over the CURRENT alive set
    (local-SGD sync).

    Intact cluster: ``process_allgather`` over the full
    ``jax.distributed`` world — the fast path, on the interconnect.
    Degraded cluster (survivors after a host loss): the distributed
    world STILL CONTAINS the dead rank, so any backend collective
    would hang forever regardless of the survivor barrier passing —
    the surviving subset all-gathers through the coordination KV
    store instead (``exchange_blobs``, keyed by generation and sync
    sequence number).  Either way the result is materialised as fresh
    process-LOCAL arrays: leaving params committed to a global
    (all-process) sharding would poison every later LOCAL computation
    once a peer dies."""
    import io
    import jax
    import jax.numpy as jnp
    if cluster.intact:
        from jax.experimental import multihost_utils
        gathered = multihost_utils.process_allgather(params)
        return jax.tree.map(
            lambda g: jnp.asarray(np.asarray(g).mean(axis=0)), gathered)
    leaves, treedef = jax.tree.flatten(params)
    buf = io.BytesIO()
    np.savez(buf, *[np.asarray(x) for x in leaves])
    blobs = cluster.exchange_blobs(
        f"avg{cluster.sync_seq}", buf.getvalue())
    acc = None
    for _, raw in sorted(blobs.items()):
        with np.load(io.BytesIO(raw)) as z:
            peer = [z[f"arr_{i}"] for i in range(len(leaves))]
        acc = peer if acc is None else \
            [a + p for a, p in zip(acc, peer)]
    return jax.tree.unflatten(
        treedef, [jnp.asarray(a / len(blobs)) for a in acc])


def _state_template(cfg, params):
    from repro.optim import Adam
    return {"params": params, "opt_state": Adam(lr=LR).init(params)}


def replay_post_reform(ckpt_dir: str, restore_step: int, n_steps: int,
                       n_shards: int = 1) -> Dict[str, Any]:
    """Fresh restore of the reform checkpoint → digest of its stream.

    The determinism oracle for the acceptance test: rebuild EXACTLY
    what the survivor rebuilt (same checkpoint step, same shard count,
    same deterministic stack), run the same number of steps, and
    return the digest — bit-equality against the survivor's
    ``post_digest`` proves the reformed stream is a pure function of
    (checkpoint, shard count), not of the incident history.  Restores
    READ-ONLY at ``restore_step`` (no ``discard_after`` — the
    survivor's own post-reform checkpoints must outlive the replay).
    """
    import jax
    from repro.data import make_token_corpus, mean_pool_feature_fn, \
        lm_head_query_fn
    from repro.models import ModelConfig, init_params  # noqa: F401
    from repro.optim import Adam
    from repro.train import Trainer, TrainerConfig, checkpoint as ckpt
    from repro.train.elastic import rebuild_sharded_pipeline

    cfg = model_cfg()
    params0 = init_params(jax.random.PRNGKey(PARAM_KEY_SEED), cfg)
    state, extra = ckpt.restore(ckpt_dir, restore_step,
                                _state_template(cfg, params0))
    corpus = make_token_corpus(CORPUS["seed"], CORPUS["n_examples"],
                               CORPUS["seq_len"], cfg.vocab,
                               hard_frac=CORPUS["hard_frac"])
    pipe = rebuild_sharded_pipeline(
        jax.random.PRNGKey(PIPE_KEY_SEED), corpus.tokens,
        mean_pool_feature_fn(cfg), lm_head_query_fn(), pipe_cfg(),
        extra.get("step", restore_step), n_shards=n_shards,
        params=state["params"])
    rec = RecordBatches(pipe)
    tr = Trainer(cfg, state["params"], Adam(lr=LR),
                 tcfg=TrainerConfig(ckpt_dir=None, log_every=1000),
                 resume=False, sampler=rec)
    tr.opt_state = state["opt_state"]
    tr.step = extra.get("step", restore_step)
    out = tr.run(n_steps)
    tr.finalize()
    return {
        "digest": batch_digest(rec.records),
        "losses": out["losses"],
        "restore_step": tr.step - len(out["losses"]),
        "weight_means": rec.weight_means,
    }


def make_step_hook(cluster: ElasticCluster):
    """The trainer attachment point: heartbeat every step; at sync
    boundaries (``cluster.at_sync_boundary``, generation-local
    cadence), barrier then average params over the alive set.  Raises
    ``HostLossDetected`` out of the trainer when the barrier exhausts
    its retries — the worker's incident handler takes over."""

    def hook(tr):
        step = tr.step
        cluster.heartbeat(step)
        # boundary + barrier name both come from generation-LOCAL
        # counters, not tr.step: survivors unwind an incident at
        # divergent steps, and step-named barriers would time each
        # other out in a cascade of false host-loss classifications.
        if not cluster.at_sync_boundary():
            return
        if len(cluster.alive) <= 1:
            return                      # nothing to sync with
        try:
            cluster.sync_barrier(cluster.next_sync_tag())
            # the average itself may barrier again (degraded KV
            # exchange) — a survivor dying mid-exchange classifies
            # like any other loss instead of leaking BarrierTimeout.
            avg = _average_params(tr.params, cluster)
        except BarrierTimeout:
            raise HostLossDetected(step, cluster.classify_failure(step))
        tr.params = avg
        tr.sampler.set_params(avg)

    return hook


def run_worker(args) -> int:
    mcfg = MultihostConfig(
        rank=args.rank, num_processes=args.nprocs,
        coordinator=args.coordinator,
        heartbeat_timeout_s=args.heartbeat_timeout,
        barrier_timeout_s=args.barrier_timeout,
        barrier_retries=args.barrier_retries,
        barrier_backoff_s=args.barrier_backoff,
        sync_every=args.sync_every)
    initialize(mcfg)                    # before any backend touch

    import jax
    from repro.models import init_params
    from repro.optim import Adam
    from repro.testing import ProcHang, ProcKill
    from repro.train import Trainer, TrainerConfig, checkpoint as ckpt
    from repro.train.elastic import (
        rebuild_sharded_pipeline, restore_latest_valid_on_mesh)

    coord = JaxCoord() if mcfg.num_processes > 1 else NullCoord()
    cluster = ElasticCluster(mcfg, coord)
    if args.kill_at is not None:
        cluster.set_fault_injector(ProcKill(at_step=args.kill_at))
    elif args.hang_at is not None:
        cluster.set_fault_injector(
            ProcHang(at_step=args.hang_at, seconds=args.hang_seconds))

    # per-step wall clocks (one stamp per completed step, sync cost
    # included at sync boundaries) — raw material for tab_multihost's
    # 2-process-vs-1-process step-time comparison.
    step_stamps: List[float] = []
    timings: Dict[str, Any] = {"step_stamps": step_stamps}

    cfg = model_cfg()
    params = init_params(jax.random.PRNGKey(PARAM_KEY_SEED), cfg)
    pipe = build_pipeline(params, n_shards=args.nprocs,
                          owned_shards=[args.rank])
    rec = RecordBatches(pipe)
    # checkpoints: rank 0 writes (one writer — no cross-host fs races);
    # every rank knows the path for the reform restore.
    elastic_hook = make_step_hook(cluster)

    def timed_hook(tr_):
        elastic_hook(tr_)               # may raise HostLossDetected
        step_stamps.append(time.perf_counter())

    tcfg = TrainerConfig(
        ckpt_dir=args.ckpt_dir if args.rank == 0 else None,
        ckpt_every=args.ckpt_every, log_every=1000,
        step_hook=timed_hook)
    tr = Trainer(cfg, params, Adam(lr=LR), tcfg=tcfg, resume=False,
                 sampler=rec)

    result: Dict[str, Any] = {"rank": args.rank, "incident": None}
    incident = None
    try:
        out = tr.run(args.steps)
        result["losses_pre"] = out["losses"]
    except HostLossDetected as e:
        incident = e

    if incident is not None:
        result["incident"] = {"step": incident.step,
                              "dead": incident.dead}
        result["pre_steps"] = tr.step   # run() unwound; no losses list
        # -- DEGRADED: adopt the lost shards, keep training locally ---
        adopt = cluster.shards_to_adopt(args.nprocs)
        pipe.adopt_shards(adopt, step=tr.step)
        cluster.note_adopted(tr.step, adopt)
        # the raise unwound run() AFTER its prefetch draw: the old
        # shards' counters sit one draw ahead of tr.step.  Realign the
        # whole pipeline (cheap — counters only, no rebuild).
        pipe.restore_at(tr.step, rebuild=False)
        n_before = len(rec.records)
        out_deg = tr.run(args.degraded_steps)
        result["losses_degraded"] = out_deg["losses"]
        result["degraded_weight_means"] = rec.weight_means[n_before:]
        tr.finalize()

        # -- REFORM: newest verified checkpoint, surviving shards -----
        n_surv = len(cluster.alive)
        # single writer: the lowest surviving rank claims the shared
        # dir through the generation fence; every other survivor (or a
        # split-brain loser) restores READ-ONLY — concurrent writers
        # would race each other's saves and discard_after and corrupt
        # the checkpoint history.
        writer = claim_reform_writer(
            args.ckpt_dir, cluster.generation, args.rank, cluster.alive)
        t_reform0 = time.perf_counter()
        step_r, state, extra = restore_latest_valid_on_mesh(
            args.ckpt_dir, _state_template(cfg, params), mesh=None)
        from repro.data import make_token_corpus, \
            mean_pool_feature_fn, lm_head_query_fn
        corpus = make_token_corpus(
            CORPUS["seed"], CORPUS["n_examples"], CORPUS["seq_len"],
            cfg.vocab, hard_frac=CORPUS["hard_frac"])
        pipe2 = rebuild_sharded_pipeline(
            jax.random.PRNGKey(PIPE_KEY_SEED), corpus.tokens,
            mean_pool_feature_fn(cfg), lm_head_query_fn(), pipe_cfg(),
            extra.get("step", step_r), n_shards=n_surv,
            params=state["params"])
        rec2 = RecordBatches(pipe2)

        def mark_first_post_step(tr_):
            # reform-time-to-first-step: restore + rebuild + the first
            # post-reform trainer step, one number (tab_multihost).
            timings.setdefault(
                "reform_to_first_step_s",
                time.perf_counter() - t_reform0)

        tr2 = Trainer(cfg, state["params"], Adam(lr=LR),
                      tcfg=TrainerConfig(
                          ckpt_dir=args.ckpt_dir if writer else None,
                          ckpt_every=args.ckpt_every,
                          log_every=1000,
                          step_hook=mark_first_post_step),
                      resume=False, sampler=rec2)
        tr2.opt_state = state["opt_state"]
        tr2.step = extra.get("step", step_r)
        if writer:
            # the incident timeline past the restore point is
            # abandoned — the reformed run's own writes are
            # authoritative.  Writer-only: a racing discard here is
            # exactly the corruption the fence exists to prevent.
            ckpt.discard_after(args.ckpt_dir, tr2.step)
        cluster.note_reformed(tr2.step, n_surv)
        result["restore_step"] = tr2.step
        result["reform_shards"] = n_surv
        result["reform_writer"] = writer
        out_post = tr2.run(args.post_steps)
        tr2.finalize()
        result["losses_post"] = out_post["losses"]
        result["post_digest"] = batch_digest(rec2.records)
        result["post_draws"] = len(rec2.records)
    else:
        tr.finalize()
        result["final_step"] = tr.step
        result["weight_means"] = rec.weight_means
        result["digest"] = batch_digest(rec.records)

    result["cluster"] = cluster.summary()
    result["timings"] = timings
    if args.result:
        os.makedirs(os.path.dirname(args.result) or ".", exist_ok=True)
        tmp = args.result + ".tmp"
        with open(tmp, "w") as f:
            json.dump(result, f)
        os.replace(tmp, args.result)
    finalize_and_exit(cluster, 0)


def build_arg_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rank", type=int, required=True)
    ap.add_argument("--nprocs", type=int, required=True)
    ap.add_argument("--coordinator", default="127.0.0.1:9876")
    ap.add_argument("--ckpt-dir", required=True,
                    help="shared checkpoint dir (rank 0 writes pre-"
                         "incident; the fenced lowest survivor after)")
    ap.add_argument("--result", default="",
                    help="write this rank's result JSON here")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--sync-every", type=int, default=5)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--degraded-steps", type=int, default=6)
    ap.add_argument("--post-steps", type=int, default=10)
    ap.add_argument("--heartbeat-timeout", type=float, default=3.0)
    ap.add_argument("--barrier-timeout", type=float, default=2.0)
    ap.add_argument("--barrier-retries", type=int, default=1)
    ap.add_argument("--barrier-backoff", type=float, default=0.1)
    ap.add_argument("--kill-at", type=int, default=None,
                    help="hard-exit THIS rank at this step (ProcKill)")
    ap.add_argument("--hang-at", type=int, default=None,
                    help="stall THIS rank at this step (ProcHang)")
    ap.add_argument("--hang-seconds", type=float, default=8.0)
    return ap


def main(argv=None) -> int:
    return run_worker(build_arg_parser().parse_args(argv))


if __name__ == "__main__":
    main()
