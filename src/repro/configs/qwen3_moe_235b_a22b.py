"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936,
MoE 128e top-8.  Every layer is MoE (fine-grained experts, Qwen3 style).
"""

from repro.models import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=0,
    vocab=151936,
    moe_experts=128,
    moe_top_k=8,
    moe_d_ff=1536,
    block_pattern=("attn",),
)

SMOKE = FULL.with_(
    name="qwen3-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    vocab=128,
    moe_experts=8,
    moe_top_k=2,
    moe_d_ff=32,
    chunk=16,
    loss_chunk=16,
    dtype="float32",
)
