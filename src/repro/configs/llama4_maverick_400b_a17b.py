"""llama4-maverick-400b-a17b [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
The early-fusion multimodal frontend is out of the LM backbone scope
(per the assignment the backbone only is modelled); every layer routes
top-1 over 128 experts of d_ff=8192.
"""

from repro.models import ModelConfig

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=0,
    vocab=202048,
    moe_experts=128,
    moe_top_k=1,
    moe_d_ff=8192,
    block_pattern=("attn",),
)

SMOKE = FULL.with_(
    name="llama4-maverick-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    vocab=128,
    moe_experts=8,
    moe_top_k=1,
    moe_d_ff=32,
    chunk=16,
    loss_chunk=16,
    dtype="float32",
)
