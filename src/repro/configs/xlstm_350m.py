"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.  Block ratio follows
the paper's xLSTM[7:1]: seven mLSTM blocks per sLSTM block (period 8,
3 repeats).  d_ff=0: xLSTM blocks carry no separate FFN (the mLSTM
up/down projections play that role).
"""

from repro.models import ModelConfig

FULL = ModelConfig(
    name="xlstm-350m",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    chunk=256,
    rope_theta=10000.0,
)

SMOKE = FULL.with_(
    name="xlstm-350m-smoke",
    n_layers=8,
    d_model=64,
    vocab=128,
    chunk=16,
    loss_chunk=16,
    dtype="float32",
)
