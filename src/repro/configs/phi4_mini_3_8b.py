"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""

from repro.models import ModelConfig

FULL = ModelConfig(
    name="phi4-mini-3.8b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    act="swiglu",
)

SMOKE = FULL.with_(
    name="phi4-mini-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=128,
    chunk=16,
    loss_chunk=16,
    dtype="float32",
)
