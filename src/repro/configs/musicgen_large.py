"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=2048.  The EnCodec
frontend is a STUB: ``input_specs`` provides precomputed frame embeddings
(B, S, d_model); the backbone predicts codebook tokens (vocab 2048).
"""

from repro.models import ModelConfig

FULL = ModelConfig(
    name="musicgen-large",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    act="gelu",
    frontend="embed_stub",
)

SMOKE = FULL.with_(
    name="musicgen-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=64,
    chunk=16,
    loss_chunk=16,
    dtype="float32",
)
