"""starcoder2-15b [dense] — GQA, RoPE [arXiv:2402.19173].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.  StarCoder2 uses
a plain (non-gated) GELU MLP.
"""

from repro.models import ModelConfig

FULL = ModelConfig(
    name="starcoder2-15b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    act="gelu",
)

SMOKE = FULL.with_(
    name="starcoder2-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=128,
    chunk=16,
    loss_chunk=16,
    dtype="float32",
)
