"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the FULL production config; ``get_smoke(name)``
the reduced same-family config used by CPU smoke tests.  FULL configs
are only ever lowered via ShapeDtypeStructs (launch/dryrun.py) — never
allocated on the CPU host.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models import ModelConfig

ARCHS: List[str] = [
    "xlstm_350m",
    "qwen3_moe_235b_a22b",
    "llama4_maverick_400b_a17b",
    "phi4_mini_3_8b",
    "granite_3_8b",
    "starcoder2_15b",
    "nemotron_4_15b",
    "musicgen_large",
    "llama_3_2_vision_90b",
    "zamba2_1_2b",
]

# accepted CLI aliases (--arch with dashes/dots)
def _canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_canon(name)}")
    return mod.FULL


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_canon(name)}")
    return mod.SMOKE


def all_archs() -> List[str]:
    return list(ARCHS)
