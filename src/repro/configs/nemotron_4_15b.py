"""nemotron-4-15b [dense] — GQA, squared-ReLU [arXiv:2402.16819; unverified].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
"""

from repro.models import ModelConfig

FULL = ModelConfig(
    name="nemotron-4-15b",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    act="squared_relu",
)

SMOKE = FULL.with_(
    name="nemotron-4-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=128,
    act="squared_relu",
    chunk=16,
    loss_chunk=16,
    dtype="float32",
)
