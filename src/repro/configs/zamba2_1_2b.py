"""zamba2-1.2b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
Zamba2 design: a Mamba-2 backbone with ONE shared attention(+MLP) block
interleaved periodically (weights shared across its occurrences).  Here:
pattern of 19 layers = 18 mamba2 + 1 shared_attn, repeated twice.
"""

from repro.models import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    block_pattern=("mamba2",) * 18 + ("shared_attn",),
    ssm_state=64,
    rope_theta=10000.0,
)

SMOKE = FULL.with_(
    name="zamba2-smoke",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=128,
    block_pattern=("mamba2", "mamba2", "mamba2", "shared_attn"),
    ssm_state=16,
    chunk=16,
    loss_chunk=16,
    dtype="float32",
)
