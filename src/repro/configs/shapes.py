"""Assigned input shapes and their ShapeDtypeStruct stand-ins.

Five shapes per LM architecture:
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill (inference)
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524,288 global_batch 1     -> serve_step; needs
                                                 sub-quadratic attention,
                                                 run only for SSM/hybrid
                                                 archs (cfg.supports_long_context)
  vocab_large  seq 4,096   global_batch 64    -> serve_step with the arch's
                                                 vocab OVERRIDDEN to 131,072
                                                 (production-LM vocab): the
                                                 dryrun/roofline-only cell
                                                 where the O(V·d) head
                                                 dominates the decode byte
                                                 budget and the LSH-sampled
                                                 softmax ratio is projected
                                                 (benchmarks/run.py
                                                 tab_softmax); never run as a
                                                 tier-1 compute cell.

A ``ShapeSpec.vocab`` override applies only on the abstract-eval paths
(``launch.dryrun.run_cell`` and ``launch.roofline``) — smoke/tier-1
configs keep their small vocabs so test runtime is unaffected.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import ModelConfig
from repro.models.lm import ATTN_KINDS
from repro.models import ssm as ssm_mod


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str     # "train" | "prefill" | "decode"
    # when set, the cell runs with cfg.vocab overridden (dryrun/roofline
    # abstract-eval only — see apply_vocab)
    vocab: Optional[int] = None


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
    "vocab_large": ShapeSpec("vocab_large", 4_096, 64, "decode",
                             vocab=131_072),
}


def apply_vocab(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """The config the cell actually runs: vocab overridden when the
    shape pins one (vocab_large), unchanged otherwise."""
    if shape.vocab is None or shape.vocab == cfg.vocab:
        return cfg
    return dataclasses.replace(cfg, vocab=shape.vocab)


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention arch: 500k-token context is "
                "quadratic-prefill/O(seq) KV-cache territory reserved for "
                "sub-quadratic mixers per the assignment (see DESIGN.md)")
    return None


def _f(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _i(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for the model-input batch dict."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, object] = {}
    if shape.kind == "decode":
        if cfg.frontend == "embed_stub":
            specs["embeds"] = _f((b, 1, cfg.d_model), dt)
        else:
            specs["tokens"] = _i((b, 1))
        specs["positions"] = _i((b, 1))
    else:
        if cfg.frontend == "embed_stub":
            specs["embeds"] = _f((b, s, cfg.d_model), dt)
        else:
            specs["tokens"] = _i((b, s))
        if shape.kind == "train":
            specs["targets"] = _i((b, s))
    if "cross_attn" in cfg.block_pattern:
        specs["image_embeds"] = _f((b, max(cfg.n_patches, 1), cfg.d_model), dt)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> list:
    """ShapeDtypeStructs matching models.lm.init_cache output."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    r = cfg.repeats
    out = []
    for kind in cfg.block_pattern:
        if kind in ATTN_KINDS:
            out.append({"attn": {
                "k": _f((r, b, s, cfg.n_kv_heads, cfg.d_head), dt),
                "v": _f((r, b, s, cfg.n_kv_heads, cfg.d_head), dt),
                "len": _i((r, b)),
            }})
        elif kind == "mamba2":
            d_inner = cfg.ssm_expand * cfg.d_model
            nh = d_inner // cfg.ssm_head_dim
            out.append({"state": _f(
                (r, b, nh, cfg.ssm_state, cfg.ssm_head_dim))})
        elif kind == "mlstm":
            dh = cfg.d_model // cfg.n_heads
            out.append({"state": _f((r, b, cfg.n_heads, dh, dh + 1))})
        elif kind == "slstm":
            out.append({"state": tuple(
                _f((r, b, cfg.d_model)) for _ in range(3))})
        else:
            raise ValueError(kind)
    return out
