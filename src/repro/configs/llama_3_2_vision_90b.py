"""llama-3.2-vision-90b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  Every 5th
layer cross-attends to image patch embeddings; the vision encoder is a
STUB (``input_specs`` provides precomputed patch embeddings, n_patches
= 1024 ~ one 1600-patch tile pooled).
"""

from repro.models import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-90b",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    act="swiglu",
    block_pattern=("attn", "attn", "attn", "attn", "cross_attn"),
    n_patches=1024,
)

SMOKE = FULL.with_(
    name="llama-vision-smoke",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=128,
    n_patches=8,
    chunk=16,
    loss_chunk=16,
    dtype="float32",
)
