"""Memory-efficient chunked attention in pure XLA (lax.scan over q-blocks).

This is the XLA twin of the Pallas flash kernel: on the dry-run host
(and any non-TPU backend) it gives the same O(S * block) activation
memory so 32k-token prefill/train cells fit HBM, while keeping the HLO
analyzable for the roofline accounting.  On TPU targets the Pallas
kernel replaces it (cfg.attn_impl = "pallas").

Schedule: outer lax.scan over query blocks; each step attends its block
to the full (masked) KV — softmax in f32 with the usual max-subtraction.
The step body is rematerialised so the backward pass recomputes the
(block_q x S) score matrix instead of storing it.

Note the causal mask is applied by `where`, so the XLA path spends ~2x
the minimal causal FLOPs on above-diagonal blocks; the Pallas kernel
skips those blocks structurally.  Recorded in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def chunked_gqa_attention(
    q: jax.Array,   # (B, S, Hq, D)
    k: jax.Array,   # (B, S_kv, Hkv, D)
    v: jax.Array,   # (B, S_kv, Hkv, D)
    *,
    causal: bool = True,
    block_q: int = 1024,
    scale: float | None = None,
) -> jax.Array:
    b, s, hq, d = q.shape
    s_kv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    bq = min(block_q, s)
    pad = (-s) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (s + pad) // bq

    qg = q.reshape(b, nq, bq, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, Hkv, G, bq, D)
    kg = k.transpose(0, 2, 1, 3)         # (B, Hkv, S_kv, D)
    vg = v.transpose(0, 2, 1, 3)
    kv_pos = jnp.arange(s_kv)

    @jax.checkpoint
    def step(carry, xs):
        qi, block_idx = xs               # (B,Hkv,G,bq,D), scalar
        logits = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
            kg.astype(jnp.float32)) * scale
        if causal:
            q_pos = block_idx * bq + jnp.arange(bq)
            mask = q_pos[:, None] >= kv_pos[None, :]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        m = jnp.max(logits, axis=-1, keepdims=True)
        p = jnp.exp(logits - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p / jnp.maximum(l, 1e-30),
                       vg.astype(jnp.float32))
        return carry, o.astype(q.dtype)

    _, outs = jax.lax.scan(step, (), (qg, jnp.arange(nq)))
    # (nq, B, Hkv, G, bq, D) -> (B, S, Hq, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(
        b, nq * bq, hq, d)
    return out[:, :s]
