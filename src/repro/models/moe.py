"""Mixture-of-Experts FFN with sort-based capacity dispatch (GShard-style).

TPU adaptation: no ragged tensors — tokens are routed to a fixed
(E, C, d) buffer via a sort + rank-in-expert computation so every shape
is static.  Tokens beyond an expert's capacity C are dropped (their
residual passes through), the standard trade on TPU (Switch/GShard).

Expert weights are laid out (E, d, ff) and sharded expert-parallel along
the 'model' mesh axis (see dist/sharding.PARAM_RULES) — the dispatch
then lowers to an all-to-all over the expert dimension.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical
from .config import ModelConfig
from .layers import init_rmsnorm, rms_norm


def init_moe(key, cfg: ModelConfig):
    d, ffe, e = cfg.d_model, cfg.moe_d_ff, cfg.moe_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    s_in, s_out = d ** -0.5, ffe ** -0.5
    return {
        "norm": init_rmsnorm(d),
        "router": (jax.random.normal(kr, (d, e)) * s_in).astype(jnp.float32),
        "experts_gate": (jax.random.normal(kg, (e, d, ffe)) * s_in).astype(dt),
        "experts_up": (jax.random.normal(ku, (e, d, ffe)) * s_in).astype(dt),
        "experts_down": (jax.random.normal(kd, (e, ffe, d)) * s_out).astype(dt),
    }


def _dispatch_one_group(h, logits, e, k, capacity):
    """Token dispatch within ONE group (a batch row): all sort/rank work is
    local to the group, so it shards cleanly over the data axis.

    h: (T, d); logits: (T, E).  Returns (buf (E, C, d), combine info)."""
    t, d = h.shape
    gates, experts = jax.lax.top_k(logits, k)               # (T, k)
    gates = jax.nn.softmax(gates, axis=-1).astype(h.dtype)

    flat_expert = experts.reshape(-1)                        # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)                # (T*k,)
    flat_gate = gates.reshape(-1)

    # rank within expert via sort (static shapes)
    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    seg_start = jnp.searchsorted(sorted_expert, jnp.arange(e))
    rank_sorted = jnp.arange(t * k) - seg_start[sorted_expert]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))

    keep = rank < capacity
    slot = jnp.where(keep, flat_expert * capacity + rank, e * capacity)
    buf = jnp.zeros((e * capacity, d), h.dtype)
    buf = buf.at[slot].set(h[flat_token], mode="drop")
    return buf.reshape(e, capacity, d), (slot, keep, flat_token, flat_gate)


def _combine_one_group(out_buf, info, t, d, dtype):
    slot, keep, flat_token, flat_gate = info
    flat = out_buf.reshape(-1, d)
    gathered = jnp.where(
        keep[:, None], flat.at[slot].get(mode="fill", fill_value=0), 0)
    return jnp.zeros((t, d), dtype).at[flat_token].add(
        gathered * flat_gate[:, None])


def moe_ffn(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, d) -> (B, S, d) with residual add.

    GShard-style GROUPED dispatch: each batch row is a dispatch group with
    its own capacity, so the sort/rank/scatter tensors keep the batch dim
    and stay sharded over the data axis (a global-token sort would force
    full replication under SPMD — measured 137 GB/device on the 235B
    config before this layout).  Expert weights are sharded over the
    model axis; GSPMD lowers the (group, expert) einsums to all-to-alls.
    """
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    capacity = max(int(s * k / e * cfg.moe_capacity_factor), 1)

    h = rms_norm(p["norm"], x, cfg.norm_eps)                 # (B, S, d)
    # dispatch must be LOCAL per batch row: pin h to batch-only sharding
    # (un-shard seq) so the scatter/gather of tokens into the expert
    # buffer never crosses a mesh axis — GSPMD otherwise replicates the
    # buffers via TB-scale all-reduces (measured: 3.2 TB/step on qwen3).
    h = logical(h, "batch", None, None)
    logits = jnp.einsum("bsd,de->bse", h.astype(jnp.float32), p["router"])

    buf, info = jax.vmap(
        lambda hh, ll: _dispatch_one_group(hh, ll, e, k, capacity)
    )(h, logits)                                             # buf (B,E,C,d)
    buf = logical(buf, "batch", "experts", None, None)

    gate_h = jnp.einsum("becd,edf->becf", buf, p["experts_gate"])
    up_h = jnp.einsum("becd,edf->becf", buf, p["experts_up"])
    act = jax.nn.silu(gate_h) * up_h
    out_buf = jnp.einsum("becf,efd->becd", act, p["experts_down"])
    out_buf = logical(out_buf, "batch", "experts", None, None)

    out = jax.vmap(
        lambda ob, inf: _combine_one_group(ob, inf, s, d, h.dtype)
    )(out_buf, info)
    out = logical(out, "batch", None, None)
    return x + out


def aux_load_balance_loss(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * p_e (optional add-on)."""
    b, s, d = x.shape
    h = rms_norm(p["norm"], x, cfg.norm_eps).reshape(b * s, d)
    probs = jax.nn.softmax(h.astype(jnp.float32) @ p["router"], axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, cfg.moe_experts), axis=0)
    pmean = jnp.mean(probs, axis=0)
    return cfg.moe_experts * jnp.sum(f * pmean)
