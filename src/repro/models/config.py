"""Unified model configuration covering all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                   # 0 -> d_model // n_heads

    # activation / FFN
    act: str = "swiglu"               # swiglu | gelu | squared_relu

    # MoE (0 experts = dense)
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25

    # layer pattern, cycled to n_layers.  Block kinds:
    #   attn          self-attention + FFN
    #   cross_attn    self-attn + cross-attn(image) + FFN  (vision layers)
    #   mamba2        Mamba-2 SSD block
    #   mlstm         xLSTM matrix-LSTM block
    #   slstm         xLSTM scalar-LSTM block
    #   shared_attn   attention block with weights shared across repeats
    block_pattern: Tuple[str, ...] = ("attn",)

    # sequence-mixer extras
    ssm_state: int = 0                # Mamba2 state size N
    ssm_head_dim: int = 64            # Mamba2/mLSTM head dim P
    ssm_expand: int = 2               # d_inner = expand * d_model
    chunk: int = 256                  # chunked-scan length for SSM/linear attn

    # modality frontend: "none" = token ids; "embed_stub" = precomputed
    # frame/patch embeddings are the input (audio/vlm backbones).
    frontend: str = "none"
    n_patches: int = 0                # vision: image patch count (stub)

    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # execution knobs
    attn_impl: str = "chunked"        # chunked (XLA flash) | ref | pallas
    attn_block_q: int = 512           # q-block for the chunked scan
    # sequence parallelism: shard the residual stream's seq dim over the
    # model axis at layer boundaries (Megatron-SP) — divides saved remat
    # activations and norm/embedding work by the TP degree.
    seq_shard: bool = True
    remat: bool = True
    loss_chunk: int = 1024            # vocab-projection chunk (tokens)
    scan_layers: bool = True          # lax.scan over pattern repeats

    # LGD integration (data-pipeline-level adaptive sampling)
    lgd_enabled: bool = False
    lgd_k: int = 7
    lgd_l: int = 10
    lgd_refresh_every: int = 200

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0, (
            self.n_heads, self.n_kv_heads)
        if self.n_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not a multiple of "
                f"pattern length {len(self.block_pattern)}")

    @property
    def repeats(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return all(b in ("mamba2", "mlstm", "slstm")
                   for b in self.block_pattern)

    @property
    def has_ssm(self) -> bool:
        return any(b in ("mamba2", "mlstm", "slstm")
                   for b in self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs: SSM/hybrid/linear-attn run long_500k."""
        return self.has_ssm

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
