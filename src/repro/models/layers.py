"""Shared neural layers: norm, RoPE, GQA attention, FFN, losses.

Functional style: ``init_*`` returns a params dict; ``apply`` functions
are pure.  Activations carry logical sharding annotations
(repro.dist.sharding.logical) that are no-ops outside a mesh context.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical
from repro.kernels.flash_attention import gqa_attention, gqa_decode
from .config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (self / cross), with optional KV cache
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    dh, hq, hkv = cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    scale = d ** -0.5
    dt = _dtype(cfg)
    return {
        "norm": init_rmsnorm(d),
        "wq": (jax.random.normal(kq, (d, hq, dh)) * scale).astype(dt),
        "wk": (jax.random.normal(kk, (d, hkv, dh)) * scale).astype(dt),
        "wv": (jax.random.normal(kv, (d, hkv, dh)) * scale).astype(dt),
        "wo": (jax.random.normal(ko, (hq, dh, d)) * scale * 0.5).astype(dt),
    }


def attention(
    p,
    cfg: ModelConfig,
    x: jax.Array,                     # (B, S, d)
    positions: jax.Array,
    *,
    kv: Optional[jax.Array] = None,   # cross-attn memory (B, S_mem, d)
    cache: Optional[dict] = None,     # {"k","v","len"} decode cache
    causal: bool = True,
):
    """Returns (out, new_cache)."""
    h = rms_norm(p["norm"], x, cfg.norm_eps)
    # one all-gather of the (seq-sharded) residual per attention block,
    # shared by the q/k/v projections — instead of one per einsum.
    h = logical(h, "batch", None, None)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    q = logical(q, "batch", None, "heads", None)
    src = h if kv is None else kv      # memory (e.g. image patch embeds)
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    k = logical(k, "batch", None, "heads", None)
    v = logical(v, "batch", None, "heads", None)

    # cross-attention (q-len != kv-len) takes the plain XLA path; the
    # flash kernel / chunked scan handle the self-attention hot spot.
    impl = cfg.attn_impl if kv is None else "ref"
    if cache is None or x.shape[1] > 1:
        # full-sequence path (training, or prefill writing into the cache)
        if kv is None:   # self attention with rope
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        if impl == "chunked":
            from .attention_xla import chunked_gqa_attention
            out = chunked_gqa_attention(
                q, k, v, causal=causal and kv is None,
                block_q=cfg.attn_block_q)
        else:
            out = gqa_attention(q, k, v, causal=causal and kv is None,
                                use_pallas=impl == "pallas")
        new_cache = None
        if kv is None and cache is not None:
            s = x.shape[1]
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
                "len": jnp.full((x.shape[0],), s, jnp.int32),
            }
    else:
        # single-token decode: append to cache, flash-decode over it
        assert x.shape[1] == 1
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        b = x.shape[0]
        idx = cache["len"]             # (B,) current lengths
        k_cache = cache["k"].at[jnp.arange(b), idx].set(k[:, 0])
        v_cache = cache["v"].at[jnp.arange(b), idx].set(v[:, 0])
        new_len = idx + 1
        out = gqa_decode(q, k_cache, v_cache, new_len,
                         use_pallas=impl == "pallas")
        new_cache = {"k": k_cache, "v": v_cache, "len": new_len}

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    out = logical(out, "batch", None, None)
    return x + out, new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = _dtype(cfg)
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.d_head), dt),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.d_head), dt),
        "len": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    dt = _dtype(cfg)
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {
        "norm": init_rmsnorm(d),
        "w_up": (jax.random.normal(ku, (d, ff)) * s_in).astype(dt),
        "w_down": (jax.random.normal(kd, (ff, d)) * s_out).astype(dt),
    }
    if cfg.act == "swiglu":
        p["w_gate"] = (jax.random.normal(kg, (d, ff)) * s_in).astype(dt)
    return p


def mlp(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = rms_norm(p["norm"], x, cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", h, p["w_up"])
    up = logical(up, "batch", None, "ff")
    if cfg.act == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", h, p["w_gate"])
        act = jax.nn.silu(gate) * up
    elif cfg.act == "squared_relu":
        r = jax.nn.relu(up)
        act = r * r
    else:
        act = jax.nn.gelu(up)
    out = jnp.einsum("bsf,fd->bsd", act, p["w_down"])
    out = logical(out, "batch", None, None)
    return x + out


# ---------------------------------------------------------------------------
# embedding / head / loss
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    ke, kh = jax.random.split(key)
    return {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab))
                    * cfg.d_model ** -0.5).astype(dt),
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def embed_tokens(p, tokens: jax.Array) -> jax.Array:
    return logical(p["embed"][tokens], "batch", None, None)


def lm_logits(p, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = rms_norm(p["final_norm"], h, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, p["lm_head"])
    return logical(logits, "batch", None, "vocab")


def chunked_cross_entropy(
    p, cfg: ModelConfig, h: jax.Array, targets: jax.Array,
    weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Mean next-token xent without materialising (B, S, V) logits.

    The (d -> vocab) projection + softmax run per sequence-chunk inside a
    remat'd scan so peak activation memory is B*chunk*V instead of B*S*V —
    the difference between fitting and not fitting 200k-vocab configs.
    """
    b, s, d = h.shape
    h = rms_norm(p["final_norm"], h, cfg.norm_eps)
    c = min(cfg.loss_chunk, s)
    if s % c != 0:
        c = s
    n_chunks = s // c
    hc = h.reshape(b, n_chunks, c, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n_chunks, c).transpose(1, 0, 2)

    w = None if weights is None else weights.astype(jnp.float32)  # (B,)

    @jax.checkpoint
    def chunk_loss(carry, xs):
        hx, tx = xs                               # (B, c, d), (B, c)
        logits = jnp.einsum("bsd,dv->bsv", hx, p["lm_head"])
        logits = logical(logits, "batch", None, "vocab").astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tx[..., None], axis=-1)[..., 0]
        xent = logz - gold                        # (B, c)
        if w is not None:
            xent = xent * w[:, None]              # LGD importance weights
        return carry + jnp.sum(xent), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hc, tc))
    return total / (b * s)
