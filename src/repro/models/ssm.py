"""Sub-quadratic sequence mixers: Mamba-2 (SSD), xLSTM mLSTM/sLSTM.

Mamba-2 and mLSTM share one *chunked gated linear-attention* core:

    S_t = a_t * S_{t-1} + k_t^T v_t          (per-head matrix state, PxN)
    y_t = q_t S_t   (+ normaliser for mLSTM)

computed chunk-parallel (FlashLinearAttention schedule): within a chunk
the contribution is a small causal "attention" matmul weighted by decay
ratios; across chunks a lax.scan carries the (P, N) state.  This is the
TPU-native adaptation — all chunk work is MXU matmuls, the sequential
dependency is only over S/chunk steps.

sLSTM keeps a per-channel scalar state and is inherently sequential;
it runs as a lax.scan over time (xLSTM uses few sLSTM blocks).

Decode: every mixer exposes a single-token state-update path with O(1)
cost per token — the reason these archs run the long_500k shape.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical
from .config import ModelConfig
from .layers import init_rmsnorm, rms_norm


# ---------------------------------------------------------------------------
# chunked gated linear attention core
# ---------------------------------------------------------------------------

def gla_chunked(
    q: jax.Array,        # (B, S, H, N)  query / C in mamba2
    k: jax.Array,        # (B, S, H, N)  key   / B in mamba2
    v: jax.Array,        # (B, S, H, P)  value / x in mamba2
    log_a: jax.Array,    # (B, S, H)     per-step log decay (<= 0)
    chunk: int,
    state0: Optional[jax.Array] = None,   # (B, H, N, P)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), state (B,H,N,P))."""
    b, s, h, n = q.shape
    p = v.shape[-1]
    c = min(chunk, s)
    s_orig = s
    if s % c != 0:
        # pad with zero-k/v and zero log-decay: state passes through pads
        pad = c - s % c
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // c

    qc = q.reshape(b, nc, c, h, n).transpose(1, 0, 3, 2, 4)  # (nc,B,H,c,N)
    kc = k.reshape(b, nc, c, h, n).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nc, c, h, p).transpose(1, 0, 3, 2, 4)  # (nc,B,H,c,P)
    la = log_a.reshape(b, nc, c, h).transpose(1, 0, 3, 2)    # (nc,B,H,c)

    cum = jnp.cumsum(la, axis=-1)                            # (nc,B,H,c)
    total = cum[..., -1:]

    if state0 is None:
        state0 = jnp.zeros((b, h, n, p), jnp.float32)

    def step(state, xs):
        qi, ki, vi, cumi, toti = xs
        # decay from chunk start to position t (inclusive of a_t)
        d_q = jnp.exp(cumi)                                  # (B,H,c)
        # decay from position t (exclusive) to chunk end
        d_k = jnp.exp(toti - cumi)                           # (B,H,c)
        # intra-chunk causal attention with decay ratio exp(cum_i - cum_j)
        att = jnp.einsum("bhin,bhjn->bhij", qi, ki)          # (B,H,c,c)
        ratio = jnp.exp(cumi[..., :, None] - cumi[..., None, :])
        mask = jnp.tril(jnp.ones((c, c), bool))
        att = jnp.where(mask, att * ratio, 0.0)
        y_intra = jnp.einsum("bhij,bhjp->bhip", att, vi)
        # inter-chunk: carried state
        y_state = jnp.einsum("bhin,bhnp->bhip", qi * d_q[..., None], state)
        # state update
        k_dec = ki * d_k[..., None]                          # (B,H,c,N)
        state_new = state * jnp.exp(toti)[..., None] + jnp.einsum(
            "bhcn,bhcp->bhnp", k_dec, vi)
        return state_new, y_intra + y_state

    qf = qc.astype(jnp.float32)
    kf = kc.astype(jnp.float32)
    vf = vc.astype(jnp.float32)
    state, ys = jax.lax.scan(step, state0, (qf, kf, vf, cum, total))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(v.dtype), state


def gla_decode_step(
    q: jax.Array,      # (B, H, N)
    k: jax.Array,      # (B, H, N)
    v: jax.Array,      # (B, H, P)
    log_a: jax.Array,  # (B, H)
    state: jax.Array,  # (B, H, N, P)
) -> Tuple[jax.Array, jax.Array]:
    a = jnp.exp(log_a)[..., None, None].astype(jnp.float32)
    state = state * a + jnp.einsum(
        "bhn,bhp->bhnp", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), state)
    return y.astype(v.dtype), state


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------

def _mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, nh, n = _mamba_dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    s = d ** -0.5
    # in_proj emits [x (d_inner), z (d_inner), B (N), C (N), dt (nh)]
    out_dim = 2 * d_inner + 2 * n + nh
    return {
        "norm": init_rmsnorm(d),
        "in_proj": (jax.random.normal(k1, (d, out_dim)) * s).astype(dt),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "out_proj": (jax.random.normal(k2, (d_inner, d))
                     * d_inner ** -0.5).astype(dt),
    }


def _mamba_project(p, cfg, x):
    d_inner, nh, n = _mamba_dims(cfg)
    h = rms_norm(p["norm"], x, cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    proj = logical(proj, "batch", None, "ff")
    xin, z, bmat, cmat, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    b_, s_ = x.shape[0], x.shape[1]
    xin = xin.reshape(b_, s_, nh, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    log_a = -jnp.exp(p["a_log"])[None, None, :] * dt       # (B,S,nh) <= 0
    # B/C shared across heads (single group)
    k = jnp.broadcast_to(bmat[:, :, None, :], (b_, s_, nh, n))
    q = jnp.broadcast_to(cmat[:, :, None, :], (b_, s_, nh, n))
    # discretised input: dt-scaled
    v = xin * dt[..., None].astype(xin.dtype)
    return q, k, v, log_a, xin, z


def mamba2(p, cfg: ModelConfig, x: jax.Array,
           state: Optional[jax.Array] = None):
    """Returns (out, new_state). state: (B, H, N, P)."""
    d_inner, nh, n = _mamba_dims(cfg)
    q, k, v, log_a, xin, z = _mamba_project(p, cfg, x)
    y, new_state = gla_chunked(q, k, v, log_a, cfg.chunk, state)
    y = y + xin * p["d_skip"][None, None, :, None].astype(xin.dtype)
    y = y.reshape(x.shape[0], x.shape[1], d_inner)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return x + logical(out, "batch", None, None), new_state


def mamba2_decode(p, cfg: ModelConfig, x: jax.Array, state: jax.Array):
    """x: (B, 1, d). O(1) per-token state update."""
    d_inner, nh, n = _mamba_dims(cfg)
    q, k, v, log_a, xin, z = _mamba_project(p, cfg, x)
    y, new_state = gla_decode_step(
        q[:, 0], k[:, 0], v[:, 0], log_a[:, 0], state)
    y = y[:, None] + xin * p["d_skip"][None, None, :, None].astype(xin.dtype)
    y = y.reshape(x.shape[0], 1, d_inner) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return x + logical(out, "batch", None, None), new_state


def init_mamba2_state(cfg: ModelConfig, batch: int):
    _, nh, n = _mamba_dims(cfg)
    return jnp.zeros((batch, nh, n, cfg.ssm_head_dim), jnp.float32)


# ---------------------------------------------------------------------------
# xLSTM mLSTM block (matrix memory + exponential gating)
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    s = d ** -0.5
    # qkv + input/forget gate pre-activations per head
    return {
        "norm": init_rmsnorm(d),
        "qkv_proj": (jax.random.normal(k1, (d, 3 * d)) * s).astype(dt),
        "gate_proj": (jax.random.normal(k2, (d, 2 * nh)) * s).astype(dt),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]).astype(jnp.float32),
        "out_proj": (jax.random.normal(k3, (d, d)) * s).astype(dt),
    }


def _mlstm_project(p, cfg, x):
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    b, s, _ = x.shape
    h = rms_norm(p["norm"], x, cfg.norm_eps)
    qkv = jnp.einsum("bsd,de->bse", h, p["qkv_proj"])
    qkv = logical(qkv, "batch", None, "ff")
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, nh, dh) * dh ** -0.5
    k = k.reshape(b, s, nh, dh) * dh ** -0.5
    v = v.reshape(b, s, nh, dh)
    gates = jnp.einsum("bsd,de->bse", h, p["gate_proj"]).astype(jnp.float32)
    gates = gates + p["gate_bias"][None, None, :]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)           # (B,S,nh)
    log_f = jax.nn.log_sigmoid(f_gate)                      # <= 0
    i_scale = jnp.exp(jnp.minimum(i_gate, 0.0))             # stabilised exp
    return q, k * i_scale[..., None].astype(k.dtype), v, log_f


def mlstm(p, cfg: ModelConfig, x: jax.Array,
          state: Optional[jax.Array] = None):
    """Returns (out, new_state); state holds (C, n) stacked: (B,H,dh+1,dh)."""
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    q, k, v, log_f = _mlstm_project(p, cfg, x)
    # normaliser: run the same recurrence with v=1 (appended column)
    v_ext = jnp.concatenate(
        [v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
    y_ext, new_state = gla_chunked(
        q, k, v_ext, log_f, cfg.chunk, state)
    y, n = y_ext[..., :dh], y_ext[..., dh:]
    y = y / jnp.maximum(jnp.abs(n), 1.0)
    y = y.reshape(x.shape[0], x.shape[1], d)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return x + logical(out, "batch", None, None), new_state


def mlstm_decode(p, cfg: ModelConfig, x: jax.Array, state: jax.Array):
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    q, k, v, log_f = _mlstm_project(p, cfg, x)
    v_ext = jnp.concatenate(
        [v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
    y_ext, new_state = gla_decode_step(
        q[:, 0], k[:, 0], v_ext[:, 0], log_f[:, 0], state)
    y, n = y_ext[..., :dh], y_ext[..., dh:]
    y = (y / jnp.maximum(jnp.abs(n), 1.0)).reshape(x.shape[0], 1, d)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return x + logical(out, "batch", None, None), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    return jnp.zeros((batch, nh, dh, dh + 1), jnp.float32)


# ---------------------------------------------------------------------------
# xLSTM sLSTM block (scalar memory, sequential scan)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    s = d ** -0.5
    # fused projections for z (cell input), i, f, o gates
    return {
        "norm": init_rmsnorm(d),
        "in_proj": (jax.random.normal(k1, (d, 4 * d)) * s).astype(dt),
        "out_proj": (jax.random.normal(k2, (d, d)) * s).astype(dt),
    }


def _slstm_scan(zi, ii, fi, oi, carry0):
    """Stabilised sLSTM recurrence over time — PARALLEL form.

    With input-only gates (this implementation projects i/f/o/z from x,
    no hidden-to-hidden recurrence), the stabiliser is a max-plus scan
    and the cell/normaliser updates are first-order linear recurrences —
    all three are ASSOCIATIVE, so the whole layer runs as
    jax.lax.associative_scan in O(log S) depth instead of S sequential
    steps.  TPU win measured in EXPERIMENTS.md §Perf (xlstm train cell:
    the 4096-step while loop was the dominant HBM-traffic term).

    Inputs: (B, S, d) f32; carry0 = (c0, n0, m0) each (B, d).
    """
    c0, n0, m0 = carry0
    log_f = jax.nn.log_sigmoid(fi)                       # (B, S, d)

    # 1) stabiliser: m_t = max(log_f_t + m_{t-1}, i_t)  — max-plus scan
    #    represented as pairs (a, b): m_t = max(a + m_{t-1}, b)
    #    composition: (a2,b2)∘(a1,b1) = (a1+a2, max(b1+a2, b2))
    def mp_op(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 + a2, jnp.maximum(b1 + a2, b2)

    a_all, b_all = jax.lax.associative_scan(
        mp_op, (log_f, ii), axis=1)
    m = jnp.maximum(a_all + m0[:, None, :], b_all)       # (B, S, d)

    m_prev = jnp.concatenate([m0[:, None, :], m[:, :-1]], axis=1)
    i_p = jnp.exp(ii - m)
    f_p = jnp.exp(log_f + m_prev - m)

    # 2) linear recurrences x_t = f'_t x_{t-1} + u_t  (for c and n)
    def lin_op(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    def lin_scan(u, x0):
        aa, bb = jax.lax.associative_scan(lin_op, (f_p, u), axis=1)
        return aa * x0[:, None, :] + bb

    c = lin_scan(i_p * jnp.tanh(zi), c0)
    n = lin_scan(i_p, n0)
    h = jax.nn.sigmoid(oi) * c / jnp.maximum(n, 1.0)
    return h, (c[:, -1], n[:, -1], m[:, -1])


def slstm(p, cfg: ModelConfig, x: jax.Array, state=None):
    """state: (c, n, m) each (B, d) f32."""
    b, s, d = x.shape
    h = rms_norm(p["norm"], x, cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, p["in_proj"]).astype(jnp.float32)
    z, i, f, o = jnp.split(proj, 4, axis=-1)
    if state is None:
        state = init_slstm_state(cfg, b)
    hs, new_state = _slstm_scan(z, i, f, o, state)
    out = jnp.einsum("bsd,de->bse", hs.astype(x.dtype), p["out_proj"])
    return x + logical(out, "batch", None, None), new_state


def init_slstm_state(cfg: ModelConfig, batch: int):
    zeros = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return (zeros, zeros, zeros)
