"""LSH-sampled softmax: the large-vocab head as an LGD problem.

The full-vocab LM head pays O(V) per token twice: the training loss
normaliser ``Z = sum_v exp(l_v)`` streams all V columns of ``lm_head``
through the logsumexp, and greedy decode streams them again through the
argmax matmul.  That is the paper's chicken-and-egg loop in miniature —
touching every row to decide which rows matter costs more than the step
— and the same MIPS machinery that breaks it for example sampling
breaks it here: the CORPUS is the ``lm_head`` embedding table (rows =
vocabulary), the QUERY is the final hidden state, and Algorithm 1's
exact inclusion probabilities make the sampled estimate unbiased.

TRAINING (``sampled_softmax_loss``).  Per token with hidden state h and
target t, the exact loss is ``log Z - l_t``.  We keep the target logit
EXACT (a single differentiable column gather) and estimate only the
normaliser with m LSH-sampled negatives j drawn by Algorithm 1 with
exact probability p_j over the vocabulary:

    Zhat = (1/m) sum_j exp(l_j) / p_j          E[Zhat] = Z

(the sum-estimator twin of the 1/(p·N) mean estimator: w = 1/p instead
of 1/(p·N)).  The loss uses ``log Zhat = logsumexp(l_j - log p_j) -
log m`` — a consistent (O(1/m)-biased, as every sampled softmax) plug-in
for log Z whose gradient is the self-normalised importance-sampling
estimate of the softmax distribution.  Per-step head cost drops from
O(V·d) to O(m·d + probe), breaking per-step O(V) the way LGD breaks
per-step O(N).

INDEX OVER PARAMS (``LMHeadIndex``).  Unlike the data pipeline's corpus,
this corpus is TRAINABLE — every optimizer step moves the indexed rows.
The lifecycle therefore keys off optimizer steps: ``step_hook`` (or any
caller of ``maybe_refresh``) refreshes every ``refresh_every`` steps,
with ``refresh_mode="delta"`` re-hashing only rows marked dirty (target
ids seen since the last refresh + a drift-sampled remainder) through
``mutate_index(op="delta")`` under the PINNED MIPS scale M, and every
``full_every``-th refresh running a full warm-started ``op="refresh"``
that re-pins M.  Staleness between refreshes does NOT bias the
estimator: the collision probability is evaluated on the STORED
``x_aug`` (the vectors actually hashed into the tables), so p_j stays
exact with respect to the as-built index and only the sampling QUALITY
(variance) degrades as live rows drift from their hashed snapshots —
the same contract as the data pipeline's delta refresh.

The index rides through the TRAINED STEP'S BATCH DICT (``inject``):
closing the jitted loss over the index would bake a stale pytree
constant into the jaxpr; as batch leaves, the fresh index/x_aug/key
flow through the one compiled program every step, shape-static across
refreshes.  Requires ``TrainerConfig.grad_accum == 1`` (micro-batching
reshapes every batch leaf along dim 0, which would shred the index
arrays).

SERVING (``lsh_decode_step``).  The same probe, used as an approximate
top-k shortlist: probe the query's bucket in every (probe code, table)
pair, take up to ``shortlist_per_table`` candidates from each bucket
slice (static J·L·c candidate shape), gather only those head columns
and argmax over the masked candidate logits — O(shortlist·d) instead of
O(V·d) per token.  BIAS BOUNDARY: unlike training (exactly unbiased in
expectation), the shortlist is approximate retrieval — when no probed
bucket holds the true argmax the decoded token differs from the full
matmul.  ``tests/test_sampled_softmax.py`` pins recall@k on a
structured head and ``benchmarks/run.py tab_softmax`` gates it in CI.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.families import get_family
from repro.core.sampler import sample_batched
from repro.core.simhash import LSHParams, probe_masks
from repro.core.tables import (
    IndexMutation,
    LSHIndex,
    bucket_bounds_banded,
    bucket_bounds_batched,
    bucket_bounds_multi,
    hash_points,
    mutate_index,
)

from .config import ModelConfig
from .layers import rms_norm
from .lm import decode_hidden, forward

# fold_in salts of the head-index key streams (disjoint from the data
# pipeline's 0x0B11D/0x057E9/0x0F5E5 family so a run using both draws
# independent streams from one root seed).
_SALT_HEAD_BUILD = 0x5EAD0
_SALT_HEAD_STEP = 0x5EAD1
_SALT_HEAD_DRIFT = 0x5EAD2


@dataclasses.dataclass(frozen=True)
class SampledSoftmaxConfig:
    """Static knobs of the LSH-sampled head (hashable: jit-static safe).

    Defaults follow the paper's BERT recipe (K=7, L=10) — the vocab
    corpus is small-N by LGD standards, so few tables suffice — with
    the asymmetric MIPS family so un-normalised head columns sample by
    raw inner product.
    """

    k: int = 7                    # bits per table
    l: int = 10                   # tables
    n_samples: int = 32           # m: LSH-sampled negatives per token
    multiprobe: int = 2           # extra Hamming-ball codes per table
    family: str = "mips"          # core.families registry key
    refresh_every: int = 50       # optimizer steps between refreshes
    refresh_mode: str = "delta"   # "delta" | "full"
    full_every: int = 10          # every Nth refresh is full (re-pins M);
    #                               0 = never force full
    drift_sample: float = 0.05    # fraction of clean rows re-hashed per
    #                               delta refresh (head drift is global:
    #                               the normaliser term touches every row)
    p_floor: float = 1e-8         # probability floor inside log Zhat
    max_probes: Optional[int] = None   # static cap on table draws
    shortlist_per_table: int = 8  # decode candidates per (probe, table)
    use_pallas: Optional[bool] = None
    interpret: bool = False
    seed: int = 0

    def __post_init__(self):
        if self.refresh_mode not in ("delta", "full"):
            raise ValueError(
                f"refresh_mode must be 'delta' or 'full', "
                f"got {self.refresh_mode!r}")
        if self.n_samples < 1:
            raise ValueError(f"n_samples must be >= 1, got {self.n_samples}")


def head_lsh_params(cfg: ModelConfig, scfg: SampledSoftmaxConfig) -> LSHParams:
    """The hash-family parameters of the lm_head index (dim = aug_dim(d))."""
    fam = get_family(scfg.family)
    return LSHParams(k=scfg.k, l=scfg.l, dim=fam.aug_dim(cfg.d_model),
                     family=scfg.family, seed=scfg.seed)


def _head_rows(params) -> jax.Array:
    """The corpus: lm_head columns as (V, d) float32 rows."""
    return params["embed_group"]["lm_head"].astype(jnp.float32).T


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# the head-level sampled cross entropy (shared by the model loss + tests)
# ---------------------------------------------------------------------------

def sampled_head_xent(q: jax.Array, lm_head: jax.Array, targets: jax.Array,
                      neg_ids: jax.Array, neg_probs: jax.Array,
                      p_floor: float = 1e-8) -> jax.Array:
    """Per-token sampled softmax xent: ``log Zhat - l_target``.

    Args:
      q: (T, d) float32 differentiable queries (final-norm'd hidden
        states) — the logits are ``q @ lm_head``.
      lm_head: (d, V) head matrix (live, differentiable).
      targets: (T,) int32 gold token ids (their logits stay EXACT).
      neg_ids / neg_probs: (T, m) Algorithm-1 samples over the vocab and
        their exact inclusion probabilities (gradients are stopped on
        the probabilities — they are sampling-law constants, not model
        outputs).

    Returns:
      (T,) per-token losses ``logsumexp(l_j - log p_j) - log m - l_t``:
      ``Zhat = (1/m) sum_j exp(l_j)/p_j`` satisfies E[Zhat] = Z exactly
      (sum-estimator with w = 1/p), so the loss is a consistent plug-in
      for ``log Z - l_t`` with the usual O(1/m) logsumexp bias.
    """
    head = lm_head.astype(jnp.float32)
    m = neg_ids.shape[-1]
    w_neg = jnp.take(head, neg_ids, axis=1)         # (d, T, m)
    l_neg = jnp.einsum("td,dtm->tm", q, w_neg)      # (T, m)
    logp = jnp.log(jnp.maximum(jax.lax.stop_gradient(neg_probs), p_floor))
    log_zhat = jax.nn.logsumexp(l_neg - logp, axis=-1) - jnp.log(float(m))
    w_gold = jnp.take(head, targets, axis=1)        # (d, T)
    l_gold = jnp.einsum("td,dt->t", q, w_gold)
    return log_zhat - l_gold


def sampled_softmax_loss(params, cfg: ModelConfig,
                         scfg: SampledSoftmaxConfig, batch) -> jax.Array:
    """Trainer-compatible LM loss with the LSH-sampled normaliser.

    Drop-in for ``models.loss`` when the batch carries the head-index
    leaves (``LMHeadIndex.inject``):

      * ``head_index``  — the ``LSHIndex`` pytree over lm_head rows,
      * ``head_x_aug``  — the (V, aug_dim) vectors actually hashed
        (probabilities are evaluated on THESE, so index staleness never
        biases E[Zhat]),
      * ``head_key``    — the per-step sampling key.

    The query used for SAMPLING is gradient-stopped (the draw is data
    selection, not a model output); the same hidden state flows
    differentiably into the sampled logits, so gradients reach
    ``lm_head`` only through the m+1 gathered columns per token —
    O(m·d) instead of O(V·d) per token, forward and backward.
    """
    lsh = head_lsh_params(cfg, scfg)
    fam = get_family(scfg.family)
    h = forward(params, cfg, batch)                             # (B, S, d)
    hn = rms_norm(params["embed_group"]["final_norm"], h,
                  cfg.norm_eps).astype(jnp.float32)
    b, s, d = hn.shape
    q = hn.reshape(b * s, d)
    q_aug = fam.augment_query(jax.lax.stop_gradient(q))
    res = sample_batched(
        batch["head_key"], batch["head_index"], batch["head_x_aug"],
        q_aug, lsh, m=scfg.n_samples, max_probes=scfg.max_probes,
        multiprobe=scfg.multiprobe, use_pallas=scfg.use_pallas,
        interpret=scfg.interpret)                               # (BS, m)
    xent = sampled_head_xent(
        q, params["embed_group"]["lm_head"], batch["targets"].reshape(-1),
        res.indices, res.probs, p_floor=scfg.p_floor)           # (BS,)
    w = batch.get("loss_weights")
    if w is not None:
        xent = (xent.reshape(b, s) * w.astype(jnp.float32)[:, None]).reshape(-1)
    return jnp.mean(xent)


# ---------------------------------------------------------------------------
# index-over-params lifecycle
# ---------------------------------------------------------------------------

class LMHeadIndex:
    """MIPS index over the TRAINABLE lm_head rows, refreshed by step.

    The write surface is ``mutate_index`` throughout: ``op="build"``
    once, then ``op="delta"`` merges of dirty rows re-augmented at the
    PINNED scale M (tie-stable: delta with every row dirty is bitwise a
    full warm refresh), with periodic full ``op="refresh"`` passes that
    re-pin M.  ``x_aug`` is updated in lockstep with the table codes —
    the invariant the unbiasedness proof needs is exactly "probabilities
    are computed on the vectors the tables were built from".

    Drive it either via ``TrainerConfig.step_hook = head.step_hook``
    (+ ``batches=head.wrap_batches(...)``) or by calling
    ``note_targets`` / ``maybe_refresh`` / ``inject`` yourself.
    """

    def __init__(self, params, cfg: ModelConfig,
                 scfg: SampledSoftmaxConfig = SampledSoftmaxConfig()):
        self.cfg = cfg
        self.scfg = scfg
        self.lsh = head_lsh_params(cfg, scfg)
        self._fam = get_family(scfg.family)
        self._root_key = jax.random.PRNGKey(scfg.seed)
        self._dirty = np.zeros((cfg.vocab,), bool)
        self._step = 0
        self._last_refresh_step = 0
        self.refreshes = 0          # total refreshes applied
        self.delta_refreshes = 0
        self.full_refreshes = 0
        self.build(params)

    # -- writes (all via mutate_index) --------------------------------------

    def build(self, params) -> None:
        """(Re)build from scratch: fresh scale pin, fresh sort."""
        rows = _head_rows(params)
        self.scale = self._fam.data_scale(rows)
        self.x_aug = self._fam.augment_data(rows, scale=self.scale)
        key = jax.random.fold_in(self._root_key, _SALT_HEAD_BUILD)
        self.index: LSHIndex = mutate_index(
            None, IndexMutation("build", key=key, x_aug=self.x_aug),
            self.lsh, use_pallas=self.scfg.use_pallas,
            interpret=self.scfg.interpret)
        self._dirty[:] = False

    def refresh(self, params, mode: Optional[str] = None,
                repin_scale: Optional[bool] = None) -> None:
        """One refresh pass. ``mode`` defaults to ``scfg.refresh_mode``;
        ``repin_scale`` defaults to True for full / False for delta
        (delta MUST re-augment at the pinned M of the last full pass —
        mixing scales would break code/x_aug consistency)."""
        mode = mode or self.scfg.refresh_mode
        rows = _head_rows(params)
        if mode == "full":
            if repin_scale is None or repin_scale:
                self.scale = self._fam.data_scale(rows)
            self.x_aug = self._fam.augment_data(rows, scale=self.scale)
            self.index = mutate_index(
                self.index,
                IndexMutation("refresh", x_aug=self.x_aug, warm_start=True),
                self.lsh, use_pallas=self.scfg.use_pallas,
                interpret=self.scfg.interpret)
            self.full_refreshes += 1
        else:
            ids = self._dirty_ids()
            if ids.size:
                aug_d = self._fam.augment_data(rows[ids], scale=self.scale)
                codes = hash_points(aug_d, self.index.projections, self.lsh,
                                    use_pallas=self.scfg.use_pallas,
                                    interpret=self.scfg.interpret)
                self.index = mutate_index(
                    self.index,
                    IndexMutation("delta", ids=jnp.asarray(ids, jnp.int32),
                                  codes=codes))
                self.x_aug = self.x_aug.at[jnp.asarray(ids, jnp.int32)].set(
                    aug_d)
            self.delta_refreshes += 1
        self._dirty[:] = False
        self.refreshes += 1

    def _dirty_ids(self) -> np.ndarray:
        """Dirty rows + drift-sampled remainder, padded to a power of two.

        Every head row drifts each step (the normaliser gradient
        scatter-adds into the sampled negatives), so on top of the
        exactly-tracked target ids a deterministic ``drift_sample``
        fraction of the clean rows is re-hashed per delta pass —
        bounded staleness for rows that are never targets.  Padding
        repeats the first id (duplicate ids with equal code columns are
        a no-op under the tie-stable merge), bounding jit recompiles to
        O(log V) code shapes.
        """
        dirty = np.nonzero(self._dirty)[0]
        clean = np.nonzero(~self._dirty)[0]
        n_extra = int(round(clean.size * self.scfg.drift_sample))
        if n_extra:
            rng = np.random.default_rng(
                (self.scfg.seed, _SALT_HEAD_DRIFT, self.refreshes))
            dirty = np.concatenate(
                [dirty, rng.choice(clean, size=n_extra, replace=False)])
        if dirty.size == 0:
            return dirty.astype(np.int32)
        pad = min(_next_pow2(dirty.size), self.cfg.vocab) - dirty.size
        if pad:
            dirty = np.concatenate([dirty, np.full(pad, dirty[0])])
        return dirty.astype(np.int32)

    # -- the step-keyed cadence ---------------------------------------------

    def note_targets(self, targets) -> None:
        """Mark this batch's target ids dirty (host-side bitmap)."""
        self._dirty[np.asarray(targets).reshape(-1)] = True

    def maybe_refresh(self, step: int, params) -> bool:
        """Refresh iff ``refresh_every`` optimizer steps have elapsed.

        Every ``full_every``-th refresh is forced full (re-pins M);
        the rest follow ``scfg.refresh_mode``.  Returns True if a
        refresh ran.
        """
        self._step = step
        if step - self._last_refresh_step < self.scfg.refresh_every:
            return False
        force_full = (self.scfg.full_every > 0 and
                      (self.refreshes + 1) % self.scfg.full_every == 0)
        self.refresh(params, mode="full" if force_full else None)
        self._last_refresh_step = step
        return True

    def step_hook(self, trainer) -> None:
        """``TrainerConfig.step_hook`` adapter (optimizer-step-keyed)."""
        self.maybe_refresh(trainer.step, trainer.params)

    # -- batch plumbing ------------------------------------------------------

    def inject(self, batch: dict, step: Optional[int] = None) -> dict:
        """Return ``batch`` + the head-index leaves the jitted loss reads.

        The index/x_aug/key ride the batch dict INTO the jitted step
        (shape-static across refreshes, one compilation) instead of
        being closed over — a closure would bake the build-time pytree
        into the jaxpr and sample from a permanently stale index.
        """
        step = self._step if step is None else step
        out = dict(batch)
        out["head_index"] = self.index
        out["head_x_aug"] = self.x_aug
        out["head_key"] = jax.random.fold_in(
            jax.random.fold_in(self._root_key, _SALT_HEAD_STEP), step)
        return out

    def wrap_batches(self, batches: Iterator[dict]) -> Iterator[dict]:
        """Wrap a batch iterator for ``Trainer(batches=...)`` use.

        Marks each batch's targets dirty and injects the CURRENT index
        (with the trainer's prefetch, batch k+1 is drawn before step
        k's hook refreshes — one step of benign staleness, covered by
        the probabilities-on-stored-x_aug invariant).  Pair with
        ``TrainerConfig(step_hook=head.step_hook, grad_accum=1)``.
        """
        for i, batch in enumerate(batches):
            if "targets" in batch:
                self.note_targets(batch["targets"])
            yield self.inject(batch, step=i)


def make_sampled_loss(cfg: ModelConfig, scfg: SampledSoftmaxConfig):
    """``loss_fn(params, batch)`` for ``Trainer(loss_fn=...)``."""
    return lambda params, batch: sampled_softmax_loss(params, cfg, scfg,
                                                      batch)


# ---------------------------------------------------------------------------
# serving: the probe as an approximate top-k shortlist
# ---------------------------------------------------------------------------

def shortlist_candidates(index: LSHIndex, q_aug: jax.Array,
                         lsh: LSHParams, scfg: SampledSoftmaxConfig):
    """Static-shape candidate ids from the query's probed buckets.

    For each query, each probe code j and table t, take up to
    ``shortlist_per_table`` slots from the bucket slice [lo, hi) —
    candidates = J·L·c ids per query regardless of bucket sizes, so
    the decode step stays one fixed compiled program.

    Args:
      index: the lm_head index.
      q_aug: (B, aug_dim) family-augmented queries.
      lsh / scfg: hash params + head config (static).

    Returns:
      (ids, valid): (B, J·L·c) int32 candidate token ids and the bool
      mask of slots that actually fall inside their bucket (duplicates
      across tables are fine for masked argmax/top-k).
    """
    masks = probe_masks(lsh.k, 1 + scfg.multiprobe)
    b = q_aug.shape[0]
    if get_family(lsh.family).num_bands() > 1:
        lo, hi = bucket_bounds_banded(
            index, q_aug, lsh, masks, use_pallas=scfg.use_pallas,
            interpret=scfg.interpret)              # (B, nb, J, L)
        lo = lo.reshape(b, -1, lo.shape[-1])
        hi = hi.reshape(b, -1, hi.shape[-1])
    elif len(masks) == 1:
        lo, hi = bucket_bounds_batched(
            index, q_aug, lsh, use_pallas=scfg.use_pallas,
            interpret=scfg.interpret)              # (B, L)
        lo, hi = lo[:, None, :], hi[:, None, :]
    else:
        lo, hi = bucket_bounds_multi(
            index, q_aug, lsh, masks, use_pallas=scfg.use_pallas,
            interpret=scfg.interpret)              # (B, J, L)
    c = scfg.shortlist_per_table
    offs = jnp.arange(c, dtype=jnp.int32)
    slots = lo[..., None] + offs                   # (B, J, L, c)
    valid = offs < (hi - lo)[..., None]
    slots = jnp.minimum(slots, index.n_points - 1)
    n_tables = index.order.shape[0]
    t_idx = jnp.arange(n_tables, dtype=jnp.int32)[None, None, :, None]
    ids = index.order[t_idx, slots]                # (B, J, L, c)
    return ids.reshape(b, -1).astype(jnp.int32), valid.reshape(b, -1)


def shortlist_logits(lm_head: jax.Array, q: jax.Array, ids: jax.Array,
                     valid: jax.Array) -> jax.Array:
    """(B, K) candidate logits, invalid slots masked to -inf."""
    w = jnp.take(lm_head.astype(jnp.float32), ids, axis=1)   # (d, B, K)
    logits = jnp.einsum("bd,dbk->bk", q.astype(jnp.float32), w)
    return jnp.where(valid, logits, -jnp.inf)


def lsh_decode_step(params, cfg: ModelConfig, scfg: SampledSoftmaxConfig,
                    batch, cache, index: LSHIndex):
    """One greedy decode step through the LSH-shortlisted head.

    ``decode_hidden`` runs the unchanged transformer body; the head is
    probe -> gather shortlist columns -> masked argmax, O(J·L·c·d)
    instead of O(V·d) per token.  If EVERY probed bucket is empty the
    (masked-to--inf) argmax degrades to candidate slot 0 — the serving
    twin of the sampler's uniform fallback, visible in the recall gate
    rather than hidden.

    Returns (tokens (B, 1) int32, new_cache).
    """
    lsh = head_lsh_params(cfg, scfg)
    h, new_cache = decode_hidden(params, cfg, batch, cache)   # (B, 1, d)
    q = rms_norm(params["embed_group"]["final_norm"], h,
                 cfg.norm_eps)[:, 0].astype(jnp.float32)      # (B, d)
    q_aug = get_family(lsh.family).augment_query(q)
    ids, valid = shortlist_candidates(index, q_aug, lsh, scfg)
    logits = shortlist_logits(params["embed_group"]["lm_head"], q, ids,
                              valid)
    best = jnp.argmax(logits, axis=-1)
    tok = jnp.take_along_axis(ids, best[:, None], axis=1)     # (B, 1)
    return tok, new_cache
