"""Generic decoder-only LM assembled from a block pattern.

One implementation serves all ten assigned architectures: the config's
``block_pattern`` (cycled ``repeats`` times to n_layers) names the mixer
of each layer; FFNs are dense or MoE; weights of ``shared_attn`` blocks
are shared across repeats (Zamba-style).

Layer stacking: parameters of each pattern position are *stacked* over
repeats and the forward pass is a single ``lax.scan`` over repeats —
the compiled HLO contains each distinct layer body once, keeping 94-100
layer configs compilable in seconds and enabling per-repeat activation
rematerialisation (``cfg.remat``).

Three entry points (pure functions of params):
  forward(params, cfg, batch)             -> final hidden states (B,S,d)
  loss(params, cfg, batch)                -> scalar LM loss
  prefill(params, cfg, batch, cache)      -> (hidden, cache)
  decode_step(params, cfg, tok, cache)    -> (logits, cache)
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical
from . import ssm
from .config import ModelConfig
from .layers import (
    attention,
    chunked_cross_entropy,
    embed_tokens,
    init_attention,
    init_attention_cache,
    init_embed,
    init_mlp,
    lm_logits,
    mlp,
)
from .moe import init_moe, moe_ffn

ATTN_KINDS = ("attn", "cross_attn", "shared_attn")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    ka, kc, kf = jax.random.split(key, 3)
    p: Dict[str, Any] = {}
    if kind in ("attn", "shared_attn"):
        p["attn"] = init_attention(ka, cfg)
    elif kind == "cross_attn":
        p["attn"] = init_attention(ka, cfg)
        p["xattn"] = init_attention(kc, cfg)
    elif kind == "mamba2":
        p["mamba"] = init_mamba2_wrap(ka, cfg)
    elif kind == "mlstm":
        p["mlstm"] = ssm.init_mlstm(ka, cfg)
    elif kind == "slstm":
        p["slstm"] = ssm.init_slstm(ka, cfg)
    else:
        raise ValueError(kind)
    # FFN: attention-style blocks carry the MLP/MoE; pure mixers don't,
    # except when the config gives them an FFN (d_ff>0 and kind=="mamba2"
    # in hybrid archs is still FFN-free — Zamba puts the FFN in the shared
    # block only).
    if kind in ATTN_KINDS and (cfg.is_moe or cfg.d_ff > 0):
        p["ffn"] = init_moe(kf, cfg) if cfg.is_moe else init_mlp(kf, cfg)
    return p


def init_mamba2_wrap(key, cfg):
    return ssm.init_mamba2(key, cfg)


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, len(cfg.block_pattern) + 2)
    params: Dict[str, Any] = {"embed_group": init_embed(keys[0], cfg)}
    blocks = []
    shared = None
    for j, kind in enumerate(cfg.block_pattern):
        kj = keys[j + 1]
        if kind == "shared_attn":
            # single copy, shared across repeats
            if shared is None:
                shared = _init_block(kj, cfg, kind)
            blocks.append(None)
        else:
            stacked = jax.vmap(
                lambda k: _init_block(k, cfg, kind)
            )(jax.random.split(kj, cfg.repeats))
            blocks.append(stacked)
    params["blocks"] = blocks
    if shared is not None:
        params["shared"] = shared
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_block(
    kind: str,
    p,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    image_mem: Optional[jax.Array],
    cache_entry,
    decode: bool,
):
    """Returns (x, new_cache_entry)."""
    new_cache = cache_entry
    if kind in ("attn", "shared_attn", "cross_attn"):
        att_cache = None if cache_entry is None else cache_entry["attn"]
        x, c = attention(p["attn"], cfg, x, positions, cache=att_cache)
        if kind == "cross_attn":
            x, _ = attention(p["xattn"], cfg, x, positions, kv=image_mem,
                             causal=False)
        if cache_entry is not None:
            new_cache = dict(cache_entry)
            new_cache["attn"] = c if c is not None else cache_entry["attn"]
    elif kind == "mamba2":
        st = None if cache_entry is None else cache_entry["state"]
        if decode:
            x, st = ssm.mamba2_decode(p["mamba"], cfg, x, st)
        else:
            x, st = ssm.mamba2(p["mamba"], cfg, x, st)
        if cache_entry is not None:
            new_cache = {"state": st}
    elif kind == "mlstm":
        st = None if cache_entry is None else cache_entry["state"]
        if decode:
            x, st = ssm.mlstm_decode(p["mlstm"], cfg, x, st)
        else:
            x, st = ssm.mlstm(p["mlstm"], cfg, x, st)
        if cache_entry is not None:
            new_cache = {"state": st}
    elif kind == "slstm":
        st = None if cache_entry is None else cache_entry["state"]
        x, st = ssm.slstm(p["slstm"], cfg, x, st)
        if cache_entry is not None:
            new_cache = {"state": st}
    else:
        raise ValueError(kind)

    if "ffn" in (p or {}):
        x = moe_ffn(p["ffn"], cfg, x) if cfg.is_moe else mlp(p["ffn"], cfg, x)
    return x, new_cache


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _inputs(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    if cfg.frontend == "embed_stub":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(params["embed_group"], batch["tokens"])
    image_mem = batch.get("image_embeds")
    if image_mem is not None:
        image_mem = image_mem.astype(x.dtype)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return x, image_mem, positions


def _scan_blocks(params, cfg: ModelConfig, x, positions, image_mem,
                 cache, decode: bool):
    """lax.scan over repeats; python loop over pattern positions inside."""
    shared = params.get("shared")

    def body(xc, xs):
        xx, _ = xc
        if cfg.seq_shard and not decode:
            xx = logical(xx, "batch", "seq", None)
        rep_params, rep_cache = xs
        new_rep_cache = []
        for j, kind in enumerate(cfg.block_pattern):
            pj = shared if kind == "shared_attn" else rep_params[j]
            cj = None if rep_cache is None else rep_cache[j]
            xx, cj_new = _apply_block(
                kind, pj, cfg, xx, positions, image_mem, cj, decode)
            new_rep_cache.append(cj_new)
        if rep_cache is None:
            return (xx, None), None
        return (xx, None), new_rep_cache

    if cfg.remat and not decode:
        body = jax.checkpoint(body)

    # xs pytrees: blocks list with leading dim = repeats (None for shared)
    xs_params = [
        b if b is not None else None for b in params["blocks"]
    ]
    # replace None entries (shared) with dummy zeros so scan shapes match
    xs_params = [b if b is not None else jnp.zeros((cfg.repeats,))
                 for b in xs_params]

    if cfg.scan_layers:
        (x, _), new_cache = jax.lax.scan(
            body, (x, None), (xs_params, cache))
    else:
        new_cache_list = []
        for r in range(cfg.repeats):
            rep_params = jax.tree.map(lambda a: a[r], xs_params)
            rep_cache = (None if cache is None
                         else jax.tree.map(lambda a: a[r], cache))
            (x, _), nc = body((x, None), (rep_params, rep_cache))
            new_cache_list.append(nc)
        new_cache = (None if cache is None else jax.tree.map(
            lambda *xs: jnp.stack(xs), *new_cache_list))
    return x, new_cache


def forward(params, cfg: ModelConfig, batch) -> jax.Array:
    x, image_mem, positions = _inputs(params, cfg, batch)
    x, _ = _scan_blocks(params, cfg, x, positions, image_mem, None, False)
    return x


def loss(params, cfg: ModelConfig, batch) -> jax.Array:
    h = forward(params, cfg, batch)
    return chunked_cross_entropy(
        params["embed_group"], cfg, h, batch["targets"],
        weights=batch.get("loss_weights"))


def logits(params, cfg: ModelConfig, batch) -> jax.Array:
    h = forward(params, cfg, batch)
    return lm_logits(params["embed_group"], cfg, h)


# ---------------------------------------------------------------------------
# LGD feature-extraction hooks (paper Sec. 3.2: the BERT recipe)
# ---------------------------------------------------------------------------

def pooled_features(params, cfg: ModelConfig, batch) -> jax.Array:
    """Per-example feature vector: mean-pooled final hidden state (f32).

    The paper hashes each example's pooled last-layer representation into
    the LSH index; this is the model-side half of that contract (the
    pipeline half is ``repro.data.LSHSampledPipeline``).
    """
    h = forward(params, cfg, batch)
    return jnp.mean(h.astype(jnp.float32), axis=1)


def lm_head_query(params) -> jax.Array:
    """LGD query from the output layer (paper: classification-layer
    weights as queries): the mean lm_head column, in feature space."""
    w = params["embed_group"]["lm_head"].astype(jnp.float32)
    return jnp.mean(w, axis=1)


# ---------------------------------------------------------------------------
# cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked (repeats, ...) cache per pattern position."""
    def one(kind):
        if kind in ATTN_KINDS:
            return {"attn": init_attention_cache(cfg, batch, max_len)}
        if kind == "mamba2":
            return {"state": ssm.init_mamba2_state(cfg, batch)}
        if kind == "mlstm":
            return {"state": ssm.init_mlstm_state(cfg, batch)}
        if kind == "slstm":
            return {"state": ssm.init_slstm_state(cfg, batch)}
        raise ValueError(kind)

    return [
        jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.repeats,) + a.shape).copy(),
            one(kind),
        )
        for kind in cfg.block_pattern
    ]


def prefill(params, cfg: ModelConfig, batch, cache):
    """Run the prompt through the model, filling caches; returns (h, cache).

    Attention caches are written as full-sequence K/V (the train path);
    SSM states come out of the chunked scan.
    """
    x, image_mem, positions = _inputs(params, cfg, batch)
    x, new_cache = _scan_blocks(
        params, cfg, x, positions, image_mem, cache, False)
    return x, new_cache


def decode_hidden(params, cfg: ModelConfig, batch, cache):
    """One-token decode up to (but not including) the lm head.

    The transformer body of ``decode_step``, split out so alternative
    heads (e.g. the LSH-shortlisted head in ``models.sampled_softmax``)
    can reuse the unchanged block stack without paying the O(V) logits
    matmul.  Returns (hidden (B, 1, d), new_cache)."""
    if cfg.frontend == "embed_stub":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(params["embed_group"], batch["tokens"])
    image_mem = batch.get("image_embeds")
    if image_mem is not None:
        image_mem = image_mem.astype(x.dtype)
    positions = batch["positions"]           # (B, 1) int32
    return _scan_blocks(params, cfg, x, positions, image_mem, cache, True)


def decode_step(params, cfg: ModelConfig, batch, cache):
    """One-token decode: batch["tokens"]/batch["embeds"] has S=1.

    Returns (logits (B, 1, V), new_cache)."""
    x, new_cache = decode_hidden(params, cfg, batch, cache)
    return lm_logits(params["embed_group"], cfg, x), new_cache
