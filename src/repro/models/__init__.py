from .config import ModelConfig  # noqa: F401
from . import layers, lm, moe, sampled_softmax, ssm  # noqa: F401
from .lm import (  # noqa: F401
    decode_hidden,
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_head_query,
    logits,
    loss,
    pooled_features,
    prefill,
)
from .sampled_softmax import (  # noqa: F401
    LMHeadIndex,
    SampledSoftmaxConfig,
    lsh_decode_step,
    make_sampled_loss,
    sampled_softmax_loss,
)
