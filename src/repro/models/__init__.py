from .config import ModelConfig  # noqa: F401
from . import layers, lm, moe, ssm  # noqa: F401
from .lm import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_params,
    lm_head_query,
    logits,
    loss,
    pooled_features,
    prefill,
)
