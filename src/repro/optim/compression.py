"""Gradient compression for cross-pod data parallelism.

At multi-pod scale the DP gradient all-reduce crosses the (slow)
inter-pod links; int8 block quantisation cuts that wire traffic 4x
(bf16) with convergence preserved by ERROR FEEDBACK (Seide et al. /
1-bit SGD lineage): the quantisation residual is carried into the next
step instead of discarded, so the long-run compression error is O(1)
rather than O(T).

Usage (trainer wires this around the optimiser):

    state = init_error_feedback(params)
    q, state = compress_with_feedback(grads, state)   # before all-reduce
    grads_hat = decompress(q)                          # after all-reduce

The quantised tree is what crosses the wire: int8 payload + one f32
scale per 256-value block (2.06 bytes per bf16/f32 gradient value).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .optimizers import QTensor, _dequantize_blockwise, _quantize_blockwise

BLOCK = 256


def compress(grads: Any, block: int = BLOCK) -> Any:
    """Quantise every gradient leaf to int8 QTensors."""
    return jax.tree.map(
        lambda g: _quantize_blockwise(g.astype(jnp.float32), block), grads)


def decompress(qtree: Any, like: Any = None) -> Any:
    """Inverse of compress; casts back to `like`'s dtypes if given."""
    is_qt = lambda x: isinstance(x, QTensor)
    deq = jax.tree.map(_dequantize_blockwise, qtree, is_leaf=is_qt)
    if like is not None:
        deq = jax.tree.map(lambda d, l: d.astype(l.dtype), deq, like)
    return deq


def init_error_feedback(params: Any) -> Any:
    """Residual accumulator, same structure/shapes as the gradients."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(
    grads: Any, residual: Any, block: int = BLOCK,
) -> Tuple[Any, Any]:
    """Quantise (grads + residual); carry the quantisation error forward.

    Returns (qtree, new_residual)."""
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    qtree = jax.tree.map(
        lambda c: _quantize_blockwise(c, block), corrected)
    # walk explicitly: qtree leaves are QTensor containers
    flat_c, treedef = jax.tree_util.tree_flatten(corrected)
    flat_q = treedef.flatten_up_to(qtree)
    new_residual = treedef.unflatten([
        c - _dequantize_blockwise(q) for c, q in zip(flat_c, flat_q)])
    return qtree, new_residual


def wire_bytes(qtree: Any) -> int:
    """Bytes a compressed gradient tree puts on the wire."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(qtree):
        total += leaf.size * leaf.dtype.itemsize
    return total
