"""Learning-rate schedules (time/step decay, exponential, warmup+cosine)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(lr0: float, decay: float, every: int):
    """lr0 * decay^(step // every) — the paper's 'time based (or step based)'."""
    return lambda step: lr0 * decay ** (step // every)


def exponential_decay(lr0: float, rate: float):
    """lr0 * exp(-rate * step) — Xu (2011) exponential decay."""
    return lambda step: lr0 * jnp.exp(-rate * step.astype(jnp.float32))


def inverse_time_decay(lr0: float, rate: float):
    return lambda step: lr0 / (1.0 + rate * step.astype(jnp.float32))


def warmup_cosine(lr_peak: float, warmup: int, total: int, lr_min: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr_peak * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = lr_min + 0.5 * (lr_peak - lr_min) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup, warm, cos)
    return fn
