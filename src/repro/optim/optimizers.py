"""First-order optimisers (built from scratch — no optax dependency).

All optimisers share a tiny functional interface:

    opt.init(params)                      -> opt_state (pytree)
    opt.update(grads, opt_state, params)  -> (updates, new_opt_state)
    params_new = params + updates         (via jax.tree.map / apply_updates)

Each optimiser is a frozen dataclass → hashable → usable as a jit-static
argument.  LGD plugs in as a gradient *estimator* underneath any of them
(paper Sec. 2.2: "AdaGrad as well as those learning rate decay methods
are customized options that can be used in conjunction").

``Adam8bit`` stores the moments block-quantised to int8 — a
distributed-optimisation trick that cuts optimiser-state HBM by 3.5x and
is what lets the 773B-param llama4-maverick config fit a v5e pod (see
DESIGN.md §Memory-budget).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

try:  # optional — everything here works without optax installed
    import optax as _optax
except ImportError:  # pragma: no cover - exercised on optax-free installs
    _optax = None

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def make_optimizer(name: str, lr: Optional[Schedule] = None, **kwargs):
    """Build an optimiser by CLI-friendly name.

    Args:
      name: one of ``sgd`` (plain), ``momentum`` (SGD with heavy-ball
        momentum 0.9), ``adagrad``, ``adam``, ``adamw``, ``adam8bit``,
        ``adafactor`` — or ``optax:<name>`` to wrap any optax
        constructor (e.g. ``optax:adam``, ``optax:lion``) behind the
        same interface via :class:`OptaxAdapter`.
      lr: learning rate or schedule; per-name defaults when omitted
        (3e-2 for sgd/momentum/adagrad, 3e-3 for the Adam family,
        Adafactor, and ``optax:*``).
      **kwargs: forwarded to the optimiser dataclass (e.g. ``b1``,
        ``eps``, ``weight_decay``) or, for ``optax:*`` names, to the
        optax constructor.

    Returns:
      A frozen optimiser dataclass (hashable, jit-static).  All of
      them compose with the LGD sampler path unchanged: the trainer
      applies the 1/(p·N) importance weights inside the loss, so every
      optimiser's moments accumulate the unbiased gradient ESTIMATE
      (see ``repro.train.trainer``).
    """
    key = name.lower()
    if key.startswith("optax:"):
        return _make_optax(key[len("optax:"):], lr, **kwargs)
    makers = {
        "sgd": lambda lr, **kw: SGD(lr=3e-2 if lr is None else lr, **kw),
        "momentum": lambda lr, **kw: SGD(
            lr=3e-2 if lr is None else lr, **{"momentum": 0.9, **kw}),
        "adagrad": lambda lr, **kw: AdaGrad(
            lr=3e-2 if lr is None else lr, **kw),
        "adam": lambda lr, **kw: Adam(lr=3e-3 if lr is None else lr, **kw),
        "adamw": lambda lr, **kw: Adam(
            lr=3e-3 if lr is None else lr, **{"weight_decay": 0.01, **kw}),
        "adam8bit": lambda lr, **kw: Adam8bit(
            lr=3e-3 if lr is None else lr, **kw),
        "adafactor": lambda lr, **kw: Adafactor(
            lr=3e-3 if lr is None else lr, **kw),
    }
    if key not in makers:
        raise ValueError(
            f"unknown optimizer {name!r}; choose from {sorted(makers)}")
    return makers[key](lr, **kwargs)


def _lr_at(lr: Schedule, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


# ---------------------------------------------------------------------------
# Optax compatibility
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class OptaxAdapter:
    """Wrap an optax ``GradientTransformation`` behind this module's
    interface.

    The conventions already line up — ``tx.update(grads, state, params)``
    returns additive updates — so the adapter is a passthrough.  What it
    adds is *hashability*: optax transforms are NamedTuples of closures
    and compare/hash by content, which breaks jit-static caching.  The
    adapter hashes by identity (``eq=False`` keeps ``object.__hash__``),
    so reusing one adapter instance reuses compiled trainer steps, same
    as the built-in frozen dataclasses.
    """

    tx: Any          # optax.GradientTransformation (duck-typed)
    name: str = "optax"

    def __post_init__(self):
        if not (hasattr(self.tx, "init") and hasattr(self.tx, "update")):
            raise TypeError(
                "OptaxAdapter needs an optax-style GradientTransformation "
                f"with .init/.update, got {type(self.tx).__name__}")

    def init(self, params):
        return self.tx.init(params)

    def update(self, grads, state, params=None):
        return self.tx.update(grads, state, params)


def from_optax(tx, name: str = "optax") -> OptaxAdapter:
    """Adapt any optax ``GradientTransformation`` (or chain) for use
    everywhere the built-in optimisers go — ``Trainer``, LGD sampling,
    checkpointing (optax states are pytrees of arrays, which the
    checkpoint format already handles)."""
    return OptaxAdapter(tx, name)


def _make_optax(ctor_name: str, lr: Optional[Schedule], **kwargs):
    if _optax is None:
        raise ImportError(
            f"optimizer 'optax:{ctor_name}' requires optax, which is not "
            "installed; use a built-in name instead")
    ctor = getattr(_optax, ctor_name, None)
    if ctor is None or not callable(ctor):
        raise ValueError(f"optax has no optimizer constructor {ctor_name!r}")
    lr = 3e-3 if lr is None else lr
    return from_optax(ctor(learning_rate=lr, **kwargs),
                      name=f"optax:{ctor_name}")


# ---------------------------------------------------------------------------
# SGD (+ momentum)
# ---------------------------------------------------------------------------

class SGDState(NamedTuple):
    step: jax.Array
    momentum: Any


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Schedule = 1e-2
    momentum: float = 0.0
    nesterov: bool = False

    def init(self, params):
        mom = (
            jax.tree.map(jnp.zeros_like, params) if self.momentum else None
        )
        return SGDState(jnp.zeros((), jnp.int32), mom)

    def update(self, grads, state: SGDState, params=None):
        lr = _lr_at(self.lr, state.step)
        if self.momentum:
            mom = jax.tree.map(
                lambda m, g: self.momentum * m + g, state.momentum, grads
            )
            if self.nesterov:
                upd = jax.tree.map(
                    lambda m, g: -lr * (self.momentum * m + g), mom, grads
                )
            else:
                upd = jax.tree.map(lambda m: -lr * m, mom)
            return upd, SGDState(state.step + 1, mom)
        upd = jax.tree.map(lambda g: -lr * g, grads)
        return upd, SGDState(state.step + 1, None)

    def __hash__(self):  # lr may be a closure
        return hash((id(self.lr) if callable(self.lr) else self.lr,
                     self.momentum, self.nesterov))


# ---------------------------------------------------------------------------
# AdaGrad (Duchi et al., 2011) — the paper's adaptive-LR companion to LGD
# ---------------------------------------------------------------------------

class AdaGradState(NamedTuple):
    step: jax.Array
    accum: Any


@dataclasses.dataclass(frozen=True)
class AdaGrad:
    lr: Schedule = 1e-2
    eps: float = 1e-10
    initial_accum: float = 0.0

    def init(self, params):
        return AdaGradState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(
                lambda p: jnp.full_like(p, self.initial_accum, jnp.float32),
                params,
            ),
        )

    def update(self, grads, state: AdaGradState, params=None):
        lr = _lr_at(self.lr, state.step)
        accum = jax.tree.map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)),
            state.accum, grads,
        )
        upd = jax.tree.map(
            lambda g, a: -lr * g / (jnp.sqrt(a) + self.eps), grads, accum
        )
        return upd, AdaGradState(state.step + 1, accum)

    def __hash__(self):
        return hash((id(self.lr) if callable(self.lr) else self.lr,
                     self.eps, self.initial_accum))


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: Schedule = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0  # AdamW-style decoupled decay

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(zeros, params),
            jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamState, params=None):
        step = state.step + 1
        lr = _lr_at(self.lr, state.step)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(
            lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32),
            state.m, grads,
        )
        v = jax.tree.map(
            lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.v, grads,
        )
        t = step.astype(jnp.float32)
        mhat_scale = 1.0 / (1 - b1**t)
        vhat_scale = 1.0 / (1 - b2**t)

        def upd_fn(g, mi, vi, p=None):
            u = -lr * (mi * mhat_scale) / (
                jnp.sqrt(vi * vhat_scale) + self.eps
            )
            if self.weight_decay and p is not None:
                u = u - lr * self.weight_decay * p.astype(jnp.float32)
            # emit updates in param dtype: the apply add rounds to the
            # param dtype anyway, and f32 update buffers double the
            # transient HBM of giant stacked weights.
            return u.astype(g.dtype)

        if self.weight_decay and params is not None:
            upd = jax.tree.map(upd_fn, grads, m, v, params)
        else:
            upd = jax.tree.map(upd_fn, grads, m, v)
        return upd, AdamState(step, m, v)

    def __hash__(self):
        return hash((id(self.lr) if callable(self.lr) else self.lr,
                     self.b1, self.b2, self.eps, self.weight_decay))


# ---------------------------------------------------------------------------
# Adam with block-wise int8 moments (optimizer-state compression)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Block-quantised tensor: int8 payload + per-block fp32 scales.

    ``shape`` is static pytree aux data so QTensor trees pass cleanly
    through jit/sharding APIs.
    """
    q: jax.Array        # int8, flat padded to block multiple
    scale: jax.Array    # f32 (nblocks,)
    shape: tuple        # original shape (static)

    def tree_flatten(self):
        return (self.q, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)


def _quantize_blockwise(x: jax.Array, block: int) -> QTensor:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return QTensor(q, scale, x.shape)


def _dequantize_blockwise(qt: QTensor) -> jax.Array:
    flat = (qt.q.astype(jnp.float32) * qt.scale[:, None]).reshape(-1)
    size = 1
    for s in qt.shape:
        size *= s
    return flat[:size].reshape(qt.shape)


class Adam8bitState(NamedTuple):
    step: jax.Array
    m: Any   # pytree of QTensor
    v: Any


@dataclasses.dataclass(frozen=True)
class Adam8bit:
    """Adam with int8 block-quantised first/second moments (Dettmers-style).

    HBM for optimiser state drops from 8 bytes/param (fp32 m+v) to
    ~2.06 bytes/param, which combined with bf16 params makes trillion-
    scale MoE configs fit a 16 GB/chip v5e pod.  Small quantisation noise
    on the moments; update math is done in fp32 after dequantisation.
    """

    lr: Schedule = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    block: int = 256

    def init(self, params):
        qz = lambda p: _quantize_blockwise(jnp.zeros(p.shape, jnp.float32),
                                           self.block)
        is_leaf = lambda x: isinstance(x, QTensor)
        del is_leaf
        return Adam8bitState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(qz, params),
            jax.tree.map(qz, params),
        )

    def update(self, grads, state: Adam8bitState, params=None):
        step = state.step + 1
        lr = _lr_at(self.lr, state.step)
        b1, b2 = self.b1, self.b2
        is_qt = lambda x: isinstance(x, QTensor)

        def upd_one(g, mq, vq):
            m = b1 * _dequantize_blockwise(mq) + (1 - b1) * g.astype(jnp.float32)
            v = b2 * _dequantize_blockwise(vq) + (1 - b2) * jnp.square(
                g.astype(jnp.float32))
            t = step.astype(jnp.float32)
            u = -lr * (m / (1 - b1**t)) / (
                jnp.sqrt(v / (1 - b2**t)) + self.eps)
            return u, _quantize_blockwise(m, self.block), \
                _quantize_blockwise(v, self.block)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        del is_qt
        outs = [upd_one(g, m, v) for g, m, v in zip(flat_g, flat_m, flat_v)]
        upd = treedef.unflatten([o[0] for o in outs])
        m = treedef.unflatten([o[1] for o in outs])
        v = treedef.unflatten([o[2] for o in outs])
        return upd, Adam8bitState(step, m, v)

    def __hash__(self):
        return hash((id(self.lr) if callable(self.lr) else self.lr,
                     self.b1, self.b2, self.eps, self.block))


# ---------------------------------------------------------------------------
# Adafactor (factored second moment) — memory-lean alternative for giants
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any   # row second-moment (or full v for <2D tensors)
    vc: Any   # col second-moment (None entries for <2D)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Schedule = 1e-2
    decay: float = 0.8     # t^-decay running-average exponent
    eps: float = 1e-30
    clip_threshold: float = 1.0

    def init(self, params):
        def vr_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((0,), jnp.float32)

        return AdafactorState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(vr_init, params),
            jax.tree.map(vc_init, params),
        )

    def update(self, grads, state: AdafactorState, params=None):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-self.decay)
        lr = _lr_at(self.lr, state.step)

        def upd_one(g, vr, vc):
            grads_dtype = g.dtype
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if g.ndim >= 2:
                vr_n = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc_n = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr_n / jnp.maximum(
                    jnp.mean(vr_n, axis=-1, keepdims=True), self.eps)
                v = r[..., None] * vc_n[..., None, :]
            else:
                vr_n = beta * vr + (1 - beta) * g2
                vc_n = vc
                v = vr_n
            u = g / jnp.sqrt(jnp.maximum(v, self.eps))
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            # param-dtype updates: halves the transient HBM on stacked
            # giant weights (see Adam.upd_fn note).
            return (-lr * u).astype(grads_dtype), vr_n, vc_n

        flat_g, treedef = jax.tree.flatten(grads)
        flat_vr = treedef.flatten_up_to(state.vr)
        flat_vc = treedef.flatten_up_to(state.vc)
        outs = [upd_one(g, r, c) for g, r, c in zip(flat_g, flat_vr, flat_vc)]
        return (
            treedef.unflatten([o[0] for o in outs]),
            AdafactorState(step, treedef.unflatten([o[1] for o in outs]),
                           treedef.unflatten([o[2] for o in outs])),
        )

    def __hash__(self):
        return hash((id(self.lr) if callable(self.lr) else self.lr,
                     self.decay, self.eps, self.clip_threshold))
