from .optimizers import (  # noqa: F401
    Adafactor,
    AdafactorState,
    AdaGrad,
    AdaGradState,
    Adam,
    Adam8bit,
    Adam8bitState,
    AdamState,
    OptaxAdapter,
    QTensor,
    SGD,
    SGDState,
    apply_updates,
    from_optax,
    make_optimizer,
)
from . import compression, schedules  # noqa: F401
