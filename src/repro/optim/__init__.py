from .optimizers import (  # noqa: F401
    Adafactor,
    AdafactorState,
    AdaGrad,
    AdaGradState,
    Adam,
    Adam8bit,
    Adam8bitState,
    AdamState,
    QTensor,
    SGD,
    SGDState,
    apply_updates,
    make_optimizer,
)
from . import compression, schedules  # noqa: F401
