"""Deterministic fault injection for the self-healing LGD stack.

Chaos engineering in miniature: every injector here is DETERMINISTIC —
it fires on exact (refresh cycle / draw index / byte offset) triggers,
never on wall clock or randomness — so a chaos test that survives a
fault proves the recovery path, and a failure replays exactly.

Three fault surfaces, matching the failure model in
docs/ARCHITECTURE.md:

* REFRESH faults (``RefreshRaise``, ``RefreshHang``) hook the
  pipeline's ``set_fault_injector`` port and fire inside the refresh
  computation — exercising retry/backoff, the hang watchdog, and the
  stale-index / uniform-fallback ladder.
* CHECKPOINT corrupters (``truncate_arrays``, ``delete_leaf``,
  ``flip_manifest_byte``) damage on-disk state the way real incidents
  do (truncated write, lost file, bit rot) — exercising ``verify()``
  and ``latest_valid_step`` fallback.
* GRADIENT poison (``NanLossWeights``) wraps a sampler and multiplies
  a window of batches' ``loss_weights`` by NaN — the loss and every
  gradient go non-finite, exercising the trainer's skip guard and
  checkpoint rollback.  Injection rides in BATCH DATA, not in the loss
  function, so the jitted step is untouched (no recompiles, no
  step-conditional tracing).
* PROCESS faults (``ProcKill``, ``ProcHang``, ``DropBarrier``) hook
  the elastic cluster's ``set_fault_injector`` port
  (``repro.dist.multihost.ElasticCluster``) and fire on its
  ``cluster_step`` / ``sync_barrier`` events — exercising host-loss
  detection (stale heartbeats), barrier retry/backoff, and the
  missing-host-degraded → reformed ladder.  ``ProcKill`` is the one
  deliberately NON-recoverable injector: it hard-exits the process the
  way a dead host disappears (no atexit, no flush), and the SURVIVORS'
  recovery is what the chaos test proves.
"""

from __future__ import annotations

import json
import os
import time
import zipfile

import jax.numpy as jnp
import numpy as np


class FaultError(RuntimeError):
    """Raised by injectors — distinguishable from organic failures."""


class FaultInjector:
    """Base injector: ``fire(event, **info)`` is called by instrumented
    code at fault points; subclasses raise/hang/poison on their trigger.
    Events fired by the pipeline:

    * ``refresh_compute`` (``refresh=<cycle>, attempt=<n>``) — inside
      every refresh attempt, including retries;
    * ``recover_rebuild`` (``step=<s>``) — inside a uniform-fallback
      recovery rebuild.
    """

    def fire(self, event: str, **info):   # pragma: no cover - interface
        pass


class RefreshRaise(FaultInjector):
    """Fail the first ``cycles`` refresh cycles (every attempt of each,
    so retries are exhausted and the cycle genuinely fails).

    ``fail_recovery=True`` also fails uniform-fallback recovery rebuilds
    for those cycles' lifetime (count tracked separately).
    """

    def __init__(self, cycles: int = 3, fail_recovery: bool = False,
                 recovery_fails: int = 0):
        self.cycles = cycles
        self._seen: set = set()
        self.fired = 0                 # total injected raises
        self._recovery_left = recovery_fails if fail_recovery or \
            recovery_fails else 0

    def fire(self, event: str, **info):
        if event == "recover_rebuild" and self._recovery_left > 0:
            self._recovery_left -= 1
            self.fired += 1
            raise FaultError(
                f"injected recovery failure at step {info.get('step')}")
        if event != "refresh_compute":
            return
        r = info.get("refresh")
        if r in self._seen or len(self._seen) < self.cycles:
            self._seen.add(r)
            self.fired += 1
            raise FaultError(
                f"injected refresh failure (cycle {r}, "
                f"attempt {info.get('attempt')})")


class RefreshHang(FaultInjector):
    """Hang the first ``cycles`` refresh cycles' attempts for
    ``seconds`` — longer than the pipeline's ``refresh_timeout`` so the
    watchdog abandons the worker and counts the attempt as failed."""

    def __init__(self, seconds: float = 5.0, cycles: int = 1):
        self.seconds = seconds
        self.cycles = cycles
        self._seen: set = set()
        self.fired = 0

    def fire(self, event: str, **info):
        if event != "refresh_compute":
            return
        r = info.get("refresh")
        if r in self._seen or len(self._seen) < self.cycles:
            self._seen.add(r)
            self.fired += 1
            time.sleep(self.seconds)


class NanLossWeights:
    """Sampler proxy poisoning ``loss_weights`` with NaN for the draws
    serving steps ``[at_step, at_step + count)``.

    One-shot by design: the poison budget (``count`` draws) is spent
    once and never refills, so after a trainer ROLLBACK the replayed
    window comes through clean — the chaos test then proves the rolled-
    back run actually recovers rather than re-poisoning forever.  The
    draw counter tracks the wrapped pipeline's step alignment (batch k
    trains step k) and rewinds on ``restore_at``.
    """

    def __init__(self, inner, at_step: int, count: int = 1):
        self._inner = inner
        self._at = at_step
        self._count = count
        self._draws = getattr(inner, "_step", 0)
        self.fired = 0                 # poisoned batches so far

    def __getattr__(self, name):
        # full sampler surface (set_params, sampler_stats, note_loss,
        # check_health, finalize, ...) delegates to the wrapped pipeline
        return getattr(self._inner, name)

    def _poison(self, batch):
        batch = dict(batch)
        batch["loss_weights"] = batch["loss_weights"] * jnp.float32(
            np.nan)
        self.fired += 1
        return batch

    def next_batch(self, *args, **kwargs):
        b = self._inner.next_batch(*args, **kwargs)
        s, self._draws = self._draws, self._draws + 1
        if self.fired < self._count and s >= self._at:
            return self._poison(b)
        return b

    def restore_at(self, step: int, **kwargs):
        self._inner.restore_at(step, **kwargs)
        self._draws = step             # batch k <-> step k realignment


# -- process-level faults (multi-host elastic protocol) ----------------------
# Fired by ``ElasticCluster``: ``cluster_step`` (``step=, rank=``) on
# every heartbeat call, ``sync_barrier`` (``name=, attempt=, rank=``)
# before every barrier arrival.


class ProcKill(FaultInjector):
    """Hard-exit the process at step ``at_step`` — a host loss.

    ``os._exit`` (not ``sys.exit``): a dead host does not run atexit
    hooks, flush buffers, or arrive at the distributed runtime's
    shutdown barrier — and neither does this injector.  Exit code 17
    marks the death as injected for the harness.
    """

    EXIT_CODE = 17

    def __init__(self, at_step: int):
        self.at_step = at_step

    def fire(self, event: str, **info):
        if event == "cluster_step" and info.get("step") == self.at_step:
            os._exit(self.EXIT_CODE)


class ProcHang(FaultInjector):
    """Stall the process for ``seconds`` at step ``at_step`` — a slow /
    GC-paused / partitioned host.  Shorter than the cluster's total
    barrier grace it costs one retry; longer, the host is declared lost
    even though it still lives (the ladder's slow == failed policy)."""

    def __init__(self, at_step: int, seconds: float):
        self.at_step = at_step
        self.seconds = seconds
        self.fired = 0

    def fire(self, event: str, **info):
        if event == "cluster_step" and info.get("step") == self.at_step:
            self.fired += 1
            time.sleep(self.seconds)


class DropBarrier(FaultInjector):
    """Fail this rank's first ``count`` arrivals at sync barriers whose
    name contains ``match`` — a dropped collective (lost packet, stuck
    NCCL ring).  The cluster counts the failed attempt and retries with
    backoff, so ``count <= barrier_retries`` heals transparently."""

    def __init__(self, match: str = "", count: int = 1):
        self.match = match
        self.count = count
        self.fired = 0

    def fire(self, event: str, **info):
        if event != "sync_barrier" or self.fired >= self.count:
            return
        if self.match in str(info.get("name", "")):
            self.fired += 1
            raise FaultError(
                f"injected dropped barrier {info.get('name')!r} "
                f"(attempt {info.get('attempt')})")


# -- checkpoint corrupters ---------------------------------------------------
# Damage MUST defeat naive restore but be caught by verify(): each
# corrupter mimics a distinct real-world incident class.


def _ckpt_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def truncate_arrays(ckpt_dir: str, step: int, keep_bytes: int = 512):
    """Truncate ``arrays.npz`` to ``keep_bytes`` — a writer killed mid-
    flush / disk-full incident.  Kills the zip central directory, so
    even opening the file fails verify."""
    p = os.path.join(_ckpt_path(ckpt_dir, step), "arrays.npz")
    size = os.path.getsize(p)
    with open(p, "r+b") as f:
        f.truncate(min(keep_bytes, size))


def delete_leaf(ckpt_dir: str, step: int, index: int = 0):
    """Rewrite ``arrays.npz`` without its ``index``-th member — a lost
    object / partial replication incident.  The zip stays VALID, so
    only the manifest cross-check catches it."""
    p = os.path.join(_ckpt_path(ckpt_dir, step), "arrays.npz")
    with zipfile.ZipFile(p) as z:
        names = z.namelist()
        victim = names[index % len(names)]
        survivors = {n: z.read(n) for n in names if n != victim}
    with zipfile.ZipFile(p, "w", zipfile.ZIP_STORED) as z:
        for n, blob in survivors.items():
            z.writestr(n, blob)
    return victim


def flip_manifest_byte(ckpt_dir: str, step: int, offset: int = -2):
    """Flip one byte of ``manifest.json`` — bit rot.  Lands inside the
    JSON body (default: near the end, inside the checksum hex), so the
    manifest either stops parsing or fails its self-checksum."""
    p = os.path.join(_ckpt_path(ckpt_dir, step), "manifest.json")
    with open(p, "r+b") as f:
        data = bytearray(f.read())
        data[offset % len(data)] ^= 0xFF
        f.seek(0)
        f.write(data)
        f.truncate(len(data))
