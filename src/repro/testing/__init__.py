from .faults import (  # noqa: F401
    DropBarrier,
    FaultError,
    FaultInjector,
    NanLossWeights,
    ProcHang,
    ProcKill,
    RefreshHang,
    RefreshRaise,
    delete_leaf,
    flip_manifest_byte,
    truncate_arrays,
)
