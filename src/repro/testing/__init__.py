from .faults import (  # noqa: F401
    FaultError,
    FaultInjector,
    NanLossWeights,
    RefreshHang,
    RefreshRaise,
    delete_leaf,
    flip_manifest_byte,
    truncate_arrays,
)
