"""Fused LSH bucket-probe Pallas TPU kernel: hash + searchsorted + sizes.

The per-step hot op of LGD sampling: given B query vectors, find for
every hash table t the contiguous slice [lo, hi) of the sorted-code
index that holds the query's bucket,

    lo[b, t] = #{ n : sorted_codes[t, n] <  code(q_b)[t] }
    hi[b, t] = #{ n : sorted_codes[t, n] <= code(q_b)[t] }

The XLA reference does this as (matmul, sign, pack) followed by an
L-way vmap of two ``searchsorted`` binary searches — O(log N) serial
gathers per table, a layout TPUs hate.  The kernel instead fuses

  1. the query projection matmul (B, d) @ (d, BL*K) on the MXU,
  2. the sign + bit-pack (a second tiny MXU dot with the power-of-two
     vector), and
  3. a *counting* probe: rank-by-comparison against the (BL, BN) tile of
     sorted codes, accumulated over N blocks

into one VMEM-resident pass.  Counting replaces the binary search with a
dense VPU reduction — O(N) work but contiguous reads and zero gathers.
The trade is explicit: the kernel streams all L*N sorted codes per call
(at HBM bandwidth, amortised over the B query batch), so it wins when
N/B is moderate and loses to O(log N) searchsorted when a huge index is
probed by few queries — ``core.tables.bucket_bounds_batched`` auto-
dispatches on exactly that ratio
(``COUNTING_PROBE_MAX_POINTS_PER_QUERY``).

Unsigned order trick: codes are uint32 but Mosaic comparisons are
cleanest in int32, so both sides are *biased* — ``c ^ 0x8000_0000``
reinterpreted as int32 preserves unsigned order exactly (the wrapper
biases ``sorted_codes`` once; the kernel biases the query codes it
computes).

Block layout:
  grid  = (B / BB, L / BL, N / BN)   — N innermost, sequential
  q     : (BB, d)        — query tile, reused across L and N steps
  w     : (d, BL*K)      — projections for BL tables
  sc    : (BL, BN)       — biased int32 sorted-code tile
  lo/hi : (BB, BL)       — int32 output tile, accumulated over N steps
  qc    : (BB, BL)       — scratch: biased query codes, computed at n==0

PERFORMANCE.  VMEM per step ~ BB*d + d*BL*K + BL*BN + 3*BB*BL words
(< 2 MiB at the defaults); the comparison broadcast (BB, BL, BN) is the
VPU working set — keep BB*BL*BN ≲ 1M lanes.  The projection matmul runs
once per (B, L) tile and is fully hidden behind the N-streaming steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BB = 128   # query rows per tile
DEFAULT_BL = 8     # tables per tile
DEFAULT_BN = 512   # sorted-code columns per step

def _pack_codes_biased(proj: jax.Array, k: int, bl: int) -> jax.Array:
    """(BB, BL*K) projections -> (BB, BL) biased-int32 packed codes."""
    bb = proj.shape[0]
    if k <= 24:
        # MXU pack: dot with the power-of-two vector (exact in f32 to 2^24).
        bits = (proj >= 0.0).astype(jnp.float32).reshape(bb, bl, k)
        weights = 2.0 ** jnp.arange(k, dtype=jnp.float32)
        packed = jax.lax.dot_general(
            bits, weights[:, None],
            dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[..., 0].astype(jnp.int32)
    else:
        bits = (proj >= 0.0).reshape(bb, bl, k).astype(jnp.uint32)
        weights = jnp.uint32(1) << jnp.arange(k, dtype=jnp.uint32)
        packed = jax.lax.bitcast_convert_type(
            jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32), jnp.int32)
    return packed ^ jnp.int32(-(2 ** 31))   # xor toggles the sign bit


def _count_tile(qc, sc, n_off, n_actual, bl, bn):
    """Rank counts of qc (BB, BL) against the sc (BL, BN) tile."""
    col = jax.lax.broadcasted_iota(jnp.int32, (bl, bn), 1) + n_off
    valid = col < n_actual                               # mask N padding
    less = (sc[None] < qc[:, :, None]) & valid[None]     # (BB, BL, BN)
    leq = (sc[None] <= qc[:, :, None]) & valid[None]
    return (jnp.sum(less, axis=2, dtype=jnp.int32),
            jnp.sum(leq, axis=2, dtype=jnp.int32))


def _fused_kernel(q_ref, w_ref, sc_ref, lo_ref, hi_ref, qc_ref,
                  *, k: int, bl: int, bn: int, n_actual: int):
    n_idx = pl.program_id(2)

    @pl.when(n_idx == 0)
    def _init():
        proj = jnp.dot(q_ref[...], w_ref[...],
                       preferred_element_type=jnp.float32)
        qc_ref[...] = _pack_codes_biased(proj, k, bl)
        lo_ref[...] = jnp.zeros_like(lo_ref)
        hi_ref[...] = jnp.zeros_like(hi_ref)

    less, leq = _count_tile(qc_ref[...], sc_ref[...], n_idx * bn, n_actual,
                            bl, bn)
    lo_ref[...] += less
    hi_ref[...] += leq


def _codes_kernel(qc_in_ref, sc_ref, lo_ref, hi_ref,
                  *, bl: int, bn: int, n_actual: int):
    n_idx = pl.program_id(2)

    @pl.when(n_idx == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lo_ref)
        hi_ref[...] = jnp.zeros_like(hi_ref)

    less, leq = _count_tile(qc_in_ref[...], sc_ref[...], n_idx * bn,
                            n_actual, bl, bn)
    lo_ref[...] += less
    hi_ref[...] += leq


def _multi_kernel(q_ref, w_ref, sc_ref, lo_ref, hi_ref, qc_ref,
                  *, k: int, bl: int, bn: int, n_actual: int, masks: tuple):
    """Multi-probe variant: count ranks for every Hamming-ball probe code.

    The query codes are hashed ONCE per (B, L) tile; each probe mask is
    a compile-time XOR constant applied to the base code, so the J-way
    probe walk reuses the same streamed sorted-code tile — multi-probe
    costs no extra HBM traffic over the single-probe kernel, only VPU
    compares.  Output tile layout is j-major: columns
    [j*BL, (j+1)*BL) hold probe j's counts for this table tile (the
    wrapper untangles the block layout).
    """
    n_idx = pl.program_id(2)

    @pl.when(n_idx == 0)
    def _init():
        proj = jnp.dot(q_ref[...], w_ref[...],
                       preferred_element_type=jnp.float32)
        qc_ref[...] = _pack_codes_biased(proj, k, bl)
        lo_ref[...] = jnp.zeros_like(lo_ref)
        hi_ref[...] = jnp.zeros_like(hi_ref)

    base = qc_ref[...]
    for j, mask in enumerate(masks):
        # XOR with the biased mask constant preserves unsigned order:
        # (raw ^ bias) ^ mask == (raw ^ mask) ^ bias since XOR commutes.
        m_i32 = mask - (1 << 32) if mask >= (1 << 31) else mask
        qc_j = base ^ jnp.int32(m_i32)
        less, leq = _count_tile(qc_j, sc_ref[...], n_idx * bn, n_actual,
                                bl, bn)
        lo_ref[:, j * bl:(j + 1) * bl] += less
        hi_ref[:, j * bl:(j + 1) * bl] += leq


def _out_specs(block_b: int, block_l: int):
    spec = pl.BlockSpec((block_b, block_l), lambda i, j, n: (i, j))
    return [spec, spec]


def bucket_probe_pallas(
    q: jax.Array,             # (B, d) float32 queries, B % block_b == 0
    w: jax.Array,             # (d, L*K) float32 projections
    sc_biased: jax.Array,     # (L, N) int32 biased sorted codes, N padded
    *,
    k: int,
    l: int,
    n_actual: int,
    block_b: int = DEFAULT_BB,
    block_l: int = DEFAULT_BL,
    block_n: int = DEFAULT_BN,
    interpret: bool = False,
):
    """Fused hash+probe: returns (lo, hi), each (B, L) int32."""
    b, d = q.shape
    ll, n = sc_biased.shape
    assert ll == l and w.shape == (d, l * k), (q.shape, w.shape, sc_biased.shape)
    assert b % block_b == 0 and l % block_l == 0 and n % block_n == 0
    grid = (b // block_b, l // block_l, n // block_n)
    out_shape = [jax.ShapeDtypeStruct((b, l), jnp.int32)] * 2
    return pl.pallas_call(
        functools.partial(_fused_kernel, k=k, bl=block_l, bn=block_n,
                          n_actual=n_actual),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j, n: (i, 0)),
            pl.BlockSpec((d, block_l * k), lambda i, j, n: (0, j)),
            pl.BlockSpec((block_l, block_n), lambda i, j, n: (j, n)),
        ],
        out_specs=_out_specs(block_b, block_l),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((block_b, block_l), jnp.int32)],
        interpret=interpret,
    )(q.astype(jnp.float32), w.astype(jnp.float32), sc_biased)


def bucket_probe_multi_pallas(
    q: jax.Array,             # (B, d) float32 queries, B % block_b == 0
    w: jax.Array,             # (d, L*K) float32 projections
    sc_biased: jax.Array,     # (L, N) int32 biased sorted codes, N padded
    *,
    masks: tuple,
    k: int,
    l: int,
    n_actual: int,
    block_b: int = DEFAULT_BB,
    block_l: int = DEFAULT_BL,
    block_n: int = DEFAULT_BN,
    interpret: bool = False,
):
    """Fused hash + J-way Hamming-ball probe.

    Returns (lo, hi), each (B, L*J) int32 in BLOCK j-major layout:
    global column (t // BL)*J*BL + j*BL + (t % BL) holds probe j's
    count for table t — ``ops.bucket_probe_multi`` untangles this to
    (B, J, L).  One streamed pass over the sorted codes serves all J
    probe codes.
    """
    b, d = q.shape
    ll, n = sc_biased.shape
    j = len(masks)
    assert ll == l and w.shape == (d, l * k), (q.shape, w.shape, sc_biased.shape)
    assert b % block_b == 0 and l % block_l == 0 and n % block_n == 0
    grid = (b // block_b, l // block_l, n // block_n)
    out_shape = [jax.ShapeDtypeStruct((b, l * j), jnp.int32)] * 2
    out_spec = pl.BlockSpec((block_b, block_l * j), lambda i, jl, nn: (i, jl))
    return pl.pallas_call(
        functools.partial(_multi_kernel, k=k, bl=block_l, bn=block_n,
                          n_actual=n_actual, masks=tuple(masks)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, jl, nn: (i, 0)),
            pl.BlockSpec((d, block_l * k), lambda i, jl, nn: (0, jl)),
            pl.BlockSpec((block_l, block_n), lambda i, jl, nn: (jl, nn)),
        ],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((block_b, block_l), jnp.int32)],
        interpret=interpret,
    )(q.astype(jnp.float32), w.astype(jnp.float32), sc_biased)


def bucket_probe_codes_pallas(
    qc_biased: jax.Array,     # (B, L) int32 biased query codes
    sc_biased: jax.Array,     # (L, N) int32 biased sorted codes
    *,
    n_actual: int,
    block_b: int = DEFAULT_BB,
    block_l: int = DEFAULT_BL,
    block_n: int = DEFAULT_BN,
    interpret: bool = False,
):
    """Probe-only variant for families hashed outside the kernel
    (quadratic SRP hashes via a per-function quadratic form, not a single
    matmul).  Returns (lo, hi), each (B, L) int32."""
    b, l = qc_biased.shape
    ll, n = sc_biased.shape
    assert ll == l, (qc_biased.shape, sc_biased.shape)
    assert b % block_b == 0 and l % block_l == 0 and n % block_n == 0
    grid = (b // block_b, l // block_l, n // block_n)
    out_shape = [jax.ShapeDtypeStruct((b, l), jnp.int32)] * 2
    return pl.pallas_call(
        functools.partial(_codes_kernel, bl=block_l, bn=block_n,
                          n_actual=n_actual),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_l), lambda i, j, n: (i, j)),
            pl.BlockSpec((block_l, block_n), lambda i, j, n: (j, n)),
        ],
        out_specs=_out_specs(block_b, block_l),
        out_shape=out_shape,
        interpret=interpret,
    )(qc_biased, sc_biased)
