"""Pure-jnp oracle for the fused bucket-probe kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bucket_probe_codes_ref(qcodes: jax.Array, sorted_codes: jax.Array):
    """Batched two-binary-search probe.

    qcodes: (B, L) uint32; sorted_codes: (L, N) uint32 ascending per row.
    Returns (lo, hi) int32 (B, L): per table, the [lo, hi) slice of the
    query's bucket.
    """
    def per_table(sc, c):                       # sc: (N,), c: (B,)
        lo = jnp.searchsorted(sc, c, side="left")
        hi = jnp.searchsorted(sc, c, side="right")
        return lo.astype(jnp.int32), hi.astype(jnp.int32)

    return jax.vmap(per_table, in_axes=(0, 1), out_axes=1)(
        sorted_codes, qcodes)


def bucket_probe_ref(q: jax.Array, w: jax.Array, sorted_codes: jax.Array,
                     *, k: int, l: int):
    """Hash B queries then probe: the oracle for the fully fused kernel."""
    from ..simhash.ref import simhash_codes_ref

    qcodes = simhash_codes_ref(q, w, k=k, l=l)       # (B, L)
    return bucket_probe_codes_ref(qcodes, sorted_codes)


def bucket_probe_multi_ref(q: jax.Array, w: jax.Array,
                           sorted_codes: jax.Array, masks,
                           *, k: int, l: int):
    """Oracle for the fused multi-probe kernel.

    Hash B queries, XOR each packed code with every Hamming-ball probe
    mask, and binary-search every perturbed code.  Returns (lo, hi)
    int32 of shape (B, J, L) where J = len(masks); [b, j, t] is the
    bucket slice of probe code ``code(q_b)[t] ^ masks[j]`` in table t.
    """
    from ..simhash.ref import simhash_codes_ref

    qcodes = simhash_codes_ref(q, w, k=k, l=l)               # (B, L)
    marr = jnp.asarray(list(masks), jnp.uint32)
    pcodes = qcodes[:, None, :] ^ marr[None, :, None]        # (B, J, L)
    b, j, ll = pcodes.shape
    lo, hi = bucket_probe_codes_ref(pcodes.reshape(b * j, ll), sorted_codes)
    return lo.reshape(b, j, ll), hi.reshape(b, j, ll)
