from .ops import bucket_probe, bucket_probe_codes  # noqa: F401
from .ref import bucket_probe_codes_ref, bucket_probe_ref  # noqa: F401
from .kernel import (  # noqa: F401
    bucket_probe_codes_pallas,
    bucket_probe_pallas,
)
