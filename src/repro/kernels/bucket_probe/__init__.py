from .ops import (  # noqa: F401
    bucket_probe,
    bucket_probe_codes,
    bucket_probe_multi,
)
from .ref import (  # noqa: F401
    bucket_probe_codes_ref,
    bucket_probe_multi_ref,
    bucket_probe_ref,
)
from .kernel import (  # noqa: F401
    bucket_probe_codes_pallas,
    bucket_probe_multi_pallas,
    bucket_probe_pallas,
)
