"""Jit'd public wrappers for the bucket-probe kernel (padding + dispatch).

Contract: ``use_pallas=False`` (the CPU-host default chosen by callers)
runs the pure-XLA oracle; ``use_pallas=True, interpret=True`` runs the
kernel under the Pallas interpreter and must match the oracle exactly —
that is the parity surface the tests pin down.  Padding keeps arbitrary
(B, L, N) shapes legal: B and L are padded to block multiples (padded
rows/tables are computed then sliced off), N is padded to a block
multiple and masked *inside* the kernel so padded columns never count.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import round_up as _round_up
from .kernel import (
    DEFAULT_BB,
    DEFAULT_BL,
    DEFAULT_BN,
    bucket_probe_codes_pallas,
    bucket_probe_multi_pallas,
    bucket_probe_pallas,
)
from .ref import (
    bucket_probe_codes_ref,
    bucket_probe_multi_ref,
    bucket_probe_ref,
)


def _bias(codes_u32: jax.Array) -> jax.Array:
    """uint32 -> order-preserving int32 (toggle the sign bit)."""
    return jax.lax.bitcast_convert_type(
        codes_u32 ^ jnp.uint32(0x80000000), jnp.int32)


def _blocks(b: int, l: int, n: int):
    bb = min(DEFAULT_BB, _round_up(b, 8))
    bl = min(DEFAULT_BL, l)
    bn = min(DEFAULT_BN, _round_up(n, 128))
    return bb, bl, bn


def _pad_sc(sorted_codes: jax.Array, l_pad: int, n_pad: int) -> jax.Array:
    l, n = sorted_codes.shape
    sc = jnp.pad(sorted_codes, ((0, l_pad - l), (0, n_pad - n)))
    return _bias(sc)


@partial(jax.jit, static_argnames=("k", "l", "use_pallas", "interpret"))
def bucket_probe(
    q: jax.Array,             # (B, d) or (d,) query vectors
    w: jax.Array,             # (d, L*K) projections
    sorted_codes: jax.Array,  # (L, N) uint32, ascending per row
    *,
    k: int,
    l: int,
    use_pallas: bool = True,
    interpret: bool = False,
):
    """Fused hash+probe -> (lo, hi) int32, (B, L) (or (L,) for 1-D q)."""
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    if w.shape != (q.shape[1], l * k):
        raise ValueError(f"projections {w.shape} != (d={q.shape[1]}, L*K={l * k})")
    if sorted_codes.shape[0] != l:
        raise ValueError(f"sorted_codes {sorted_codes.shape} has {sorted_codes.shape[0]} tables, expected L={l}")
    if not use_pallas:
        lo, hi = bucket_probe_ref(q, w, sorted_codes, k=k, l=l)
    else:
        b, d = q.shape
        _, n = sorted_codes.shape
        bb, bl, bn = _blocks(b, l, n)
        b_pad, l_pad, n_pad = (_round_up(b, bb), _round_up(l, bl),
                               _round_up(n, bn))
        lo, hi = bucket_probe_pallas(
            jnp.pad(q, ((0, b_pad - b), (0, 0))),
            jnp.pad(w, ((0, 0), (0, (l_pad - l) * k))),
            _pad_sc(sorted_codes, l_pad, n_pad),
            k=k, l=l_pad, n_actual=n, block_b=bb, block_l=bl, block_n=bn,
            interpret=interpret,
        )
        lo, hi = lo[:b, :l], hi[:b, :l]
    return (lo[0], hi[0]) if squeeze else (lo, hi)


@partial(jax.jit, static_argnames=("masks", "k", "l", "use_pallas",
                                   "interpret"))
def bucket_probe_multi(
    q: jax.Array,             # (B, d) or (d,) query vectors
    w: jax.Array,             # (d, L*K) projections
    sorted_codes: jax.Array,  # (L, N) uint32, ascending per row
    masks: tuple,             # J static XOR masks (probe_masks(k, J))
    *,
    k: int,
    l: int,
    use_pallas: bool = True,
    interpret: bool = False,
):
    """Fused hash + multi-probe: (lo, hi) int32, (B, J, L) (or (J, L)).

    For each query, table, and Hamming-ball probe mask, the [lo, hi)
    slice of the bucket whose code is ``code(q)[t] ^ masks[j]``.  The
    kernel hashes once and reuses the streamed sorted-code tile for all
    J probe codes; the XLA reference path (``use_pallas=False``) lowers
    to hash + J*L binary searches.  Parity between the two is pinned by
    tests/test_multiprobe.py.
    """
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    if w.shape != (q.shape[1], l * k):
        raise ValueError(
            f"projections {w.shape} != (d={q.shape[1]}, L*K={l * k})")
    if sorted_codes.shape[0] != l:
        raise ValueError(
            f"sorted_codes {sorted_codes.shape} has {sorted_codes.shape[0]} "
            f"tables, expected L={l}")
    j = len(masks)
    if not use_pallas:
        lo, hi = bucket_probe_multi_ref(q, w, sorted_codes, masks, k=k, l=l)
    else:
        b, d = q.shape
        _, n = sorted_codes.shape
        bb, bl, bn = _blocks(b, l, n)
        b_pad, l_pad, n_pad = (_round_up(b, bb), _round_up(l, bl),
                               _round_up(n, bn))
        lo, hi = bucket_probe_multi_pallas(
            jnp.pad(q, ((0, b_pad - b), (0, 0))),
            jnp.pad(w, ((0, 0), (0, (l_pad - l) * k))),
            _pad_sc(sorted_codes, l_pad, n_pad),
            masks=tuple(masks), k=k, l=l_pad, n_actual=n,
            block_b=bb, block_l=bl, block_n=bn, interpret=interpret,
        )
        # kernel layout: column (t//BL)*J*BL + j*BL + (t%BL); untangle
        # to (B, J, L) and slice the padding off.
        def unblock(a):
            a = a.reshape(b_pad, l_pad // bl, j, bl)
            return a.transpose(0, 2, 1, 3).reshape(b_pad, j, l_pad)[:b, :, :l]
        lo, hi = unblock(lo), unblock(hi)
    return (lo[0], hi[0]) if squeeze else (lo, hi)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def bucket_probe_codes(
    qcodes: jax.Array,        # (B, L) or (L,) uint32 query codes
    sorted_codes: jax.Array,  # (L, N) uint32, ascending per row
    *,
    use_pallas: bool = True,
    interpret: bool = False,
):
    """Probe-only entry point (pre-hashed queries, e.g. quadratic SRP)."""
    squeeze = qcodes.ndim == 1
    if squeeze:
        qcodes = qcodes[None]
    if not use_pallas:
        lo, hi = bucket_probe_codes_ref(qcodes, sorted_codes)
    else:
        b, l = qcodes.shape
        _, n = sorted_codes.shape
        bb, bl, bn = _blocks(b, l, n)
        b_pad, l_pad, n_pad = (_round_up(b, bb), _round_up(l, bl),
                               _round_up(n, bn))
        lo, hi = bucket_probe_codes_pallas(
            jnp.pad(_bias(qcodes), ((0, b_pad - b), (0, l_pad - l))),
            _pad_sc(sorted_codes, l_pad, n_pad),
            n_actual=n, block_b=bb, block_l=bl, block_n=bn,
            interpret=interpret,
        )
        lo, hi = lo[:b, :l], hi[:b, :l]
    return (lo[0], hi[0]) if squeeze else (lo, hi)
