from .ops import gqa_attention, gqa_decode  # noqa: F401
from .ref import attention_ref, decode_ref  # noqa: F401
from .kernel import flash_attention_pallas, flash_decode_pallas  # noqa: F401
