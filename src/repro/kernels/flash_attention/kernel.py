"""Causal GQA flash attention, Pallas TPU.

Online-softmax tiling (Flash-Attention 2 schedule adapted to the TPU
memory hierarchy): the KV sequence is the innermost *grid* dimension so
each (batch*head, q-block) owns VMEM scratch carrying the running max
``m``, normaliser ``l`` and accumulator ``acc`` across KV steps; XLA's
Pallas pipeline overlaps the HBM->VMEM streaming of the next KV block
with the MXU matmuls of the current one.

Causality is exploited structurally: KV blocks strictly above the
diagonal contribute nothing and their compute is skipped with pl.when
(the roofline win: 2x fewer MXU FLOPs at long sequence).

GQA: queries arrive grouped as (B, Hkv, G, S, D) so one KV head's block
is shared by its G query heads without re-streaming K/V — the layout
turns grouped attention into a plain batched matmul over the fused
(G*bq, D) tile.

Block sizes default to (bq, bk) = (256, 256): MXU-aligned (multiples of
128 in the contracted dims come from D >= 128) and small enough that
q/k/v/acc tiles fit VMEM for D <= 256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, bq: int, bk: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip blocks strictly above the diagonal
    run = (not causal) or (ki * bk < (qi + 1) * bq)

    @pl.when(run)
    def _step():
        q = q_ref[0]                         # (G*bq, D) fused group-of-queries
        k = k_ref[0]                         # (bk, D)
        v = v_ref[0]                         # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                            # (G*bq, bk)
        if causal:
            g_bq = q.shape[0]
            g = g_bq // bq
            q_pos = qi * bq + (
                jax.lax.broadcasted_iota(jnp.int32, (g_bq, bk), 0) % bq
            )
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (g_bq, bk), 1)
            del g
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_scr[...]                  # (G*bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)               # (G*bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # (B, Hkv, G, S, D) — G query heads per KV head
    k: jax.Array,   # (B, Hkv, S, D)
    v: jax.Array,   # (B, Hkv, S, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, Hkv, G, S, D) attention output."""
    b, hkv, g, s, d = q.shape
    assert k.shape == (b, hkv, s, d) and v.shape == (b, hkv, s, d)
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    scale = scale if scale is not None else d ** -0.5
    nq, nk = s // bq, s // bk
    bh = b * hkv

    # rows grouped as (G, bq) per q-block: reorder to (bh, nq*g*bq, d)
    qf = q.reshape(bh, g, nq, bq, d).transpose(0, 2, 1, 3, 4).reshape(
        bh, nq * g * bq, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, bq=bq, bk=bk, causal=causal
        ),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, g * bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g * bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq * g * bq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq, 1), jnp.float32),
            pltpu.VMEM((g * bq, 1), jnp.float32),
            pltpu.VMEM((g * bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(bh, nq, g, bq, d).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, hkv, g, s, d)


# ---------------------------------------------------------------------------
# flash decode: one query token against a long KV cache
# ---------------------------------------------------------------------------

def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, bk: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                             # (G, D) — all grouped heads
    k = k_ref[0]                             # (bk, D)
    v = v_ref[0]
    kv_len = len_ref[0]                      # valid cache length
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                # (G, bk)
    pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < kv_len, s, NEG_INF)
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_decode_pallas(
    q: jax.Array,        # (B, Hkv, G, D) single new token
    k_cache: jax.Array,  # (B, Hkv, S, D)
    v_cache: jax.Array,  # (B, Hkv, S, D)
    kv_len: jax.Array,   # (B,) int32 valid lengths
    *,
    scale: float | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, Hkv, G, D)."""
    b, hkv, g, d = q.shape
    s = k_cache.shape[2]
    bk = min(block_k, s)
    assert s % bk == 0
    scale = scale if scale is not None else d ** -0.5
    bh = b * hkv
    qf = q.reshape(bh, g, d)
    kf = k_cache.reshape(bh, s, d)
    vf = v_cache.reshape(bh, s, d)
    lens = jnp.repeat(kv_len.astype(jnp.int32), hkv)  # (bh,)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bk=bk),
        grid=(bh, s // bk),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1,), lambda h, j: (h,)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, lens)
    return out.reshape(b, hkv, g, d)
