"""Pure-jnp oracles for flash attention / flash decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,   # (B, Hkv, G, S, D)
    k: jax.Array,   # (B, Hkv, S, D)
    v: jax.Array,   # (B, Hkv, S, D)
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    b, hkv, g, s, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum(
        "bhgqd,bhkd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_ref(
    q: jax.Array,        # (B, Hkv, G, D)
    k_cache: jax.Array,  # (B, Hkv, S, D)
    v_cache: jax.Array,  # (B, Hkv, S, D)
    kv_len: jax.Array,   # (B,)
    *,
    scale: float | None = None,
) -> jax.Array:
    b, hkv, g, d = q.shape
    s = k_cache.shape[2]
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum(
        "bhgd,bhkd->bhgk", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    mask = jnp.arange(s)[None, :] < kv_len[:, None]     # (B, S)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
