"""Jit'd public wrappers: dispatch between the Pallas kernel and the oracle.

The model code calls these; on the TPU target ``use_pallas=True`` is the
default through configs, while CPU smoke tests run the oracle (XLA:CPU)
and the kernel tests run interpret mode.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas, flash_decode_pallas
from .ref import attention_ref, decode_ref


@partial(jax.jit, static_argnames=("causal", "use_pallas", "interpret",
                                   "block_q", "block_k"))
def gqa_attention(
    q: jax.Array,   # (B, S, Hq, D)  — model layout
    k: jax.Array,   # (B, S, Hkv, D)
    v: jax.Array,   # (B, S, Hkv, D)
    *,
    causal: bool = True,
    use_pallas: bool = False,
    interpret: bool = False,
    block_q: int = 256,
    block_k: int = 256,
) -> jax.Array:
    """Grouped-query attention; returns (B, S, Hq, D)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,S,D)
    kg = k.transpose(0, 2, 1, 3)                              # (B,Hkv,S,D)
    vg = v.transpose(0, 2, 1, 3)
    if use_pallas:
        out = flash_attention_pallas(
            qg, kg, vg, causal=causal, block_q=block_q, block_k=block_k,
            interpret=interpret,
        )
    else:
        out = attention_ref(qg, kg, vg, causal=causal)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, d)


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "block_k"))
def gqa_decode(
    q: jax.Array,        # (B, 1, Hq, D)
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, D)
    kv_len: jax.Array,   # (B,)
    *,
    use_pallas: bool = False,
    interpret: bool = False,
    block_k: int = 512,
) -> jax.Array:
    """Single-token decode against a KV cache; returns (B, 1, Hq, D)."""
    b, one, hq, d = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    qg = q[:, 0].reshape(b, hkv, g, d)
    kg = k_cache.transpose(0, 2, 1, 3)
    vg = v_cache.transpose(0, 2, 1, 3)
    if use_pallas:
        out = flash_decode_pallas(
            qg, kg, vg, kv_len, block_k=block_k, interpret=interpret
        )
    else:
        out = decode_ref(qg, kg, vg, kv_len)
    return out.reshape(b, 1, hq, d)
