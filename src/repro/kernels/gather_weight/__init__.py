from .kernel import gather_weight_pallas  # noqa: F401
from .ops import gather_weight  # noqa: F401
from .ref import gather_weight_ref  # noqa: F401
