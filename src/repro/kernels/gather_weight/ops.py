"""Jit'd public wrapper for the gather+weight kernel (padding + dispatch).

Contract: ``use_pallas=False`` (the CPU-host default chosen by callers)
runs the pure-XLA oracle; ``use_pallas=True, interpret=True`` runs the
kernel under the Pallas interpreter and must match the oracle exactly —
that is the parity surface pinned by tests/test_gather_weight.py.  The
row width is padded to a lane multiple (padded columns are sliced off;
they are gathered but never observed), so arbitrary sequence lengths
are legal.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import round_up as _round_up
from .kernel import gather_weight_pallas
from .ref import gather_weight_ref

_LANE = 128


@partial(jax.jit, static_argnames=("p_floor", "use_pallas", "interpret"))
def gather_weight(
    store: jax.Array,   # (N, S) int32 device-resident token rows
    idx: jax.Array,     # (m,) int32 sampled row ids
    probs: jax.Array,   # (m,) f32 Algorithm-1 probabilities
    *,
    p_floor: float = 1e-8,
    use_pallas: bool = True,
    interpret: bool = False,
):
    """Fused batch assembly: (rows (m, S) int32, weights (m,) f32)."""
    if idx.shape != probs.shape or idx.ndim != 1:
        raise ValueError(
            f"idx {idx.shape} and probs {probs.shape} must be matching "
            "1-D arrays")
    if not use_pallas:
        return gather_weight_ref(store, idx, probs, p_floor=p_floor)
    n, s = store.shape
    # hot-path note: callers on the kernel path should hand in a store
    # whose row width is already a lane multiple (the LGD pipeline pads
    # its device store ONCE at build) — then this pad is zero-width and
    # compiles away; an unpadded store still works but costs an O(N*S)
    # copy per call.
    s_pad = _round_up(s, _LANE)
    rows, w = gather_weight_pallas(
        jnp.pad(store, ((0, 0), (0, s_pad - s))),
        idx, probs[:, None],
        p_floor=p_floor, interpret=interpret)
    return rows[:, :s], w[:, 0]
