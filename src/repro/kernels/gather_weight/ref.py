"""Pure-jnp oracle for the fused gather+weight kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_weight_ref(store: jax.Array, idx: jax.Array, probs: jax.Array,
                      *, p_floor: float):
    """rows = store[idx]; w = 1/(max(p, p_floor) * N).

    store: (N, S) int32; idx: (m,) int32; probs: (m,) f32.
    Returns (rows (m, S) int32, w (m,) f32).
    """
    rows = jnp.take(store, idx, axis=0)
    w = 1.0 / (jnp.maximum(probs.astype(jnp.float32), p_floor)
               * store.shape[0])
    return rows, w
