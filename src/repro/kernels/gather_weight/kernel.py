"""Fused gather+weight Pallas TPU kernel: token-row gather + 1/(p·N) weights.

The last host-resident op of the LGD step path: Algorithm 1 emits m
sampled example ids and their exact probabilities; the batch the trainer
consumes is the gathered token rows plus the importance weights

    w_j = 1 / (max(p_j, p_floor) * N)

that de-bias the adaptive draw.  Before this kernel the gather ran on
the host (``np.asarray`` per batch — a device->host->device round-trip
every step); here the token store stays resident in HBM and the whole
batch assembly is one kernel launch appended to the step's program.

HARDWARE ADAPTATION.  A row gather with data-dependent row ids cannot be
expressed with static BlockSpecs alone — the block index must be
computed from the sampled ids.  This is the canonical scalar-prefetch
pattern: the ids are a ``PrefetchScalarGridSpec`` scalar operand, so the
index_map of the token-store input reads ``idx_ref[i]`` and DMAs exactly
the sampled row into VMEM for grid step i.  The weight is computed in
the same step on the VPU from the (1, 1) probability block — the
probabilities never round-trip anywhere else.

Block layout:
  grid   = (m,)                  — one sampled row per step
  idx    : (m,) int32            — scalar-prefetch operand (SMEM)
  probs  : (1, 1) f32            — probability block of row i
  store  : (1, S_pad) int32      — token row idx[i], selected by index_map
  rows   : (1, S_pad) int32      — output tile i
  w      : (1, 1) f32            — output weight i

m is tiny (a minibatch, 8..512), S_pad is the 128-padded row width; the
per-step VMEM footprint is a single token row, and the m DMAs are issued
back-to-back by the pipelined grid.  The XLA reference (``ref.py``) is
``store[idx]`` + the same arithmetic — bit-identical, and the path CPU
hosts auto-dispatch to (see ``ops.gather_weight``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_weight_kernel(idx_ref, probs_ref, store_ref, rows_ref, w_ref,
                          *, n_points: int, p_floor: float):
    del idx_ref  # consumed by the index_map; the body only copies blocks
    rows_ref[...] = store_ref[...]
    p = jnp.maximum(probs_ref[0, 0], p_floor)
    w_ref[0, 0] = 1.0 / (p * n_points)


def gather_weight_pallas(
    store: jax.Array,       # (N, S_pad) int32 token rows, S_pad % 128 == 0
    idx: jax.Array,         # (m,) int32 sampled row ids
    probs: jax.Array,       # (m, 1) f32 Algorithm-1 probabilities
    *,
    p_floor: float,
    interpret: bool = False,
):
    """Fused gather+weight: returns (rows (m, S_pad) int32, w (m, 1) f32)."""
    n, s_pad = store.shape
    m = idx.shape[0]
    assert probs.shape == (m, 1), (probs.shape, m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, idx_ref: (i, 0)),
            pl.BlockSpec((1, s_pad), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s_pad), lambda i, idx_ref: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, idx_ref: (i, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_gather_weight_kernel, n_points=n, p_floor=p_floor),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, s_pad), jnp.int32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(idx.astype(jnp.int32), probs.astype(jnp.float32), store)
