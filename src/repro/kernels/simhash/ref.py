"""Pure-jnp oracle for the SimHash kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def simhash_codes_ref(x: jax.Array, w: jax.Array, *, k: int, l: int) -> jax.Array:
    """codes[n, t] = sum_k (x[n] @ w[:, t*K+k] >= 0) << k  — (N, L) uint32."""
    proj = x.astype(jnp.float32) @ w.astype(jnp.float32)      # (N, L*K)
    bits = (proj >= 0).reshape(x.shape[0], l, k).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(k, dtype=jnp.uint32)
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)
