"""Jit'd public wrapper for the SimHash kernel (padding + dispatch)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import round_up as _round_up
from .kernel import DEFAULT_BL, DEFAULT_BN, simhash_codes_pallas
from .ref import simhash_codes_ref


@partial(jax.jit, static_argnames=("k", "l", "use_pallas", "interpret"))
def simhash_codes(
    x: jax.Array,
    w: jax.Array,
    *,
    k: int,
    l: int,
    use_pallas: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Packed SimHash codes (N, L) uint32; pads N and L to block multiples.

    ``use_pallas=False`` falls back to the jnp oracle (used on CPU hosts
    where the interpreter would be slower than XLA:CPU).
    """
    if not use_pallas:
        return simhash_codes_ref(x, w, k=k, l=l)
    n, d = x.shape
    bn = min(DEFAULT_BN, _round_up(n, 8))
    bl = min(DEFAULT_BL, l)
    n_pad = _round_up(n, bn)
    l_pad = _round_up(l, bl)
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, (l_pad - l) * k)))
    codes = simhash_codes_pallas(
        xp, wp, k=k, l=l_pad, block_n=bn, block_l=bl, interpret=interpret
    )
    return codes[:n, :l]
