"""Fused SimHash Pallas TPU kernel: projection matmul + sign + bit-pack.

The hot op of LGD's preprocessing/refresh path: hash every training point
(N can be 1e5..1e9 across data shards) into L packed K-bit codes,

    codes[n, t] = sum_k (x[n] @ w[:, t*K + k] >= 0) << k        (uint32)

HARDWARE ADAPTATION (vs. the paper's CPU sparse projections): on TPU the
MXU makes a *dense* (BN, d) @ (d, BL*K) tile matmul essentially free
compared to the HBM traffic of streaming X, so instead of sparse
multiplications we fuse the full projection, the sign, and the bit-pack
into one VMEM-resident pass — one read of X, one tiny write of codes
(32x smaller than the projection output it replaces).  The pack is a
dot-product with the power-of-two vector so it also runs on the MXU/VPU
rather than looping over bits.

Block layout:
  grid  = (N / BN, L / BL)
  x     : (BN, d)       — full feature dim resident in VMEM (d <= few k)
  w     : (d, BL*K)     — projections for BL tables
  codes : (BN, BL)      — uint32 output tile
VMEM per step ~ BN*d + d*BL*K + BN*BL*K floats; defaults keep this
< 4 MiB for d up to 4096 with BN=256, BL=8, K<=32.

PERFORMANCE.  This is the hot op of ``build_index``/``refresh_index``
(`repro.core.tables`): one fused pass replaces three XLA ops (matmul,
compare, reduce-pack) and the (N, L*K) f32 projection intermediate —
the dominant HBM round-trip at refresh time — never leaves VMEM.

FALLBACK CONTRACT.  ``ops.simhash_codes(use_pallas=False)`` lowers to
``ref.simhash_codes_ref`` and is bit-identical to the kernel (both are
f32 matmul + sign + exact pack); ``use_pallas=True, interpret=True``
runs this kernel under the Pallas interpreter and is the parity surface
CI pins on CPU.  Callers auto-dispatch via
``repro.kernels.default_use_pallas()`` — TPU gets the kernel, every
other backend gets the identical XLA reference, so results never depend
on the platform.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BN = 256
DEFAULT_BL = 8


def _simhash_kernel(x_ref, w_ref, out_ref, *, k: int, bl: int):
    x = x_ref[...]                      # (BN, d)
    w = w_ref[...]                      # (d, BL*K)
    proj = jnp.dot(x, w, preferred_element_type=jnp.float32)  # (BN, BL*K) MXU
    bn = proj.shape[0]
    if k <= 24:
        # MXU-friendly pack: dot with the power-of-two vector (exact for
        # K <= 24 since float32 holds integers up to 2^24 exactly).
        bits = (proj >= 0.0).astype(jnp.float32).reshape(bn, bl, k)
        weights = (2.0 ** jnp.arange(k, dtype=jnp.float32))   # (K,)
        packed = jax.lax.dot_general(
            bits, weights[:, None],
            dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (BN, BL, 1)
        out_ref[...] = packed[..., 0].astype(jnp.uint32)
    else:
        # exact integer pack on the VPU for 24 < K <= 32
        bits = (proj >= 0.0).reshape(bn, bl, k).astype(jnp.uint32)
        weights = jnp.uint32(1) << jnp.arange(k, dtype=jnp.uint32)
        out_ref[...] = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)


def simhash_codes_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    k: int,
    l: int,
    block_n: int = DEFAULT_BN,
    block_l: int = DEFAULT_BL,
    interpret: bool = False,
) -> jax.Array:
    """Packed SimHash codes for a batch of points.

    x: (N, d) float; w: (d, L*K) float.  Returns (N, L) uint32.
    N must be a multiple of block_n and L of block_l (ops.py pads).
    """
    n, d = x.shape
    assert w.shape == (d, l * k), (w.shape, d, l, k)
    assert n % block_n == 0 and l % block_l == 0, (n, l, block_n, block_l)
    grid = (n // block_n, l // block_l)
    return pl.pallas_call(
        functools.partial(_simhash_kernel, k=k, bl=block_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_l * k), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_l), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, l), jnp.uint32),
        interpret=interpret,
    )(x.astype(jnp.float32), w.astype(jnp.float32))
