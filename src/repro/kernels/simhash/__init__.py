from .ops import simhash_codes  # noqa: F401
from .ref import simhash_codes_ref  # noqa: F401
from .kernel import simhash_codes_pallas  # noqa: F401
