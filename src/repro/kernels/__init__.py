# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

import jax


def round_up(a: int, b: int) -> int:
    """Round ``a`` up to the next multiple of ``b`` (block padding)."""
    return (a + b - 1) // b * b


def default_use_pallas() -> bool:
    """Platform dispatch for kernel fast paths.

    True when the active backend compiles Mosaic kernels (TPU); CPU
    hosts take the XLA reference, which beats the Pallas interpreter by
    orders of magnitude and keeps numerics identical to the kernel
    (see the parity tests in tests/test_kernels.py).
    """
    return jax.default_backend() == "tpu"
