"""LGD at deep-learning scale: LSH-sampled data pipeline (paper Sec. 3.2/App. E).

The paper's BERT recipe, integrated as a first-class pipeline feature:

  * each training example owns a FEATURE VECTOR (for BERT: the pooled
    last-layer representation; here: any per-example embedding the model
    exposes — see ``repro.models.lm.pooled_features``).  Features are
    hashed into the LSH index.
  * the QUERY at step t is derived from the output-layer parameters
    (paper: the classification-layer weights) — as the model changes, the
    query changes, but the tables are only refreshed every
    ``refresh_every`` steps ("the representations do not change
    drastically in every iteration so we can periodically update them").
  * each batch is drawn by Algorithm 1 (m independent samples), and the
    per-sample probabilities become importance weights 1/(p_i N) on the
    loss so gradients stay unbiased.

OVERLAPPED REFRESH (double buffering): with ``refresh_async=True`` the
periodic re-embed + re-hash runs on a host thread into a second buffer,
launched ``refresh_lead`` steps before the swap boundary; the trainer's
device steps keep running while the host hashes.  The swap happens at a
fixed step boundary (the thread is joined there), so the batch sequence
is bit-deterministic regardless of thread timing — the only semantic
difference from the synchronous path is that features are embedded from
the params as of ``refresh_lead`` steps before the boundary, which is
exactly the paper's amortisation argument (features drift slowly).

SHARD-BY-EXAMPLE SCALE-OUT (1000+ nodes): ``ShardedLSHPipeline`` gives
each data-parallel group its own index over a contiguous corpus shard
(bounds from ``repro.dist.sharding.example_shard_bounds``).  Per-shard
Algorithm-1 sampling with LOCAL importance weights 1/(p_i n_s) is an
unbiased estimator of the shard mean; re-scaling the local weight by
n_s * S / N (i.e. w_i = S / (p_i N)) and concatenating equal-size
per-shard sub-batches makes the plain batch mean equal the average of
shard-mean estimates — exactly what the DP all-reduce of per-device
means computes.  No cross-host hash-table traffic, no O(N) anything per
step: the paper's O(1) property survives scale-out.  Elastic restarts
that change the mesh (and hence shard count) rebuild every per-shard
index bit-deterministically from the restored step — see
``repro.train.elastic.rebuild_sharded_pipeline``.

KEY DISCIPLINE: all randomness derives from the constructor key by
``fold_in`` with distinct stream salts (build / per-step sampling /
per-refresh), never by chained ``split``.  The determinism contract is
that any two pipelines restored at the same step draw bit-identical
batch sequences (what elastic restarts rely on).  A restore does NOT in
general replay the uninterrupted run: ``restore_at`` re-embeds features
from the restored-step params and rebuilds the index canonically (fresh
argsort, not the history-dependent warm-start chain), so batches match
the uninterrupted run only when the embedded features are unchanged —
e.g. params-independent feature hooks with no intervening refresh.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    LSHParams,
    build_index,
    refresh_index,
    sample,
    sample_batched,
)
from repro.core.tables import LSHIndex
from repro.dist.sharding import example_shard_bounds

# fold_in stream salts: one disjoint stream per random consumer, so a
# pipeline's draw at (stream, counter) is independent of how many draws
# other streams made — the restore-at-step property.
_SALT_BUILD = 0x0B11D
_SALT_STEP = 0x057E9
_SALT_REFRESH = 0x0F5E5


@dataclasses.dataclass
class LSHPipelineConfig:
    k: int = 7                   # paper BERT: K=7
    l: int = 10                  # paper BERT: L=10
    refresh_every: int = 200     # steps between feature re-hash
    minibatch: int = 32
    p_floor: float = 1e-8
    use_pallas: Optional[bool] = None   # None = auto (fused kernels on TPU)
    interpret: bool = False
    # host-side double-buffered refresh: launch the re-embed + re-hash
    # ``refresh_lead`` steps before the swap boundary on a thread so
    # hashing overlaps device compute.  Deterministic: the swap still
    # happens exactly at the boundary (thread joined there).
    refresh_async: bool = False
    refresh_lead: int = 1
    # normalise importance weights to mean 1 over the emitted batch
    # (keeps the LR scale of uniform sampling).  Sharded sub-pipelines
    # run with raw weights and normalise once globally.
    normalize_weights: bool = True


class LSHSampledPipeline:
    """Adaptive example sampler over a (local shard of a) token corpus.

    ``feature_fn`` / ``query_fn`` come in two flavours:
      * legacy closures: ``feature_fn(tokens)``, ``query_fn()`` — params
        are baked into the closure.
      * params-aware (pass ``params=`` to the constructor):
        ``feature_fn(params, tokens)``, ``query_fn(params)`` — the
        trainer pushes fresh params via ``set_params`` after every step,
        so queries always reflect the live model and refreshes re-embed
        with the params current at refresh-launch time.
    """

    def __init__(
        self,
        key: jax.Array,
        tokens: np.ndarray,                  # (N, S+1) local shard
        feature_fn: Callable,
        query_fn: Callable,
        config: LSHPipelineConfig,
        feature_batch: int = 512,
        params: Any = None,
        example_offset: int = 0,
        emit_numpy: bool = False,
    ):
        self.cfg = config
        # sharded sub-pipelines emit host numpy so the composer
        # concatenates and uploads ONCE instead of S round-trips
        self.emit_numpy = emit_numpy
        self.tokens = tokens
        self.n = tokens.shape[0]
        self.feature_fn = feature_fn
        self.query_fn = query_fn
        self.feature_batch = feature_batch
        self.params = params
        self._params_aware = params is not None
        self.example_offset = example_offset
        self._base_key = key
        self._step_stream = jax.random.fold_in(key, _SALT_STEP)
        self._refresh_stream = jax.random.fold_in(key, _SALT_REFRESH)
        self._build_key = jax.random.fold_in(key, _SALT_BUILD)
        self._step = 0
        self._refresh_count = 0
        self._refresh_thread: Optional[threading.Thread] = None
        self._refresh_box: Optional[dict] = None
        self.features = self._compute_features()
        dim = self.features.shape[-1]
        self.lsh = LSHParams(k=config.k, l=config.l, dim=dim,
                             family="dense")
        self.index: LSHIndex = build_index(
            self._build_key, self.features, self.lsh,
            use_pallas=config.use_pallas, interpret=config.interpret)

    # -- params hook ---------------------------------------------------------

    def set_params(self, params: Any):
        """Point the feature/query hooks at fresh model params (cheap).

        No-op signal for legacy-closure pipelines (constructed without
        ``params=``): their hooks close over params already, so the
        stored value is never passed to them.
        """
        self.params = params

    # -- features -----------------------------------------------------------

    def _embed(self, chunk: jax.Array, params: Any) -> jax.Array:
        if self._params_aware:
            return self.feature_fn(params, chunk)
        return self.feature_fn(chunk)

    def _compute_features(self, params: Any = None) -> jax.Array:
        """Embed every local example; normalised for SimHash."""
        params = self.params if params is None else params
        outs = []
        for i in range(0, self.n, self.feature_batch):
            chunk = jnp.asarray(self.tokens[i:i + self.feature_batch, :-1])
            outs.append(self._embed(chunk, params))
        f = jnp.concatenate(outs, axis=0)
        return f / jnp.maximum(
            jnp.linalg.norm(f, axis=-1, keepdims=True), 1e-30)

    def refresh(self):
        """Re-embed + re-hash the local shard synchronously.

        ``refresh_index`` re-sorts with the previous ``order`` as a warm
        start (features drift slowly between refreshes), so the rebuilt
        index double-buffers cleanly: unchanged codes keep their slots.
        """
        kr = jax.random.fold_in(self._refresh_stream, self._refresh_count)
        self.features = self._compute_features()
        self.index = refresh_index(
            kr, self.index, self.features, self.lsh,
            use_pallas=self.cfg.use_pallas, interpret=self.cfg.interpret)
        self._refresh_count += 1

    def _launch_refresh(self):
        """Start the double-buffer refresh on a host thread (overlap)."""
        if self._refresh_thread is not None:
            return
        kr = jax.random.fold_in(self._refresh_stream, self._refresh_count)
        params = self.params          # snapshot: params as of launch step
        old_index = self.index
        box: dict = {}

        def work():
            try:
                feats = self._compute_features(params)
                box["features"] = feats
                box["index"] = refresh_index(
                    kr, old_index, feats, self.lsh,
                    use_pallas=self.cfg.use_pallas,
                    interpret=self.cfg.interpret)
            except BaseException as e:   # surfaced at the swap boundary
                box["error"] = e

        t = threading.Thread(target=work, daemon=True)
        t.start()
        self._refresh_thread, self._refresh_box = t, box

    def _swap_refresh(self):
        """Join the in-flight refresh and swap buffers (fixed boundary)."""
        if self._refresh_thread is None:   # e.g. fresh restore: sync path
            self.refresh()
            return
        self._refresh_thread.join()
        box = self._refresh_box
        self._refresh_thread, self._refresh_box = None, None
        if "error" in box:                 # re-raise the worker's failure
            raise box["error"]
        self.features = box["features"]
        self.index = box["index"]
        self._refresh_count += 1

    def finalize(self):
        """Join any in-flight refresh thread (call before teardown);
        re-raises a worker failure that had not yet hit a swap boundary
        so it cannot vanish at shutdown."""
        if self._refresh_thread is not None:
            self._refresh_thread.join()
            box = self._refresh_box
            self._refresh_thread, self._refresh_box = None, None
            if box and "error" in box:
                raise box["error"]

    def _maybe_refresh(self):
        re = self.cfg.refresh_every
        if re <= 0:
            return
        s = self._step
        if self.cfg.refresh_async and self.cfg.refresh_lead > 0:
            lead = min(self.cfg.refresh_lead, re - 1)
            if s + lead >= re and (s + lead) % re == 0:
                self._launch_refresh()
            if s >= re and s % re == 0:
                self._swap_refresh()
        elif s >= re and s % re == 0:
            self.refresh()

    # -- batches ------------------------------------------------------------

    def _tick(self):
        """Shared refresh gate + per-step key for both batch entry points."""
        self._maybe_refresh()
        sub = jax.random.fold_in(self._step_stream, self._step)
        self._step += 1
        return sub

    def restore_at(self, step: int, rebuild: bool = True):
        """Elastic/deterministic resume: rewind counters to ``step`` and
        canonically rebuild the index from current params.

        The rebuilt index reuses the original projections (same build
        key) on freshly-embedded features with a fresh argsort — NOT the
        warm-started order chain, which is history-dependent through tie
        layouts.  Two restores at the same step are therefore bitwise
        identical, and the fold_in key streams make every subsequent
        batch identical across restores too.

        ``rebuild=False`` skips the O(N) re-embed + re-hash; valid ONLY
        when the pipeline was just constructed from the restored params
        (its ``__init__`` build is bitwise what the rebuild would
        produce) — the elastic restore path uses this to avoid paying
        the corpus embed twice.
        """
        self.finalize()
        re = self.cfg.refresh_every
        self._step = step
        self._refresh_count = (
            0 if re <= 0 or step < 1 else (step - 1) // re)
        if rebuild:
            self.features = self._compute_features()
            self.index = build_index(
                self._build_key, self.features, self.lsh,
                use_pallas=self.cfg.use_pallas,
                interpret=self.cfg.interpret)

    def _assemble_batch(self, indices, probs) -> Dict[str, jax.Array]:
        """Gather tokens + importance weights 1/(p*N) for one sample draw.

        With ``normalize_weights`` the weights are scaled to mean 1 over
        the batch (keeps the LR scale of uniform sampling; relative
        weighting is what de-biases the adaptive sampling).  Sharded
        composition runs with raw weights instead.
        """
        idx = np.asarray(indices)
        chunk = self.tokens[idx]
        w = 1.0 / (np.maximum(np.asarray(probs), self.cfg.p_floor) * self.n)
        if self.cfg.normalize_weights:
            w = w / max(w.mean(), 1e-30)
        batch = {
            "tokens": chunk[:, :-1],
            "targets": chunk[:, 1:],
            "loss_weights": w.astype(np.float32),
            "example_ids": (idx + self.example_offset).astype(np.int32),
        }
        if self.emit_numpy:
            return batch
        return {k: jnp.asarray(v) for k, v in batch.items()}

    def _query(self) -> jax.Array:
        q = self.query_fn(self.params) if self._params_aware \
            else self.query_fn()
        return q / jnp.maximum(jnp.linalg.norm(q), 1e-30)

    def next_batch(self, query: Optional[jax.Array] = None
                   ) -> Dict[str, jax.Array]:
        """Draw one batch; ``query`` (already normalised) lets a sharded
        owner compute the shared global query once for all shards."""
        sub = self._tick()
        q = self._query() if query is None else query
        res = sample(sub, self.index, self.features, q, self.lsh,
                     m=self.cfg.minibatch, use_pallas=self.cfg.use_pallas,
                     interpret=self.cfg.interpret)
        return self._assemble_batch(res.indices, res.probs)

    def next_batch_multi(self, queries: jax.Array) -> list:
        """One batch per query row (multi-chain / perturbed-query training).

        ``queries``: (C, dim).  All C queries are hashed and probed by a
        SINGLE fused bucket-probe pass (``sample_batched``), amortising
        the L*K projection matmul across chains; each chain still gets
        exact per-sample Algorithm-1 probabilities under its own query.
        """
        sub = self._tick()
        qn = queries / jnp.maximum(
            jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-30)
        res = sample_batched(
            sub, self.index, self.features, qn, self.lsh,
            m=self.cfg.minibatch, use_pallas=self.cfg.use_pallas,
            interpret=self.cfg.interpret)             # fields (C, m)
        return [self._assemble_batch(res.indices[c], res.probs[c])
                for c in range(queries.shape[0])]


class ShardedLSHPipeline:
    """Shard-by-example LGD: one LSH index per data-parallel corpus shard.

    The global corpus (N examples) is split into ``n_shards`` contiguous
    shards (``example_shard_bounds``); shard s owns an independent
    ``LSHSampledPipeline`` keyed by ``fold_in(key, s)`` over its n_s
    examples.  Every global batch is the concatenation of equal-size
    per-shard sub-batches (minibatch must divide by n_shards), laid out
    so dim 0 slices map shard s's examples to DP group s under
    ``dist.sharding.batch_sharding`` — the DP all-reduce of per-device
    weighted means is then exactly the average of per-shard estimates.

    UNBIASEDNESS: shard s's local estimator (1/m_s) sum_j g_j / (p_j n_s)
    is unbiased for the shard mean; the emitted global weight is the
    local weight rescaled by n_s * S / N, i.e. w_j = S / (p_j N), which
    makes the plain mean over the whole (m = S * m_s)-example batch equal
    the average of shard-mean estimates — an unbiased estimator of the
    full-corpus mean gradient for ANY shard sizes (each shard estimates
    its shard-sum / (N/S); contiguous balanced bounds keep n_s equal up
    to 1).  With ``normalize_weights`` the composed weights are finally
    scaled to mean 1 over the global batch, preserving relative (and
    cross-shard) weighting.

    Each shard refreshes its own index on the shared schedule — with
    ``refresh_async`` all S host-side re-hashes overlap device compute.
    """

    def __init__(
        self,
        key: jax.Array,
        tokens: np.ndarray,                  # (N, S+1) global corpus
        feature_fn: Callable,
        query_fn: Callable,
        config: LSHPipelineConfig,
        n_shards: int = 1,
        feature_batch: int = 512,
        params: Any = None,
        mesh=None,
    ):
        if config.minibatch % n_shards != 0:
            raise ValueError(
                f"minibatch={config.minibatch} must divide by "
                f"n_shards={n_shards}")
        self.cfg = config
        self.n = tokens.shape[0]
        self.n_shards = n_shards
        self.mesh = mesh
        shard_cfg = dataclasses.replace(
            config, minibatch=config.minibatch // n_shards,
            normalize_weights=False)
        self.shards: List[LSHSampledPipeline] = []
        for s in range(n_shards):
            lo, hi = example_shard_bounds(self.n, s, n_shards)
            self.shards.append(LSHSampledPipeline(
                jax.random.fold_in(key, s), tokens[lo:hi], feature_fn,
                query_fn, shard_cfg, feature_batch=feature_batch,
                params=params, example_offset=lo, emit_numpy=True))

    @property
    def params(self):
        return self.shards[0].params

    def set_params(self, params: Any):
        for p in self.shards:
            p.set_params(params)

    def restore_at(self, step: int, rebuild: bool = True):
        """Rebuild every per-shard index at ``step`` (elastic restore)."""
        for p in self.shards:
            p.restore_at(step, rebuild=rebuild)

    def finalize(self):
        for p in self.shards:
            p.finalize()

    def refresh(self):
        for p in self.shards:
            p.refresh()

    def next_batch(self) -> Dict[str, jax.Array]:
        # the global query is shard-independent: compute + normalise it
        # once and share it across all S per-shard sample calls.
        q = self.shards[0]._query()
        subs = [p.next_batch(query=q) for p in self.shards]
        m_s = self.cfg.minibatch // self.n_shards
        parts: Dict[str, list] = {k: [] for k in
                                  ("tokens", "targets", "loss_weights",
                                   "example_ids")}
        shard_ids = []
        for s, (p, b) in enumerate(zip(self.shards, subs)):
            # local 1/(p n_s) -> global S/(p N): each sample stands in
            # for N/S corpus examples under the batch mean.
            scale = p.n * self.n_shards / self.n
            parts["loss_weights"].append(
                np.asarray(b["loss_weights"], np.float64) * scale)
            for k in ("tokens", "targets", "example_ids"):
                parts[k].append(np.asarray(b[k]))
            shard_ids.append(np.full((m_s,), s, np.int32))
        w = np.concatenate(parts["loss_weights"])
        if self.cfg.normalize_weights:
            w = w / max(w.mean(), 1e-30)
        batch = {
            "tokens": jnp.asarray(np.concatenate(parts["tokens"])),
            "targets": jnp.asarray(np.concatenate(parts["targets"])),
            "loss_weights": jnp.asarray(w, jnp.float32),
            "example_ids": jnp.asarray(
                np.concatenate(parts["example_ids"]), jnp.int32),
            "shard_ids": jnp.asarray(np.concatenate(shard_ids)),
        }
        if self.mesh is not None and isinstance(self.mesh,
                                                jax.sharding.Mesh):
            from repro.dist.sharding import batch_sharding
            sh = batch_sharding(self.mesh)
            batch = {k: jax.device_put(v, sh) for k, v in batch.items()}
        return batch


def mean_pool_feature_fn(cfg):
    """Params-aware feature hook: mean-pooled final hidden state
    (the paper's BERT pooled-representation recipe) — pass the result as
    ``feature_fn`` with ``params=`` so the trainer keeps it fresh."""
    from repro.models.lm import pooled_features

    def fn(params, tokens: jax.Array) -> jax.Array:
        return pooled_features(params, cfg, {"tokens": tokens})
    return jax.jit(fn)


def lm_head_query_fn():
    """Params-aware query hook from the output layer (paper: classifier
    weights as queries): the mean lm_head column approximates the
    direction in feature space along which next-token loss is largest."""
    from repro.models.lm import lm_head_query
    return lm_head_query
