"""LGD at deep-learning scale: LSH-sampled data pipeline (paper Sec. 3.2/App. E).

The paper's BERT recipe, integrated as a first-class pipeline feature:

  * each training example owns a FEATURE VECTOR (for BERT: the pooled
    last-layer representation; here: any per-example embedding the model
    exposes).  Features are hashed into the LSH index.
  * the QUERY at step t is derived from the output-layer parameters
    (paper: the classification-layer weights) — as the model changes, the
    query changes, but the tables are only refreshed every
    ``refresh_every`` steps ("the representations do not change
    drastically in every iteration so we can periodically update them").
  * each batch is drawn by Algorithm 1 (m independent samples), and the
    per-sample probabilities become importance weights 1/(p_i N) on the
    loss so gradients stay unbiased.

SCALE-OUT DESIGN (1000+ nodes): the index is *sharded by example* — each
data-parallel group builds and queries the index of its own corpus shard
only.  Because the global corpus is randomly partitioned, per-shard
LGD sampling + per-shard importance weighting is an unbiased estimator
of the global gradient (each shard estimates its shard-mean; the
all-reduce averages shard-means).  No cross-host hash-table traffic,
no O(N) anything per step — the paper's O(1) property survives scale-out.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    LSHParams,
    build_index,
    refresh_index,
    sample,
    sample_batched,
)
from repro.core.tables import LSHIndex


@dataclasses.dataclass
class LSHPipelineConfig:
    k: int = 7                   # paper BERT: K=7
    l: int = 10                  # paper BERT: L=10
    refresh_every: int = 200     # steps between feature re-hash
    minibatch: int = 32
    p_floor: float = 1e-8
    use_pallas: Optional[bool] = None   # None = auto (fused kernels on TPU)
    interpret: bool = False


class LSHSampledPipeline:
    """Adaptive example sampler over a (local shard of a) token corpus."""

    def __init__(
        self,
        key: jax.Array,
        tokens: np.ndarray,                  # (N, S+1) local shard
        feature_fn: Callable[[jax.Array], jax.Array],
        query_fn: Callable[[], jax.Array],
        config: LSHPipelineConfig,
        feature_batch: int = 512,
    ):
        self.cfg = config
        self.tokens = tokens
        self.n = tokens.shape[0]
        self.feature_fn = feature_fn
        self.query_fn = query_fn
        self.feature_batch = feature_batch
        self._key = key
        self._step = 0
        self.features = self._compute_features()
        dim = self.features.shape[-1]
        self.lsh = LSHParams(k=config.k, l=config.l, dim=dim,
                             family="dense")
        self._key, sub = jax.random.split(self._key)
        self.index: LSHIndex = build_index(
            sub, self.features, self.lsh, use_pallas=config.use_pallas,
            interpret=config.interpret)

    # -- features -----------------------------------------------------------

    def _compute_features(self) -> jax.Array:
        """Embed every local example; normalised for SimHash."""
        outs = []
        for i in range(0, self.n, self.feature_batch):
            chunk = jnp.asarray(self.tokens[i:i + self.feature_batch, :-1])
            outs.append(self.feature_fn(chunk))
        f = jnp.concatenate(outs, axis=0)
        return f / jnp.maximum(
            jnp.linalg.norm(f, axis=-1, keepdims=True), 1e-30)

    def refresh(self):
        """Re-embed + re-hash the local shard (amortised, off critical path).

        ``refresh_index`` re-sorts with the previous ``order`` as a warm
        start (features drift slowly between refreshes), so the rebuilt
        index double-buffers cleanly: unchanged codes keep their slots.
        """
        self.features = self._compute_features()
        self._key, sub = jax.random.split(self._key)
        self.index = refresh_index(
            sub, self.index, self.features, self.lsh,
            use_pallas=self.cfg.use_pallas, interpret=self.cfg.interpret)

    # -- batches ------------------------------------------------------------

    def _tick(self):
        """Shared refresh gate + per-step key for both batch entry points."""
        if self._step > 0 and self._step % self.cfg.refresh_every == 0:
            self.refresh()
        self._step += 1
        self._key, sub = jax.random.split(self._key)
        return sub

    def _assemble_batch(self, indices, probs) -> Dict[str, jax.Array]:
        """Gather tokens + importance weights 1/(p*N) for one sample draw.

        Weights are normalised to mean 1 over the batch (keeps the LR
        scale of uniform sampling; relative weighting is what de-biases
        the adaptive sampling).
        """
        idx = np.asarray(indices)
        chunk = self.tokens[idx]
        w = 1.0 / (np.maximum(np.asarray(probs), self.cfg.p_floor) * self.n)
        w = w / max(w.mean(), 1e-30)
        return {
            "tokens": jnp.asarray(chunk[:, :-1]),
            "targets": jnp.asarray(chunk[:, 1:]),
            "loss_weights": jnp.asarray(w, jnp.float32),
            "example_ids": jnp.asarray(idx, jnp.int32),
        }

    def next_batch(self) -> Dict[str, jax.Array]:
        sub = self._tick()
        q = self.query_fn()
        q = q / jnp.maximum(jnp.linalg.norm(q), 1e-30)
        res = sample(sub, self.index, self.features, q, self.lsh,
                     m=self.cfg.minibatch, use_pallas=self.cfg.use_pallas,
                     interpret=self.cfg.interpret)
        return self._assemble_batch(res.indices, res.probs)

    def next_batch_multi(self, queries: jax.Array) -> list:
        """One batch per query row (multi-chain / perturbed-query training).

        ``queries``: (C, dim).  All C queries are hashed and probed by a
        SINGLE fused bucket-probe pass (``sample_batched``), amortising
        the L*K projection matmul across chains; each chain still gets
        exact per-sample Algorithm-1 probabilities under its own query.
        """
        sub = self._tick()
        qn = queries / jnp.maximum(
            jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-30)
        res = sample_batched(
            sub, self.index, self.features, qn, self.lsh,
            m=self.cfg.minibatch, use_pallas=self.cfg.use_pallas,
            interpret=self.cfg.interpret)             # fields (C, m)
        return [self._assemble_batch(res.indices[c], res.probs[c])
                for c in range(queries.shape[0])]


def mean_pool_feature_fn(params, cfg, forward):
    """Default feature: mean-pooled final hidden state (BERT-pooled analogue)."""
    def fn(tokens: jax.Array) -> jax.Array:
        h = forward(params, cfg, {"tokens": tokens})
        return jnp.mean(h.astype(jnp.float32), axis=1)
    return jax.jit(fn)


def lm_head_query_fn(params):
    """Query from the output layer (paper: classifier weights): the
    direction in feature space along which next-token loss is largest is
    approximated by the mean lm_head column weighted by... in practice the
    mean output embedding works as the paper's 'classification layer
    parameters as queries'."""
    def fn() -> jax.Array:
        w = params["embed_group"]["lm_head"].astype(jnp.float32)
        return jnp.mean(w, axis=1)
    return fn
