"""LGD at deep-learning scale: LSH-sampled data pipeline (paper Sec. 3.2/App. E).

The paper's BERT recipe, integrated as a first-class pipeline feature:

  * each training example owns a FEATURE VECTOR (for BERT: the pooled
    last-layer representation; here: any per-example embedding the model
    exposes — see ``repro.models.lm.pooled_features``).  Features are
    hashed into the LSH index.
  * the QUERY at step t is derived from the output-layer parameters
    (paper: the classification-layer weights) — as the model changes, the
    query changes, but the tables are only refreshed every
    ``refresh_every`` steps ("the representations do not change
    drastically in every iteration so we can periodically update them").
  * each batch is drawn by Algorithm 1 (m independent samples), and the
    per-sample probabilities become importance weights 1/(p_i N) on the
    loss so gradients stay unbiased.

DEVICE-RESIDENT STEP PATH: the token corpus is uploaded to device ONCE
at pipeline build (``self.store``, lane-padded for the kernel gather;
committed via ``dist.sharding.shard_store_device`` — mesh-replicated
under a single-controller mesh), and every ``next_batch`` /
``next_batch_multi`` is a single jitted on-device program
(``core.sampler.sample_gather``): query hash -> fused bucket probe ->
within-bucket draw -> token-row gather -> 1/(p·N) weight computation
(the ``kernels/gather_weight`` Pallas kernel on TPU, its bit-identical
XLA reference elsewhere).  No host numpy touches the per-step loop; the
sharded composer concatenates sub-batches on device under the mesh
(``dist.sharding.compose_sharded_batch`` — per-shard parts are adopted
zero-copy as the shards of the global batch).

REFRESH MODES (``refresh_mode``):
  * ``"full"`` (default) — re-embed + re-hash the whole shard, the
    original periodic-refresh semantics.
  * ``"delta"`` — refresh cost proportional to drift, not to N: the
    pipeline tracks which examples were VISITED since the last refresh
    (a device-side dirty mask updated by every draw) plus a
    drift-sampled remainder (``drift_frac`` of the shard, drawn from the
    refresh key stream so restores stay deterministic), re-embeds and
    re-hashes ONLY that subset, and merges the changed codes into the
    sorted-code index through the previous ``order``
    (``core.tables.refresh_index_delta`` — tie-stable, and bit-identical
    to a full warm-started refresh when every row is dirty).  Dirty
    counts are padded to power-of-two buckets so jit recompilation stays
    bounded.  ``refresh(full=True)`` forces the full path at any time.

OVERLAPPED REFRESH (double buffering): with ``refresh_async=True`` the
periodic refresh runs on a host thread into a second buffer, launched
``refresh_lead`` steps before the swap boundary; the trainer's device
steps keep running while the refresh computes.  The swap happens at a
fixed step boundary (the thread is joined there), so the batch sequence
is bit-deterministic regardless of thread timing.  In delta mode the
dirty mask is snapshotted (and reset) at LAUNCH time: examples visited
during the lead window roll into the next refresh — the same
features-drift-slowly amortisation argument as the lead itself.

SHARD-BY-EXAMPLE SCALE-OUT (1000+ nodes): ``ShardedLSHPipeline`` gives
each data-parallel group its own index over a contiguous corpus shard
(bounds from ``repro.dist.sharding.example_shard_bounds``).  Per-shard
Algorithm-1 sampling with LOCAL importance weights 1/(p_i n_s) is an
unbiased estimator of the shard mean; re-scaling the local weight by
n_s * S / N (i.e. w_i = S / (p_i N)) and concatenating equal-size
per-shard sub-batches makes the plain batch mean equal the average of
shard-mean estimates — exactly what the DP all-reduce of per-device
means computes.  No cross-host hash-table traffic, no O(N) anything per
step: the paper's O(1) property survives scale-out.  Elastic restarts
that change the mesh (and hence shard count) rebuild every per-shard
index bit-deterministically from the restored step — see
``repro.train.elastic.rebuild_sharded_pipeline``.

HASH FAMILY (``LSHPipelineConfig.family``): "srp" (default) keeps the
paper's recipe — feature embeddings row-normalised so cosine SimHash
proxies the inner product — bit-identical to the pre-family pipeline;
"mips" hashes embeddings UN-normalised through the asymmetric
Simple-LSH augmentation (``core.families.mips``), whose collision
probability is monotone in the raw inner product.  Augmentation runs
at build/refresh time on the feature side and once per draw on the
query side, so the per-step jitted sample->gather->weight program is
byte-for-byte the same; the MIPS data scale M is pinned at each full
(re)build and replayed for delta-refresh subsets (``_feat_scale`` —
async refreshes commit features, index and scale together at the swap
boundary, so a failed refresh cannot leave them out of sync).

SELF-HEALING (the degradation ladder — see ``repro.data.health``): a
refresh that raises is retried with exponential backoff + deterministic
jitter (``refresh_retries`` / ``refresh_backoff``); a refresh worker
that HANGS is abandoned by a watchdog (``refresh_timeout``) and counts
as a failed attempt.  On exhausted retries the pipeline enters
STALE-INDEX mode: it keeps drawing from the last good (features,
index) buffer — still unbiased w.r.t. the indexed vectors — instead of
re-raising at the swap boundary, with a bounded staleness counter.
Past the staleness bound (or on a fallback-rate spike / non-finite-loss
streak reported by the trainer) it degrades to UNIFORM-FALLBACK:
batches are drawn uniformly with weight 1 (unbiased by construction,
zero LSH dependence) from the same per-step key stream, and every
``recover_after`` steps a full canonical index rebuild is attempted;
on success the ladder returns to healthy.  All transitions are recorded
in ``health.transitions`` and surfaced through the trainer's metrics.
Fault injection for tests/chaos drills hooks in via
``set_fault_injector`` (see ``repro.testing.faults``).

STREAMING CORPORA (``streaming=True`` / ``window=``): the token store,
feature buffer and index become CAPACITY-MANAGED device buffers sized
to powers of two (``min_capacity`` floor).  Dead slots hash to the
sentinel ``EMPTY_CODE`` and cluster at every table's sorted tail, so
bucket probes and the uniform fallback only ever see live rows, and
capacity changes (grow on append past capacity, compact when
n_live <= capacity/4) are the ONLY recompile points — mutation
batches are padded to power-of-two id buckets exactly like delta
refresh.  All index mutations go through ONE entry point,
``mutate(IndexMutation(...))`` with an explicit op (``append`` /
``evict`` / ``delta`` / ``refresh`` / ``build``);
``append_rows(tokens)`` / ``evict_rows(ids)`` are the typed
conveniences behind it.  Appended rows are embedded at the pinned
family scale and tie-stably merged through the previous sort order
(the same contract as delta refresh); evictions are sentinel merges.
Per-draw weights become 1/(p·n_live) with n_live a TRACED scalar —
live-count changes do not recompile the step program — so the
estimator stays exactly unbiased as the window advances; with
``window=`` set, appends auto-evict the oldest live rows first.
Mutations compose with the async double-buffered refresh: the launch
snapshots (store, live mask, capacity); mutations during the flight
apply to the live buffers AND are recorded as touched slots; at the
swap boundary the committed result is reconciled by one delta merge
over the touched slots (a capacity change in flight discards the
worker's result and refreshes synchronously on current state).
Explicit mutations are recorded in a MUTATION LOG
(``mutation_log()`` / ``load_mutation_log``): ``restore_at(t)``
truncates the log to entries with step <= t, replays MEMBERSHIP only
(window evictions, growth and compaction are re-derived
deterministically; no embeds) and then rebuilds the index canonically
from restored params — restored-at-step-t bit-determinism survives
streaming.  Slot ids are reused after eviction and remapped by
compaction: ``example_ids`` identify live store rows, not immortal
examples.

KEY DISCIPLINE: all randomness derives from the constructor key by
``fold_in`` with distinct stream salts (build / per-step sampling /
per-refresh), never by chained ``split``.  The determinism contract is
that any two pipelines restored at the same step draw bit-identical
batch sequences (what elastic restarts rely on).  A restore does NOT in
general replay the uninterrupted run: ``restore_at`` re-embeds features
from the restored-step params, rebuilds the index canonically (fresh
argsort, not the history-dependent warm-start chain) and clears the
dirty mask, so batches match the uninterrupted run only when the
embedded features are unchanged — e.g. params-independent feature hooks
(then every refresh, full or delta, is an index no-op and the two runs
coincide bitwise; pinned by tests/test_sharded_lgd.py).
"""

from __future__ import annotations

import dataclasses
import logging
import threading
import time
import warnings
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    EMPTY_CODE,
    IndexMutation,
    LSHParams,
    get_family,
    hash_points,
    mutate_index,
    sample_gather,
    sample_gather_batched,
)
from repro.core.tables import LSHIndex, grow_index
from repro.dist.sharding import (
    compose_sharded_batch,
    example_shard_bounds,
    shard_store_device,
)
from repro.kernels import default_use_pallas
from .health import (
    HEALTHY,
    STALE_INDEX,
    UNIFORM_FALLBACK,
    HealthConfig,
    HealthMonitor,
)

log = logging.getLogger("repro.lgd.health")

# fold_in stream salts: one disjoint stream per random consumer, so a
# pipeline's draw at (stream, counter) is independent of how many draws
# other streams made — the restore-at-step property.
_SALT_BUILD = 0x0B11D
_SALT_STEP = 0x057E9
_SALT_REFRESH = 0x0F5E5


def _dirty_bucket(n: int) -> int:
    """Pad a dirty count to a power-of-two bucket (bounded recompiles)."""
    b = 64
    while b < n:
        b <<= 1
    return b


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def _pad_mutation(ids: np.ndarray, codes, capacity: int):
    """Pad a mutation batch to a power-of-two id bucket (bounded jit
    recompiles, the delta-refresh trick).  Padding repeats the first
    (id, code) column — a duplicate scatter of identical values, i.e.
    a merge no-op."""
    b = int(ids.shape[0])
    size = min(_dirty_bucket(b), capacity)
    ids_j = jnp.asarray(ids, jnp.int32)
    codes_j = jnp.asarray(codes)
    if size <= b:
        return ids_j, codes_j
    pad = size - b
    ids_p = jnp.concatenate([ids_j, jnp.full((pad,), int(ids[0]),
                                             jnp.int32)])
    codes_p = jnp.concatenate(
        [codes_j, jnp.tile(codes_j[:, :1], (1, pad))], axis=1)
    return ids_p, codes_p


# streaming sharded pipelines space their shards' global example ids by
# a fixed stride (instead of the contiguous initial bounds), so ids stay
# disjoint no matter how far each shard's window advances:
# gid // _SHARD_STRIDE recovers the owning shard, gid % _SHARD_STRIDE
# its local slot.
_SHARD_STRIDE = 1 << 20

_LEGACY_HOOK_MSG = (
    "legacy closure hooks feature_fn(tokens) / query_fn() are "
    "deprecated; pass params= to the pipeline constructor and use the "
    "params-aware flavour feature_fn(params, tokens) / "
    "query_fn(params) (the trainer keeps params fresh via set_params)")


@dataclasses.dataclass
class LSHPipelineConfig:
    k: int = 7                   # paper BERT: K=7
    l: int = 10                  # paper BERT: L=10
    refresh_every: int = 200     # steps between feature re-hash
    minibatch: int = 32
    p_floor: float = 1e-8
    use_pallas: Optional[bool] = None   # None = auto (fused kernels on TPU)
    interpret: bool = False
    # host-side double-buffered refresh: launch the re-embed + re-hash
    # ``refresh_lead`` steps before the swap boundary on a thread so
    # refresh work overlaps device compute.  Deterministic: the swap
    # still happens exactly at the boundary (thread joined there).
    refresh_async: bool = False
    refresh_lead: int = 1
    # "full": re-embed + re-hash the whole shard every refresh.
    # "delta": re-embed + re-hash only the visited-since-last-refresh
    # rows plus a drift-sampled ``drift_frac`` remainder, merged into
    # the index through the previous order (cost ~ drift, not N).
    refresh_mode: str = "full"
    drift_frac: float = 0.05
    # normalise importance weights to mean 1 over the emitted batch
    # (keeps the LR scale of uniform sampling).  Sharded sub-pipelines
    # run with raw weights and normalise once globally.
    normalize_weights: bool = True
    # multi-probe querying: number of ADDITIONAL Hamming-ball probe
    # codes (flip-1 then flip-2 of the packed code) walked per table
    # before the next table draw.  Empty/under-filled buckets then
    # resolve to probability-corrected near-bucket samples instead of
    # uniform fallbacks — weights stay unbiased (core.sampler), the
    # fallback rate drops (tab_optimizers gates this on a skewed
    # corpus).  0 = the paper's single-probe Algorithm 1.
    multiprobe: int = 0
    # LSH family (core.families registry name).  "srp" (default, the
    # pre-family behaviour bit-identically): features are row-L2
    # normalised before hashing so cosine proxies the inner product.
    # "mips": features are hashed UN-normalised through the asymmetric
    # Simple-LSH augmentation — collision probability monotone in the
    # raw inner product, the right family for feature embeddings whose
    # norms carry signal.  Augmentation runs at build/refresh (feature
    # side) and once per draw (query side); the per-step jitted
    # sample->gather->weight program is unchanged.
    family: str = "srp"
    # -- self-healing refresh (module docstring: degradation ladder) --
    # retries after a failed refresh attempt (so 1 + refresh_retries
    # attempts total per refresh cycle) before declaring the cycle
    # failed and entering stale-index mode.
    refresh_retries: int = 2
    # base backoff seconds between retry attempts; attempt j sleeps
    # backoff * 2^(j-1) * (1 + jitter), with the jitter derived
    # deterministically from (refresh_count, attempt).  0 disables.
    refresh_backoff: float = 0.05
    # watchdog seconds for a refresh computation: an attempt exceeding
    # it is abandoned (daemon thread) and counted as failed.  For the
    # async double-buffered path this is the EXTRA wait at the swap-
    # boundary join (the worker already had ``refresh_lead`` steps).
    # None = wait forever (no watchdog).
    refresh_timeout: Optional[float] = None
    # degradation-ladder thresholds; None = HealthConfig() defaults.
    health: Optional[HealthConfig] = None
    # -- streaming corpora (module docstring: STREAMING CORPORA) --
    # capacity-managed store + the mutate()/append_rows()/evict_rows()
    # index-mutation API.  Setting ``window`` implies streaming.
    streaming: bool = False
    # sliding window: appends past ``window`` live rows auto-evict the
    # oldest rows first.  None = unbounded (explicit evicts only).
    window: Optional[int] = None
    # smallest (power-of-two) store capacity; compaction never shrinks
    # below it.
    min_capacity: int = 64

    def __post_init__(self):
        if self.refresh_mode not in ("full", "delta"):
            raise ValueError(
                f"refresh_mode must be 'full' or 'delta', "
                f"got {self.refresh_mode!r}")
        if self.multiprobe < 0:
            raise ValueError(
                f"multiprobe must be >= 0, got {self.multiprobe}")
        if self.refresh_retries < 0:
            raise ValueError(
                f"refresh_retries must be >= 0, got {self.refresh_retries}")
        if self.window is not None:
            if self.window < 1:
                raise ValueError(f"window must be >= 1, got {self.window}")
            self.streaming = True
        if self.streaming:
            cw = get_family(self.family).code_width(self.k)
            if cw > 31:
                # the sentinel capacity model needs every packed code —
                # including a banded family's high-bit band tags — to
                # sort strictly before EMPTY_CODE = 2^32 - 1.
                raise ValueError(
                    f"streaming requires code_width(k) <= 31 (sentinel "
                    f"codes), got {cw} (k={self.k}, "
                    f"family={self.family!r})")
            if self.min_capacity < 1 or (
                    self.min_capacity & (self.min_capacity - 1)):
                raise ValueError(
                    f"min_capacity must be a power of two >= 1, "
                    f"got {self.min_capacity}")
        get_family(self.family)   # raises on unknown family names


class LSHSampledPipeline:
    """Adaptive example sampler over a (local shard of a) token corpus.

    ``feature_fn`` / ``query_fn`` come in two flavours:
      * legacy closures: ``feature_fn(tokens)``, ``query_fn()`` — params
        are baked into the closure.  DEPRECATED: constructing without
        ``params=`` warns (DeprecationWarning) and the flavour will be
        removed; migrate to the params-aware hooks.
      * params-aware (pass ``params=`` to the constructor):
        ``feature_fn(params, tokens)``, ``query_fn(params)`` — the
        trainer pushes fresh params via ``set_params`` after every step,
        so queries always reflect the live model and refreshes re-embed
        with the params current at refresh-launch time.

    ``store_device`` pins the device-resident token store (and hence all
    per-step sampling compute) to a specific device — the sharded owner
    passes each shard's DP-group device (``shard_store_device``).

    Args:
      key: constructor PRNG key; ALL pipeline randomness derives from
        it via salted fold_in streams (module docstring).
      tokens: (N, S+1) int32 local token shard, uploaded to device once.
      feature_fn / query_fn: per-example embedding and query hooks
        (legacy closures or params-aware — see above).
      config: ``LSHPipelineConfig`` (refresh policy, minibatch,
        ``multiprobe``, kernel dispatch).
      feature_batch: embed chunk size for the corpus re-embeds.
      params: initial model params; passing them selects the
        params-aware hook flavour.
      example_offset: lifts store-local row ids to global example ids
        (sharded owner passes the shard's lower bound).
      store_device: optional device for the token store.

    Determinism: two pipelines built with the same (key, tokens,
    config) draw bit-identical batch sequences, and ``restore_at(t)``
    rewinds to step t's stream positions (elastic restarts rely on
    both).  ``sampler_stats()`` exposes cumulative fallback /
    primary-miss rates without touching the step path.
    """

    def __init__(
        self,
        key: jax.Array,
        tokens: np.ndarray,                  # (N, S+1) local shard
        feature_fn: Callable,
        query_fn: Callable,
        config: LSHPipelineConfig,
        feature_batch: int = 512,
        params: Any = None,
        example_offset: int = 0,
        store_device=None,
        _warn_legacy: bool = True,
    ):
        if params is None and _warn_legacy:
            warnings.warn(_LEGACY_HOOK_MSG, DeprecationWarning,
                          stacklevel=2)
        self.cfg = config
        self.family = get_family(config.family)
        self.tokens = tokens
        self.n = tokens.shape[0]
        self.streaming = config.streaming
        # the device-resident example store: uploaded exactly once; every
        # subsequent step gathers from it on device.  On the Pallas
        # gather path the row width is lane-padded HERE, once, so the
        # kernel wrapper's per-call pad is zero-width and compiles away
        # (``row_width`` keeps the logical S+1 for slicing).  Streaming
        # pipelines additionally pad ROWS up to the power-of-two
        # capacity (dead slots excluded from the index by the sentinel).
        self.row_width = tokens.shape[1]
        self._store_device = store_device
        self._init_membership(tokens)
        self.feature_fn = feature_fn
        self.query_fn = query_fn
        self.feature_batch = feature_batch
        self.params = params
        self._params_aware = params is not None
        self.example_offset = example_offset
        self._base_key = key
        self._step_stream = jax.random.fold_in(key, _SALT_STEP)
        self._refresh_stream = jax.random.fold_in(key, _SALT_REFRESH)
        self._build_key = jax.random.fold_in(key, _SALT_BUILD)
        self._step = 0
        self._refresh_count = 0
        self._refresh_thread: Optional[threading.Thread] = None
        self._refresh_box: Optional[dict] = None
        # snapshot of the async refresh's inputs, kept until the swap
        # boundary so a failed/hung worker can be retried synchronously
        # on bit-identical inputs.
        self._refresh_snapshot: Optional[tuple] = None
        self._health_cfg = config.health or HealthConfig()
        self.health = HealthMonitor(self._health_cfg)
        self.fault_injector = None         # repro.testing.faults hook
        self._uniform_fn = None            # lazy jit: uniform-fallback draw
        self._track_dirty = (config.refresh_mode == "delta"
                             and config.refresh_every > 0)
        self._dirty = jnp.zeros((self.capacity,), jnp.bool_)
        # streaming: explicit-mutation log (restore_at replays it) and
        # the touched-slot set reconciled at async swap boundaries.
        self._mutlog: List[dict] = []
        self._touched: set = set()
        # sampling diagnostics: device-side lazy accumulators (no sync
        # on the step path; syncs happen only when sampler_stats() is
        # read, e.g. at the trainer's log cadence).
        self._stat_draws = 0
        self._fallback_sum = jnp.zeros((), jnp.int32)
        self._primary_miss_sum = jnp.zeros((), jnp.int32)
        self._last_fallback = jnp.zeros((), jnp.float32)
        # asymmetric-family scale (MIPS: the max feature norm M), pinned
        # at each FULL (re)build so partial re-augmentations (delta
        # refresh) stay consistent with the indexed vectors.
        self._feat_scale = None
        self.features = self._compute_features()
        dim = self.features.shape[-1]          # post-augmentation dim
        # "srp" instantiates the registry's dense-SRP entry under its
        # canonical LSHParams name — bit-identical to the pre-family
        # pipeline (pinned by tests/test_families.py).
        lsh_family = "dense" if config.family == "srp" else config.family
        self.lsh = LSHParams(k=config.k, l=config.l, dim=dim,
                             family=lsh_family)
        self.index: LSHIndex = mutate_index(
            None,
            IndexMutation("build", key=self._build_key,
                          x_aug=self.features, live_mask=self._live_dev),
            self.lsh,
            use_pallas=config.use_pallas, interpret=config.interpret)

    # -- membership / capacity (streaming) -----------------------------------

    def _upload_store(self, rows: jnp.ndarray) -> jax.Array:
        """Lane-pad + device-place a (cap, row_width) token block."""
        if (self.cfg.use_pallas if self.cfg.use_pallas is not None
                else default_use_pallas()):
            rows = jnp.pad(rows, ((0, 0), (0, (-self.row_width) % 128)))
        return (jax.device_put(rows, self._store_device)
                if self._store_device is not None else rows)

    def _init_membership(self, tokens: np.ndarray):
        """(Re)initialise the store + membership state from the
        construction-time corpus — shared by ``__init__`` and the
        ``restore_at`` replay reset."""
        n0 = tokens.shape[0]
        store = jnp.asarray(tokens, jnp.int32)
        if self.streaming:
            cap = max(_next_pow2(max(n0, 1)), self.cfg.min_capacity)
            store = jnp.pad(store, ((0, cap - n0), (0, 0)))
            self.capacity = cap
            self._live_np = np.zeros((cap,), np.bool_)
            self._live_np[:n0] = True
            self._arrival = np.full((cap,), -1, np.int64)
            self._arrival[:n0] = np.arange(n0)
            self._next_arrival = n0
            self._free = list(range(n0, cap))
            self._n_live = n0
        else:
            self.capacity = n0
            self._live_np = None
            self._arrival = None
            self._next_arrival = n0
            self._free = []
            self._n_live = n0
        self.store = self._upload_store(store)
        self._sync_live_dev()

    def _sync_live_dev(self):
        """Refresh the device mirrors of the membership state.  The
        live-count scalar is TRACED into the step program, so advancing
        the window never recompiles; non-streaming pipelines keep both
        mirrors at None — the pre-streaming traces, bit-identically."""
        if self.streaming:
            self._live_dev = jnp.asarray(self._live_np)
            self._n_live_dev = jnp.int32(self._n_live)
        else:
            self._live_dev = None
            self._n_live_dev = None

    @property
    def n_live(self) -> int:
        """Live (indexed) example count — ``n`` unless streaming."""
        return self._n_live

    # -- params hook ---------------------------------------------------------

    def set_params(self, params: Any):
        """Point the feature/query hooks at fresh model params (cheap).

        No-op signal for legacy-closure pipelines (constructed without
        ``params=``): their hooks close over params already, so the
        stored value is never passed to them.
        """
        self.params = params

    # -- features -----------------------------------------------------------

    def _embed(self, chunk: jax.Array, params: Any) -> jax.Array:
        if self._params_aware:
            return self.feature_fn(params, chunk)
        return self.feature_fn(chunk)

    def _normalize(self, f: jax.Array) -> jax.Array:
        return f / jnp.maximum(
            jnp.linalg.norm(f, axis=-1, keepdims=True), 1e-30)

    def _compute_features_scaled(self, params: Any = None, store=None,
                                 live=None):
        """(features, scale) for a full-store embed — NO attribute
        writes, so async refresh workers can call it on launch-time
        snapshots (``store``/``live``) and hand the freshly derived
        scale to the swap boundary.

        Symmetric families row-normalise (the pre-family behaviour,
        bit-identical) and return ``scale=None``; asymmetric families
        run ``augment_data`` under a freshly derived data scale M and
        return it.  With a ``live`` mask (streaming) dead rows are
        zeroed BEFORE the scale derivation, so recycled slots holding
        stale tokens never influence M (or the normalised features that
        the sentinel already excludes from every bucket).
        """
        params = self.params if params is None else params
        store = self.store if store is None else store
        w = self.row_width
        outs = []
        for i in range(0, store.shape[0], self.feature_batch):
            outs.append(self._embed(
                store[i:i + self.feature_batch, :w - 1], params))
        raw = jnp.concatenate(outs, axis=0)
        if live is not None:
            raw = jnp.where(live[:, None], raw, 0.0)
        if not self.family.asymmetric:
            return self._normalize(raw), None
        scale = self.family.data_scale(raw)
        return self.family.augment_data(raw, scale=scale), scale

    def _compute_features(self, params: Any = None) -> jax.Array:
        """Embed every local example; family-augmented for hashing.

        Synchronous entry: pins the asymmetric-family scale M alongside
        the returned features (build / sync refresh / restore paths).
        Async refreshes must use ``_compute_features_scaled`` and commit
        features, index and scale together at the swap boundary.
        """
        feats, scale = self._compute_features_scaled(
            params, live=self._live_dev)
        if self.family.asymmetric:
            self._feat_scale = scale
        return feats

    def _embed_rows(self, ids: jax.Array, params: Any,
                    scale=None, store=None) -> jax.Array:
        """Embed a gathered subset of rows (delta refresh / append /
        reconcile), augmented.

        Chunked exactly like ``_compute_features`` so an all-rows subset
        produces bitwise the same features as a full re-embed — for
        asymmetric families at ``scale`` (the pinned M the indexed
        vectors were built with; delta refresh snapshots it at launch).
        """
        store = self.store if store is None else store
        rows = jnp.take(store, ids, axis=0)[:, :self.row_width - 1]
        outs = []
        for i in range(0, rows.shape[0], self.feature_batch):
            outs.append(self._embed(rows[i:i + self.feature_batch], params))
        raw = jnp.concatenate(outs, axis=0)
        if not self.family.asymmetric:
            return self._normalize(raw)
        return self.family.augment_data(raw, scale=scale)

    # -- refresh ------------------------------------------------------------

    def _take_dirty(self) -> jax.Array:
        """Snapshot and clear the dirty mask (refresh claims the dirt)."""
        dirty, self._dirty = (self._dirty,
                              jnp.zeros((self.capacity,), jnp.bool_))
        return dirty

    def _delta_refresh_values(self, kr: jax.Array, params: Any,
                              dirty: jax.Array, features: jax.Array,
                              index: LSHIndex, scale=None, store=None,
                              live=None):
        """(features, index) after a delta refresh of ``dirty`` rows.

        Pure in its explicit inputs so the async thread can run it on a
        launch-time snapshot.  The visited mask is widened by a
        ``drift_frac`` Bernoulli draw from the refresh key stream —
        deterministic per refresh index, so restores replay it — then
        padded to a power-of-two id bucket (duplicate ids are benign:
        identical rows re-embed to identical codes, and the scatter
        writes identical values).  Streaming: the mask is intersected
        with the (snapshot) live mask, so a drift draw never re-embeds
        a dead slot.
        """
        cap = dirty.shape[0]
        if self.cfg.drift_frac > 0.0:
            kd = jax.random.fold_in(kr, 1)
            dirty = jnp.logical_or(
                dirty,
                jax.random.bernoulli(kd, self.cfg.drift_frac, (cap,)))
        if live is not None:
            dirty = jnp.logical_and(dirty, live)
        nd = int(jnp.sum(dirty))
        if nd == 0:
            return features, index
        size = min(_dirty_bucket(nd), cap)
        ids = jnp.flatnonzero(dirty, size=size,
                              fill_value=jnp.argmax(dirty))
        feats_d = self._embed_rows(ids, params, scale=scale, store=store)
        codes_d = hash_points(feats_d, index.projections, self.lsh,
                              use_pallas=self.cfg.use_pallas,
                              interpret=self.cfg.interpret)
        return (features.at[ids].set(feats_d),
                mutate_index(index,
                             IndexMutation("delta", ids=ids, codes=codes_d)))

    # -- refresh resilience --------------------------------------------------

    def set_fault_injector(self, injector):
        """Install a ``repro.testing.faults`` injector (None clears).

        The pipeline fires ``refresh_compute`` (per refresh attempt) and
        ``recover_rebuild`` (per uniform-fallback recovery attempt)
        events through it — deterministic chaos for tests and drills.
        """
        self.fault_injector = injector

    def _fault(self, event: str, **info):
        if self.fault_injector is not None:
            self.fault_injector.fire(event, **info)

    def _sleep_backoff(self, attempt: int):
        """Exponential backoff with DETERMINISTIC jitter: the jitter is
        a pure function of (refresh_count, attempt), so two replays of
        the same faulted run sleep identically (wall time is not part of
        the batch-determinism contract, but keeping it reproducible
        makes chaos drills comparable)."""
        base = self.cfg.refresh_backoff
        if base <= 0 or attempt <= 0:
            return
        j = (zlib.crc32(f"{self._refresh_count}:{attempt}".encode())
             % 1000) / 1000.0
        time.sleep(base * (2 ** (attempt - 1)) * (1.0 + 0.5 * j))

    def _attempt_refresh(self, kr, full, dirty, params, features, index,
                         scale, store, live, attempt: int):
        """ONE refresh attempt on explicit inputs -> (features, index,
        scale).  Attribute-write-free so failed attempts cannot leave
        partially-committed state (features newer than index, or a scale
        out of sync with both).  ``store``/``live`` are launch-time
        snapshots: streaming mutations replace ``self.store`` under the
        worker, and the swap boundary reconciles the delta."""
        self._fault("refresh_compute", refresh=self._refresh_count,
                    attempt=attempt)
        if full:
            feats, new_scale = self._compute_features_scaled(
                params, store=store, live=live)
            new_index = mutate_index(
                index,
                IndexMutation("refresh", key=kr, x_aug=feats,
                              live_mask=live, warm_start=True),
                self.lsh,
                use_pallas=self.cfg.use_pallas, interpret=self.cfg.interpret)
            return feats, new_index, new_scale
        feats, new_index = self._delta_refresh_values(
            kr, params, dirty, features, index, scale=scale, store=store,
            live=live)
        return feats, new_index, scale

    def _guarded(self, thunk):
        """Run ``thunk`` under the hang watchdog: with
        ``refresh_timeout`` set it runs on a daemon thread and a run
        exceeding the timeout raises TimeoutError here (the worker is
        abandoned — it only ever writes its private box)."""
        if self.cfg.refresh_timeout is None:
            return thunk()
        box: dict = {}

        def work():
            try:
                box["result"] = thunk()
            except BaseException as e:
                box["error"] = e

        t = threading.Thread(target=work, daemon=True)
        t.start()
        t.join(self.cfg.refresh_timeout)
        if t.is_alive():
            raise TimeoutError(
                f"refresh attempt exceeded watchdog timeout "
                f"{self.cfg.refresh_timeout}s; worker abandoned")
        if "error" in box:
            raise box["error"]
        return box["result"]

    def _retry_refresh(self, kr, full, dirty, params, features, index,
                       scale, store, live, first_error=None,
                       start_attempt=0) -> bool:
        """Retry loop around the refresh computation; commits the
        (features, index, scale) triple atomically on success.

        Returns True on success.  On exhausted retries the pipeline
        STAYS on its last good buffer (stale-index mode: Algorithm 1's
        probabilities remain exact w.r.t. the indexed vectors, the index
        merely lags the model) and the health monitor decides whether
        the staleness bound was crossed — nothing raises at the swap
        boundary.
        """
        attempts = 1 + max(self.cfg.refresh_retries, 0)
        err = first_error
        for attempt in range(start_attempt, attempts):
            self._sleep_backoff(attempt)
            try:
                feats, new_index, new_scale = self._guarded(
                    lambda: self._attempt_refresh(
                        kr, full, dirty, params, features, index, scale,
                        store, live, attempt))
            except Exception as e:       # noqa: BLE001 — any failure retries
                err = e
                log.warning("refresh %d attempt %d failed: %r",
                            self._refresh_count, attempt, e)
                continue
            self.features, self.index = feats, new_index
            if self.family.asymmetric:
                self._feat_scale = new_scale
            self.health.note_refresh_success(self._step)
            return True
        log.warning("refresh %d failed after %d attempt(s); keeping stale "
                    "index (last error: %r)", self._refresh_count,
                    attempts - start_attempt, err)
        self.health.note_refresh_failure(self._step, repr(err))
        return False

    def refresh(self, full: Optional[bool] = None) -> bool:
        """Re-embed + re-hash the local shard synchronously.

        ``full=None`` follows ``cfg.refresh_mode``; ``full=True`` forces
        the whole-shard path regardless of mode.  Both paths re-sort
        through the previous ``order`` (warm start / delta merge), so
        the rebuilt index double-buffers cleanly: unchanged codes keep
        their slots.  Failures retry with backoff; on exhaustion the
        last good buffer stays live (returns False, health degrades).
        """
        full = (self.cfg.refresh_mode != "delta") if full is None else full
        kr = jax.random.fold_in(self._refresh_stream, self._refresh_count)
        dirty = self._take_dirty()
        ok = self._retry_refresh(kr, full, dirty, self.params,
                                 self.features, self.index,
                                 self._feat_scale, self.store,
                                 self._live_dev)
        self._refresh_count += 1
        return ok

    def _launch_refresh(self):
        """Start the double-buffer refresh on a host thread (overlap)."""
        if self._refresh_thread is not None:
            return
        kr = jax.random.fold_in(self._refresh_stream, self._refresh_count)
        params = self.params          # snapshot: params as of launch step
        full = self.cfg.refresh_mode != "delta"
        dirty = self._take_dirty()    # delta dirt is claimed at launch
        old_index, old_features = self.index, self.features
        old_scale = self._feat_scale  # snapshot: delta re-augments at it
        # streaming: the worker computes on the LAUNCH-time store /
        # membership; mutations landing during the flight go to the live
        # buffers and into ``_touched`` for the swap-boundary reconcile.
        old_store, old_live = self.store, self._live_dev
        old_capacity = self.capacity
        self._touched = set()
        box: dict = {}

        def work():
            # attribute-write-free: features/index/scale are committed
            # TOGETHER at the swap boundary, so an errored or abandoned
            # refresh cannot leave self._feat_scale out of sync with
            # the live (features, index) pair.
            try:
                box["result"] = self._attempt_refresh(
                    kr, full, dirty, params, old_features, old_index,
                    old_scale, old_store, old_live, attempt=0)
            except BaseException as e:   # handled at the swap boundary
                box["error"] = e

        t = threading.Thread(target=work, daemon=True)
        t.start()
        self._refresh_thread, self._refresh_box = t, box
        # the retry path re-runs the worker's computation on the SAME
        # inputs, so a boundary retry is bit-identical to what the
        # worker would have produced.
        self._refresh_snapshot = (kr, full, dirty, params, old_features,
                                  old_index, old_scale, old_store,
                                  old_live, old_capacity)

    def _swap_refresh(self):
        """Join the in-flight refresh and swap buffers (fixed boundary).

        A worker that errored is retried synchronously (same inputs,
        backoff between attempts); one that HANGS past
        ``refresh_timeout`` is abandoned by the watchdog and counted as
        a failed attempt.  Exhausted retries leave the last good buffer
        live (stale-index mode) instead of raising.
        """
        if self._refresh_thread is None:   # e.g. fresh restore: sync path
            self.refresh()
            return
        t, box = self._refresh_thread, self._refresh_box
        snap = self._refresh_snapshot
        t.join(self.cfg.refresh_timeout)
        hung = t.is_alive()
        self._refresh_thread = None
        self._refresh_box = None
        self._refresh_snapshot = None
        (kr, full, dirty, params, features, index, scale, store, live,
         snap_capacity) = snap
        if self.streaming and snap_capacity != self.capacity:
            # a grow/compact landed during the flight: the worker's
            # buffers have the wrong capacity (and compaction remapped
            # slots).  Discard it and refresh synchronously on CURRENT
            # state — the full path, since the claimed dirty mask also
            # predates the remap.
            self._touched = set()
            zero_dirty = jnp.zeros((self.capacity,), jnp.bool_)
            self._retry_refresh(kr, True, zero_dirty, self.params,
                                self.features, self.index,
                                self._feat_scale, self.store,
                                self._live_dev)
            self._refresh_count += 1
            return
        if hung:
            err = TimeoutError(
                f"async refresh worker hung past the swap boundary "
                f"(watchdog {self.cfg.refresh_timeout}s); abandoned")
            log.warning("%s", err)
            ok = self._retry_refresh(kr, full, dirty, params, features,
                                     index, scale, store, live,
                                     first_error=err, start_attempt=1)
        elif "error" in box:
            ok = self._retry_refresh(kr, full, dirty, params, features,
                                     index, scale, store, live,
                                     first_error=box["error"],
                                     start_attempt=1)
        else:
            feats, new_index, new_scale = box["result"]
            self.features, self.index = feats, new_index
            if self.family.asymmetric:
                self._feat_scale = new_scale
            self.health.note_refresh_success(self._step)
            ok = True
        if self.streaming:
            if ok:
                # the committed buffers predate any in-flight mutations;
                # fold them back in with one delta merge.
                self._reconcile_touched()
            else:
                # stale-index mode keeps the LIVE buffers, which already
                # carry every mutation — nothing to reconcile.
                self._touched = set()
        self._refresh_count += 1

    def _reconcile_touched(self):
        """Merge in-flight mutations into a just-committed refresh
        result: touched live slots are re-embedded from the CURRENT
        store at the committed scale and delta-merged; touched dead
        slots are sentinel-merged — one tie-stable merge for both."""
        touched = sorted(self._touched)
        self._touched = set()
        if not touched:
            return
        slots = np.asarray(touched, np.int64)
        live = self._live_np[slots]
        codes = np.full((self.lsh.l, len(slots)), EMPTY_CODE, np.uint32)
        if live.any():
            l_ids = jnp.asarray(slots[live], jnp.int32)
            feats = self._embed_rows(l_ids, self.params,
                                     scale=self._feat_scale)
            codes_l = hash_points(feats, self.index.projections, self.lsh,
                                  use_pallas=self.cfg.use_pallas,
                                  interpret=self.cfg.interpret)
            codes = jnp.asarray(codes).at[:, jnp.asarray(
                np.flatnonzero(live))].set(codes_l)
            self.features = self.features.at[l_ids].set(feats)
        ids_p, codes_p = _pad_mutation(
            np.asarray(slots, np.int32), jnp.asarray(codes), self.capacity)
        self.index = mutate_index(
            self.index, IndexMutation("delta", ids=ids_p, codes=codes_p))

    def _attempt_recovery(self) -> bool:
        """Uniform-fallback -> healthy: try a full CANONICAL index
        rebuild (fresh argsort from the build key, like ``restore_at`` —
        not the refresh-stream warm-start chain, which the failed
        refreshes desynced).  Failure stays in uniform-fallback until
        the next ``recover_after`` boundary."""
        try:
            def build():
                self._fault("recover_rebuild", step=self._step)
                feats, scale = self._compute_features_scaled(
                    self.params, live=self._live_dev)
                idx = mutate_index(
                    None,
                    IndexMutation("build", key=self._build_key,
                                  x_aug=feats, live_mask=self._live_dev),
                    self.lsh,
                    use_pallas=self.cfg.use_pallas,
                    interpret=self.cfg.interpret)
                return feats, idx, scale
            feats, idx, scale = self._guarded(build)
        except Exception as e:           # noqa: BLE001
            log.warning("recovery rebuild failed at step %d: %r",
                        self._step, e)
            self.health.refresh_failures += 1
            return False
        self.features, self.index = feats, idx
        if self.family.asymmetric:
            self._feat_scale = scale
        self._dirty = jnp.zeros((self.capacity,), jnp.bool_)
        self.health.note_recovered(self._step)
        log.info("recovered at step %d: index rebuilt", self._step)
        return True

    def _discard_refresh(self):
        """Abandon any in-flight refresh worker (it only writes its
        private box) — used when degrading to uniform-fallback, where
        the refresh schedule is suspended."""
        self._refresh_thread = None
        self._refresh_box = None
        self._refresh_snapshot = None

    def note_loss(self, finite: bool):
        """Trainer hook: per-step loss finiteness feeds the ladder (a
        non-finite streak degrades to uniform-fallback)."""
        pre = self.health.state
        self.health.note_loss(self._step, finite)
        if self.health.state != pre and \
                self.health.state == UNIFORM_FALLBACK:
            self._discard_refresh()

    def check_health(self):
        """Feed the latest batch's fallback rate into the ladder (syncs
        a device scalar — call at log cadence, not per step) and return
        the current state."""
        pre = self.health.state
        if self._stat_draws > 0 and pre != UNIFORM_FALLBACK:
            self.health.note_fallback_rate(
                self._step, float(self._last_fallback))
            if self.health.state == UNIFORM_FALLBACK:
                self._discard_refresh()
        return self.health.state

    def health_state(self) -> str:
        return self.health.state

    def health_summary(self) -> dict:
        return self.health.summary()

    def finalize(self):
        """Join any in-flight refresh thread (call before teardown).
        A worker failure that had not yet hit a swap boundary is folded
        into the health state (and logged) rather than raised — teardown
        is resilient by design."""
        if self._refresh_thread is not None:
            self._refresh_thread.join(self.cfg.refresh_timeout)
            box = self._refresh_box or {}
            self._discard_refresh()
            if "error" in box:
                log.warning("in-flight refresh failed at teardown: %r",
                            box["error"])
                self.health.note_refresh_failure(
                    self._step, repr(box["error"]))

    def _maybe_refresh(self):
        re = self.cfg.refresh_every
        if re <= 0:
            return
        s = self._step
        if self.cfg.refresh_async and self.cfg.refresh_lead > 0:
            lead = min(self.cfg.refresh_lead, re - 1)
            if s + lead >= re and (s + lead) % re == 0:
                self._launch_refresh()
            if s >= re and s % re == 0:
                self._swap_refresh()
        elif s >= re and s % re == 0:
            self.refresh()

    # -- batches ------------------------------------------------------------

    def _tick(self):
        """Shared refresh gate + per-step key for both batch entry points.

        In uniform-fallback the refresh schedule is suspended (the index
        is not trusted); instead the pipeline periodically attempts a
        full canonical rebuild to climb back to healthy.  The per-step
        key stream advances identically in every state, so a run that
        degrades and recovers stays on the same key schedule as a
        healthy one.
        """
        if self.health.state == UNIFORM_FALLBACK:
            if self.health.should_attempt_recovery(self._step):
                self._attempt_recovery()
        else:
            self._maybe_refresh()
        sub = jax.random.fold_in(self._step_stream, self._step)
        self._step += 1
        return sub

    def _uniform_batch(self, sub: jax.Array, m: int):
        """Uniform-fallback draw: m uniform rows with weight 1.

        Plain Monte-Carlo — E[(1/m)·Σ ∇f_i] over uniform i is the exact
        mean gradient, so weight 1 is unbiased by construction with ZERO
        dependence on the LSH state (Needell & Ward's safe baseline).
        Under sharding the owner rescales by n_s·S/N exactly as for
        weighted batches, which composes shard-means into the global
        mean — no special-casing needed.

        Streaming: the draw is uniform over the LIVE rows — slot u of
        table 0's sorted order for u < n_live (the sentinel clusters
        every dead slot past the live prefix), with store / order /
        n_live passed as traced arguments so mutations never recompile.
        """
        if self.streaming:
            if self._uniform_fn is None:
                off, rw = self.example_offset, self.row_width

                def draw(key, store, order0, n_live, mm):
                    u = jax.random.randint(key, (mm,), 0, n_live)
                    idx = order0[u]
                    rows = jnp.take(store, idx, axis=0)[:, :rw]
                    return {
                        "tokens": rows[:, :-1],
                        "targets": rows[:, 1:],
                        "loss_weights": jnp.ones((mm,), jnp.float32),
                        "example_ids": idx + off,
                    }, idx
                self._uniform_fn = jax.jit(draw, static_argnums=4)
            batch, idx = self._uniform_fn(sub, self.store,
                                          self.index.order[0],
                                          self._n_live_dev, m)
            self._mark_dirty(idx)
            return batch
        if self._uniform_fn is None:
            n, off, rw = self.n, self.example_offset, self.row_width

            def draw(key, mm):
                idx = jax.random.randint(key, (mm,), 0, n)
                rows = jnp.take(self.store, idx, axis=0)[:, :rw]
                return {
                    "tokens": rows[:, :-1],
                    "targets": rows[:, 1:],
                    "loss_weights": jnp.ones((mm,), jnp.float32),
                    "example_ids": idx + off,
                }, idx
            self._uniform_fn = jax.jit(draw, static_argnums=1)
        batch, idx = self._uniform_fn(sub, m)
        self._mark_dirty(idx)
        return batch

    def restore_at(self, step: int, rebuild: bool = True):
        """Elastic/deterministic resume: rewind counters to ``step`` and
        canonically rebuild the index from current params.

        The rebuilt index reuses the original projections (same build
        key) on freshly-embedded features with a fresh argsort — NOT the
        warm-started order chain, which is history-dependent through tie
        layouts.  Two restores at the same step are therefore bitwise
        identical, and the fold_in key streams make every subsequent
        batch identical across restores too.  The dirty mask restarts
        empty: a restored pipeline re-embeds everything, so it owes no
        deferred refresh work.

        ``rebuild=False`` skips the O(N) re-embed + re-hash; valid ONLY
        when the pipeline was just constructed from the restored params
        (its ``__init__`` build is bitwise what the rebuild would
        produce) — the elastic restore path uses this to avoid paying
        the corpus embed twice.

        Streaming: the mutation log is truncated to entries with
        step <= ``step`` and replayed MEMBERSHIP-ONLY (store writes,
        window evictions, growth/compaction — all re-derived
        deterministically, no embeds), then the index is rebuilt
        canonically over the replayed membership; a non-empty replay
        forces ``rebuild=True``.  Two restores at the same step are
        bitwise identical — streaming included.
        """
        self.finalize()
        if self.streaming:
            kept = [e for e in self._mutlog if e["step"] <= step]
            self._init_membership(self.tokens)
            for e in kept:
                if e["op"] == "append":
                    self._apply_append(e["tokens"], with_index=False)
                else:
                    self._apply_evict(
                        np.asarray(e["ids"], np.int64)
                        - self.example_offset, with_index=False)
            self._mutlog = kept
            self._touched = set()
            if kept:
                rebuild = True
        re = self.cfg.refresh_every
        self._step = step
        self._refresh_count = (
            0 if re <= 0 or step < 1 else (step - 1) // re)
        self._dirty = jnp.zeros((self.capacity,), jnp.bool_)
        # a restored pipeline starts HEALTHY: the rebuild below (or the
        # constructor build it mirrors) is a fresh, verified index, and
        # determinism requires replays to be state-independent.
        self.health = HealthMonitor(self._health_cfg)
        self._refresh_snapshot = None
        if rebuild:
            self.features = self._compute_features()
            self.index = mutate_index(
                None,
                IndexMutation("build", key=self._build_key,
                              x_aug=self.features,
                              live_mask=self._live_dev),
                self.lsh,
                use_pallas=self.cfg.use_pallas,
                interpret=self.cfg.interpret)

    # -- index mutations (the unified entry point) ---------------------------

    def _require_streaming(self, what: str):
        if not self.streaming:
            raise ValueError(
                f"{what} requires streaming=True (or window=) in "
                f"LSHPipelineConfig")

    def mutate(self, mutation: IndexMutation):
        """THE index-mutation entry point (explicit op — see
        ``core.tables.IndexMutation``):

          * ``append`` — ``tokens`` (B, S+1): add rows (streaming);
            returns the assigned global example ids.
          * ``evict`` — ``ids``: remove rows by global id (streaming).
          * ``delta`` — refresh only visited + drift rows (the
            ``refresh(full=False)`` path).
          * ``refresh`` — full warm refresh (``refresh(full=True)``).
          * ``build`` — canonical rebuild: re-embed everything and
            fresh-argsort from the build key (what ``restore_at`` and
            fault recovery do); discards any in-flight async refresh.

        ``build``/``refresh``/``delta`` run synchronously here; the
        periodic schedule (``refresh_every`` / ``refresh_async``) is
        unchanged and composes with mutations as described in the
        module docstring.
        """
        op = mutation.op
        if op == "append":
            if mutation.tokens is None:
                raise ValueError("mutate(append) needs tokens=")
            return self.append_rows(mutation.tokens)
        if op == "evict":
            if mutation.ids is None:
                raise ValueError("mutate(evict) needs ids=")
            return self.evict_rows(np.asarray(mutation.ids))
        if op == "refresh":
            return self.refresh(full=True)
        if op == "delta":
            return self.refresh(full=False)
        # op == "build" (IndexMutation validates the op set)
        return self._canonical_rebuild()

    def append_rows(self, tokens) -> np.ndarray:
        """Append token rows to the live window (streaming only).

        Embeds the new rows at the pinned family scale, hashes them and
        tie-stably merges them into every table; with ``window=`` set,
        the oldest live rows are auto-evicted first.  Logged for
        checkpoint replay.  Returns the assigned global example ids
        (slot + ``example_offset``; slots are reused after eviction).
        """
        self._require_streaming("append_rows")
        tokens = np.asarray(tokens, np.int32)
        slots = self._apply_append(tokens, with_index=True)
        self._mutlog.append({"op": "append", "step": self._step,
                             "tokens": tokens.copy()})
        return slots + self.example_offset

    def evict_rows(self, ids) -> None:
        """Evict rows by global example id (streaming only): a sentinel
        merge pushes their slots past every table's live prefix.  Logged
        for checkpoint replay."""
        self._require_streaming("evict_rows")
        ids = np.asarray(ids, np.int64).reshape(-1)
        self._apply_evict(ids - self.example_offset, with_index=True)
        self._mutlog.append({"op": "evict", "step": self._step,
                             "ids": ids.copy()})

    def _apply_append(self, tokens: np.ndarray,
                      with_index: bool) -> np.ndarray:
        """Membership append (+ index merge when ``with_index``) —
        shared verbatim by the live path and the restore replay, so
        window evictions, growth and slot assignment re-derive
        identically."""
        if tokens.ndim != 2 or tokens.shape[1] != self.row_width:
            raise ValueError(
                f"append tokens must be (B, {self.row_width}), "
                f"got {tokens.shape}")
        b = tokens.shape[0]
        if b < 1:
            raise ValueError("append needs at least one row")
        w = self.cfg.window
        if w is not None:
            if b > w:
                raise ValueError(
                    f"append batch {b} exceeds window {w}")
            over = self._n_live + b - w
            if over > 0:
                live_slots = np.flatnonzero(self._live_np)
                oldest = live_slots[np.argsort(
                    self._arrival[live_slots], kind="stable")][:over]
                self._apply_evict(oldest, with_index=with_index)
        if self._n_live + b > self.capacity:
            self._grow(_next_pow2(self._n_live + b), with_index)
        self._free.sort()
        slots = np.asarray(self._free[:b], np.int64)
        del self._free[:b]
        jslots = jnp.asarray(slots, jnp.int32)
        rows = jnp.pad(jnp.asarray(tokens, jnp.int32),
                       ((0, 0), (0, self.store.shape[1] - self.row_width)))
        self.store = self.store.at[jslots].set(rows)
        self._live_np[slots] = True
        self._arrival[slots] = np.arange(self._next_arrival,
                                         self._next_arrival + b)
        self._next_arrival += b
        self._n_live += b
        self._sync_live_dev()
        if with_index:
            feats = self._embed_rows(jslots, self.params,
                                     scale=self._feat_scale)
            codes = hash_points(feats, self.index.projections, self.lsh,
                                use_pallas=self.cfg.use_pallas,
                                interpret=self.cfg.interpret)
            self.features = self.features.at[jslots].set(feats)
            ids_p, codes_p = _pad_mutation(slots.astype(np.int32), codes,
                                           self.capacity)
            self.index = mutate_index(
                self.index,
                IndexMutation("delta", ids=ids_p, codes=codes_p))
            if self._refresh_thread is not None:
                self._touched.update(int(s) for s in slots)
        return slots

    def _apply_evict(self, slots: np.ndarray, with_index: bool):
        """Membership evict (+ sentinel merge when ``with_index``) —
        shared by the live path, window auto-evict and restore replay."""
        slots = np.asarray(slots, np.int64).reshape(-1)
        if slots.size == 0:
            return
        if np.unique(slots).size != slots.size:
            raise ValueError("duplicate ids in evict batch")
        if ((slots < 0) | (slots >= self.capacity)).any() or \
                not self._live_np[slots].all():
            raise ValueError("evict of unknown or already-dead rows")
        self._live_np[slots] = False
        self._arrival[slots] = -1
        self._free.extend(int(s) for s in slots)
        self._n_live -= int(slots.size)
        self._sync_live_dev()
        if with_index:
            size = min(_dirty_bucket(int(slots.size)), self.capacity)
            ids_p = np.concatenate(
                [slots, np.full((size - slots.size,), slots[0])])
            self.index = mutate_index(
                self.index,
                IndexMutation("evict",
                              ids=jnp.asarray(ids_p, jnp.int32)))
            if self._refresh_thread is not None:
                self._touched.update(int(s) for s in slots)
        self._maybe_compact(with_index)

    def _grow(self, new_cap: int, with_index: bool):
        """Grow every capacity-sized buffer to ``new_cap`` (a power of
        two) — one recompile point per doubling, never per append."""
        pad = new_cap - self.capacity
        self.store = jnp.pad(self.store, ((0, pad), (0, 0)))
        self._live_np = np.concatenate(
            [self._live_np, np.zeros((pad,), np.bool_)])
        self._arrival = np.concatenate(
            [self._arrival, np.full((pad,), -1, np.int64)])
        self._free.extend(range(self.capacity, new_cap))
        if with_index:
            self.features = jnp.pad(self.features, ((0, pad), (0, 0)))
            self._dirty = jnp.pad(self._dirty, (0, pad))
            self.index = grow_index(self.index, new_cap)
        self.capacity = new_cap
        self._sync_live_dev()

    def _maybe_compact(self, with_index: bool):
        """Halve capacity once live occupancy drops to a quarter
        (hysteresis: grow doubles at full, compact halves at 1/4, so
        the two never thrash).  Live rows are packed into the prefix in
        ascending slot order — slot ids CHANGE under compaction — and
        the index is rebuilt canonically over the packed features."""
        if not (self._n_live <= self.capacity // 4
                and self.capacity > self.cfg.min_capacity):
            return
        new_cap = self.capacity // 2
        while (self._n_live <= new_cap // 4
               and new_cap > self.cfg.min_capacity):
            new_cap //= 2
        new_cap = max(new_cap, self.cfg.min_capacity)
        live_slots = np.flatnonzero(self._live_np)
        dead_slots = np.flatnonzero(~self._live_np)
        perm = np.concatenate([live_slots, dead_slots])[:new_cap]
        jperm = jnp.asarray(perm, jnp.int32)
        nl = int(live_slots.size)
        self.store = jnp.take(self.store, jperm, axis=0)
        new_live = np.zeros((new_cap,), np.bool_)
        new_live[:nl] = True
        new_arrival = np.full((new_cap,), -1, np.int64)
        new_arrival[:nl] = self._arrival[live_slots]
        self._live_np, self._arrival = new_live, new_arrival
        self._free = list(range(nl, new_cap))
        self.capacity = new_cap
        self._sync_live_dev()
        if with_index:
            self.features = jnp.take(self.features, jperm, axis=0)
            self._dirty = jnp.logical_and(
                jnp.take(self._dirty, jperm), jnp.asarray(new_live))
            self._canonical_rebuild_index()

    def _canonical_rebuild_index(self):
        self.index = mutate_index(
            None,
            IndexMutation("build", key=self._build_key,
                          x_aug=self.features, live_mask=self._live_dev),
            self.lsh,
            use_pallas=self.cfg.use_pallas, interpret=self.cfg.interpret)

    def _canonical_rebuild(self) -> bool:
        """``mutate(build)``: re-embed everything + fresh argsort from
        the build key (the restore/recovery construction)."""
        self._discard_refresh()
        self.features = self._compute_features()
        self._canonical_rebuild_index()
        self._dirty = jnp.zeros((self.capacity,), jnp.bool_)
        return True

    def mutation_log(self) -> list:
        """The explicit-mutation log as JSON-serialisable entries (what
        the trainer checkpoints; ``load_mutation_log`` + ``restore_at``
        replay it)."""
        out = []
        for e in self._mutlog:
            if e["op"] == "append":
                out.append({"op": "append", "step": int(e["step"]),
                            "tokens": np.asarray(e["tokens"],
                                                 np.int32).tolist()})
            else:
                out.append({"op": "evict", "step": int(e["step"]),
                            "ids": [int(i) for i in e["ids"]]})
        return out

    def load_mutation_log(self, entries):
        """Install a checkpointed mutation log; the next ``restore_at``
        replays it (membership-only) before the canonical rebuild."""
        self._require_streaming("load_mutation_log")
        norm = []
        for e in entries:
            if e["op"] == "append":
                norm.append({"op": "append", "step": int(e["step"]),
                             "tokens": np.asarray(e["tokens"], np.int32)})
            elif e["op"] == "evict":
                norm.append({"op": "evict", "step": int(e["step"]),
                             "ids": np.asarray(e["ids"], np.int64)})
            else:
                raise ValueError(f"unknown mutation-log op {e['op']!r}")
        self._mutlog = norm

    def _query(self) -> jax.Array:
        q = self.query_fn(self.params) if self._params_aware \
            else self.query_fn()
        # family query augmentation: SRP normalises (bit-identical to
        # the pre-family pipeline), MIPS appends the zero coordinate.
        return self.family.augment_query(q)

    def _mark_dirty(self, indices: jax.Array):
        if self._track_dirty:
            self._dirty = self._dirty.at[indices.reshape(-1)].set(True)

    def _accum_stats(self, gb):
        """Accumulate per-step sampling diagnostics (device-lazy)."""
        fb = gb.fallback.reshape(-1)
        pm = (gb.probe_code.reshape(-1) != 0)
        self._stat_draws += fb.shape[0]
        self._fallback_sum = self._fallback_sum + jnp.sum(
            fb.astype(jnp.int32))
        self._primary_miss_sum = self._primary_miss_sum + jnp.sum(
            pm.astype(jnp.int32))
        self._last_fallback = jnp.mean(fb.astype(jnp.float32))

    def sampler_stats(self) -> Dict[str, float]:
        """Cumulative sampling diagnostics (syncs; read at log cadence).

        Returns:
          ``draws``: samples drawn since construction;
          ``fallback_rate``: fraction that fell back to uniform 1/N;
          ``primary_miss_rate``: fraction whose exact bucket was empty
          (resolved by a multi-probe neighbour OR by fallback);
          ``last_fallback_rate``: the most recent batch's fallback
          fraction.
        """
        d = max(self._stat_draws, 1)
        return {
            "draws": self._stat_draws,
            "fallback_rate": float(self._fallback_sum) / d,
            "primary_miss_rate": float(self._primary_miss_sum) / d,
            "last_fallback_rate": float(self._last_fallback),
        }

    def next_batch(self, query: Optional[jax.Array] = None
                   ) -> Dict[str, jax.Array]:
        """Draw one batch — a single jitted on-device program; ``query``
        (already normalised) lets a sharded owner compute the shared
        global query once for all shards."""
        if self.streaming and self._n_live == 0:
            raise RuntimeError(
                "cannot draw a batch from an empty streaming window "
                "(append rows first)")
        sub = self._tick()
        if self.health.state == UNIFORM_FALLBACK:
            return self._uniform_batch(sub, self.cfg.minibatch)
        q = self._query() if query is None else query
        gb = sample_gather(
            sub, self.index, self.features, q, self.store, self.lsh,
            m=self.cfg.minibatch, example_offset=self.example_offset,
            multiprobe=self.cfg.multiprobe,
            p_floor=self.cfg.p_floor,
            normalize=self.cfg.normalize_weights,
            use_pallas=self.cfg.use_pallas, interpret=self.cfg.interpret,
            row_width=self.row_width, n_live=self._n_live_dev)
        self._mark_dirty(gb.indices)
        self._accum_stats(gb)
        return {
            "tokens": gb.tokens,
            "targets": gb.targets,
            "loss_weights": gb.loss_weights,
            "example_ids": gb.example_ids,
        }

    def next_batch_multi(self, queries: jax.Array) -> list:
        """One batch per query row (multi-chain / perturbed-query training).

        ``queries``: (C, dim).  All C queries are hashed and probed by a
        SINGLE fused bucket-probe pass, and all C·m rows are gathered and
        weighted by a single gather+weight pass
        (``core.sampler.sample_gather_batched``); each chain still gets
        exact per-sample Algorithm-1 probabilities under its own query.
        """
        if self.streaming and self._n_live == 0:
            raise RuntimeError(
                "cannot draw a batch from an empty streaming window "
                "(append rows first)")
        sub = self._tick()
        if self.health.state == UNIFORM_FALLBACK:
            c, m = queries.shape[0], self.cfg.minibatch
            big = self._uniform_batch(sub, c * m)
            return [{k: v[i * m:(i + 1) * m] for k, v in big.items()}
                    for i in range(c)]
        qn = self.family.augment_query(queries)
        gb = sample_gather_batched(
            sub, self.index, self.features, qn, self.store, self.lsh,
            m=self.cfg.minibatch, example_offset=self.example_offset,
            multiprobe=self.cfg.multiprobe,
            p_floor=self.cfg.p_floor,
            normalize=self.cfg.normalize_weights,
            use_pallas=self.cfg.use_pallas,
            interpret=self.cfg.interpret,
            row_width=self.row_width,
            n_live=self._n_live_dev)                 # fields (C, m, ...)
        self._mark_dirty(gb.indices)
        self._accum_stats(gb)
        return [{
            "tokens": gb.tokens[c],
            "targets": gb.targets[c],
            "loss_weights": gb.loss_weights[c],
            "example_ids": gb.example_ids[c],
        } for c in range(queries.shape[0])]


class ShardedLSHPipeline:
    """Shard-by-example LGD: one LSH index per data-parallel corpus shard.

    The global corpus (N examples) is split into ``n_shards`` contiguous
    shards (``example_shard_bounds``); shard s owns an independent
    ``LSHSampledPipeline`` keyed by ``fold_in(key, s)`` over its n_s
    examples, with its token store uploaded once and committed via
    ``shard_store_device`` — NOTE: under a single-controller mesh that
    placement is MESH-REPLICATED (the mesh-sharded model params force
    every embed/sample computation to span the mesh; budget HBM for
    every store on every device).  True per-DP-group store residency is
    the multi-controller deployment, where each process constructs only
    its own shard's pipeline.  Every global batch is the concatenation
    of equal-size per-shard sub-batches (minibatch must divide by
    n_shards), laid out so dim 0 slices map shard s's examples to DP
    group s under ``dist.sharding.batch_sharding`` — with a mesh the
    composition is ``compose_sharded_batch``: the per-shard device
    arrays are adopted zero-copy as the shards of the global batch, so
    batch assembly costs no host round-trip and no cross-host traffic.

    UNBIASEDNESS: shard s's local estimator (1/m_s) sum_j g_j / (p_j n_s)
    is unbiased for the shard mean; the emitted global weight is the
    local weight rescaled by n_s * S / N, i.e. w_j = S / (p_j N), which
    makes the plain mean over the whole (m = S * m_s)-example batch equal
    the average of shard-mean estimates — an unbiased estimator of the
    full-corpus mean gradient for ANY shard sizes (each shard estimates
    its shard-sum / (N/S); contiguous balanced bounds keep n_s equal up
    to 1).  With ``normalize_weights`` the composed weights are finally
    scaled to mean 1 over the global batch, preserving relative (and
    cross-shard) weighting.

    Each shard refreshes its own index on the shared schedule — with
    ``refresh_async`` all S refreshes overlap device compute, and with
    ``refresh_mode="delta"`` each shard re-embeds only its own visited
    rows.

    Args:
      key: master PRNG key; shard s is keyed by ``fold_in(key, s)``.
      tokens: (N, S+1) int32 GLOBAL corpus (sharded internally).
      feature_fn / query_fn / config / feature_batch / params: as in
        ``LSHSampledPipeline`` (``config.minibatch`` is the GLOBAL
        batch and must divide by ``n_shards``).
      n_shards: number of per-shard indexes (one per DP group at scale).
      mesh: optional ``jax.sharding.Mesh`` enabling the zero-copy
        sharded batch composition.
      owned_shards: the subset of shard ids THIS process builds and
        draws from (default: all — the single-controller mode).  In the
        multi-controller deployment (``repro.dist.multihost``) process
        r passes ``owned_shards=[r]``: only its own shard's store is
        embedded/hashed/resident here, and ``next_batch`` returns just
        the owned sub-batches — the LOCAL slice of the global batch.
        The emitted weights keep the GLOBAL w = S/(p·N) composition
        (``n_shards`` and the shard bounds are corpus-global), so each
        process's batch is an unbiased estimator of its shards' portion
        and the DP mean across processes of the full corpus.  Partial
        ownership is incompatible with ``streaming`` (remote live
        counts are unknowable locally) and with ``normalize_weights``
        (mean-1 normalisation is a global-batch statistic) — both
        raise.  ``adopt_shards`` extends ownership at runtime (host-
        loss recovery).

    Determinism: as ``LSHSampledPipeline``, per shard — shard s's draw
    stream depends only on ``fold_in(key, s)`` and the params history,
    NOT on which process owns it, so per-process draws compose bitwise
    into the single-controller batch.  ``restore_at`` rewinds every
    owned shard, and a restore onto a DIFFERENT ``n_shards`` (elastic
    reshape) goes through
    ``repro.train.elastic.rebuild_sharded_pipeline``.
    """

    def __init__(
        self,
        key: jax.Array,
        tokens: np.ndarray,                  # (N, S+1) global corpus
        feature_fn: Callable,
        query_fn: Callable,
        config: LSHPipelineConfig,
        n_shards: int = 1,
        feature_batch: int = 512,
        params: Any = None,
        mesh=None,
        owned_shards: Optional[Sequence[int]] = None,
    ):
        if config.minibatch % n_shards != 0:
            raise ValueError(
                f"minibatch={config.minibatch} must divide by "
                f"n_shards={n_shards}")
        if params is None:
            warnings.warn(_LEGACY_HOOK_MSG, DeprecationWarning,
                          stacklevel=2)
        if owned_shards is None:
            owned = list(range(n_shards))
        else:
            owned = sorted({int(s) for s in owned_shards})
            if not owned:
                raise ValueError("owned_shards must not be empty")
            bad = [s for s in owned if not 0 <= s < n_shards]
            if bad:
                raise ValueError(
                    f"owned_shards {bad} not in [0, {n_shards})")
        partial = len(owned) < n_shards
        if partial and config.streaming:
            raise ValueError(
                "owned_shards with streaming=True is unsupported: the "
                "sharded weight composition needs every shard's LIVE "
                "count, which a partial owner cannot observe — run "
                "streaming pipelines with full ownership per process "
                "group (n_shards == len(owned_shards))")
        if partial and config.normalize_weights:
            raise ValueError(
                "owned_shards with normalize_weights=True is "
                "unsupported: mean-1 normalisation is a statistic of "
                "the GLOBAL batch, which a partial owner never sees — "
                "normalise after the cross-process composition instead")
        self.cfg = config
        self.n = tokens.shape[0]
        self.n_shards = n_shards
        self.owned = owned
        self.mesh = mesh
        self.streaming = config.streaming
        # adopt_shards rebuilds missing shards from the construction
        # corpus: keep the ingredients (references, not copies).
        self._key = key
        self._tokens = tokens
        self._feature_fn = feature_fn
        self._query_fn = query_fn
        self._feature_batch = feature_batch
        shard_window = None
        if config.streaming:
            if config.window is not None:
                if config.window % n_shards != 0:
                    raise ValueError(
                        f"window={config.window} must divide by "
                        f"n_shards={n_shards}")
                shard_window = config.window // n_shards
            if self.n // n_shards + 1 >= _SHARD_STRIDE:
                raise ValueError(
                    f"initial shard size {self.n // n_shards + 1} "
                    f"exceeds the streaming id stride {_SHARD_STRIDE}")
        self._shard_cfg = dataclasses.replace(
            config, minibatch=config.minibatch // n_shards,
            normalize_weights=False, window=shard_window)
        self.shards: List[LSHSampledPipeline] = [
            self._make_shard(s, params) for s in self.owned]

    def _make_shard(self, s: int, params: Any) -> "LSHSampledPipeline":
        """Build shard ``s``'s pipeline — keyed by ``fold_in(key, s)``
        over its contiguous corpus slice, identically on any owner."""
        lo, hi = example_shard_bounds(self.n, s, self.n_shards)
        # streaming shards address global ids by a fixed per-shard
        # stride (ids stay disjoint as windows advance); static
        # shards keep the contiguous initial bounds bit-compatibly.
        off = s * _SHARD_STRIDE if self.cfg.streaming else lo
        return LSHSampledPipeline(
            jax.random.fold_in(self._key, s), self._tokens[lo:hi],
            self._feature_fn, self._query_fn, self._shard_cfg,
            feature_batch=self._feature_batch, params=params,
            example_offset=off,
            store_device=shard_store_device(self.mesh, s, self.n_shards),
            _warn_legacy=False)

    def adopt_shards(self, shard_ids: Sequence[int], step: int,
                     params: Any = None):
        """Take ownership of additional shards (host-loss recovery).

        The multi-controller incident path: a peer process died, so the
        survivor adopts its shard(s) — builds the missing per-shard
        pipelines from the construction corpus slice with the same
        ``fold_in(key, s)`` key streams, embedded from ``params``
        (default: current params), and rewinds them to ``step``.

        UNBIASEDNESS: ``n_shards`` and the shard bounds are unchanged —
        only ownership moved — so the composed weights keep the exact
        global w = S/(p·N) form and E[1/(pN)] stays 1 mid-incident
        (Algorithm 1's probabilities are exact w.r.t. the indexed
        vectors, whatever those vectors are).  DETERMINISM: the adopted
        index is embedded from the CURRENT params, not the lost host's
        refresh history (gone with the host), so mid-incident draws are
        NOT bit-reproducible; the full reform
        (``rebuild_sharded_pipeline`` from a verified checkpoint)
        restores the determinism contract.
        """
        if self.streaming:
            raise ValueError(
                "adopt_shards requires a static corpus (streaming "
                "pipelines run fully-owned per process group)")
        params = self.params if params is None else params
        for s in sorted({int(x) for x in shard_ids}):
            if s in self.owned:
                raise ValueError(f"shard {s} is already owned")
            if not 0 <= s < self.n_shards:
                raise ValueError(
                    f"shard {s} not in [0, {self.n_shards})")
            p = self._make_shard(s, params)
            p.restore_at(step, rebuild=False)
            pos = int(np.searchsorted(np.asarray(self.owned), s))
            self.owned.insert(pos, s)
            self.shards.insert(pos, p)

    @property
    def params(self):
        return self.shards[0].params

    def set_params(self, params: Any):
        for p in self.shards:
            p.set_params(params)

    def restore_at(self, step: int, rebuild: bool = True):
        """Rebuild every per-shard index at ``step`` (elastic restore)."""
        for p in self.shards:
            p.restore_at(step, rebuild=rebuild)

    def finalize(self):
        for p in self.shards:
            p.finalize()

    def refresh(self, full: Optional[bool] = None):
        for p in self.shards:
            p.refresh(full=full)

    # -- index mutations (streaming) -----------------------------------------

    def mutate(self, mutation: IndexMutation):
        """Unified mutation entry (see ``LSHSampledPipeline.mutate``):
        ``append``/``evict`` route across shards; the refresh/build ops
        apply to every shard."""
        op = mutation.op
        if op == "append":
            if mutation.tokens is None:
                raise ValueError("mutate(append) needs tokens=")
            return self.append_rows(mutation.tokens)
        if op == "evict":
            if mutation.ids is None:
                raise ValueError("mutate(evict) needs ids=")
            return self.evict_rows(np.asarray(mutation.ids))
        return [p.mutate(mutation) for p in self.shards]

    def append_rows(self, tokens) -> np.ndarray:
        """Append rows across shards (streaming): each incoming row goes
        to the currently least-live shard (ties to the lowest shard
        index) — deterministic greedy balancing, so per-shard windows
        advance together.  Returns global ids in input-row order."""
        if not self.streaming:
            raise ValueError(
                "append_rows requires streaming=True (or window=) in "
                "LSHPipelineConfig")
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 2:
            raise ValueError(f"append tokens must be 2-D, "
                             f"got {tokens.shape}")
        counts = [p.n_live for p in self.shards]
        owner = np.empty((tokens.shape[0],), np.int64)
        for i in range(tokens.shape[0]):
            s = int(np.argmin(counts))
            owner[i] = s
            counts[s] += 1
        gids = np.empty((tokens.shape[0],), np.int64)
        for s, p in enumerate(self.shards):
            rows = np.flatnonzero(owner == s)
            if rows.size:
                gids[rows] = p.append_rows(tokens[rows])
        return gids

    def evict_rows(self, ids) -> None:
        """Evict rows by global id (streaming): ids route to their
        owning shard by ``gid // stride``."""
        if not self.streaming:
            raise ValueError(
                "evict_rows requires streaming=True (or window=) in "
                "LSHPipelineConfig")
        ids = np.asarray(ids, np.int64).reshape(-1)
        owner = ids // _SHARD_STRIDE
        if ((owner < 0) | (owner >= self.n_shards)).any():
            raise ValueError("evict ids outside any shard's id range")
        for s, p in enumerate(self.shards):
            mine = ids[owner == s]
            if mine.size:
                p.evict_rows(mine)

    def mutation_log(self) -> dict:
        """Per-shard mutation logs + the shard count they were routed
        under (replay is only valid on the same ``n_shards``)."""
        return {"n_shards": self.n_shards,
                "shards": [p.mutation_log() for p in self.shards]}

    def load_mutation_log(self, entries: dict):
        if int(entries.get("n_shards", self.n_shards)) != self.n_shards:
            raise ValueError(
                f"mutation log was recorded under n_shards="
                f"{entries.get('n_shards')} but this pipeline has "
                f"n_shards={self.n_shards}; streaming elastic reshape "
                f"is not supported — restore on the recorded shard "
                f"count")
        for p, log_s in zip(self.shards, entries["shards"]):
            p.load_mutation_log(log_s)

    def set_fault_injector(self, injector, shard: Optional[int] = None):
        """Install a fault injector on one shard — a GLOBAL shard id,
        which must be owned here — or on all owned shards (None)."""
        if shard is None:
            targets = self.shards
        else:
            if shard not in self.owned:
                raise ValueError(
                    f"shard {shard} is not owned here (owned: "
                    f"{self.owned})")
            targets = [self.shards[self.owned.index(shard)]]
        for p in targets:
            p.set_fault_injector(injector)

    def note_loss(self, finite: bool):
        for p in self.shards:
            p.note_loss(finite)

    def check_health(self) -> str:
        for p in self.shards:
            p.check_health()
        return self.health_state()

    def health_state(self) -> str:
        """Worst state across shards (one degraded shard degrades the
        reported aggregate — its portion of every batch is affected)."""
        rank = {HEALTHY: 0, STALE_INDEX: 1, UNIFORM_FALLBACK: 2}
        worst = max(self.shards, key=lambda p: rank[p.health.state])
        return worst.health.state

    def health_summary(self) -> dict:
        per = [p.health_summary() for p in self.shards]
        return {
            "state": self.health_state(),
            "stale_refreshes": max(s["stale_refreshes"] for s in per),
            "refresh_failures": sum(s["refresh_failures"] for s in per),
            "recoveries": sum(s["recoveries"] for s in per),
            "transitions": [
                (shard_id,) + tuple(t)
                for shard_id, s in zip(self.owned, per)
                for t in s["transitions"]],
        }

    def sampler_stats(self) -> Dict[str, float]:
        """Draw-weighted aggregate of per-shard sampling diagnostics."""
        per = [p.sampler_stats() for p in self.shards]
        draws = sum(s["draws"] for s in per)
        d = max(draws, 1)
        return {
            "draws": draws,
            "fallback_rate": sum(
                s["fallback_rate"] * s["draws"] for s in per) / d,
            "primary_miss_rate": sum(
                s["primary_miss_rate"] * s["draws"] for s in per) / d,
            "last_fallback_rate": float(
                np.mean([s["last_fallback_rate"] for s in per])),
        }

    def _compose(self, parts: list) -> jax.Array:
        # the zero-copy mesh composition lays out the FULL global batch;
        # a partial owner's batch is its local slice — plain concat.
        if self.mesh is not None and isinstance(self.mesh,
                                                jax.sharding.Mesh) \
                and len(self.owned) == self.n_shards:
            return compose_sharded_batch(parts, self.mesh)
        return jnp.concatenate(parts)

    def next_batch(self) -> Dict[str, jax.Array]:
        # the global query is shard-independent: compute + normalise it
        # once and share it across all owned per-shard sample calls
        # (bitwise the same value on every process — query_fn sees only
        # the replicated params, never the shard).
        q = self.shards[0]._query()
        subs = [p.next_batch(query=q) for p in self.shards]
        m_s = self.cfg.minibatch // self.n_shards
        batch = {
            k: self._compose([b[k] for b in subs])
            for k in ("tokens", "targets", "example_ids")
        }
        # local 1/(p n_s) -> global S/(p N): each sample stands in for
        # N/S corpus examples under the batch mean.  Scaled per shard on
        # the shard's device, composed, then normalised globally — all
        # device ops.  Streaming: n_s and N are the LIVE counts at this
        # draw (the per-shard weights already carry 1/n_live_s), so the
        # composition stays exactly unbiased as the windows advance.
        if self.streaming:
            total_live = sum(p.n_live for p in self.shards)
            w = self._compose([
                b["loss_weights"] * (p.n_live * self.n_shards
                                     / total_live)
                for p, b in zip(self.shards, subs)])
        else:
            w = self._compose([
                b["loss_weights"] * (p.n * self.n_shards / self.n)
                for p, b in zip(self.shards, subs)])
        if self.cfg.normalize_weights:
            w = w / jnp.maximum(jnp.mean(w), 1e-30)
        batch["loss_weights"] = w.astype(jnp.float32)
        batch["shard_ids"] = self._compose([
            jnp.full((m_s,), s, jnp.int32) for s in self.owned])
        return batch


def mean_pool_feature_fn(cfg):
    """Params-aware feature hook: mean-pooled final hidden state
    (the paper's BERT pooled-representation recipe) — pass the result as
    ``feature_fn`` with ``params=`` so the trainer keeps it fresh."""
    from repro.models.lm import pooled_features

    def fn(params, tokens: jax.Array) -> jax.Array:
        return pooled_features(params, cfg, {"tokens": tokens})
    return jax.jit(fn)


def lm_head_query_fn():
    """Params-aware query hook from the output layer (paper: classifier
    weights as queries): the mean lm_head column approximates the
    direction in feature space along which next-token loss is largest."""
    from repro.models.lm import lm_head_query
    return lm_head_query
