"""Degradation ladder for the LGD pipeline: a small health-state machine.

The paper's wall-clock argument only holds if the adaptive machinery
never stalls a long run.  Related weighted-sampling work (Needell &
Ward's batched weighted SGD; online learning-to-sample) gives the safe
landing zone: UNIFORM sampling with weight 1 is always an unbiased
gradient estimator — strictly worse variance than a healthy LSH index,
but never wrong.  The ladder therefore degrades through states that
trade variance for survival, and climbs back when the index heals:

    healthy ──refresh failure──────────────▶ stale-index
    stale-index ──refresh success──────────▶ healthy        (recovered)
    stale-index ──staleness bound hit──────▶ uniform-fallback
    healthy/stale ──fallback-rate spike────▶ uniform-fallback
    healthy/stale ──non-finite loss streak─▶ uniform-fallback
    uniform-fallback ──rebuild succeeds────▶ healthy        (recovered)

STALE-INDEX: the periodic refresh failed (after retries), so draws keep
coming from the last good (features, index) buffer.  Still unbiased —
Algorithm 1's probabilities are exact w.r.t. the INDEXED vectors; the
staleness only costs sampling adaptivity (the index lags the model by
more than one refresh period).  A bounded staleness counter caps how
long this is tolerated.

UNIFORM-FALLBACK: the index is unusable (staleness bound exceeded, the
fallback rate spiked — an index that mostly misses is pure overhead —
or losses went non-finite).  The pipeline emits uniform batches with
weight 1: unbiased by construction, zero dependence on the LSH state.
Every ``recover_after`` steps the pipeline attempts a full canonical
index rebuild; on success the ladder returns to healthy.

``transitions`` records every edge as ``(step, from, to, reason)`` —
surfaced into the trainer's ``metrics_history`` so a production run's
degradation/recovery story is auditable after the fact.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

HEALTHY = "healthy"
STALE_INDEX = "stale-index"
UNIFORM_FALLBACK = "uniform-fallback"

# cluster-level ladder (multi-host deployments; see
# repro.dist.multihost and docs/ARCHITECTURE.md "Multi-host
# deployment & failure model")
CLUSTER_HEALTHY = "healthy"
CLUSTER_DEGRADED = "missing-host-degraded"
CLUSTER_REFORMED = "reformed"


@dataclasses.dataclass
class HealthConfig:
    """Thresholds driving the degradation ladder."""

    # consecutive FAILED refreshes tolerated in stale-index mode before
    # degrading to uniform-fallback (the bounded-staleness contract: the
    # index is never more than (1 + max_stale_refreshes) refresh
    # periods behind the model).
    max_stale_refreshes: int = 3
    # a batch whose uniform-fallback rate exceeds this counts as a
    # strike (the index resolved almost nothing); ``fallback_strikes``
    # consecutive strikes degrade to uniform-fallback.
    fallback_spike: float = 0.9
    fallback_strikes: int = 3
    # consecutive non-finite losses reported by the trainer before the
    # pipeline stops trusting its weighted batches.
    nonfinite_strikes: int = 3
    # steps between index-rebuild attempts while in uniform-fallback.
    recover_after: int = 25


class HealthMonitor:
    """Tracks one pipeline's position on the degradation ladder.

    Pure bookkeeping — the PIPELINE owns the behaviour (which buffer to
    draw from, when to attempt a rebuild); this object decides only the
    state, so the transition logic is testable without JAX anywhere.
    """

    def __init__(self, cfg: HealthConfig = HealthConfig()):
        self.cfg = cfg
        self.state = HEALTHY
        self.stale_refreshes = 0       # consecutive failed refreshes
        self.refresh_failures = 0      # lifetime failed refresh attempts
        self.recoveries = 0            # lifetime degraded -> healthy edges
        self._fallback_strikes = 0
        self._nonfinite_strikes = 0
        self._entered_fallback_step = 0
        self.transitions: List[Tuple[int, str, str, str]] = []

    # -- transitions ---------------------------------------------------------

    def _move(self, step: int, to: str, reason: str):
        if to == self.state:
            return
        self.transitions.append((step, self.state, to, reason))
        if to == HEALTHY and self.state != HEALTHY:
            self.recoveries += 1
        self.state = to
        if to == UNIFORM_FALLBACK:
            self._entered_fallback_step = step
        if to == HEALTHY:
            self.stale_refreshes = 0
            self._fallback_strikes = 0
            self._nonfinite_strikes = 0

    # -- signals -------------------------------------------------------------

    def note_refresh_success(self, step: int):
        self.stale_refreshes = 0
        if self.state == STALE_INDEX:
            self._move(step, HEALTHY, "refresh recovered")

    def note_refresh_failure(self, step: int, reason: str = ""):
        """A refresh failed AFTER retries were exhausted."""
        self.refresh_failures += 1
        if self.state == UNIFORM_FALLBACK:
            return
        self.stale_refreshes += 1
        if self.stale_refreshes > self.cfg.max_stale_refreshes:
            self._move(step, UNIFORM_FALLBACK,
                       f"staleness bound exceeded "
                       f"({self.stale_refreshes} failed refreshes)")
        else:
            self._move(step, STALE_INDEX,
                       f"refresh failed: {reason}" if reason
                       else "refresh failed")

    def note_fallback_rate(self, step: int, rate: float):
        """Feed a recent batch's uniform-fallback fraction (sampler_stats
        path) — an index that mostly misses is pure overhead."""
        if self.state == UNIFORM_FALLBACK:
            return
        if rate >= self.cfg.fallback_spike:
            self._fallback_strikes += 1
            if self._fallback_strikes >= self.cfg.fallback_strikes:
                self._move(step, UNIFORM_FALLBACK,
                           f"fallback-rate spike ({rate:.2f} for "
                           f"{self._fallback_strikes} checks)")
        else:
            self._fallback_strikes = 0

    def note_loss(self, step: int, finite: bool):
        """Feed the trainer's per-step loss finiteness."""
        if not finite:
            self._nonfinite_strikes += 1
            if self.state != UNIFORM_FALLBACK and \
                    self._nonfinite_strikes >= self.cfg.nonfinite_strikes:
                self._move(step, UNIFORM_FALLBACK,
                           f"non-finite loss streak "
                           f"({self._nonfinite_strikes})")
        else:
            self._nonfinite_strikes = 0

    def note_recovered(self, step: int, reason: str = "index rebuilt"):
        self._move(step, HEALTHY, reason)

    # -- queries -------------------------------------------------------------

    @property
    def degraded(self) -> bool:
        return self.state != HEALTHY

    def should_attempt_recovery(self, step: int) -> bool:
        """In uniform-fallback, rebuild every ``recover_after`` steps."""
        if self.state != UNIFORM_FALLBACK:
            return False
        waited = step - self._entered_fallback_step
        return waited > 0 and waited % max(self.cfg.recover_after, 1) == 0

    def summary(self) -> dict:
        return {
            "state": self.state,
            "stale_refreshes": self.stale_refreshes,
            "refresh_failures": self.refresh_failures,
            "recoveries": self.recoveries,
            "transitions": list(self.transitions),
        }


class ClusterHealthMonitor:
    """Cluster-level extension of the ladder for multi-host LGD.

    One level above ``HealthMonitor``: where the per-pipeline ladder
    tracks a single index's refresh health, this tracks the MEMBERSHIP
    of the training cluster itself:

        healthy ──host loss detected─────────▶ missing-host-degraded
        missing-host-degraded ──reform done──▶ reformed
        reformed ──host loss detected────────▶ missing-host-degraded

    MISSING-HOST-DEGRADED: a peer stopped heartbeating (or never
    cleared its collective barrier within the bounded retries).  The
    survivors keep training: each adopts the lost host's corpus shard
    (``ShardedLSHPipeline.adopt_shards``), and because the shard bounds
    and shard count are unchanged the composed w = S/(p·N) weights stay
    exactly unbiased mid-incident — only wall-clock per step and
    mid-incident bit-determinism are sacrificed.

    REFORMED: the survivors restored the newest verified checkpoint and
    rebuilt the pipeline with the surviving shard count
    (``rebuild_sharded_pipeline``) — a fully deterministic state again,
    bit-identical to a fresh restore on the same mesh.  Operationally
    equivalent to healthy, kept distinct so an audit of ``transitions``
    shows the membership history at a glance.

    Like ``HealthMonitor`` this is pure bookkeeping: the CLUSTER
    (``repro.dist.multihost.ElasticCluster``) owns detection and the
    reform sequence; this object only decides the state, so the ladder
    is testable without processes or JAX anywhere.  ``transitions``
    records state edges as ``(step, from, to, reason)``; ``events``
    records non-edge incidents (shard adoptions, membership changes).
    """

    def __init__(self):
        self.state = CLUSTER_HEALTHY
        self.lost_hosts: List[int] = []    # lifetime lost ranks
        self.reforms = 0                   # lifetime completed reforms
        self.transitions: List[Tuple[int, str, str, str]] = []
        self.events: List[Tuple[int, str, str]] = []

    def _move(self, step: int, to: str, reason: str):
        if to == self.state:
            return
        self.transitions.append((step, self.state, to, reason))
        self.state = to

    # -- signals -------------------------------------------------------------

    def note_host_lost(self, step: int, ranks, reason: str = ""):
        ranks = sorted(int(r) for r in ranks)
        self.lost_hosts.extend(ranks)
        detail = f"lost host(s) {ranks}" + (f": {reason}" if reason else "")
        self.events.append((step, "host-lost", detail))
        self._move(step, CLUSTER_DEGRADED, detail)

    def note_adopted(self, step: int, shard: int, by_rank: int):
        """A surviving rank took over a lost host's corpus shard (the
        mid-incident unbiasedness move — not a state edge)."""
        self.events.append(
            (step, "shard-adopted",
             f"shard {shard} adopted by rank {by_rank}"))

    def note_reformed(self, step: int, n_shards: int):
        self.reforms += 1
        self._move(step, CLUSTER_REFORMED,
                   f"reformed on {n_shards} shard(s) from verified "
                   f"checkpoint at step {step}")

    # -- queries -------------------------------------------------------------

    @property
    def degraded(self) -> bool:
        return self.state == CLUSTER_DEGRADED

    def summary(self) -> dict:
        return {
            "state": self.state,
            "lost_hosts": list(self.lost_hosts),
            "reforms": self.reforms,
            "transitions": list(self.transitions),
            "events": list(self.events),
        }
