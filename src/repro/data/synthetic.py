"""Synthetic datasets (offline container: no downloads).

Regression generators mimic the statistical shape of the paper's three
datasets (power-law targets, cluster structure, high-dimensional sparse
features); the LM stream generates token sequences with a power-law
unigram distribution and per-example "difficulty" so LGD's adaptive
sampling has signal to exploit.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RegressionDataset:
    name: str
    x_train: jax.Array
    y_train: jax.Array
    x_test: jax.Array
    y_test: jax.Array


def make_regression(
    key: jax.Array,
    name: str = "yearmsd-like",
    n_train: int = 40_000,
    n_test: int = 5_000,
    d: int = 90,
    noise: str = "pareto",       # pareto | gauss | clustered
) -> RegressionDataset:
    n = n_train + n_test
    kx, kt, kn, ks, kc = jax.random.split(key, 5)
    if noise == "clustered":
        centers = jax.random.normal(kc, (16, d)) * 2.0
        assign = jax.random.randint(ks, (n,), 0, 16)
        x = centers[assign] + 0.5 * jax.random.normal(kx, (n, d))
    else:
        x = jax.random.normal(kx, (n, d))
    theta = jax.random.normal(kt, (d,))
    y = x @ theta
    if noise == "pareto":
        # alpha=1.2: heavy power-law residuals (YearMSD-like skew) — the
        # regime Lemma 1 targets
        eps = jax.random.pareto(kn, 1.2, (n,)) * \
            jax.random.rademacher(ks, (n,)).astype(jnp.float32)
        y = y + eps
    elif noise == "gauss":
        y = y + 0.5 * jax.random.normal(kn, (n,))
    else:
        hard = (assign >= 13).astype(jnp.float32)
        y = y + hard * 8.0 * jnp.sign(jax.random.normal(kn, (n,)))
    return RegressionDataset(
        name, x[:n_train], y[:n_train], x[n_train:], y[n_train:])


def make_classification(
    key: jax.Array, n_train: int = 20_000, n_test: int = 2_000, d: int = 64,
) -> RegressionDataset:
    n = n_train + n_test
    kx, kt = jax.random.split(key)
    x = jax.random.normal(kx, (n, d))
    theta = jax.random.normal(kt, (d,))
    y = jnp.sign(x @ theta + 0.1)
    return RegressionDataset(
        "synthetic-logistic", x[:n_train], y[:n_train], x[n_train:],
        y[n_train:])


# ---------------------------------------------------------------------------
# LM token corpus
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenCorpus:
    """Fixed corpus of examples (n_examples, seq_len+1) with difficulty
    structure: a minority of 'hard' examples drawn from a shifted unigram
    distribution (their loss stays high longer -> larger gradients)."""

    tokens: np.ndarray       # (N, S+1) int32
    hard_mask: np.ndarray    # (N,) bool — ground truth for diagnostics


def make_token_corpus(
    seed: int, n_examples: int, seq_len: int, vocab: int,
    hard_frac: float = 0.1,
) -> TokenCorpus:
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    easy = rng.choice(vocab, size=(n_examples, seq_len + 1), p=probs)
    # hard examples: SAME zipf structure over a permuted vocabulary —
    # fully learnable, but rare, so they stay underfit for longer and
    # carry larger gradients (the signal adaptive sampling exploits).
    perm = rng.permutation(vocab)
    hard = perm[rng.choice(vocab, size=(n_examples, seq_len + 1), p=probs)]
    mask = rng.random(n_examples) < hard_frac
    tokens = np.where(mask[:, None], hard, easy).astype(np.int32)
    return TokenCorpus(tokens, mask)


def uniform_batches(
    corpus: TokenCorpus, batch: int, seed: int = 0,
) -> Iterator[Dict[str, jax.Array]]:
    rng = np.random.default_rng(seed)
    n = corpus.tokens.shape[0]
    while True:
        idx = rng.integers(0, n, size=batch)
        chunk = corpus.tokens[idx]
        yield {
            "tokens": jnp.asarray(chunk[:, :-1]),
            "targets": jnp.asarray(chunk[:, 1:]),
            "example_ids": jnp.asarray(idx, jnp.int32),
        }
