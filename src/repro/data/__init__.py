from .synthetic import (  # noqa: F401
    RegressionDataset,
    TokenCorpus,
    make_classification,
    make_regression,
    make_token_corpus,
    uniform_batches,
)
from .health import (  # noqa: F401
    CLUSTER_DEGRADED,
    CLUSTER_HEALTHY,
    CLUSTER_REFORMED,
    HEALTHY,
    STALE_INDEX,
    UNIFORM_FALLBACK,
    ClusterHealthMonitor,
    HealthConfig,
    HealthMonitor,
)
from .lsh_pipeline import (  # noqa: F401
    LSHPipelineConfig,
    LSHSampledPipeline,
    ShardedLSHPipeline,
    lm_head_query_fn,
    mean_pool_feature_fn,
)
