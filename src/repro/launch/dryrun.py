"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step, in_shardings=..., out_shardings=...).lower(*specs)
.compile()`` must succeed on the production meshes (16x16 single-pod,
2x16x16 multi-pod) for every assigned architecture and input shape.
The compiled artifact yields the roofline inputs:
  - compiled.cost_analysis()   -> HLO FLOPs / bytes accessed
  - compiled.memory_analysis() -> bytes per device (fits / doesn't)
  - compiled.as_text()         -> post-SPMD HLO, parsed for collective bytes

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi4_mini_3_8b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out benchmarks/dryrun
"""

# The host has ONE real CPU device; the dry-run builds the production mesh
# from 512 host-platform placeholder devices.  MUST run before any other
# import that could initialise jax.
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
from functools import partial  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                      # noqa: E402
from repro.configs.shapes import (             # noqa: E402
    SHAPES,
    apply_vocab,
    batch_specs,
    cache_specs,
    shape_applicable,
)
from repro.dist.sharding import (              # noqa: E402
    batch_sharding,
    tree_param_shardings,
    use_mesh,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import ModelConfig, decode_step, init_params, loss  # noqa: E402
from repro.optim import Adafactor, Adam, apply_updates  # noqa: E402

# Architectures whose optimiser state must be factored to fit HBM
# (params >= 100B): Adafactor; the rest use Adam (m+v fp32).
GIANT_ARCHS = {"qwen3_moe_235b_a22b", "llama4_maverick_400b_a17b",
               "llama_3_2_vision_90b"}


def pick_optimizer(arch: str):
    if configs._canon(arch) in GIANT_ARCHS:
        return Adafactor(lr=1e-2)
    return Adam(lr=3e-4)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer):
    def train_step(params, opt_state, batch):
        l, grads = jax.value_and_grad(
            lambda p: loss(p, cfg, batch))(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, l
    return train_step


def make_prefill_step(cfg: ModelConfig):
    from repro.models import prefill

    def prefill_step(params, batch, cache):
        h, cache = prefill(params, cfg, batch, cache)
        return h, cache
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, batch, cache):
        logits, cache = decode_step(params, cfg, batch, cache)
        return logits, cache
    return serve_step


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _data_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh, axes):
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def batch_shardings(specs, mesh):
    """Shard batch dim 0 over the data axes (replicate if not divisible)."""
    data_ax = _data_axes(mesh)
    dn = _axis_size(mesh, data_ax)

    def one(s):
        if s.shape and s.shape[0] % dn == 0:
            return NamedSharding(mesh, P(data_ax))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, specs)


def cache_shardings(specs, cfg: ModelConfig, mesh):
    """(repeats, batch, ...) caches: batch on data axes; attention K/V
    caches are SEQUENCE-sharded over the model axis (the long-context
    decode sharding: each model shard owns a contiguous KV slice and
    GSPMD turns the softmax reductions into all-reduces), SSM states are
    head-sharded."""
    data_ax = _data_axes(mesh)
    dn = _axis_size(mesh, data_ax)
    mn = mesh.shape["model"]

    def one(s):
        spec = [None] * len(s.shape)
        if len(s.shape) >= 2 and s.shape[1] % dn == 0:
            spec[1] = data_ax
        if len(s.shape) == 5:
            if s.shape[3] in (cfg.n_kv_heads, cfg.n_heads):
                # attn K/V (R, B, S, Hkv, D): shard the big S dim
                if s.shape[2] % mn == 0:
                    spec[2] = "model"
                elif s.shape[3] % mn == 0:
                    spec[3] = "model"
            else:
                # ssm state (R, B, H, N, P): shard heads
                if s.shape[2] % mn == 0:
                    spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs)


# ---------------------------------------------------------------------------
# collective-bytes parser (post-SPMD optimized HLO)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective type (one entry per op)."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        rhs = rhs.strip()
        m = re.match(r"(?:\([^)]*\)|\S+)\s+([\w-]+)\(", rhs)
        # result type is at the start of rhs, opcode follows
        for c in _COLLECTIVES:
            # count the op once: base form or async -start (skip -done)
            opcodes = (f" {c}(", f" {c}-start(")
            head = rhs.split("(", 1)[0]
            if head.endswith(c) or head.endswith(c + "-start"):
                out[c] += _shape_bytes(rhs.split(c)[0])
                out["count"] += 1
                break
    return out


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             cfg_override=None, verbose: bool = True) -> dict:
    cfg = cfg_override or configs.get(arch)
    shape = SHAPES[shape_name]
    skip = shape_applicable(cfg, shape)
    if skip is not None:
        return {"arch": arch, "shape": shape_name, "skipped": skip}
    # vocab_large pins a production vocab on this abstract-eval path
    cfg = apply_vocab(cfg, shape)

    mesh = make_production_mesh(multi_pod=multi_pod)
    optimizer = pick_optimizer(arch)
    t0 = time.time()

    with use_mesh(mesh):
        param_shapes = jax.eval_shape(partial(init_params, cfg=cfg),
                                      jax.random.PRNGKey(0))
        p_shard = tree_param_shardings(param_shapes, mesh)
        b_specs = batch_specs(cfg, shape)
        b_shard = batch_shardings(b_specs, mesh)

        if shape.kind == "train":
            opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
            o_shard = tree_param_shardings(opt_shapes, mesh)
            step = make_train_step(cfg, optimizer)
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard,
                               NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            ).lower(param_shapes, opt_shapes, b_specs)
        else:
            c_specs = cache_specs(cfg, shape)
            c_shard = cache_shardings(c_specs, cfg, mesh)
            if shape.kind == "prefill":
                step = make_prefill_step(cfg)
                h_spec = NamedSharding(mesh, P(_data_axes(mesh)))
                lowered = jax.jit(
                    step,
                    in_shardings=(p_shard, b_shard, c_shard),
                    out_shardings=(h_spec, c_shard),
                    donate_argnums=(2,),
                ).lower(param_shapes, b_specs, c_specs)
            else:
                step = make_serve_step(cfg)
                lg_spec = NamedSharding(mesh, P())
                lowered = jax.jit(
                    step,
                    in_shardings=(p_shard, b_shard, c_shard),
                    out_shardings=(lg_spec, c_shard),
                    donate_argnums=(2,),
                ).lower(param_shapes, b_specs, c_specs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # older jax: list of per-device dicts
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "bytes_per_device_argument": getattr(
                mem, "argument_size_in_bytes", None),
            "bytes_per_device_output": getattr(
                mem, "output_size_in_bytes", None),
            "bytes_per_device_temp": getattr(
                mem, "temp_size_in_bytes", None),
            "bytes_per_device_peak": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}

    # loop-aware per-device accounting (cost_analysis counts while bodies
    # once; see launch/hlo_analysis.py)
    from repro.launch.hlo_analysis import analyze
    hlo = analyze(compiled.as_text())
    n_params = sum(
        int(jnp.prod(jnp.array(x.shape)))
        for x in jax.tree.leaves(param_shapes))

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "n_params": n_params,
        "flops_per_device": hlo.flops,
        "bytes_per_device": hlo.bytes,
        "collectives": dict(hlo.collectives),
        "top_dots": sorted(hlo.dot_flops_by_meta.items(),
                           key=lambda kv: -kv[1])[:8],
        "top_collectives": sorted(hlo.coll_bytes_by_meta.items(),
                                  key=lambda kv: -kv[1])[:8],
        "xla_cost_analysis": {"flops": cost.get("flops"),
                              "bytes": cost.get("bytes accessed")},
        "memory": mem_d,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    if verbose:
        print(json.dumps(result, indent=None, default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in configs.all_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        try:
            results.append(run_cell(arch, shape, multi_pod=args.multi_pod))
        except Exception as e:
            results.append({"arch": arch, "shape": shape,
                            "error": repr(e)})
            print(f"FAILED {arch} x {shape}: {e!r}")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, default=str)
        print(f"wrote {args.out}")

    failed = [r for r in results if "error" in r]
    print(f"\n{len(results) - len(failed)}/{len(results)} cells OK")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
