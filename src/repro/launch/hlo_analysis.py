"""Loop-aware roofline accounting from post-SPMD optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-counts layer-scanned models by ~n_layers x.  This analyzer parses
the optimized HLO, walks the call graph (fusions, while bodies) and
multiplies by XLA's ``known_trip_count`` annotations, yielding:

  flops             dot/conv FLOPs, remat recompute included
  bytes             operand+result bytes of top-level ops (HBM-traffic
                    proxy, the same convention XLA's own heuristic uses)
  collectives       result bytes per collective opcode, trip-adjusted
  dot_flops_by_name top offenders for perf iteration

All quantities are PER DEVICE (the HLO is the per-device SPMD program).
"""

from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1,
    "u4": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "iota",
                   # pure data-movement / layout ops: the TPU compiler
                   # fuses these into producers/consumers, so charging
                   # their bytes would double-count HBM traffic that the
                   # XLA:CPU backend (which fuses far less) leaves
                   # exposed.  Documented in EXPERIMENTS.md §Roofline.
                   "copy", "transpose", "reshape", "broadcast", "slice",
                   "convert", "select", "compare", "reverse", "pad",
                   "concatenate"}


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    attrs: str

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.result_type)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # %ref -> type


_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        ls = line.strip()
        if not ls:
            continue
        hdr = _COMP_HDR.match(ls)
        if hdr and " = " not in ls.split("{")[0]:
            current = Computation(hdr.group(1))
            comps[current.name] = current
            if ls.startswith("ENTRY"):
                entry = current.name
            continue
        if ls.startswith("}"):
            continue
        m = _OP_LINE.match(line)
        if m and current is not None:
            name, rtype, opcode, rest = m.groups()
            # split operands (refs like %x or literals) from attrs
            depth, i = 1, 0
            while i < len(rest) and depth > 0:
                if rest[i] == "(":
                    depth += 1
                elif rest[i] == ")":
                    depth -= 1
                i += 1
            operand_str = rest[: i - 1]
            attrs = rest[i:]
            operands = re.findall(r"%([\w.\-]+)", operand_str)
            op = Op(name, opcode, rtype.strip(), operands, attrs)
            current.ops.append(op)
            current.types[name] = rtype.strip()
    return comps, entry


def _dot_flops(op: Op, comp: Computation,
               global_types: Dict[str, str]) -> int:
    res = _shape_dims(op.result_type)
    if not res:
        return 0
    n_res = 1
    for d in res[0][1]:
        n_res *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    contract = 1
    if m and op.operands:
        lhs_type = comp.types.get(op.operands[0]) or global_types.get(
            op.operands[0], "")
        lhs = _shape_dims(lhs_type)
        if lhs:
            dims = lhs[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2 * n_res * contract


@dataclass
class Analysis:
    flops: int = 0
    bytes: int = 0
    collectives: Dict[str, int] = field(
        default_factory=lambda: defaultdict(int))
    dot_flops_by_meta: Dict[str, int] = field(
        default_factory=lambda: defaultdict(int))
    coll_bytes_by_meta: Dict[str, int] = field(
        default_factory=lambda: defaultdict(int))
    bytes_by_meta: Dict[str, int] = field(
        default_factory=lambda: defaultdict(int))

    def as_dict(self) -> dict:
        top = sorted(self.dot_flops_by_meta.items(), key=lambda kv: -kv[1])
        topc = sorted(self.coll_bytes_by_meta.items(), key=lambda kv: -kv[1])
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collectives": dict(self.collectives),
            "top_dots": top[:12],
            "top_collectives": topc[:12],
            "top_bytes": sorted(self.bytes_by_meta.items(),
                                key=lambda kv: -kv[1])[:12],
        }


def _trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(op.attrs)
    if m:
        return int(m.group(1))
    # fall back: constant in the condition computation
    mc = re.search(r"condition=%?([\w.\-]+)", op.attrs)
    if mc and mc.group(1) in comps:
        for cop in comps[mc.group(1)].ops:
            mm = re.match(r"constant\((\d+)\)",
                          cop.opcode + "(" + ",".join(cop.operands) + ")")
            if cop.opcode == "constant":
                mm = re.search(r"constant\((\d+)\)", cop.result_type + cop.attrs)
        # conservative: assume 1 if unparseable
    return 1


def _called(op: Op) -> List[str]:
    out = []
    for key in ("calls", "body", "to_apply", "branch_computations"):
        m = re.search(rf"{key}=\{{?%?([\w.\-]+(?:, ?%[\w.\-]+)*)\}}?",
                      op.attrs)
        if m:
            out.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
    return out


def analyze(text: str) -> Analysis:
    comps, entry = parse_hlo(text)
    global_types: Dict[str, str] = {}
    for c in comps.values():
        global_types.update(c.types)
    res = Analysis()

    def meta_name(op: Op) -> str:
        m = re.search(r'op_name="([^"]+)"', op.attrs)
        return m.group(1) if m else op.name

    def walk(comp_name: str, mult: int, count_bytes: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                trip = _trip_count(op, comps)
                body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                if body:
                    walk(body.group(1), mult * trip, count_bytes)
                continue
            if oc in ("fusion", "call", "conditional", "custom-call",
                      "async-start"):
                for sub in _called(op):
                    walk(sub, mult, False)   # flops only inside fusions
                if count_bytes and oc != "async-start":
                    b = op.result_bytes + sum(
                        _shape_bytes(comp.types.get(o)
                                     or global_types.get(o, ""))
                        for o in op.operands)
                    res.bytes += b * mult
                    res.bytes_by_meta[meta_name(op)] += b * mult
                continue
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in _COLLECTIVES:
                res.collectives[base] += op.result_bytes * mult
                res.coll_bytes_by_meta[
                    f"{base}:{meta_name(op)}"] += op.result_bytes * mult
                continue
            if oc in ("dot", "convolution"):
                f = _dot_flops(op, comp, global_types)
                res.flops += f * mult
                res.dot_flops_by_meta[meta_name(op)] += f * mult
            if count_bytes and oc not in _SKIP_BYTES_OPS \
                    and not oc.endswith("-done"):
                if oc == "dynamic-update-slice":
                    # in-place on TPU: traffic = the updated slice
                    # (read-modify-write), not the whole buffer.
                    upd = (comp.types.get(op.operands[1])
                           or global_types.get(op.operands[1], "")
                           ) if len(op.operands) > 1 else ""
                    b = 2 * _shape_bytes(upd)
                elif oc in ("dynamic-slice", "gather"):
                    # reads only the addressed rows ~= result bytes
                    b = 2 * op.result_bytes
                elif oc == "scatter":
                    # writes only the update rows (operand 2) + result alias
                    upd = (comp.types.get(op.operands[2])
                           or global_types.get(op.operands[2], "")
                           ) if len(op.operands) > 2 else ""
                    b = 3 * _shape_bytes(upd)
                else:
                    b = op.result_bytes + sum(
                        _shape_bytes(comp.types.get(o)
                                     or global_types.get(o, ""))
                        for o in op.operands)
                res.bytes += b * mult
                res.bytes_by_meta[meta_name(op)] += b * mult

    if entry:
        walk(entry, 1, True)
    return res


if __name__ == "__main__":
    import sys
    print(json.dumps(analyze(open(sys.argv[1]).read()).as_dict(), indent=2))
