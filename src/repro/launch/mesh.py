"""Production mesh construction.

Target: TPU v5e pods — 256 chips/pod arranged (data=16, model=16);
multi-pod adds a leading 'pod' axis (2 pods = 512 chips for the dry-run,
the same code scales the pod axis to O(1000)-node fleets: the pod axis
only ever carries data-parallel all-reduces, which scale O(bytes) per
chip regardless of pod count).

Defined as a function so importing this module never touches jax device
state (jax locks the device count on first backend init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-host mesh (all local devices on the data axis) for examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
