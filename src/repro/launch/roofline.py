"""Roofline analysis from dry-run artifacts (TPU v5e targets).

Per (arch x shape x mesh) cell, derive the three roofline terms:

  compute    = HLO_FLOPs_per_device / peak_FLOPs          [s]
  memory     = HLO_bytes_per_device / HBM_bw              [s]
  collective = wire_bytes_per_device / ICI_bw             [s]

Hardware constants (v5e): 197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link
ICI (we budget a single link — conservative).

Wire-byte model per collective op (result bytes R, ring algorithms):
  all-gather           R * (n-1)/n   ~ R
  reduce-scatter       R * (n-1)     (input is n*R)     ~ n*R — but the
                                     parsed result IS the shard, so we
                                     charge R (the per-hop traffic) * 2
  all-reduce           2R * (n-1)/n  ~ 2R
  all-to-all           R * (n-1)/n   ~ R
  collective-permute   R
Group sizes are not recovered from the HLO here, so the asymptotic
(n-1)/n ~ 1 approximation is used; this slightly over-charges small
groups (documented in EXPERIMENTS.md).

MODEL_FLOPS uses the classic 6*N*D (train) / 2*N*D (inference) with N =
ACTIVE parameters (MoE: top_k experts only); the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat recompute, causal-mask waste and
sharding replication.
"""

from __future__ import annotations

import argparse
import json
from typing import Dict

from repro import configs
from repro.configs.shapes import SHAPES, apply_vocab
from repro.models import ModelConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s (one link)

_WIRE_FACTOR = {
    "all-gather": 1.0,
    "reduce-scatter": 2.0,
    "all-reduce": 2.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _block_kinds(cfg: ModelConfig) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for b in cfg.block_pattern:
        out[b] = out.get(b, 0) + 1
    return out


def active_params(cfg: ModelConfig) -> float:
    """Active parameters per token (MoE: routed experts only)."""
    d, dh = cfg.d_model, cfg.d_head
    counts = _block_kinds(cfg)
    per_pattern = 0.0
    for kind, cnt in counts.items():
        blk = 0.0
        if kind in ("attn", "shared_attn", "cross_attn"):
            blk += d * dh * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)  # qkvo
            if kind == "cross_attn":
                blk *= 2
            if cfg.is_moe:
                n_mats = 3
                blk += d * cfg.moe_experts  # router (all tokens)
                blk += cfg.moe_top_k * n_mats * d * cfg.moe_d_ff
            elif cfg.d_ff:
                n_mats = 3 if cfg.act == "swiglu" else 2
                blk += n_mats * d * cfg.d_ff
        elif kind == "mamba2":
            d_inner = cfg.ssm_expand * d
            nh = d_inner // cfg.ssm_head_dim
            blk += d * (2 * d_inner + 2 * cfg.ssm_state + nh)
            blk += d_inner * d
        elif kind == "mlstm":
            blk += d * 3 * d + d * 2 * cfg.n_heads + d * d
        elif kind == "slstm":
            blk += d * 4 * d + d * d
        per_pattern += cnt * blk
    total = per_pattern * cfg.repeats
    total += 2 * cfg.vocab * d          # embed + head
    return total


def model_flops(cfg: ModelConfig, shape, n_devices: int) -> float:
    """Analytic useful FLOPs per device for the cell."""
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
        # attention reads over the KV cache: 2 * 2 * Hkv*Dh * S per layer
        n_attn_layers = sum(
            1 for b in cfg.block_pattern
            if b in ("attn", "shared_attn", "cross_attn")) * cfg.repeats
        total += (4.0 * cfg.n_heads * cfg.d_head * shape.seq_len
                  * n_attn_layers * shape.global_batch)
    return total / n_devices


def roofline_terms(record: dict) -> dict:
    cfg = apply_vocab(configs.get(record["arch"]), SHAPES[record["shape"]])
    shape = SHAPES[record["shape"]]
    n_dev = record["n_devices"]
    compute_t = record["flops_per_device"] / PEAK_FLOPS
    memory_t = record["bytes_per_device"] / HBM_BW
    wire = sum(_WIRE_FACTOR.get(k, 1.0) * v
               for k, v in record["collectives"].items())
    coll_t = wire / ICI_BW
    mf = model_flops(cfg, shape, n_dev)
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    useful_t = mf / PEAK_FLOPS
    bound = max(compute_t, memory_t, coll_t)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_device": mf,
        "useful_flops_ratio": mf / max(record["flops_per_device"], 1),
        # fraction of roofline-achievable throughput this cell realises,
        # assuming perfect overlap: useful work time / max(term)
        "roofline_fraction": useful_t / max(bound, 1e-12),
        "step_time_lower_bound_s": bound,
    }


_ADVICE = {
    ("compute",): "cut replicated/recomputed FLOPs: pad-shard heads, "
                  "drop causal-mask waste (Pallas kernel), looser remat",
    ("memory",): "raise arithmetic intensity: fuse, bigger blocks, bf16 "
                 "intermediates, avoid re-streaming weights",
    ("collective",): "reduce resharding: fold FSDP gathers into the scan, "
                     "overlap collectives with compute, shrink all-reduces",
}


def build_table(records: list) -> str:
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | MODEL/HLO flops | roofline frac | fix |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | SKIPPED | "
                f"— | — | {r['skipped'][:60]}… |")
            continue
        if "error" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | ERROR | — | — | — | — "
                f"| — | {r['error'][:60]} |")
            continue
        t = roofline_terms(r)
        advice = _ADVICE[(t["dominant"],)]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | **{t['dominant']}** "
            f"| {t['useful_flops_ratio']:.2f} "
            f"| {t['roofline_fraction']:.3f} | {advice[:52]}… |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", required=True)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    records = json.load(open(args.inp))
    table = build_table(records)
    enriched = []
    for r in records:
        if "skipped" not in r and "error" not in r:
            r = {**r, "roofline": roofline_terms(r)}
        enriched.append(r)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(enriched, f, indent=2, default=str)
    print(table)


if __name__ == "__main__":
    main()
