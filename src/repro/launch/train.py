"""Production training launcher: --arch <id> on a sharded mesh.

On a TPU fleet this binary runs once per host (jax.distributed picks up
the pod topology); on this CPU container it runs the same code path on
the host mesh with the arch's SMOKE config unless --full is given.

  PYTHONPATH=src python -m repro.launch.train --arch granite_3_8b \
      --steps 50 [--full] [--lgd] [--ckpt /tmp/ck] [--batch 8] [--seq 64]
"""

from __future__ import annotations

import argparse
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.data import (
    LSHPipelineConfig, ShardedLSHPipeline, lm_head_query_fn,
    make_token_corpus, mean_pool_feature_fn, uniform_batches,
)
from repro.dist.sharding import (
    batch_sharding, data_axis_size, tree_param_shardings, use_mesh,
)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import forward, init_params, loss
from repro.optim import Adam, apply_updates, schedules
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--corpus", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="use the FULL production config (TPU fleets)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use make_production_mesh() instead of host mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--lgd", action="store_true",
                    help="enable the LSH-sampled data pipeline")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = (configs.get(args.arch) if args.full
           else configs.get_smoke(args.arch))
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    print(f"arch={cfg.name}  mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    with use_mesh(mesh):
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        shardings = tree_param_shardings(params, mesh)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s), params, shardings)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"params: {n/1e6:.1f}M, sharded over {mesh.size} devices")

        if cfg.frontend == "embed_stub":
            raise SystemExit(
                f"{cfg.name} takes precomputed embeddings; use "
                "examples/serve.py or the dryrun for this arch")
        corpus = make_token_corpus(0, args.corpus, args.seq, cfg.vocab)

        sampler = batches = None
        if args.lgd:
            # shard-by-example: one LSH index per data-parallel group
            # (each queries only its corpus shard), composed into an
            # unbiased global estimator by the DP all-reduce.
            dp = data_axis_size(mesh)
            n_shards = dp if args.batch % dp == 0 else 1
            if n_shards != dp:
                print(f"WARNING: the DP degree {dp} does not divide "
                      f"batch={args.batch}; falling back to ONE global "
                      f"LSH index on host-placed batches (per-shard "
                      f"indexing disabled — every host re-embeds the "
                      f"full corpus on refresh)")
            sampler = ShardedLSHPipeline(
                jax.random.PRNGKey(2), corpus.tokens,
                mean_pool_feature_fn(cfg), lm_head_query_fn(),
                LSHPipelineConfig(minibatch=args.batch,
                                  refresh_async=True),
                n_shards=n_shards, params=params,
                # device placement needs dim 0 divisible by the DP
                # degree; in the fallback it is not, so leave batches
                # host-side and let jit shard on entry.
                mesh=mesh if n_shards == dp else None)
        else:
            batches = uniform_batches(corpus, args.batch, seed=1)

        tr = Trainer(
            cfg, params,
            Adam(lr=schedules.warmup_cosine(args.lr, 10, args.steps)),
            batches,
            TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=50, log_every=10,
                          donate=not args.lgd),
            sampler=sampler)
        tr.run(args.steps)
        tr.finalize()
        for m in tr.metrics_history[-5:]:
            print(m)


if __name__ == "__main__":
    main()
