"""SimHash parameters, projections, packed codes and probe masks.

The paper (Chen, Xu & Shrivastava, NeurIPS 2019) samples training points
with probability monotonic to |<[theta,-1], [x_i,y_i]>| using SimHash
(signed random projections).  WHICH hash family is in play — symmetric
SRP (dense/sparse projections), quadratic SRP over T(v)=vec(v vᵀ), or
the asymmetric Simple-LSH MIPS family — is pluggable: the contract and
registry live in ``core.families``; ``LSHParams.family`` names a
registry entry, and this module draws the matching projection tensor
and packs codes in the shared TPU-native layout.

All families pack ``code_width(K)`` sign bits per table into a single
uint32 code, giving ``codes[n, l]`` — the layout consumed by
``tables.py``.  The closed-form collision probabilities are owned by
the family objects; ``collision_probability`` (SRP cosine law) and
``collision_probability_quadratic`` are re-exported here for
back-compat with the pre-family API.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .families import (  # noqa: F401  (re-exported: pre-family API)
    get_family,
    quadratic_collision_prob as collision_probability_quadratic,
    srp_collision_prob as collision_probability,
)

MAX_K = 32  # sign bits packed per uint32 code


@dataclasses.dataclass(frozen=True)
class LSHParams:
    """Static hyper-parameters of the hash family."""

    k: int = 5          # bits (hash fns) per table    (paper: K=5 linear, 7 BERT)
    l: int = 100        # number of hash tables        (paper: L=100 linear, 10 BERT)
    dim: int = 0        # input dimensionality (of the *augmented* vector)
    family: str = "sparse"  # registry key: core.families.get_family
    sparsity: float = 1.0 / 30.0  # density of sparse projections
    seed: int = 0

    def __post_init__(self):
        fam = get_family(self.family)   # raises on unknown family names
        if not (1 <= fam.code_width(self.k) <= MAX_K):
            raise ValueError(
                f"code width must be in [1,{MAX_K}], got "
                f"{fam.code_width(self.k)} (K={self.k})")
        if self.l < 1:
            raise ValueError(f"L must be >= 1, got {self.l}")


def make_projections(key: jax.Array, params: LSHParams) -> jax.Array:
    """Draw the random projection tensor for the family.

    Returns (by the family's ``proj_kind``)
      dense/sparse:  (dim, L*K) float32
      quadratic:     (L*K, dim, dim) float32  (random M per hash function)

    ``params.dim`` is the dimensionality of the AUGMENTED vectors the
    family actually hashes (asymmetric families: ``aug_dim(d_raw)``).
    """
    fam = get_family(params.family)
    proj_kind = fam.proj_kind
    d, lk = params.dim, params.l * params.k
    if proj_kind == "dense":
        # mask_projections: identity for flat families; the banded MIPS
        # family zeroes the band coordinate's row so hashing sees only
        # the Simple-LSH geometry (core.families.base).
        return fam.mask_projections(
            jax.random.normal(key, (d, lk), dtype=jnp.float32))
    if proj_kind == "sparse":
        kv, ks = jax.random.split(key)
        signs = jax.random.rademacher(kv, (d, lk), dtype=jnp.float32)
        mask = jax.random.bernoulli(ks, params.sparsity, (d, lk))
        # Li et al. very-sparse projections: scale keeps inner products unbiased.
        return signs * mask / jnp.sqrt(params.sparsity)
    # quadratic: M_h ~ dense iid Gaussian (d, d); hash = sign(v^T M v), which
    # is exactly SRP on T(v)=vec(v v^T).  Sparse M would bias the analytic
    # collision probability (T(v) is highly structured), so the exact
    # importance weights 1/(p_i N) demand dense projections here.
    return jax.random.normal(key, (lk, d, d), dtype=jnp.float32)


def _pack_bits(bits: jax.Array, k: int) -> jax.Array:
    """bits: (..., L, K) bool -> (..., L) uint32 packed codes."""
    weights = (jnp.uint32(1) << jnp.arange(k, dtype=jnp.uint32))
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1, dtype=jnp.uint32)


@partial(jax.jit, static_argnames=("k", "l", "quadratic"))
def compute_codes(
    x: jax.Array,
    projections: jax.Array,
    *,
    k: int,
    l: int,
    quadratic: bool = False,
) -> jax.Array:
    """Hash a batch of vectors into packed per-table codes.

    x: (n, d) or (d,).  Returns (n, L) or (L,) uint32.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    if quadratic:
        # proj[h] = x^T M_h x  — implicit SRP over T(x)=vec(x x^T).
        proj = jnp.einsum("nd,hde,ne->nh", x, projections, x)
    else:
        proj = x @ projections  # (n, L*K)
    bits = (proj >= 0).reshape(x.shape[0], l, k)
    codes = _pack_bits(bits, k)
    return codes[0] if squeeze else codes


def probe_masks(k: int, n_codes: int) -> tuple:
    """Deterministic Hamming-ball probe sequence for multi-probe querying.

    Returns a tuple of ``n_codes`` XOR masks over the packed K-bit code,
    walked in order when a probed bucket is empty: the exact bucket
    (mask 0) first, then all flip-1 masks (ascending bit index), then
    all flip-2 masks (lexicographic bit pairs).  ``n_codes`` is clamped
    to the Hamming-ball-of-radius-2 size ``1 + K + K(K-1)/2``.

    Args:
      k: bits per table code (``LSHParams.k``).
      n_codes: total probe codes per table INCLUDING the exact bucket
        (``1 + multiprobe`` in sampler terms).

    Returns:
      Tuple of Python ints (static — safe as a jit-static argument).

    Determinism: the sequence is a pure function of ``k`` and
    ``n_codes``; the corrected sampling probability depends on the
    probed masks only through their popcounts, so any truncation of
    this sequence still yields exact probabilities (see
    ``core.sampler``).
    """
    if n_codes < 1:
        raise ValueError(f"n_codes must be >= 1, got {n_codes}")
    masks = [0]
    masks.extend(1 << i for i in range(k))
    masks.extend(
        (1 << i) | (1 << j) for i in range(k) for j in range(i + 1, k))
    return tuple(masks[:n_codes])


def augment_regression(x: jax.Array, y: jax.Array) -> jax.Array:
    """[x_i, y_i] augmentation for least squares (Eq. 4), L2-normalised rows."""
    v = jnp.concatenate([x, y[..., None]], axis=-1)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-30)


def regression_query(theta: jax.Array) -> jax.Array:
    """Query vector [theta, -1] for least squares."""
    return jnp.concatenate([theta, -jnp.ones(theta.shape[:-1] + (1,), theta.dtype)], -1)


def augment_logistic(x: jax.Array, y: jax.Array) -> jax.Array:
    """y_i * x_i augmentation for logistic regression (Sec. 2.3), normalised."""
    v = x * y[..., None]
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-30)


def logistic_query(theta: jax.Array) -> jax.Array:
    """Query -theta for logistic regression (Eq. 20)."""
    return -theta
