"""LGD (Algorithm 2): end-to-end LSH-sampled gradient descent for linear models.

Reproduces the paper's training setup:
  * least-squares regression   — hash [x_i, y_i], query [theta, -1]
  * logistic regression        — hash y_i * x_i, query -theta
  * any first-order optimizer  — LGD only replaces the *gradient estimator*,
    so SGD / AdaGrad / Adam from ``repro.optim`` plug in unchanged
    ("LGD is not an alternative but a complement", Sec. 2.2).

Each workload (kind) is a THIN FAMILY INSTANTIATION: the kind supplies
the base vector [x_i, y_i] / y_i*x_i, the base query [theta,-1] / -theta
and the per-example loss; the hash family (``problem.lsh.family``, see
``core.families``) supplies augmentation and the collision law.  With a
symmetric family, data are preprocessed as in Sec. 2.2 — rows centred
and scaled to unit L2 norm so the SimHash collision probability is
monotonic in the optimal sampling weight w*_i = |<[theta,-1],[x_i,y_i]>|
(Eq. 4) — bit-identical to the pre-family stack.  With the asymmetric
``mips`` family the unit-norm restriction is DROPPED: raw rows flow
through the Simple-LSH augmentation and the collision probability is
monotone in the raw inner product (``preprocess_*_mips``).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import estimator as est
from .families import get_family
from .sampler import SampleResult, sample, sample_batched, sample_drain
from .simhash import (
    LSHParams,
    augment_logistic,
    augment_regression,
    logistic_query,
    regression_query,
)
from .tables import IndexMutation, LSHIndex, mutate_index


# ---------------------------------------------------------------------------
# preprocessing (Sec. 2.2)
# ---------------------------------------------------------------------------

def preprocess_regression(x: jax.Array, y: jax.Array):
    """Centre features + normalise x rows to unit norm; standardise y globally.

    Eq. 4: ||grad f(x_i)||_2 = 2|[theta,-1].[x_i ||x_i||, y_i ||x_i||]|, so
    with unit-norm x_i the optimal weight is w*_i = |[theta,-1].[x_i, y_i]|
    and the stored hash-table vector is x_aug_i = [x_i, y_i].  y is centred
    and scaled *globally* (not per-row) so heavy-tailed targets keep their
    heavy-tailed gradients — exactly the regime where LGD wins (Sec. 2.3).

    Returns (x', y', x_aug).
    """
    x = x - jnp.mean(x, axis=0, keepdims=True)
    x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-30)
    y = (y - jnp.mean(y)) / jnp.maximum(jnp.std(y), 1e-30)
    x_aug = jnp.concatenate([x, y[:, None]], axis=-1)
    return x, y, x_aug


def preprocess_logistic(x: jax.Array, y: jax.Array):
    """Centre + row-normalise x; labels in {-1,+1}. Hash rows y_i * x_i."""
    x = x - jnp.mean(x, axis=0, keepdims=True)
    x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-30)
    return x, y, augment_logistic(x, y)


def preprocess_regression_mips(x: jax.Array, y: jax.Array, family):
    """No-normalisation regression preprocessing for asymmetric families.

    The symmetric path MUST row-normalise x (cosine is only a proxy for
    the inner product on unit vectors); the MIPS family hashes the raw
    [x_i, y_i] rows through its Simple-LSH augmentation instead, so the
    per-example scale information the row normalisation destroys stays
    in the index.  x is centred (removes the corpus-mean offset from
    every inner product) and y standardised GLOBALLY — per-row nothing
    is rescaled.

    Returns (x, y, x_aug) with x_aug = augment_data([x_i, y_i]).
    """
    x = x - jnp.mean(x, axis=0, keepdims=True)
    y = (y - jnp.mean(y)) / jnp.maximum(jnp.std(y), 1e-30)
    v = jnp.concatenate([x, y[:, None]], axis=-1)
    return x, y, family.augment_data(v)


def preprocess_logistic_mips(x: jax.Array, y: jax.Array, family):
    """Centre x only; hash the raw y_i * x_i rows via the family."""
    x = x - jnp.mean(x, axis=0, keepdims=True)
    v = x * y[..., None]
    return x, y, family.augment_data(v)


# ---------------------------------------------------------------------------
# per-example losses / gradients
# ---------------------------------------------------------------------------

def squared_loss(theta, x, y):
    r = jnp.dot(theta, x) - y
    return r * r


def squared_loss_grad(theta, x, y):
    return 2.0 * (jnp.dot(theta, x) - y) * x


def logistic_loss(theta, x, y):
    return jnp.log1p(jnp.exp(-y * jnp.dot(theta, x)))


def logistic_loss_grad(theta, x, y):
    z = y * jnp.dot(theta, x)
    return -y * x * jax.nn.sigmoid(-z)


# ---------------------------------------------------------------------------
# LGD problem + state
# ---------------------------------------------------------------------------

# The two linear LGD workloads as thin family instantiations: a kind
# contributes its base vector/query/loss; the family (problem.lsh.family)
# contributes augmentation + the collision law.  Adding a workload is a
# row here; adding a hash family never touches this table.
_KINDS = {
    "regression": dict(
        base_query=regression_query,
        loss=squared_loss, grad=squared_loss_grad,
        preprocess=preprocess_regression,
        preprocess_asym=preprocess_regression_mips),
    "logistic": dict(
        base_query=logistic_query,
        loss=logistic_loss, grad=logistic_loss_grad,
        preprocess=preprocess_logistic,
        preprocess_asym=preprocess_logistic_mips),
}


@dataclasses.dataclass(frozen=True)
class LGDProblem:
    """Static description of an LGD-trainable linear model."""

    kind: str                      # "regression" | "logistic"
    lsh: LSHParams
    minibatch: int = 1
    p_floor: float = 0.0
    drain: bool = False            # Appendix B.2 bucket-draining minibatch
    query_jitter: float = 0.0      # >0: one perturbed query per repetition,
    #                                hashed as a single fused batched probe
    #                                (incompatible with drain: the drained
    #                                bucket belongs to ONE query)
    multiprobe: int = 0            # extra Hamming-ball probe codes walked
    #                                per table before the next table draw
    #                                (probability-corrected, stays unbiased;
    #                                0 = the paper's single-probe Alg. 1)
    use_pallas: Optional[bool] = None   # None = auto (TPU: fused kernels)
    interpret: bool = False        # Pallas interpreter (kernel tests only)

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown kind {self.kind!r}; "
                             f"kinds: {sorted(_KINDS)}")
        if self.query_jitter > 0.0 and self.drain:
            raise ValueError(
                "query_jitter requires per-repetition queries; drain mode "
                "draws the whole minibatch from one query's bucket")
        if self.multiprobe > 0 and self.drain:
            raise ValueError(
                "multiprobe is not supported in drain mode: the drained "
                "bucket belongs to ONE (table, code) pair (Appendix B.2)")

    @property
    def family(self):
        """The hash-family singleton this problem hashes/queries with."""
        return get_family(self.lsh.family)

    def query_fn(self) -> Callable[[jax.Array], jax.Array]:
        """theta -> hashed query.  Symmetric families keep the paper's
        raw query (bit-identical to the pre-family stack); asymmetric
        families route it through ``augment_query``."""
        base = _KINDS[self.kind]["base_query"]
        fam = self.family
        if fam.asymmetric:
            return lambda theta: fam.augment_query(base(theta))
        return base

    def preprocess(self, x: jax.Array, y: jax.Array):
        """(x, y) -> (x_train, y_train, x_aug) for this kind + family."""
        kind = _KINDS[self.kind]
        if self.family.asymmetric:
            return kind["preprocess_asym"](x, y, self.family)
        return kind["preprocess"](x, y)

    def grad_fn(self):
        return _KINDS[self.kind]["grad"]

    def loss_fn(self):
        return _KINDS[self.kind]["loss"]


class LGDState(NamedTuple):
    theta: jax.Array
    opt_state: tuple
    index: LSHIndex
    step: jax.Array


def init(
    key: jax.Array,
    problem: LGDProblem,
    x: jax.Array,
    y: jax.Array,
    optimizer,
    theta0: Optional[jax.Array] = None,
):
    """Preprocess data, build hash tables (one-time cost), init optimiser.

    Returns (state, x_train, y_train, x_aug).
    """
    xt, yt, x_aug = problem.preprocess(x, y)
    k_idx, k_theta = jax.random.split(key)
    index = mutate_index(
        None, IndexMutation("build", key=k_idx, x_aug=x_aug), problem.lsh,
        use_pallas=problem.use_pallas, interpret=problem.interpret)
    theta = theta0 if theta0 is not None else jnp.zeros(xt.shape[1], jnp.float32)
    return (
        LGDState(theta, optimizer.init(theta), index, jnp.zeros((), jnp.int32)),
        xt, yt, x_aug,
    )


@partial(jax.jit, static_argnames=("problem", "optimizer"))
def lgd_step(
    key: jax.Array,
    state: LGDState,
    x: jax.Array,
    y: jax.Array,
    x_aug: jax.Array,
    problem: LGDProblem,
    optimizer,
) -> Tuple[LGDState, dict]:
    """One LGD iteration: hash-lookup sample -> unbiased grad -> optimiser."""
    query = problem.query_fn()(state.theta)
    if problem.query_jitter > 0.0:
        # One perturbed query per repetition, all hashed by a single
        # fused bucket-probe pass (sample_batched).  Each repetition's
        # probability is computed under its own query, so every
        # repetition stays an exact unbiased Algorithm-1 sample.
        k_jit, key = jax.random.split(key)
        queries = query[None] + problem.query_jitter * jax.random.normal(
            k_jit, (problem.minibatch,) + query.shape, query.dtype)
        res = sample_batched(
            key, state.index, x_aug, queries, problem.lsh, m=1,
            multiprobe=problem.multiprobe,
            use_pallas=problem.use_pallas, interpret=problem.interpret)
        res = SampleResult(*(a[:, 0] for a in res))      # (B, 1) -> (B,)
    elif problem.drain:
        # drain mode stays single-probe: the drained bucket belongs to
        # ONE (table, code) pair by construction (Appendix B.2).
        res: SampleResult = sample_drain(
            key, state.index, x_aug, query, problem.lsh,
            m=problem.minibatch, use_pallas=problem.use_pallas,
            interpret=problem.interpret,
        )
    else:
        res = sample(
            key, state.index, x_aug, query, problem.lsh,
            m=problem.minibatch, multiprobe=problem.multiprobe,
            use_pallas=problem.use_pallas, interpret=problem.interpret,
        )
    xb, yb = x[res.indices], y[res.indices]
    grad = est.lgd_gradient(
        problem.grad_fn(), state.theta, xb, yb, res,
        n_points=x.shape[0], p_floor=problem.p_floor,
    )
    updates, opt_state = optimizer.update(grad, state.opt_state, state.theta)
    theta = state.theta + updates
    metrics = {
        "sample_prob_mean": jnp.mean(res.probs),
        "n_probes_mean": jnp.mean(res.n_probes.astype(jnp.float32)),
        "bucket_size_mean": jnp.mean(res.bucket_sizes.astype(jnp.float32)),
        "fallback_frac": jnp.mean(res.fallback.astype(jnp.float32)),
        "primary_miss_frac": jnp.mean(
            (res.probe_code != 0).astype(jnp.float32)),
        "grad_norm": jnp.linalg.norm(grad),
    }
    return LGDState(theta, opt_state, state.index, state.step + 1), metrics


@partial(jax.jit, static_argnames=("problem", "optimizer"))
def sgd_step(
    key: jax.Array,
    state: LGDState,
    x: jax.Array,
    y: jax.Array,
    problem: LGDProblem,
    optimizer,
) -> Tuple[LGDState, dict]:
    """Uniform-sampling baseline with the same optimiser (the paper's SGD)."""
    n = x.shape[0]
    idx = jax.random.randint(key, (problem.minibatch,), 0, n)
    g = jax.vmap(lambda i: problem.grad_fn()(state.theta, x[i], y[i]))(idx)
    grad = jnp.mean(g, axis=0)
    updates, opt_state = optimizer.update(grad, state.opt_state, state.theta)
    return (
        LGDState(state.theta + updates, opt_state, state.index, state.step + 1),
        {"grad_norm": jnp.linalg.norm(grad)},
    )


def full_loss(theta, x, y, problem: LGDProblem):
    return jnp.mean(jax.vmap(lambda xi, yi: problem.loss_fn()(theta, xi, yi))(x, y))
