"""Algorithm 1 of the paper: LSH sampling with exact sampling probability.

Three modes are provided:

* ``sample`` (default, "vmap" mode) — m independent repetitions of the
  paper's single-sample Algorithm 1: each repetition draws tables with
  replacement until a non-empty bucket is found (l = #probes), samples
  uniformly inside the bucket, and reports
      p = cp(x, q)^K * (1 - cp(x, q)^K)^(l-1) / |S_b|.
  Independent repetitions keep every sample's probability exact, are
  embarrassingly parallel (a single vmap), and make the minibatch
  estimator an average of m unbiased single-sample estimators.

* ``sample_drain`` (Appendix B.2 mode) — finds the first non-empty bucket
  and draws the whole minibatch from it (with replacement), matching the
  paper's "sample m examples from that bucket" scheme for m < |S_b|.

* ``sample_batched`` — ``sample`` for B queries at once.  The B×L query
  hashing + bucket search runs as ONE fused ``bucket_probe`` kernel
  pass, amortising the L*K projection matmul across the query batch
  (perturbed-query minibatches, multi-chain training, per-example
  queries); per-query sampling stays the exact Algorithm 1.

* ``sample_gather`` / ``sample_gather_batched`` — the device-resident
  step path: Algorithm 1 PLUS the token-row gather and the 1/(p·N)
  importance-weight computation, fused into one jitted program over a
  device-resident token store (``kernels.gather_weight``).  The trainer
  consumes the returned ``GatherBatch`` directly — no host numpy, no
  device round-trip anywhere in the per-step loop.

Probing uses a *static* upper bound ``max_probes`` on the number of table
draws so the computation stays shape-static under jit; if every probed
bucket is empty the sampler falls back to a uniform draw with p = 1/N
(flagged in the result), which preserves unbiasedness.

MULTI-PROBE (``multiprobe > 0``): before giving up on a drawn table,
the query walks a deterministic Hamming-ball probe sequence of
``J = 1 + multiprobe`` codes per table — the exact bucket, then flip-1
perturbations of the packed code, then flip-2 (``simhash.probe_masks``)
— taking the FIRST non-empty bucket in (table-draw, probe) lexicographic
order.  The reported probability is corrected for the sequence so the
1/(p·N) weights stay exactly unbiased: with per-bit collision
probability cp, a point lands in the bucket of a weight-r mask with
probability q_r = cp^(K-r) (1-cp)^r, the J probe buckets of one table
are DISJOINT (distinct codes), so for a sample found at table-draw l
via probe j,

    p = q_{r_j} * (1 - Q)^(l-1) / |S_b|,      Q = sum_{i<J} q_{r_i}.

``multiprobe=0`` reduces to the paper's single-probe formula
(q_0 = cp^K, Q = cp^K) bit-identically.  Multi-probe replaces most
uniform fallbacks (which sample with probability 1/N regardless of the
query) with genuinely adaptive near-bucket samples — the fallback rate
drops and the estimator variance with it (gated by
``benchmarks/run.py tab_optimizers`` on a skewed corpus).

Within-bucket draws use ``_uniform_below`` — a dynamic-bound uniform
integer draw via floor(U * size) — NOT ``randint(0, N) % size``, which
over-weights small residues whenever size does not divide N.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import default_use_pallas
from repro.kernels.gather_weight import gather_weight

from .families import get_family
from .simhash import LSHParams, probe_masks
from .tables import (
    LSHIndex,
    band_starts,
    bucket_bounds_banded,
    bucket_bounds_batched,
    bucket_bounds_multi,
)


class SampleResult(NamedTuple):
    indices: jax.Array       # (m,) int32 — sampled point ids
    probs: jax.Array         # (m,) f32   — Alg. 1 probability (incl. 1/|S_b|)
    n_probes: jax.Array      # (m,) int32 — l, tables probed
    bucket_sizes: jax.Array  # (m,) int32 — |S_b| of chosen bucket
    fallback: jax.Array      # (m,) bool  — True where uniform fallback used
    probe_code: jax.Array = None  # (m,) int32 — probe-sequence index of the
    #                               winning bucket (0 = exact bucket,
    #                               -1 = uniform fallback)


class GatherBatch(NamedTuple):
    """One fully-assembled device-resident LGD batch (all fields (m, ...))."""

    tokens: jax.Array        # (m, S) int32 — input token rows
    targets: jax.Array       # (m, S) int32 — next-token targets
    loss_weights: jax.Array  # (m,) f32 — 1/(p·N), optionally mean-1 scaled
    example_ids: jax.Array   # (m,) int32 — GLOBAL example ids (offset applied)
    indices: jax.Array       # (m,) int32 — store-local sampled row ids
    probs: jax.Array         # (m,) f32 — raw Algorithm-1 probabilities
    fallback: jax.Array      # (m,) bool — uniform-fallback flags
    probe_code: jax.Array = None  # (m,) int32 — winning probe index
    #                               (0 = exact bucket, -1 = fallback)


def _cp_fn(params: LSHParams):
    """The family's closed-form collision probability (see core.families).

    Evaluated on (stored AUGMENTED vector, AUGMENTED query) — for
    symmetric families those are the raw vectors; for asymmetric ones
    (MIPS) the caller hashed/queried through ``augment_data`` /
    ``augment_query`` and this closed form is exact on that pair."""
    return get_family(params.family).collision_prob


def _uniform_below(key: jax.Array, bound: jax.Array, shape=()) -> jax.Array:
    """Uniform int32 draw in [0, bound) for a *traced* (dynamic) bound.

    ``randint(0, N) % bound`` is non-uniform whenever bound does not
    divide N (residues below N mod bound get ceil(N/bound)/N instead of
    floor(N/bound)/N — up to a bound/N relative skew).  floor(U * bound)
    is exact up to float32 rounding (bias < 2^-24 per slot, negligible
    against the 1/|S_b| probabilities it feeds); the min() guards the
    measure-zero U -> 1 edge.
    """
    u = jax.random.uniform(key, shape)
    slot = jnp.floor(u * bound.astype(jnp.float32)).astype(jnp.int32)
    return jnp.minimum(slot, bound - 1)


def _sample_one(key, lo, hi, order, x_aug, query, params: LSHParams,
                max_probes: int, masks: tuple, n_live=None):
    """Single repetition of Algorithm 1 given precomputed bucket bounds.

    ``lo``/``hi`` are (J, L) — bucket bounds of the J Hamming-ball probe
    codes per table (J = len(masks); J = 1 is the paper's single-probe
    algorithm).  Each of the ``max_probes`` table draws walks the probe
    sequence in order; the first non-empty bucket in (table-draw, probe)
    lexicographic order wins, and the reported probability is corrected
    for the walk (module docstring derives the formula).

    ``n_live`` (traced int32 scalar, streaming indexes only): the LIVE
    row count of a capacity-managed index.  Empty slots carry the
    sentinel code (``tables.EMPTY_CODE``, the sort maximum), so the
    first ``n_live`` entries of EVERY table's sorted order are exactly
    the live ids — the uniform fallback draws from that prefix with
    p = 1/n_live, keeping the estimator exactly unbiased over the live
    window.  ``None`` keeps the dense-index path bit-identical.
    """
    n_tables, n_points = order.shape
    j_codes = len(masks)
    sizes = hi - lo                                # (J, L)
    k_tables, k_slot, k_fb = jax.random.split(key, 3)

    # Draw tables with replacement; walk the J probe codes within each.
    ts = jax.random.randint(k_tables, (max_probes,), 0, n_tables)
    nonempty = (sizes[:, ts] > 0).T.reshape(-1)    # (max_probes*J,),
    #                                                table-draw major
    found = jnp.any(nonempty)
    first = jnp.argmax(nonempty)                   # first non-empty probe
    i = first // j_codes                           # table-draw index
    pj = first % j_codes                           # probe-sequence index
    t = ts[i]
    l = (i + 1).astype(jnp.int32)

    size = jnp.maximum(sizes[pj, t], 1)
    slot = lo[pj, t] + _uniform_below(k_slot, size)
    idx = order[t, slot]

    if n_live is None:
        fb_idx = jax.random.randint(k_fb, (), 0, n_points)
        p_fb = 1.0 / n_points
    else:
        # live rows occupy sorted slots [0, n_live) of every table —
        # a uniform draw over that prefix is uniform over live rows.
        fb_idx = order[0, _uniform_below(k_fb, n_live)]
        p_fb = 1.0 / n_live.astype(jnp.float32)
    idx = jnp.where(found, idx, fb_idx).astype(jnp.int32)

    x = x_aug[idx]
    cp = _cp_fn(params)(x, query)
    if j_codes == 1:
        cpk = cp ** params.k
        p_lsh = cpk * (1.0 - cpk) ** (l - 1) / size.astype(jnp.float32)
    else:
        # q_r per probed mask from the family's probe-class law (default
        # cp^(K-r) (1-cp)^r — i.i.d. bit collisions); the J buckets of
        # one table are disjoint, so the per-table miss probability is
        # 1 - sum(q) and the winning probe contributes its own q.
        rs = jnp.asarray([bin(m).count("1") for m in masks], jnp.float32)
        q_all = get_family(params.family).probe_class_probs(
            cp, params.k, rs)                                  # (J,)
        miss = jnp.maximum(1.0 - jnp.sum(q_all), 0.0)
        p_lsh = q_all[pj] * miss ** (l - 1) / size.astype(jnp.float32)
    p = jnp.where(found, p_lsh, p_fb)
    return SampleResult(
        indices=idx,
        probs=p.astype(jnp.float32),
        n_probes=jnp.where(found, l, max_probes).astype(jnp.int32),
        bucket_sizes=jnp.where(found, sizes[pj, t], 0).astype(jnp.int32),
        fallback=~found,
        probe_code=jnp.where(found, pj, -1).astype(jnp.int32),
    )


def _sample_one_banded(key, lo, hi, starts, order, x_aug, query,
                       params: LSHParams, max_probes: int, masks: tuple):
    """One Algorithm-1 repetition on a norm-ranged (banded) index.

    ``lo``/``hi`` are (num_bands, J, L) — bucket bounds of every probe
    code in every band (``tables.bucket_bounds_banded``); ``starts`` is
    the (num_bands + 1,) band partition of the sorted order
    (``tables.band_starts``).  The draw composes exactly:

      1. draw a band j with probability n_j / n_live (its live-row
         share) — a uniform integer in [0, n_live) binary-searched
         against ``starts``, so empty bands are never drawn;
      2. run the ordinary (table-draw, probe) walk INSIDE band j;
      3. report  p = (n_j / n_live) * q_r * (1 - Q)^(l-1) / |S_b|,
         with q_r evaluated at the sampled point's own band scale
         (the augmented pair carries it), so 1/(p*N) stays exactly
         unbiased under heavy-tailed norms — the property
         ``tests/test_norm_ranging.py`` pins where plain ``mips``
         measures ~0.55.

    If every probed bucket of the drawn band is empty (possible: an
    evicted-empty band is unreachable, but a live band can still miss
    all ``max_probes`` draws), the uniform fallback draws from the live
    prefix with p = 1/n_live, exactly as the streaming flat path.
    """
    n_tables = order.shape[0]
    j_codes = len(masks)
    sizes = hi - lo                                # (nb, J, L)
    k_band, k_tables, k_slot, k_fb = jax.random.split(key, 4)

    total = starts[-1]                             # live rows (all bands)
    u = _uniform_below(k_band, total)
    band = jnp.searchsorted(starts[1:], u, side="right").astype(jnp.int32)
    n_band = starts[band + 1] - starts[band]
    sizes_b = sizes[band]                          # (J, L)
    lo_b = lo[band]

    ts = jax.random.randint(k_tables, (max_probes,), 0, n_tables)
    nonempty = (sizes_b[:, ts] > 0).T.reshape(-1)  # table-draw major
    found = jnp.any(nonempty)
    first = jnp.argmax(nonempty)
    i = first // j_codes
    pj = first % j_codes
    t = ts[i]
    l = (i + 1).astype(jnp.int32)

    size = jnp.maximum(sizes_b[pj, t], 1)
    slot = lo_b[pj, t] + _uniform_below(k_slot, size)
    idx = order[t, slot]

    # banded indexes are always capacity-managed semantics: live rows
    # occupy sorted slots [0, total) of every table.
    fb_idx = order[0, _uniform_below(k_fb, total)]
    p_fb = 1.0 / total.astype(jnp.float32)
    idx = jnp.where(found, idx, fb_idx).astype(jnp.int32)

    x = x_aug[idx]
    cp = _cp_fn(params)(x, query)
    rs = jnp.asarray([bin(m).count("1") for m in masks], jnp.float32)
    q_all = get_family(params.family).probe_class_probs(
        cp, params.k, rs)                          # (J,)
    miss = jnp.maximum(1.0 - jnp.sum(q_all), 0.0)
    p_band = n_band.astype(jnp.float32) / total.astype(jnp.float32)
    p_lsh = p_band * q_all[pj] * miss ** (l - 1) / size.astype(jnp.float32)
    p = jnp.where(found, p_lsh, p_fb)
    return SampleResult(
        indices=idx,
        probs=p.astype(jnp.float32),
        n_probes=jnp.where(found, l, max_probes).astype(jnp.int32),
        bucket_sizes=jnp.where(found, sizes_b[pj, t], 0).astype(jnp.int32),
        fallback=~found,
        probe_code=jnp.where(found, pj, -1).astype(jnp.int32),
    )


def _probe_bounds(index, queries, params, masks, use_pallas, interpret):
    """(J, L)-shaped bucket bounds for the probe sequence.

    J == 1 keeps the original single-code probe path (and its compiled
    program) and lifts the (…, L) bounds to (…, 1, L); J > 1 routes
    through ``bucket_bounds_multi``.
    """
    if len(masks) == 1:
        lo, hi = bucket_bounds_batched(index, queries, params,
                                       use_pallas=use_pallas,
                                       interpret=interpret)
        return lo[..., None, :], hi[..., None, :]
    return bucket_bounds_multi(index, queries, params, masks,
                               use_pallas=use_pallas, interpret=interpret)


@partial(jax.jit, static_argnames=("params", "m", "max_probes", "multiprobe",
                                   "use_pallas", "interpret"))
def sample(
    key: jax.Array,
    index: LSHIndex,
    x_aug: jax.Array,
    query: jax.Array,
    params: LSHParams,
    m: int = 1,
    max_probes: Optional[int] = None,
    multiprobe: int = 0,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    n_live: Optional[jax.Array] = None,
) -> SampleResult:
    """m independent LSH samples for one query (paper Algorithm 1 x m).

    Args:
      key: PRNG key; split into m per-repetition keys.
      index / x_aug: the LSH index and the (N, d) hashed vectors.
      query: (d,) query vector.
      params: hash-family hyper-parameters (static).
      m: number of independent repetitions.
      max_probes: static cap on table draws per repetition
        (default ``max(2L, 8)``).
      multiprobe: number of ADDITIONAL Hamming-ball probe codes walked
        per table before moving to the next table draw (0 = the paper's
        single-probe Algorithm 1, bit-identical to previous behaviour).
      use_pallas / interpret: kernel dispatch, see ``tables``.
      n_live: traced int32 live-row count of a capacity-managed
        streaming index (``None`` = dense index, bit-identical to the
        pre-streaming path).  Uniform fallbacks then draw from the live
        prefix of the sorted order with p = 1/n_live.

    Returns:
      ``SampleResult`` with every field shaped (m,).  ``probs`` is the
      exact per-sample probability (probe-sequence corrected when
      ``multiprobe > 0``), so ``1/(probs * N)`` importance weights are
      unbiased.

    Determinism: a pure function of (key, index, inputs) — same key,
    same draw, on every backend (kernel and reference paths are
    bit-identical).
    """
    max_probes = max_probes or max(2 * params.l, 8)
    masks = probe_masks(params.k, 1 + multiprobe)
    keys = jax.random.split(key, m)
    if get_family(params.family).num_bands() > 1:
        # norm-ranged composite index: probe every band, compose the
        # band-selection probability into p (``n_live`` is redundant —
        # the band partition's total IS the live count).
        lo, hi = bucket_bounds_banded(index, query, params, masks,
                                      use_pallas=use_pallas,
                                      interpret=interpret)  # (nb, J, L)
        starts = band_starts(index, params)
        return jax.vmap(
            lambda k: _sample_one_banded(k, lo, hi, starts, index.order,
                                         x_aug, query, params, max_probes,
                                         masks)
        )(keys)
    lo, hi = _probe_bounds(index, query, params, masks,
                           use_pallas, interpret)          # (J, L)
    res = jax.vmap(
        lambda k: _sample_one(k, lo, hi, index.order, x_aug, query, params,
                              max_probes, masks, n_live)
    )(keys)
    return res


@partial(jax.jit, static_argnames=("params", "m", "max_probes", "multiprobe",
                                   "use_pallas", "interpret"))
def sample_batched(
    key: jax.Array,
    index: LSHIndex,
    x_aug: jax.Array,
    queries: jax.Array,          # (B, d)
    params: LSHParams,
    m: int = 1,
    max_probes: Optional[int] = None,
    multiprobe: int = 0,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    n_live: Optional[jax.Array] = None,
) -> SampleResult:
    """Algorithm 1 for B queries at once; every field comes back (B, m).

    One fused bucket-probe pass hashes all B queries and finds all
    B*J*L bucket slices; sampling then vmaps ``_sample_one`` over
    (B, m).  Each (query b, repetition j) pair is an independent,
    exact-probability Algorithm-1 sample, so averaging over either axis
    stays unbiased.  ``multiprobe`` / ``n_live`` as in ``sample``.
    """
    if queries.ndim != 2:
        raise ValueError(
            f"sample_batched expects queries (B, d), got {queries.shape}; "
            "use sample() for a single query")
    max_probes = max_probes or max(2 * params.l, 8)
    masks = probe_masks(params.k, 1 + multiprobe)
    b = queries.shape[0]
    keys = jax.random.split(key, (b, m))
    if get_family(params.family).num_bands() > 1:
        lo, hi = bucket_bounds_banded(index, queries, params, masks,
                                      use_pallas=use_pallas,
                                      interpret=interpret)  # (B, nb, J, L)
        starts = band_starts(index, params)

        def per_query_banded(ks, lo_q, hi_q, q):
            return jax.vmap(
                lambda kk: _sample_one_banded(kk, lo_q, hi_q, starts,
                                              index.order, x_aug, q,
                                              params, max_probes, masks)
            )(ks)

        return jax.vmap(per_query_banded)(keys, lo, hi, queries)
    lo, hi = _probe_bounds(index, queries, params, masks,
                           use_pallas, interpret)          # (B, J, L)

    def per_query(ks, lo_q, hi_q, q):
        return jax.vmap(
            lambda kk: _sample_one(kk, lo_q, hi_q, index.order, x_aug, q,
                                   params, max_probes, masks, n_live)
        )(ks)

    return jax.vmap(per_query)(keys, lo, hi, queries)


def _assemble(res: SampleResult, store: jax.Array, example_offset,
              p_floor: float, normalize: bool, use_pallas: Optional[bool],
              interpret: bool, row_width: Optional[int],
              n_live=None) -> GatherBatch:
    """Gather token rows + compute 1/(p·N) weights for one draw (m,)."""
    if use_pallas is None:
        use_pallas = default_use_pallas()
    rows, w = gather_weight(store, res.indices, res.probs,
                            p_floor=p_floor, use_pallas=use_pallas,
                            interpret=interpret)
    if n_live is not None:
        # the fused kernel divides by the STORE height (capacity C of a
        # streaming store); rescale by C/n_live so every weight is
        # exactly 1/(p·N_live) — unbiased over the live window.
        w = w * (jnp.float32(store.shape[0]) / n_live.astype(jnp.float32))
    if normalize:
        w = w / jnp.maximum(jnp.mean(w), 1e-30)
    ids = (res.indices
           + jnp.asarray(example_offset, jnp.int32)).astype(jnp.int32)
    # row_width: logical S+1 of a store whose rows were lane-padded at
    # build time (Pallas gather path) — slice the padding back off.
    sw = store.shape[1] if row_width is None else row_width
    return GatherBatch(
        tokens=rows[:, :sw - 1],
        targets=rows[:, 1:sw],
        loss_weights=w.astype(jnp.float32),
        example_ids=ids,
        indices=res.indices,
        probs=res.probs,
        fallback=res.fallback,
        probe_code=res.probe_code,
    )


@partial(jax.jit, static_argnames=("params", "m", "max_probes", "multiprobe",
                                   "p_floor", "normalize", "use_pallas",
                                   "interpret", "row_width"))
def sample_gather(
    key: jax.Array,
    index: LSHIndex,
    x_aug: jax.Array,
    query: jax.Array,
    store: jax.Array,            # (N, S+1) int32 device-resident token rows
    params: LSHParams,
    m: int = 1,
    example_offset: jax.Array | int = 0,
    max_probes: Optional[int] = None,
    multiprobe: int = 0,
    p_floor: float = 1e-8,
    normalize: bool = True,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    row_width: Optional[int] = None,
    n_live: Optional[jax.Array] = None,
) -> GatherBatch:
    """The device-resident LGD step: Algorithm 1 + gather + weights, one
    compiled program.

    Args:
      key: PRNG key for this draw.
      index / x_aug: LSH index and hashed feature vectors (N, d).
      query: (d,) normalised query vector.
      store: (N, S+1) int32 device-resident token rows (lane-padded on
        the Pallas gather path — see ``row_width``).
      params: hash-family hyper-parameters (static).
      m: minibatch size (independent Algorithm-1 repetitions).
      example_offset: traced offset lifting store-local row ids to
        global example ids (all corpus shards share one compilation).
      max_probes: static cap on table draws per repetition.
      multiprobe: extra Hamming-ball probe codes per table (see
        ``sample``); 0 keeps the single-probe paper algorithm.
      p_floor: probability floor inside the weight computation.
      normalize: rescale weights to mean 1 over the batch (sharded
        composition passes False and normalises once globally).
      row_width: logical S+1 when the store rows were lane-padded once
        at build (keeps the per-call pad zero-width).
      n_live: traced int32 live-row count of a capacity-managed
        streaming store/index (``None`` = dense).  Fallback draws and
        EVERY 1/(p·N) weight then use N = n_live, so the estimator
        stays exactly unbiased as a sliding window advances.

    Returns:
      ``GatherBatch`` with every field shaped (m, ...): token rows,
      next-token targets, 1/(p·N) loss weights, global example ids and
      the per-sample sampling diagnostics (probs / fallback /
      probe_code).

    Determinism: pure in (key, index, inputs); the trainer's per-step
    key stream makes restored runs draw bit-identical batches.
    """
    res = sample(key, index, x_aug, query, params, m=m,
                 max_probes=max_probes, multiprobe=multiprobe,
                 use_pallas=use_pallas, interpret=interpret,
                 n_live=n_live)
    return _assemble(res, store, example_offset, p_floor, normalize,
                     use_pallas, interpret, row_width, n_live)


@partial(jax.jit, static_argnames=("params", "m", "max_probes", "multiprobe",
                                   "p_floor", "normalize", "use_pallas",
                                   "interpret", "row_width"))
def sample_gather_batched(
    key: jax.Array,
    index: LSHIndex,
    x_aug: jax.Array,
    queries: jax.Array,          # (C, d)
    store: jax.Array,            # (N, S+1) int32
    params: LSHParams,
    m: int = 1,
    example_offset: jax.Array | int = 0,
    max_probes: Optional[int] = None,
    multiprobe: int = 0,
    p_floor: float = 1e-8,
    normalize: bool = True,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    row_width: Optional[int] = None,
    n_live: Optional[jax.Array] = None,
) -> GatherBatch:
    """``sample_gather`` for C queries at once; every field comes back
    (C, m, ...).  The C·m gathered rows run through ONE gather+weight
    pass (flattened), and weight normalisation is per chain.  Args as
    in ``sample_gather`` (``queries`` replaces ``query``)."""
    c = queries.shape[0]
    res = sample_batched(key, index, x_aug, queries, params, m=m,
                         max_probes=max_probes, multiprobe=multiprobe,
                         use_pallas=use_pallas,
                         interpret=interpret,
                         n_live=n_live)                # fields (C, m)
    flat = SampleResult(*(f.reshape((-1,) + f.shape[2:]) for f in res))
    batch = _assemble(flat, store, example_offset, p_floor, False,
                      use_pallas, interpret, row_width, n_live)
    unflat = GatherBatch(*(f.reshape((c, m) + f.shape[1:]) for f in batch))
    if normalize:
        w = unflat.loss_weights
        w = w / jnp.maximum(jnp.mean(w, axis=1, keepdims=True), 1e-30)
        unflat = unflat._replace(loss_weights=w)
    return unflat


@partial(jax.jit, static_argnames=("params", "m", "max_probes", "use_pallas",
                                   "interpret"))
def sample_drain(
    key: jax.Array,
    index: LSHIndex,
    x_aug: jax.Array,
    query: jax.Array,
    params: LSHParams,
    m: int = 1,
    max_probes: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
) -> SampleResult:
    """Appendix B.2: draw the whole minibatch from the first non-empty bucket."""
    if get_family(params.family).num_bands() > 1:
        raise ValueError(
            "sample_drain does not support banded (norm-ranged) families: "
            "the drain scheme reuses ONE bucket for the whole minibatch, "
            "which cannot compose the per-draw band-selection probability; "
            "use sample()/sample_batched() with family "
            f"{params.family!r}")
    max_probes = max_probes or max(2 * params.l, 8)
    lo, hi = bucket_bounds_batched(index, query, params,
                                   use_pallas=use_pallas,
                                   interpret=interpret)
    sizes = hi - lo
    n_tables, n_points = index.order.shape
    k_tables, k_slot, k_fb = jax.random.split(key, 3)

    ts = jax.random.randint(k_tables, (max_probes,), 0, n_tables)
    nonempty = sizes[ts] > 0
    found = jnp.any(nonempty)
    j = jnp.argmax(nonempty)
    t = ts[j]
    l = (j + 1).astype(jnp.int32)
    size = jnp.maximum(sizes[t], 1)

    slots = lo[t] + _uniform_below(k_slot, size, (m,))
    idx = index.order[t, slots]
    fb = jax.random.randint(k_fb, (m,), 0, n_points)
    idx = jnp.where(found, idx, fb).astype(jnp.int32)

    x = x_aug[idx]
    cp = _cp_fn(params)(x, query)
    cpk = cp ** params.k
    p_lsh = cpk * (1.0 - cpk) ** (l - 1) / size.astype(jnp.float32)
    p = jnp.where(found, p_lsh, 1.0 / n_points).astype(jnp.float32)
    return SampleResult(
        indices=idx,
        probs=p,
        n_probes=jnp.full((m,), jnp.where(found, l, max_probes), jnp.int32),
        bucket_sizes=jnp.full((m,), jnp.where(found, sizes[t], 0), jnp.int32),
        fallback=jnp.broadcast_to(~found, (m,)),
        probe_code=jnp.full((m,), jnp.where(found, 0, -1), jnp.int32),
    )
