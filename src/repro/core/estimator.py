"""Unbiased LGD gradient estimator (Theorem 1) + variance diagnostics (Theorem 2).

Estimator (single sample x_m drawn by Algorithm 1 with probability
p = cp^K (1-cp^K)^(l-1) / |S_b|):

    Est = grad f(x_m, theta) / (p * N)

which by Theorem 1 satisfies E[Est] = (1/N) sum_i grad f(x_i, theta).
For a minibatch of m independent repetitions we average the m unbiased
single-sample estimators.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .families import get_family
from .sampler import SampleResult
from .simhash import LSHParams, probe_masks


def importance_weights(res: SampleResult, n_points: int,
                       p_floor: float = 0.0) -> jax.Array:
    """w_j = 1 / (p_j * N), optionally clipping tiny p for numerical safety.

    p_floor=0 reproduces the paper exactly; a small floor (e.g. 1e-8)
    trades a negligible bias for bounded weights on adversarial data.
    """
    p = jnp.maximum(res.probs, p_floor) if p_floor > 0 else res.probs
    return 1.0 / (p * n_points)


def lgd_gradient(
    grad_fn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
    theta: jax.Array,
    x: jax.Array,
    y: jax.Array,
    res: SampleResult,
    n_points: int,
    p_floor: float = 0.0,
):
    """Average of per-sample unbiased estimators.

    grad_fn(theta, x_row, y_row) -> gradient pytree/array for ONE example.
    x, y are the gathered sampled rows (m, d), (m,).
    """
    w = importance_weights(res, n_points, p_floor)          # (m,)
    g = jax.vmap(lambda xr, yr: grad_fn(theta, xr, yr))(x, y)
    return jax.tree.map(
        lambda gi: jnp.mean(
            gi * w.reshape((-1,) + (1,) * (gi.ndim - 1)), axis=0
        ),
        g,
    )


def exact_inclusion_probability(
    x_aug: jax.Array, query: jax.Array, params: LSHParams,
    l: jax.Array | int = 1,
    multiprobe: int = 0,
    band_select: jax.Array | None = None,
) -> jax.Array:
    """p_i = Q_i (1-Q_i)^(l-1) for *all* points (O(N d), analysis only).

    Family-generic: ``Q_i`` is the probability that point i lands in
    SOME probed bucket of one table — ``cp_i^K`` for single-probe, and
    the probe-sequence sum of the family's probe-class probabilities
    ``q_r = probe_class_probs(cp_i, K, r)`` under multi-probe — where
    ``cp_i`` is the family's closed-form collision probability on the
    (augmented data, augmented query) pair.  Asymmetric families (MIPS)
    therefore get exact inclusion probabilities on un-normalised
    corpora, pinned by the unbiasedness tests in
    ``tests/test_families.py``.  Used by tests and the variance
    diagnostics; never on the training path.

    ``band_select`` (banded/norm-ranged families): per-point (N,)
    band-selection probability ``n_band(i) / n_live``.  A banded draw
    selects point i's band first, THEN walks tables inside it, so the
    composed per-draw inclusion probability is
    ``band_select_i * Q_i (1-Q_i)^(l-1)`` — the table-miss factor is
    conditional on the band draw and multiplies only the per-table Q.
    ``None`` (flat families) keeps the original formula bit-identical.
    """
    fam = get_family(params.family)
    cp = fam.collision_prob(x_aug, query)
    if multiprobe <= 0:
        q_tab = cp ** params.k
    else:
        masks = probe_masks(params.k, 1 + multiprobe)
        rs = jnp.asarray([bin(m).count("1") for m in masks], jnp.float32)
        q_tab = jnp.sum(
            fam.probe_class_probs(cp[..., None], params.k, rs), axis=-1)
    p = q_tab * (1.0 - q_tab) ** (jnp.asarray(l, jnp.float32) - 1.0)
    if band_select is not None:
        p = band_select * p
    return p


class VarianceReport(NamedTuple):
    trace_lgd: jax.Array   # Tr(Sigma) of the LGD estimator (Theorem 2)
    trace_sgd: jax.Array   # Tr(Sigma) of uniform-sampling SGD
    mean_grad_norm_lgd: jax.Array
    mean_grad_norm_sgd: jax.Array


def variance_report(
    grad_norms_sq: jax.Array,   # (N,) ||grad f(x_i)||_2^2 at current theta
    p_bucket: jax.Array,        # (N,) P(x_i in probed bucket) = cp_i^K (l=1 case)
    cp_k: jax.Array,            # (N,) cp_i^K — pairwise joint approximated below
    full_grad_norm_sq: jax.Array,
) -> VarianceReport:
    """Theorem 2 trace, with E|S_b| approximated by sum_j min(cp_i,cp_j)^K.

    P(x_i, x_j in S_b) is upper/lower bounded by min/product of marginal
    collision probabilities; we use the independence approximation
    P(i,j in S_b) ~= cp_i^K * cp_j^K / cp_i^K-normalised form used in the
    paper's Eq. (9) upper bound:  sum_j p_j / (p_i^2 N).
    """
    n = grad_norms_sq.shape[0]
    mean_p = jnp.mean(cp_k)
    lhs = jnp.mean(grad_norms_sq * mean_p / jnp.maximum(p_bucket**2, 1e-30))
    trace_lgd = lhs - full_grad_norm_sq / (n * n)
    trace_sgd = jnp.mean(grad_norms_sq) - full_grad_norm_sq / (n * n)
    return VarianceReport(
        trace_lgd=trace_lgd,
        trace_sgd=trace_sgd,
        mean_grad_norm_lgd=jnp.sum(grad_norms_sq * p_bucket) / jnp.sum(p_bucket),
        mean_grad_norm_sgd=jnp.mean(grad_norms_sq),
    )


def empirical_estimator_covariance_trace(estimates: jax.Array) -> jax.Array:
    """Tr(Cov) of a stack of gradient estimates (trials, d) — for tests."""
    mu = jnp.mean(estimates, axis=0, keepdims=True)
    return jnp.mean(jnp.sum((estimates - mu) ** 2, axis=-1))
