"""TPU-native LSH hash tables: a sorted-code (CSR-like) bucket index.

HARDWARE ADAPTATION.  The paper's CPU implementation stores per-bucket
pointer lists (classic chained hash tables).  Pointer chasing does not map
to TPU: memory access must be dense, vectorised gathers.  We replace the
chained table with a *sorted-code index*:

  per table t:
    codes[t, i]      uint32 packed K-bit code of point i      (L, N)
    order[t, :]      argsort of codes[t]                      (L, N) int32
    sorted_codes[t]  codes[t, order[t]]                       (L, N)

A bucket is then the contiguous slice [lo, hi) found by two binary
searches (``searchsorted``) of the query code — O(log N) per probe, fully
vectorisable over tables and over a minibatch of queries, and the *build*
is a sort (TPU-efficient) instead of millions of scatter-appends.

PERFORMANCE.  Both halves of the index hot path route through fused
Pallas kernels on TPU (``use_pallas=None`` auto-dispatches by backend;
CPU hosts take the numerically identical XLA reference):

  * build/refresh hashing runs ``kernels.simhash`` — projection matmul,
    sign and bit-pack fused into one VMEM-resident pass (linear
    families; quadratic SRP hashes via per-function quadratic forms and
    stays on the XLA path).
  * query probing runs ``kernels.bucket_probe`` — query hashing plus the
    per-table bucket search over ``sorted_codes``, fused and batched
    over queries (see ``bucket_bounds_batched``).
  * ``refresh_index`` re-sorts through the *previous* order: composing
    the old permutation with a stable argsort of the permuted codes
    keeps tie layouts identical across refreshes — the double-buffer
    property downstream consumers rely on (unchanged codes keep their
    slots).  This is a STABILITY property, not a speedup: XLA's sort is
    data-oblivious, so nearly-sorted input costs the same as random
    input, and the composition adds two O(L*N) gathers per refresh
    (negligible next to the re-hash + sort it rides on).

The index is a pytree and can be sharded over the ``data`` mesh axis so
each data-parallel group maintains the index of its own shard of the
training set (see ``repro/data/lsh_pipeline.py``).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import default_use_pallas
from repro.kernels.bucket_probe import (
    bucket_probe,
    bucket_probe_codes,
    bucket_probe_multi,
)
from repro.kernels.simhash import simhash_codes

from .families import get_family
from .simhash import LSHParams, compute_codes, make_projections


class LSHIndex(NamedTuple):
    """Immutable sorted-code LSH index over n points (pytree)."""

    projections: jax.Array   # (d, L*K) or (L*K, d, d) for quadratic
    sorted_codes: jax.Array  # (L, N) uint32, ascending per row
    order: jax.Array         # (L, N) int32: order[t, j] = original point id

    @property
    def n_tables(self) -> int:
        return self.sorted_codes.shape[0]

    @property
    def n_points(self) -> int:
        return self.sorted_codes.shape[1]


def _hash_points(x: jax.Array, proj: jax.Array, params: LSHParams,
                 use_pallas: Optional[bool], interpret: bool) -> jax.Array:
    """(N, d) points -> (L, N) codes via the fastest path for the family.

    ``x`` is ALREADY augmented (the family's ``augment_data`` ran at the
    call site); linear families (``proj_kind`` dense/sparse — including
    the asymmetric MIPS family's augmented vectors) route through the
    fused simhash kernel dispatch, quadratic forms stay on XLA."""
    if get_family(params.family).proj_kind == "quadratic":
        codes = compute_codes(x, proj, k=params.k, l=params.l,
                              quadratic=True)
    else:
        if use_pallas is None:
            use_pallas = default_use_pallas()
        codes = simhash_codes(x, proj, k=params.k, l=params.l,
                              use_pallas=use_pallas, interpret=interpret)
    return codes.T


def build_index(key: jax.Array, x_aug: jax.Array, params: LSHParams,
                *, use_pallas: Optional[bool] = None,
                interpret: bool = False) -> LSHIndex:
    """One-time (or periodic-refresh) preprocessing: hash + sort per table.

    Args:
      key: PRNG key for the projection draw (the ONLY randomness here).
      x_aug: (N, d) augmented vectors to index (unit-norm rows for
        SimHash monotonicity).
      params: hash-family hyper-parameters (static).
      use_pallas: ``None`` routes hashing through the fused SimHash
        kernel on TPU and the bit-identical XLA reference elsewhere;
        pass True/False to force a path.
      interpret: run the kernel under the Pallas interpreter (tests).

    Returns:
      An immutable ``LSHIndex`` pytree (projections, per-table sorted
      codes, sort order).

    Determinism: a pure function of (key, x_aug, params) — two builds
    with the same inputs are bitwise identical on every backend, which
    is what ``restore_at``-style canonical rebuilds rely on.
    """
    if params.dim != x_aug.shape[-1]:
        raise ValueError(f"params.dim={params.dim} != data dim {x_aug.shape[-1]}")
    proj = make_projections(key, params)
    codes = _hash_points(x_aug, proj, params, use_pallas, interpret)  # (L, N)
    order = jnp.argsort(codes, axis=1).astype(jnp.int32)
    sorted_codes = jnp.take_along_axis(codes, order, axis=1)
    return LSHIndex(proj, sorted_codes, order)


def refresh_index(key: jax.Array, index: LSHIndex, x_aug: jax.Array,
                  params: LSHParams, *, use_pallas: Optional[bool] = None,
                  interpret: bool = False,
                  warm_start: bool = True) -> LSHIndex:
    """Re-hash the (possibly updated) points, keeping the same projections.

    Used for deep models where stored features drift slowly (Sec. 3.2 /
    Appendix E): hash tables are periodically rebuilt from fresh features.

    Args:
      key: unused when projections are reused; kept for API symmetry.
      index: the previous index (its projections are reused; with
        ``warm_start`` its ``order`` seeds the re-sort).
      x_aug: (N, d) fresh feature vectors (same N as the index).
      params: hash-family hyper-parameters (static).
      warm_start: keep tie layouts stable across refreshes (below).

    Returns:
      A new ``LSHIndex`` over the fresh features.

    With ``warm_start`` the previous ``order`` seeds the re-sort: codes
    are permuted by the old order first and a *stable* argsort of that
    permutation is composed back.  The result is bitwise-valid for any
    drift, ties keep their previous relative layout (stable double
    buffering of bucket slices), and points whose codes did not change
    keep their exact slots.  Note this buys layout *stability*, not
    sort speed — XLA sorts are data-oblivious — at the cost of two
    extra O(L*N) gathers, dwarfed by the re-hash itself.
    """
    del key
    codes = _hash_points(x_aug, index.projections, params, use_pallas,
                         interpret)  # (L, N)
    if warm_start:
        prev = index.order
        permuted = jnp.take_along_axis(codes, prev, axis=1)
        delta = jnp.argsort(permuted, axis=1, stable=True).astype(jnp.int32)
        order = jnp.take_along_axis(prev, delta, axis=1)
        sorted_codes = jnp.take_along_axis(permuted, delta, axis=1)
    else:
        order = jnp.argsort(codes, axis=1).astype(jnp.int32)
        sorted_codes = jnp.take_along_axis(codes, order, axis=1)
    return LSHIndex(index.projections, sorted_codes, order)


@jax.jit
def refresh_index_delta(index: LSHIndex, dirty_ids: jax.Array,
                        dirty_codes: jax.Array) -> LSHIndex:
    """Merge re-hashed codes for a dirty subset into the sorted index.

    ``dirty_ids``: (D,) int32 point ids whose features changed (callers
    pad D to a static bucket; duplicate ids are legal as long as their
    code columns agree — the scatter then writes identical values).
    ``dirty_codes``: (L, D) uint32, the fresh codes of exactly those
    points.  Clean points are NOT re-hashed — that is the whole point:
    the O(N·d·L·K) hash (and the O(N·model) re-embed upstream) scale
    with |dirty|, and only the merge below touches all N entries.

    The merge works in the old-sorted domain, through the previous
    ``order`` — the same tie-stability contract as the warm-started
    ``refresh_index``: scatter the dirty codes into their previous
    sorted slots (the clean segments stay sorted), then compose a
    *stable* argsort back through the old permutation.  Entries are
    therefore (re)placed by the key (new code, previous position), which
    is bitwise what ``refresh_index(warm_start=True)`` computes when the
    clean codes are unchanged — in particular, delta-refresh with ALL
    points dirty is bit-identical to a full warm-started refresh, and a
    dirty point whose code did not change keeps its exact slot.  The
    stable sort costs O(L·N log N) on packed uint32 codes — memcpy-rate
    device work, dwarfed by the avoided re-embed + re-hash.
    """
    order = index.order
    l, n = order.shape
    iota = jnp.arange(n, dtype=jnp.int32)
    # position of each point id in the old sorted order, per table
    pos = jnp.zeros_like(order).at[
        jnp.arange(l, dtype=jnp.int32)[:, None], order].set(iota[None])
    pos_d = jnp.take(pos, dirty_ids.astype(jnp.int32), axis=1)  # (L, D)
    permuted = jax.vmap(lambda sc, p, c: sc.at[p].set(c))(
        index.sorted_codes, pos_d, dirty_codes)
    delta = jnp.argsort(permuted, axis=1, stable=True).astype(jnp.int32)
    new_order = jnp.take_along_axis(order, delta, axis=1)
    new_sorted = jnp.take_along_axis(permuted, delta, axis=1)
    return LSHIndex(index.projections, new_sorted, new_order)


def hash_points(x: jax.Array, proj: jax.Array, params: LSHParams,
                *, use_pallas: Optional[bool] = None,
                interpret: bool = False) -> jax.Array:
    """Public (L, N)-layout hashing entry: the delta-refresh re-hash path."""
    return _hash_points(x, proj, params, use_pallas, interpret)


def query_codes(index: LSHIndex, q: jax.Array, params: LSHParams) -> jax.Array:
    """Hash a query (d,) or batch (m, d) -> (L,) or (m, L) uint32."""
    return compute_codes(
        q, index.projections, k=params.k, l=params.l,
        quadratic=get_family(params.family).proj_kind == "quadratic",
    )


def bucket_bounds(index: LSHIndex, qcodes: jax.Array):
    """For each table, the [lo, hi) slice of the query's bucket.

    qcodes: (L,) uint32 -> lo, hi: (L,) int32.  Vectorised binary search
    (the XLA reference path; the hot path is ``bucket_bounds_batched``).
    """
    def per_table(sc, c):
        lo = jnp.searchsorted(sc, c, side="left")
        hi = jnp.searchsorted(sc, c, side="right")
        return lo.astype(jnp.int32), hi.astype(jnp.int32)

    return jax.vmap(per_table)(index.sorted_codes, qcodes)


# The counting kernel streams all L*N sorted codes per probe call, so its
# per-query HBM traffic is L*N*4/B bytes.  Auto-dispatch only routes a
# probe through it when N/B is below this bound (~52 MB of codes for
# L=100 at the default) — above it the O(log N) searchsorted reference
# wins and keeps the paper's O(1)-per-step property for huge N.
COUNTING_PROBE_MAX_POINTS_PER_QUERY = 1 << 17


def bucket_bounds_batched(index: LSHIndex, queries: jax.Array,
                          params: LSHParams, *,
                          use_pallas: Optional[bool] = None,
                          interpret: bool = False):
    """Fused hash+probe for a query batch (B, d) (or a single (d,)).

    Returns (lo, hi) int32 of shape (B, L) — or (L,) for a 1-D query.
    On TPU this is one ``kernels.bucket_probe`` pass: the L*K projection
    matmul, sign/bit-pack and the per-table bucket search run in a
    single VMEM-resident kernel, amortised over the query batch.
    Elsewhere (or with ``use_pallas=False``) it lowers to the identical
    XLA reference: ``compute_codes`` + vmapped binary searches.

    Auto-dispatch (``use_pallas=None``) is N/B-aware: the counting
    kernel reads every sorted code, so for very large indexes probed by
    few queries the reference binary search is the faster path (see
    ``COUNTING_PROBE_MAX_POINTS_PER_QUERY``).  Pass ``use_pallas=True``
    to force the kernel regardless.  The dispatch-never-loses contract
    is gated in CI: ``benchmarks/run.py tab_sampling_cost`` times the
    dispatched path against the reference INTERLEAVED in one loop
    (sequential loops once recorded machine-load drift as a phantom 9%
    probe regression) and ``check_regression.py`` caps the ratio at
    ``--probe-cap``.
    """
    if use_pallas is None:
        b = queries.shape[0] if queries.ndim == 2 else 1
        use_pallas = (default_use_pallas() and
                      index.n_points <= b * COUNTING_PROBE_MAX_POINTS_PER_QUERY)
    if get_family(params.family).proj_kind == "quadratic":
        # quadratic SRP hashes via per-function quadratic forms — not a
        # single matmul — so hash on the XLA path, probe in the kernel.
        qcodes = query_codes(index, queries, params)
        return bucket_probe_codes(qcodes, index.sorted_codes,
                                  use_pallas=use_pallas, interpret=interpret)
    return bucket_probe(queries, index.projections, index.sorted_codes,
                        k=params.k, l=params.l, use_pallas=use_pallas,
                        interpret=interpret)


def bucket_bounds_multi(index: LSHIndex, queries: jax.Array,
                        params: LSHParams, masks: tuple, *,
                        use_pallas: Optional[bool] = None,
                        interpret: bool = False):
    """Bucket bounds for the full multi-probe code sequence.

    For every query, table t and probe mask ``masks[j]``, the [lo, hi)
    slice of the bucket whose packed code is ``code(q)[t] ^ masks[j]``
    (``core.simhash.probe_masks`` generates the deterministic
    Hamming-ball sequence).

    Args:
      index: the sorted-code index to probe.
      queries: (B, d) query batch or a single (d,) query.
      params: hash-family hyper-parameters (static).
      masks: static tuple of XOR masks (J = len(masks)).
      use_pallas / interpret: kernel dispatch, same contract as
        ``bucket_bounds_batched``.

    Returns:
      (lo, hi) int32 of shape (B, J, L) — or (J, L) for a 1-D query.

    Dispatch: the fused multi-probe kernel hashes each query once and
    counts all J probe codes against the SAME streamed sorted-code
    tile, so its HBM traffic equals the single-probe kernel's — the
    N/B auto-dispatch cutover is therefore unchanged (per QUERY, not
    per probe code).  Quadratic SRP hashes on the XLA path and probes
    the J·L perturbed codes through the probe-only kernel.
    """
    if use_pallas is None:
        b = queries.shape[0] if queries.ndim == 2 else 1
        use_pallas = (default_use_pallas() and
                      index.n_points <= b * COUNTING_PROBE_MAX_POINTS_PER_QUERY)
    if get_family(params.family).proj_kind == "quadratic":
        qcodes = query_codes(index, queries, params)        # (..., L)
        squeeze = qcodes.ndim == 1
        if squeeze:
            qcodes = qcodes[None]
        marr = jnp.asarray(list(masks), jnp.uint32)
        pcodes = qcodes[:, None, :] ^ marr[None, :, None]   # (B, J, L)
        b, j, l = pcodes.shape
        lo, hi = bucket_probe_codes(pcodes.reshape(b * j, l),
                                    index.sorted_codes,
                                    use_pallas=use_pallas,
                                    interpret=interpret)
        lo, hi = lo.reshape(b, j, l), hi.reshape(b, j, l)
        return (lo[0], hi[0]) if squeeze else (lo, hi)
    return bucket_probe_multi(queries, index.projections,
                              index.sorted_codes, tuple(masks),
                              k=params.k, l=params.l,
                              use_pallas=use_pallas, interpret=interpret)
