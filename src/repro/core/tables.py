"""TPU-native LSH hash tables: a sorted-code (CSR-like) bucket index.

HARDWARE ADAPTATION.  The paper's CPU implementation stores per-bucket
pointer lists (classic chained hash tables).  Pointer chasing does not map
to TPU: memory access must be dense, vectorised gathers.  We replace the
chained table with a *sorted-code index*:

  per table t:
    codes[t, i]      uint32 packed K-bit code of point i      (L, N)
    order[t, :]      argsort of codes[t]                      (L, N) int32
    sorted_codes[t]  codes[t, order[t]]                       (L, N)

A bucket is then the contiguous slice [lo, hi) found by two binary
searches (``searchsorted``) of the query code — O(log N) per probe, fully
vectorisable over tables and over a minibatch of queries, and the *build*
is a sort (TPU-efficient) instead of millions of scatter-appends.

PERFORMANCE.  Both halves of the index hot path route through fused
Pallas kernels on TPU (``use_pallas=None`` auto-dispatches by backend;
CPU hosts take the numerically identical XLA reference):

  * build/refresh hashing runs ``kernels.simhash`` — projection matmul,
    sign and bit-pack fused into one VMEM-resident pass (linear
    families; quadratic SRP hashes via per-function quadratic forms and
    stays on the XLA path).
  * query probing runs ``kernels.bucket_probe`` — query hashing plus the
    per-table bucket search over ``sorted_codes``, fused and batched
    over queries (see ``bucket_bounds_batched``).
  * ``refresh_index`` re-sorts through the *previous* order: composing
    the old permutation with a stable argsort of the permuted codes
    keeps tie layouts identical across refreshes — the double-buffer
    property downstream consumers rely on (unchanged codes keep their
    slots).  This is a STABILITY property, not a speedup: XLA's sort is
    data-oblivious, so nearly-sorted input costs the same as random
    input, and the composition adds two O(L*N) gathers per refresh
    (negligible next to the re-hash + sort it rides on).

The index is a pytree and can be sharded over the ``data`` mesh axis so
each data-parallel group maintains the index of its own shard of the
training set (see ``repro/data/lsh_pipeline.py``).

INDEX MUTATIONS (the ONE write surface).  Everything that changes an
index — the one-time build, the periodic full refresh, the dirty-subset
delta merge, and the streaming ``append``/``evict`` membership changes —
goes through ``mutate_index(index, IndexMutation(op, ...), params)``.
The legacy per-op entry points (``build_index`` / ``refresh_index`` /
``refresh_index_delta``) survive as thin wrappers that emit
``DeprecationWarning``; see docs/ARCHITECTURE.md for the migration
table.

STREAMING / CAPACITY MODEL.  A streaming index is allocated at a
power-of-two CAPACITY C >= N (``grow_index`` doubles it — bounded
recompiles, the same trick as the delta path's power-of-two id
buckets).  Empty slots carry the sentinel code ``EMPTY_CODE``
(0xFFFFFFFF): packed K-bit codes satisfy code < 2^K, so with K <= 31
every live code sorts strictly before every sentinel — buckets of real
query codes can never contain an empty slot, and the first ``n_live``
entries of EVERY table's sorted order are exactly the live ids (what
the sampler's live-N uniform fallback gathers from).  ``append_rows``
writes fresh codes into previously-empty slots and ``evict_rows``
writes sentinels into live ones; both are the SAME tie-stable merge as
``refresh_index_delta`` (scatter into the previous sorted slots, then
a stable argsort composed through the previous order), so appended
rows land after existing equal-code ties and unchanged rows keep their
exact slots.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import default_use_pallas
from repro.kernels.bucket_probe import (
    bucket_probe,
    bucket_probe_codes,
    bucket_probe_multi,
)
from repro.kernels.simhash import simhash_codes

from .families import get_family
from .simhash import LSHParams, compute_codes, make_projections


class LSHIndex(NamedTuple):
    """Immutable sorted-code LSH index over n points (pytree)."""

    projections: jax.Array   # (d, L*K) or (L*K, d, d) for quadratic
    sorted_codes: jax.Array  # (L, N) uint32, ascending per row
    order: jax.Array         # (L, N) int32: order[t, j] = original point id

    @property
    def n_tables(self) -> int:
        return self.sorted_codes.shape[0]

    @property
    def n_points(self) -> int:
        return self.sorted_codes.shape[1]


def _hash_points(x: jax.Array, proj: jax.Array, params: LSHParams,
                 use_pallas: Optional[bool], interpret: bool) -> jax.Array:
    """(N, d) points -> (L, N) codes via the fastest path for the family.

    ``x`` is ALREADY augmented (the family's ``augment_data`` ran at the
    call site); linear families (``proj_kind`` dense/sparse — including
    the asymmetric MIPS family's augmented vectors) route through the
    fused simhash kernel dispatch, quadratic forms stay on XLA.

    Banded families (``num_bands() > 1``) return per-row high-bit tags
    from ``code_tags`` which are ORed into the packed codes here — the
    one place data codes are produced, so build/refresh/delta re-hash
    all tag identically and every band occupies a contiguous region of
    each table's sorted order (see ``band_starts``)."""
    fam = get_family(params.family)
    if fam.proj_kind == "quadratic":
        codes = compute_codes(x, proj, k=params.k, l=params.l,
                              quadratic=True)
    else:
        if use_pallas is None:
            use_pallas = default_use_pallas()
        codes = simhash_codes(x, proj, k=params.k, l=params.l,
                              use_pallas=use_pallas, interpret=interpret)
    tags = fam.code_tags(x, params.k)
    if tags is not None:
        codes = codes | tags[:, None]                       # (N, L)
    return codes.T


# Sentinel code of an EMPTY capacity slot.  Packed K-bit codes satisfy
# code < 2^K, so for K <= 31 every live code sorts strictly before the
# sentinel: empty slots cluster at the tail of every table's sorted
# order and no real query code can ever bucket onto them.
EMPTY_CODE = 0xFFFFFFFF


def _mask_codes(codes: jax.Array,
                live_mask: Optional[jax.Array]) -> jax.Array:
    """Force the codes of dead capacity slots to the sentinel."""
    if live_mask is None:
        return codes
    return jnp.where(live_mask[None, :], codes, jnp.uint32(EMPTY_CODE))


def _build_impl(key: jax.Array, x_aug: jax.Array, params: LSHParams,
                live_mask: Optional[jax.Array],
                use_pallas: Optional[bool], interpret: bool) -> LSHIndex:
    if params.dim != x_aug.shape[-1]:
        raise ValueError(f"params.dim={params.dim} != data dim {x_aug.shape[-1]}")
    proj = make_projections(key, params)
    codes = _mask_codes(
        _hash_points(x_aug, proj, params, use_pallas, interpret),
        live_mask)                                          # (L, C)
    order = jnp.argsort(codes, axis=1).astype(jnp.int32)
    sorted_codes = jnp.take_along_axis(codes, order, axis=1)
    return LSHIndex(proj, sorted_codes, order)


def _refresh_impl(index: LSHIndex, x_aug: jax.Array, params: LSHParams,
                  live_mask: Optional[jax.Array], warm_start: bool,
                  use_pallas: Optional[bool], interpret: bool) -> LSHIndex:
    codes = _mask_codes(
        _hash_points(x_aug, index.projections, params, use_pallas,
                     interpret), live_mask)                 # (L, C)
    if warm_start:
        prev = index.order
        permuted = jnp.take_along_axis(codes, prev, axis=1)
        delta = jnp.argsort(permuted, axis=1, stable=True).astype(jnp.int32)
        order = jnp.take_along_axis(prev, delta, axis=1)
        sorted_codes = jnp.take_along_axis(permuted, delta, axis=1)
    else:
        order = jnp.argsort(codes, axis=1).astype(jnp.int32)
        sorted_codes = jnp.take_along_axis(codes, order, axis=1)
    return LSHIndex(index.projections, sorted_codes, order)


@jax.jit
def _merge_impl(index: LSHIndex, ids: jax.Array,
                codes: jax.Array) -> LSHIndex:
    """The ONE tie-stable merge under delta / append / evict.

    Scatter the changed codes into their previous sorted slots (clean
    segments stay sorted), then compose a *stable* argsort back through
    the previous ``order``.  Entries are (re)placed by the key
    (new code, previous position) — bitwise what a full warm-started
    refresh computes when the unchanged codes are unchanged.
    """
    order = index.order
    l, n = order.shape
    iota = jnp.arange(n, dtype=jnp.int32)
    # position of each point id in the old sorted order, per table
    pos = jnp.zeros_like(order).at[
        jnp.arange(l, dtype=jnp.int32)[:, None], order].set(iota[None])
    pos_d = jnp.take(pos, ids.astype(jnp.int32), axis=1)    # (L, D)
    permuted = jax.vmap(lambda sc, p, c: sc.at[p].set(c))(
        index.sorted_codes, pos_d, codes)
    delta = jnp.argsort(permuted, axis=1, stable=True).astype(jnp.int32)
    new_order = jnp.take_along_axis(order, delta, axis=1)
    new_sorted = jnp.take_along_axis(permuted, delta, axis=1)
    return LSHIndex(index.projections, new_sorted, new_order)


# -- the unified mutation surface ------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class IndexMutation:
    """ONE declarative description of an index write (see ``mutate_index``).

    ``op`` selects the mode; the other fields are its payload:

      * ``"build"``   — ``key`` (projection draw) + ``x_aug`` (C, d);
        optional ``live_mask`` (C,) bool under a managed capacity.
      * ``"refresh"`` — ``x_aug`` fresh (C, d) features (projections are
        reused); ``warm_start`` keeps tie layouts stable; optional
        ``live_mask``.
      * ``"delta"``   — ``ids`` (D,) + ``codes`` (L, D): merge fresh
        codes of a dirty subset (pad D to a static bucket; duplicate
        ids with equal code columns are legal).
      * ``"append"``  — ``ids`` (D,) previously-EMPTY slots + ``codes``
        (L, D) of the new rows.
      * ``"evict"``   — ``ids`` (D,) live slots to empty (their codes
        become ``EMPTY_CODE``).

    ``tokens`` is a pipeline-level payload (raw token rows for a
    pipeline append — ``LSHSampledPipeline.mutate`` embeds + hashes
    them); ``mutate_index`` itself never reads it.
    """

    op: str
    key: Optional[jax.Array] = None
    x_aug: Optional[jax.Array] = None
    ids: Optional[jax.Array] = None
    codes: Optional[jax.Array] = None
    live_mask: Optional[jax.Array] = None
    warm_start: bool = True
    tokens: Optional[Any] = None

    _OPS = ("build", "refresh", "delta", "append", "evict")

    def __post_init__(self):
        if self.op not in self._OPS:
            raise ValueError(
                f"IndexMutation.op must be one of {self._OPS}, "
                f"got {self.op!r}")


def _require(mutation: IndexMutation, **fields):
    for name, value in fields.items():
        if value is None:
            raise ValueError(
                f"IndexMutation(op={mutation.op!r}) requires {name}")


def mutate_index(index: Optional[LSHIndex], mutation: IndexMutation,
                 params: Optional[LSHParams] = None, *,
                 use_pallas: Optional[bool] = None,
                 interpret: bool = False) -> LSHIndex:
    """THE index write entry point: apply ``mutation`` and return the new
    index (inputs are never mutated — ``LSHIndex`` is an immutable
    pytree).

    Args:
      index: the previous index — ``None`` for ``op="build"``, required
        for every other op.
      mutation: what to do (see ``IndexMutation``).
      params: hash-family hyper-parameters; required for the hashing
        ops (``build`` / ``refresh``), unused by the pure merges
        (``delta`` / ``append`` / ``evict``, whose payload is
        pre-hashed codes).
      use_pallas / interpret: kernel dispatch, as everywhere.

    Determinism: every op is a pure function of its inputs, bitwise
    reproducible on every backend.  ``append``/``evict`` share the
    delta merge's tie-stability contract: unchanged rows keep their
    exact slots, appended rows land after existing equal-code ties in
    previous-tail order, evicted rows join the sentinel tail in their
    previous relative order.
    """
    op = mutation.op
    if op == "build":
        _require(mutation, key=mutation.key, x_aug=mutation.x_aug,
                 params=params)
        return _build_impl(mutation.key, mutation.x_aug, params,
                           mutation.live_mask, use_pallas, interpret)
    if index is None:
        raise ValueError(f"IndexMutation(op={op!r}) requires an index")
    if op == "refresh":
        _require(mutation, x_aug=mutation.x_aug, params=params)
        return _refresh_impl(index, mutation.x_aug, params,
                             mutation.live_mask, mutation.warm_start,
                             use_pallas, interpret)
    if op in ("delta", "append"):
        _require(mutation, ids=mutation.ids, codes=mutation.codes)
        return _merge_impl(index, mutation.ids, mutation.codes)
    # op == "evict"
    _require(mutation, ids=mutation.ids)
    l = index.sorted_codes.shape[0]
    codes = jnp.full((l, mutation.ids.shape[0]), EMPTY_CODE, jnp.uint32)
    return _merge_impl(index, mutation.ids, codes)


def append_rows(index: LSHIndex, ids: jax.Array,
                codes: jax.Array) -> LSHIndex:
    """Merge new rows into previously-EMPTY capacity slots.

    ``ids``: (D,) int32 slot ids that currently hold ``EMPTY_CODE``;
    ``codes``: (L, D) uint32 fresh codes of the appended rows.  Pad D
    to a static bucket by REPEATING an entry (duplicate ids with equal
    code columns are a no-op under the scatter), bounding recompiles
    exactly like the delta path.  Same tie-stable merge as
    ``refresh_index_delta``: every live row keeps its slot; appended
    rows insert after existing equal-code ties.
    """
    return _merge_impl(index, ids, codes)


def evict_rows(index: LSHIndex, ids: jax.Array) -> LSHIndex:
    """Empty the given live slots (their codes become ``EMPTY_CODE``).

    ``ids``: (D,) int32 — pad D to a static bucket by repeating an
    entry.  Evicted slots join the sentinel tail of every table's
    sorted order (stable among themselves); all remaining live rows
    keep their exact slots, so the live prefix ``order[t, :n_live]``
    stays a permutation of the live ids for every table t.
    """
    l = index.sorted_codes.shape[0]
    codes = jnp.full((l, ids.shape[0]), EMPTY_CODE, jnp.uint32)
    return _merge_impl(index, ids, codes)


def grow_index(index: LSHIndex, new_capacity: int) -> LSHIndex:
    """Grow a capacity-managed index to ``new_capacity`` slots.

    The new slots are EMPTY (sentinel codes) and are appended to the
    tail of every table's sorted order in slot order — the arrays stay
    sorted (the sentinel is the maximum code) and every existing row
    keeps its exact slot.  Callers double capacity (powers of two) so
    the per-shape jit programs downstream recompile O(log N) times
    total.
    """
    l, n = index.order.shape
    if new_capacity < n:
        raise ValueError(
            f"new_capacity={new_capacity} < current capacity {n} "
            "(shrink by compaction at the store level, not here)")
    if new_capacity == n:
        return index
    pad = new_capacity - n
    sorted_codes = jnp.pad(index.sorted_codes, ((0, 0), (0, pad)),
                           constant_values=np.uint32(EMPTY_CODE))
    extra = jnp.broadcast_to(
        jnp.arange(n, new_capacity, dtype=jnp.int32)[None], (l, pad))
    order = jnp.concatenate([index.order, extra], axis=1)
    return LSHIndex(index.projections, sorted_codes, order)


# -- deprecated per-op wrappers (migrate to mutate_index) ------------------


def _warn_deprecated(old: str, new: str):
    warnings.warn(
        f"repro.core.tables.{old} is deprecated; use "
        f"mutate_index(index, IndexMutation({new}), params) — "
        "see docs/ARCHITECTURE.md 'Index mutation API & stability'",
        DeprecationWarning, stacklevel=3)


def build_index(key: jax.Array, x_aug: jax.Array, params: LSHParams,
                *, use_pallas: Optional[bool] = None,
                interpret: bool = False) -> LSHIndex:
    """DEPRECATED thin wrapper: ``mutate_index(None,
    IndexMutation("build", key=key, x_aug=x_aug), params)``.

    One-time (or periodic-refresh) preprocessing: hash + sort per
    table.  A pure function of (key, x_aug, params) — two builds with
    the same inputs are bitwise identical on every backend, which is
    what ``restore_at``-style canonical rebuilds rely on.
    """
    _warn_deprecated("build_index", '"build", key=..., x_aug=...')
    return _build_impl(key, x_aug, params, None, use_pallas, interpret)


def refresh_index(key: jax.Array, index: LSHIndex, x_aug: jax.Array,
                  params: LSHParams, *, use_pallas: Optional[bool] = None,
                  interpret: bool = False,
                  warm_start: bool = True) -> LSHIndex:
    """DEPRECATED thin wrapper: ``mutate_index(index,
    IndexMutation("refresh", x_aug=x_aug, warm_start=...), params)``.

    Re-hash the (possibly updated) points, keeping the same projections
    (Sec. 3.2 / Appendix E periodic refresh).  With ``warm_start`` the
    previous ``order`` seeds the re-sort: codes are permuted by the old
    order first and a *stable* argsort of that permutation is composed
    back — ties keep their previous relative layout (stable double
    buffering of bucket slices) and points whose codes did not change
    keep their exact slots.  ``key`` is unused (projections are
    reused); kept for wrapper signature compatibility.
    """
    del key
    _warn_deprecated("refresh_index", '"refresh", x_aug=...')
    return _refresh_impl(index, x_aug, params, None, warm_start,
                         use_pallas, interpret)


def refresh_index_delta(index: LSHIndex, dirty_ids: jax.Array,
                        dirty_codes: jax.Array) -> LSHIndex:
    """DEPRECATED thin wrapper: ``mutate_index(index,
    IndexMutation("delta", ids=dirty_ids, codes=dirty_codes))``.

    Merge re-hashed codes for a dirty subset into the sorted index.
    Clean points are NOT re-hashed — the O(N·d·L·K) hash (and the
    O(N·model) re-embed upstream) scale with |dirty|; only the
    tie-stable merge touches all N entries.  Delta-refresh with ALL
    points dirty is bit-identical to a full warm-started refresh, and
    a dirty point whose code did not change keeps its exact slot.
    """
    _warn_deprecated("refresh_index_delta",
                     '"delta", ids=..., codes=...')
    return _merge_impl(index, dirty_ids, dirty_codes)


def hash_points(x: jax.Array, proj: jax.Array, params: LSHParams,
                *, use_pallas: Optional[bool] = None,
                interpret: bool = False) -> jax.Array:
    """Public (L, N)-layout hashing entry: the delta-refresh re-hash path."""
    return _hash_points(x, proj, params, use_pallas, interpret)


def query_codes(index: LSHIndex, q: jax.Array, params: LSHParams) -> jax.Array:
    """Hash a query (d,) or batch (m, d) -> (L,) or (m, L) uint32."""
    return compute_codes(
        q, index.projections, k=params.k, l=params.l,
        quadratic=get_family(params.family).proj_kind == "quadratic",
    )


def bucket_bounds(index: LSHIndex, qcodes: jax.Array):
    """For each table, the [lo, hi) slice of the query's bucket.

    qcodes: (L,) uint32 -> lo, hi: (L,) int32.  Vectorised binary search
    (the XLA reference path; the hot path is ``bucket_bounds_batched``).
    """
    def per_table(sc, c):
        lo = jnp.searchsorted(sc, c, side="left")
        hi = jnp.searchsorted(sc, c, side="right")
        return lo.astype(jnp.int32), hi.astype(jnp.int32)

    return jax.vmap(per_table)(index.sorted_codes, qcodes)


# The counting kernel streams all L*N sorted codes per probe call, so its
# per-query HBM traffic is L*N*4/B bytes.  Auto-dispatch only routes a
# probe through it when N/B is below this bound (~52 MB of codes for
# L=100 at the default) — above it the O(log N) searchsorted reference
# wins and keeps the paper's O(1)-per-step property for huge N.
COUNTING_PROBE_MAX_POINTS_PER_QUERY = 1 << 17


def bucket_bounds_batched(index: LSHIndex, queries: jax.Array,
                          params: LSHParams, *,
                          use_pallas: Optional[bool] = None,
                          interpret: bool = False):
    """Fused hash+probe for a query batch (B, d) (or a single (d,)).

    Returns (lo, hi) int32 of shape (B, L) — or (L,) for a 1-D query.
    On TPU this is one ``kernels.bucket_probe`` pass: the L*K projection
    matmul, sign/bit-pack and the per-table bucket search run in a
    single VMEM-resident kernel, amortised over the query batch.
    Elsewhere (or with ``use_pallas=False``) it lowers to the identical
    XLA reference: ``compute_codes`` + vmapped binary searches.

    Auto-dispatch (``use_pallas=None``) is N/B-aware: the counting
    kernel reads every sorted code, so for very large indexes probed by
    few queries the reference binary search is the faster path (see
    ``COUNTING_PROBE_MAX_POINTS_PER_QUERY``).  Pass ``use_pallas=True``
    to force the kernel regardless.  The dispatch-never-loses contract
    is gated in CI: ``benchmarks/run.py tab_sampling_cost`` times the
    dispatched path against the reference INTERLEAVED in one loop
    (sequential loops once recorded machine-load drift as a phantom 9%
    probe regression) and ``check_regression.py`` caps the ratio at
    ``--probe-cap``.
    """
    if use_pallas is None:
        b = queries.shape[0] if queries.ndim == 2 else 1
        use_pallas = (default_use_pallas() and
                      index.n_points <= b * COUNTING_PROBE_MAX_POINTS_PER_QUERY)
    if get_family(params.family).proj_kind == "quadratic":
        # quadratic SRP hashes via per-function quadratic forms — not a
        # single matmul — so hash on the XLA path, probe in the kernel.
        qcodes = query_codes(index, queries, params)
        return bucket_probe_codes(qcodes, index.sorted_codes,
                                  use_pallas=use_pallas, interpret=interpret)
    return bucket_probe(queries, index.projections, index.sorted_codes,
                        k=params.k, l=params.l, use_pallas=use_pallas,
                        interpret=interpret)


def bucket_bounds_multi(index: LSHIndex, queries: jax.Array,
                        params: LSHParams, masks: tuple, *,
                        use_pallas: Optional[bool] = None,
                        interpret: bool = False):
    """Bucket bounds for the full multi-probe code sequence.

    For every query, table t and probe mask ``masks[j]``, the [lo, hi)
    slice of the bucket whose packed code is ``code(q)[t] ^ masks[j]``
    (``core.simhash.probe_masks`` generates the deterministic
    Hamming-ball sequence).

    Args:
      index: the sorted-code index to probe.
      queries: (B, d) query batch or a single (d,) query.
      params: hash-family hyper-parameters (static).
      masks: static tuple of XOR masks (J = len(masks)).
      use_pallas / interpret: kernel dispatch, same contract as
        ``bucket_bounds_batched``.

    Returns:
      (lo, hi) int32 of shape (B, J, L) — or (J, L) for a 1-D query.

    Dispatch: the fused multi-probe kernel hashes each query once and
    counts all J probe codes against the SAME streamed sorted-code
    tile, so its HBM traffic equals the single-probe kernel's — the
    N/B auto-dispatch cutover is therefore unchanged (per QUERY, not
    per probe code).  Quadratic SRP hashes on the XLA path and probes
    the J·L perturbed codes through the probe-only kernel.
    """
    if use_pallas is None:
        b = queries.shape[0] if queries.ndim == 2 else 1
        use_pallas = (default_use_pallas() and
                      index.n_points <= b * COUNTING_PROBE_MAX_POINTS_PER_QUERY)
    if get_family(params.family).proj_kind == "quadratic":
        qcodes = query_codes(index, queries, params)        # (..., L)
        squeeze = qcodes.ndim == 1
        if squeeze:
            qcodes = qcodes[None]
        marr = jnp.asarray(list(masks), jnp.uint32)
        pcodes = qcodes[:, None, :] ^ marr[None, :, None]   # (B, J, L)
        b, j, l = pcodes.shape
        lo, hi = bucket_probe_codes(pcodes.reshape(b * j, l),
                                    index.sorted_codes,
                                    use_pallas=use_pallas,
                                    interpret=interpret)
        lo, hi = lo.reshape(b, j, l), hi.reshape(b, j, l)
        return (lo[0], hi[0]) if squeeze else (lo, hi)
    return bucket_probe_multi(queries, index.projections,
                              index.sorted_codes, tuple(masks),
                              k=params.k, l=params.l,
                              use_pallas=use_pallas, interpret=interpret)


# -- banded (norm-ranged) probing ------------------------------------------


def band_starts(index: LSHIndex, params: LSHParams) -> jax.Array:
    """Start offsets of each band's region in the sorted order.

    Banded families OR ``band << K`` into the high bits of every data
    code (``_hash_points``), so each band is a contiguous region of
    every table's sorted order and the region boundaries are the SAME
    across tables (each table sorts the same per-row tags).  Recover
    them in-jit by binary-searching table 0:

    Returns (num_bands + 1,) int32 with ``starts[j] <= i < starts[j+1]``
    iff sorted slot i holds a band-j row.  ``starts[-1]`` is the live
    count: the edge code ``num_bands << K`` is at most ``2^code_width``
    <= 2^31, which still sorts strictly below the ``EMPTY_CODE``
    sentinel tail — the same inequality the streaming capacity model
    rests on (``data.lsh_pipeline`` enforces ``code_width(K) <= 31``).
    """
    nb = get_family(params.family).num_bands()
    edges = jnp.arange(1, nb + 1, dtype=jnp.uint32) << jnp.uint32(params.k)
    starts = jnp.searchsorted(
        index.sorted_codes[0], edges, side="left").astype(jnp.int32)
    return jnp.concatenate([jnp.zeros((1,), jnp.int32), starts])


def bucket_bounds_banded(index: LSHIndex, queries: jax.Array,
                         params: LSHParams, masks: tuple, *,
                         use_pallas: Optional[bool] = None,
                         interpret: bool = False):
    """Multi-probe bucket bounds in EVERY band for a banded index.

    The query's augmented vector hashes untagged (its band coordinate
    is 0 and that projection row is zeroed), so the probe codes for
    band j are ``(code(q)[t] ^ masks[p]) | (j << K)`` — the same
    Hamming-ball walk as ``bucket_bounds_multi``, replicated across the
    band tags.  All ``num_bands * J * L`` probe codes go through the
    ``bucket_probe_codes`` kernel in one batch (the quadratic family's
    pre-computed-codes route), so no new kernel is needed.

    Returns:
      (lo, hi) int32 of shape (B, num_bands, J, L) — or
      (num_bands, J, L) for a single (d,) query.
    """
    nb = get_family(params.family).num_bands()
    if use_pallas is None:
        b = queries.shape[0] if queries.ndim == 2 else 1
        use_pallas = (default_use_pallas() and
                      index.n_points <= b * COUNTING_PROBE_MAX_POINTS_PER_QUERY)
    qcodes = query_codes(index, queries, params)            # (..., L)
    squeeze = qcodes.ndim == 1
    if squeeze:
        qcodes = qcodes[None]
    marr = jnp.asarray(list(masks), jnp.uint32)
    tags = jnp.arange(nb, dtype=jnp.uint32) << jnp.uint32(params.k)
    pcodes = ((qcodes[:, None, None, :] ^ marr[None, None, :, None])
              | tags[None, :, None, None])                  # (B, nb, J, L)
    b, _, j, l = pcodes.shape
    lo, hi = bucket_probe_codes(pcodes.reshape(b * nb * j, l),
                                index.sorted_codes,
                                use_pallas=use_pallas, interpret=interpret)
    lo, hi = lo.reshape(b, nb, j, l), hi.reshape(b, nb, j, l)
    return (lo[0], hi[0]) if squeeze else (lo, hi)
