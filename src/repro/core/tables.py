"""TPU-native LSH hash tables: a sorted-code (CSR-like) bucket index.

HARDWARE ADAPTATION.  The paper's CPU implementation stores per-bucket
pointer lists (classic chained hash tables).  Pointer chasing does not map
to TPU: memory access must be dense, vectorised gathers.  We replace the
chained table with a *sorted-code index*:

  per table t:
    codes[t, i]      uint32 packed K-bit code of point i      (L, N)
    order[t, :]      argsort of codes[t]                      (L, N) int32
    sorted_codes[t]  codes[t, order[t]]                       (L, N)

A bucket is then the contiguous slice [lo, hi) found by two binary
searches (``searchsorted``) of the query code — O(log N) per probe, fully
vectorisable over tables and over a minibatch of queries, and the *build*
is a sort (TPU-efficient) instead of millions of scatter-appends.

The index is a pytree and can be sharded over the ``data`` mesh axis so
each data-parallel group maintains the index of its own shard of the
training set (see ``repro/data/lsh_pipeline.py``).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .simhash import LSHParams, compute_codes, make_projections


class LSHIndex(NamedTuple):
    """Immutable sorted-code LSH index over n points (pytree)."""

    projections: jax.Array   # (d, L*K) or (L*K, d, d) for quadratic
    sorted_codes: jax.Array  # (L, N) uint32, ascending per row
    order: jax.Array         # (L, N) int32: order[t, j] = original point id

    @property
    def n_tables(self) -> int:
        return self.sorted_codes.shape[0]

    @property
    def n_points(self) -> int:
        return self.sorted_codes.shape[1]


def build_index(key: jax.Array, x_aug: jax.Array, params: LSHParams) -> LSHIndex:
    """One-time (or periodic-refresh) preprocessing: hash + sort per table."""
    if params.dim != x_aug.shape[-1]:
        raise ValueError(f"params.dim={params.dim} != data dim {x_aug.shape[-1]}")
    proj = make_projections(key, params)
    codes = compute_codes(
        x_aug, proj, k=params.k, l=params.l, quadratic=params.family == "quadratic"
    )  # (N, L)
    codes = codes.T  # (L, N)
    order = jnp.argsort(codes, axis=1).astype(jnp.int32)
    sorted_codes = jnp.take_along_axis(codes, order.astype(jnp.int32), axis=1)
    return LSHIndex(proj, sorted_codes, order)


def refresh_index(key: jax.Array, index: LSHIndex, x_aug: jax.Array,
                  params: LSHParams) -> LSHIndex:
    """Re-hash the (possibly updated) points, keeping the same projections.

    Used for deep models where stored features drift slowly (Sec. 3.2 /
    Appendix E): hash tables are periodically rebuilt from fresh features.
    `key` is unused when projections are reused but kept for API symmetry.
    """
    del key
    codes = compute_codes(
        x_aug, index.projections, k=params.k, l=params.l,
        quadratic=params.family == "quadratic",
    ).T
    order = jnp.argsort(codes, axis=1).astype(jnp.int32)
    sorted_codes = jnp.take_along_axis(codes, order, axis=1)
    return LSHIndex(index.projections, sorted_codes, order)


def query_codes(index: LSHIndex, q: jax.Array, params: LSHParams) -> jax.Array:
    """Hash a query (d,) or batch (m, d) -> (L,) or (m, L) uint32."""
    return compute_codes(
        q, index.projections, k=params.k, l=params.l,
        quadratic=params.family == "quadratic",
    )


def bucket_bounds(index: LSHIndex, qcodes: jax.Array):
    """For each table, the [lo, hi) slice of the query's bucket.

    qcodes: (L,) uint32 -> lo, hi: (L,) int32.  Vectorised binary search.
    """
    def per_table(sc, c):
        lo = jnp.searchsorted(sc, c, side="left")
        hi = jnp.searchsorted(sc, c, side="right")
        return lo.astype(jnp.int32), hi.astype(jnp.int32)

    return jax.vmap(per_table)(index.sorted_codes, qcodes)
