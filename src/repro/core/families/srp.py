"""Signed-random-projection (SimHash) family — the paper's workhorse.

Collision probability (Goemans–Williamson):

    cp(x, q) = 1 - arccos(cos_sim(x, q)) / pi

monotonically increasing in the inner product for normalised vectors —
the monotonicity LGD's adaptive distribution relies on, which is why
the symmetric SRP callers (``core.lgd`` preprocess, the pipeline's
feature path) row-normalise stored vectors before hashing.  The family
itself is augmentation-free: ``augment_data`` is the identity, and the
probability formula is exact for vectors of ANY norm (the cosine
normalises internally), so un-normalised inputs merely weaken the
monotonicity link, never the unbiasedness.

Two registry entries share this class: ``"dense"`` (dense Gaussian
projections) and ``"sparse"`` (Li et al. very-sparse Rademacher
projections, density ~1/30 as in the paper's experiments) — they
differ only in the projection tensor ``core.simhash.make_projections``
draws (``proj_kind``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import LSHFamily, normalize_rows


def srp_collision_prob(x: jax.Array, q: jax.Array) -> jax.Array:
    """SimHash collision probability cp(x,q) = 1 - arccos(cos)/pi.

    x: (..., d), q: (d,) or broadcastable.  Computed in float32.  The
    exact expression the pre-family stack used (``core.simhash.
    collision_probability`` re-exports it) — pinned bit-identical by
    the SRP parity tests.
    """
    xn = jnp.linalg.norm(x, axis=-1)
    qn = jnp.linalg.norm(q, axis=-1)
    cos = jnp.sum(x * q, axis=-1) / jnp.maximum(xn * qn, 1e-30)
    cos = jnp.clip(cos, -1.0, 1.0)
    return 1.0 - jnp.arccos(cos) / jnp.pi


@dataclasses.dataclass(frozen=True)
class SignedRPFamily(LSHFamily):
    """Symmetric SRP: identity augmentation, cosine collision law.

    ``augment_query`` L2-normalises (cp is scale-invariant, so this
    changes no probability — it keeps the pipeline's query handling,
    which always normalised, inside the family contract)."""

    name: str = "dense"
    proj_kind: str = "dense"
    asymmetric: bool = False

    def augment_query(self, q: jax.Array) -> jax.Array:
        return normalize_rows(q)

    def collision_prob(self, x_aug: jax.Array, q_aug: jax.Array) -> jax.Array:
        return srp_collision_prob(x_aug, q_aug)
