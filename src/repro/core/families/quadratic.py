"""Quadratic SRP family: SimHash over the implicit expansion T(v)=vec(v vᵀ).

Handles the |⟨q, x⟩| absolute value of the paper's optimal weight
exactly (Sec. 2.1): collision probability is monotonic in (v·q)², so
sign-symmetric gradients hash to the same buckets.  A projection w on
T(v) is the quadratic form vᵀ M v, evaluated without materialising T —
which is why ``proj_kind = "quadratic"`` draws per-function (d, d)
matrices and hashing stays on the XLA path (no single-matmul structure
for the fused simhash kernel to exploit).

    cos(T(x), T(q)) = (x·q)² / (‖x‖² ‖q‖²)     (⟨T(u),T(v)⟩ = (u·v)²)
    cp = 1 - arccos(cos)/π
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import LSHFamily


def quadratic_collision_prob(x: jax.Array, q: jax.Array) -> jax.Array:
    """Collision prob. of QuadraticSRP = SimHash cp between T(x), T(q).

    The exact pre-family expression (``core.simhash.
    collision_probability_quadratic`` re-exports it)."""
    xn2 = jnp.sum(x * x, axis=-1)
    qn2 = jnp.sum(q * q, axis=-1)
    ip = jnp.sum(x * q, axis=-1)
    cos = ip * ip / jnp.maximum(xn2 * qn2, 1e-30)
    cos = jnp.clip(cos, -1.0, 1.0)
    return 1.0 - jnp.arccos(cos) / jnp.pi


@dataclasses.dataclass(frozen=True)
class QuadraticSRPFamily(LSHFamily):
    """Symmetric quadratic SRP: identity augmentation, (v·q)² law."""

    name: str = "quadratic"
    proj_kind: str = "quadratic"
    asymmetric: bool = False

    def collision_prob(self, x_aug: jax.Array, q_aug: jax.Array) -> jax.Array:
        return quadratic_collision_prob(x_aug, q_aug)
