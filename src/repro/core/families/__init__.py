"""Pluggable LSH families: registry + the contract (see ``base``).

Every layer above ``core`` names a family by its registry key and asks
``get_family`` for the object; nothing outside this package hard-wires
a collision law or an augmentation.

Registered families:

  ``dense``      symmetric SRP, dense Gaussian projections
  ``sparse``     symmetric SRP, very-sparse Rademacher projections
  ``srp``        alias of ``dense`` (the user-facing CLI name)
  ``quadratic``  SRP over the implicit quadratic expansion T(v)
  ``mips``       asymmetric Simple-LSH MIPS (un-normalised corpora)
  ``mips_banded`` norm-ranged MIPS: banded sub-indexes with per-band
                 scales M_j (heavy-tailed norm distributions)
"""

from __future__ import annotations

from .banded import BandedScale, NormRangedMIPSFamily  # noqa: F401
from .base import LSHFamily, normalize_rows  # noqa: F401
from .mips import SimpleLSHMIPSFamily
from .quadratic import QuadraticSRPFamily, quadratic_collision_prob  # noqa: F401
from .srp import SignedRPFamily, srp_collision_prob  # noqa: F401

_DENSE = SignedRPFamily(name="dense", proj_kind="dense")
_SPARSE = SignedRPFamily(name="sparse", proj_kind="sparse")

FAMILIES = {
    "dense": _DENSE,
    "sparse": _SPARSE,
    "srp": _DENSE,            # CLI-facing alias
    "quadratic": QuadraticSRPFamily(),
    "mips": SimpleLSHMIPSFamily(),
    "mips_banded": NormRangedMIPSFamily(),
}


def get_family(name: str) -> LSHFamily:
    """Resolve a registry key to its family singleton (KeyError-safe)."""
    try:
        return FAMILIES[name]
    except KeyError:
        raise ValueError(
            f"unknown LSH family {name!r}; registered: "
            f"{sorted(FAMILIES)}") from None


def family_names() -> tuple:
    return tuple(sorted(FAMILIES))
