"""The LSH-family contract every layer of the stack is generic over.

Algorithm 1 of the paper needs ONE thing from its hash family: an exact
closed-form collision probability that is monotonic in the quantity the
sampler should favour (the optimal sampling weight w*_i ∝ ||∇f_i||,
Needell et al.).  Everything else — augmentation of stored vectors and
queries, the per-probe-class probabilities of multi-probe querying, the
packed code width — is family detail the rest of the stack must not
hard-wire.  This module defines that contract; concrete families live
next to it (``srp.py``, ``quadratic.py``, ``mips.py``) and register in
``core.families.get_family``.

The contract (all methods pure jnp, jit-safe; family objects are frozen
dataclass singletons, hashable, and therefore legal inside jit-static
``LSHParams``):

* ``augment_data(x, scale=None)`` — map raw stored vectors (N, d) to
  the vectors actually hashed/indexed (N, aug_dim(d)).  Symmetric
  families return ``x`` unchanged; asymmetric (MIPS) families append
  the Simple-LSH norm coordinate.  ``scale`` pins a data-dependent
  normaliser (MIPS: the max row norm) so partial re-augmentations
  (delta refresh) stay consistent with the full build; ``None`` lets
  the family derive it from ``x``.
* ``data_scale(x)`` — the scale ``augment_data`` would derive from
  ``x`` (symmetric families: ``None``).  Callers that re-augment
  subsets later (the pipeline's delta refresh) capture it once here.
* ``augment_query(q)`` — map a raw query (…, d) to the hashed query
  (…, aug_dim(d)).  Never needs the data scale: asymmetry means only
  the data side carries it (Shrivastava & Li).
* ``collision_prob(x_aug, q_aug)`` — the family's exact per-hash
  collision probability, evaluated on AUGMENTED vectors.  This is the
  closed form the sampler's probability correction, the estimator's
  ``exact_inclusion_probability`` and the statistical property tests
  all share.
* ``probe_class_probs(cp, k, rs)`` — multi-probe class probabilities:
  the probability q_r that a point with per-bit collision probability
  ``cp`` lands in the bucket of a weight-``r`` XOR mask of the query's
  K-bit code.  Default ``cp^(K-r) (1-cp)^r`` — exact whenever the K
  bits are i.i.d. sign agreements, which holds for every SRP-derived
  family here.
* ``code_width(k)`` — packed bits per table code (k for the flat
  families; the banded MIPS family widens it by its band-tag bits
  without touching ``tables.py``).
* ``num_bands()`` / ``code_tags(x_aug, k)`` / ``mask_projections(p)``
  — the multi-index (norm-ranging) hooks.  A banded family partitions
  the corpus into ``num_bands()`` sub-indexes that share ONE sorted-
  code index: ``code_tags`` returns per-row high-bit tags ORed into
  the packed codes at hash time (band regions become contiguous slices
  of every table) and ``mask_projections`` zeroes projection rows of
  augmentation coordinates that carry index layout rather than
  geometry.  Flat families return 1 / ``None`` / the projections
  unchanged — the defaults below keep every existing family
  bit-identical.
* ``aug_dim(d)`` — dimensionality after ``augment_data``.
* ``proj_kind`` — "dense" | "sparse" | "quadratic": which projection
  tensor ``core.simhash.make_projections`` draws, and whether hashing
  routes through the fused linear simhash kernel (dense/sparse) or the
  per-function quadratic-form XLA path.
* ``asymmetric`` — True when data and query augmentations differ (the
  caller must NOT row-normalise stored vectors; the family owns the
  norm information).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LSHFamily:
    """Base contract; concrete families override the augment/cp methods."""

    name: str = "base"
    proj_kind: str = "dense"     # "dense" | "sparse" | "quadratic"
    asymmetric: bool = False

    # -- augmentation -------------------------------------------------------

    def augment_data(self, x: jax.Array, scale=None) -> jax.Array:
        """Raw stored vectors -> hashed vectors (identity by default)."""
        del scale
        return x

    def data_scale(self, x: jax.Array):
        """The scale ``augment_data`` derives from ``x`` (None = stateless)."""
        del x
        return None

    def augment_query(self, q: jax.Array) -> jax.Array:
        """Raw query -> hashed query (identity by default)."""
        return q

    def aug_dim(self, d: int) -> int:
        """Dimensionality of augmented vectors given raw dimension d."""
        return d

    # -- probabilities ------------------------------------------------------

    def collision_prob(self, x_aug: jax.Array, q_aug: jax.Array) -> jax.Array:
        """Exact per-hash collision probability on augmented vectors."""
        raise NotImplementedError

    def probe_class_probs(self, cp: jax.Array, k: int,
                          rs: jax.Array) -> jax.Array:
        """q_r = cp^(K-r) (1-cp)^r for mask popcounts ``rs`` (float array).

        Exact for i.i.d. per-bit collisions — every SRP-derived family.
        A family with correlated bits must override this alongside
        ``collision_prob`` to keep multi-probe weights unbiased.
        """
        return cp ** (k - rs) * (1.0 - cp) ** rs

    # -- code layout --------------------------------------------------------

    def code_width(self, k: int) -> int:
        """Packed bits per table code (k sign bits for SRP families)."""
        return k

    # -- multi-index (norm-ranging) hooks -----------------------------------

    def num_bands(self) -> int:
        """Number of norm bands (1 = flat family, no band routing)."""
        return 1

    def code_tags(self, x_aug: jax.Array, k: int):
        """Per-row uint32 high-bit tags ORed into packed codes at hash
        time (``None`` = untagged; banded families return band << k)."""
        del x_aug, k
        return None

    def mask_projections(self, proj: jax.Array) -> jax.Array:
        """Post-draw projection adjustment (identity for flat families;
        banded families zero the band coordinate's row)."""
        return proj


def normalize_rows(v: jax.Array) -> jax.Array:
    """Row-L2 normalisation with the stack-wide 1e-30 guard.

    The exact expression the pre-family pipeline used — families that
    normalise (SRP query side) must keep these bits so the SRP path
    stays pinned bit-identical.
    """
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-30)
