"""Norm-ranged MIPS family: banded Simple-LSH sub-indexes (Yan et al.).

The plain ``mips`` family's calibration boundary is documented and
measured: with one global scale M = max_i ||x_i||, a heavy-tailed
(log-normal) norm distribution lets a single outlier dominate M, every
bulk row collapses toward the augmentation pole [0, ..., 0, 1], probed
buckets are empty with *correlated* occupancy, and the paper's
(1-q)^(l-1) miss factor degrades to a measured E[1/(p*N)] ~ 0.55 —
a silently biased estimator (docs/ARCHITECTURE.md).

Norm-ranging is the literature's fix (Yan et al., "Norm-Ranging LSH for
Maximum Inner Product Search"): partition the corpus into ``n_bands``
norm bands at quantile boundaries, and run Simple-LSH *per band* with a
per-band scale

    M_j = max { ||x_i|| : i in band j }.

Within a band the norm ratio is bounded, no row sits near the pole, and
the populated-bucket regime where Algorithm 1's probability formula is
exact is restored — at log-normal norms, not just mild spreads.

COMPOSITE INDEX WITHOUT NEW MACHINERY.  A sub-index per band would
duplicate every table structure; instead the band id is packed into the
HIGH bits of the uint32 table code:

    code'(x) = (band(x) << K) | srp_code(S_j(x))          (K sign bits)

so the sorted-code index groups each band into a contiguous region of
every table (``tables.band_starts`` recovers the partition in-jit by
binary search), buckets never mix bands, and every fused kernel —
``simhash``, ``bucket_probe``/multi-probe, ``gather_weight`` — is
reused unchanged.  The augmented vector carries the band id as a final
coordinate whose projection row is zeroed (``mask_projections``), so
hashing ignores it and ``code_tags`` recovers it at hash time.

EXACT PER-BAND PROBABILITY COMPOSITION.  A draw first selects a band
with probability n_j / n_live (its live-row share, read off the sorted
index), then runs Algorithm 1 inside the band:

    p = (n_j / n_live) * q_r * (1 - Q)^(l-1) / |S_b|

with q_r evaluated at the band's scale (the SRP law normalises
internally, so cp is exact on the band-augmented pair).  Summing over
bands restores E[1/(p*N)] = 1 exactly in the populated-bucket regime —
pinned by ``tests/test_norm_ranging.py`` on the log-normal corpus where
plain ``mips`` measures ~0.55.

SCALE PINNING.  ``data_scale`` returns a ``BandedScale`` (quantile
boundaries + per-band maxima) — a pytree, so the pipeline pins and
replays it exactly like the plain family's scalar M.  Band assignment
is a pure function of (row norm, pinned boundaries): delta refresh,
append and mutation-log replay all re-derive it bit-deterministically,
and a drifted row that crosses a boundary simply changes code (band tag
included) through the ordinary tie-stable merge.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .base import normalize_rows
from .mips import SimpleLSHMIPSFamily
from .srp import srp_collision_prob


class BandedScale(NamedTuple):
    """Pinned norm-ranging state (a pytree — pipelines treat it opaquely).

    boundaries: (n_bands - 1,) ascending norm quantile edges; a row with
      norm exactly on ``boundaries[j]`` belongs to band j + 1 (the
      ``searchsorted(side="right")`` tie rule, pinned by tests).
    scales: (n_bands,) per-band maxima M_j (>= every member norm at
      derivation time, 1e-30 guarded; empty bands carry the guard).
    """

    boundaries: jax.Array
    scales: jax.Array


@dataclasses.dataclass(frozen=True)
class NormRangedMIPSFamily(SimpleLSHMIPSFamily):
    """Banded Simple-LSH MIPS: per-band scales M_j + band-tagged codes."""

    name: str = "mips_banded"
    n_bands: int = 8

    # -- banding hooks (family contract) ------------------------------------

    def num_bands(self) -> int:
        return self.n_bands

    def band_bits(self) -> int:
        return (self.n_bands - 1).bit_length()

    def code_width(self, k: int) -> int:
        # band tag occupies the bits ABOVE the K sign bits
        return k + self.band_bits()

    def aug_dim(self, d: int) -> int:
        return d + 2                     # Simple-LSH tail + band coordinate

    # -- band assignment -----------------------------------------------------

    def band_of_norms(self, norms: jax.Array,
                      boundaries: jax.Array) -> jax.Array:
        """Band id per norm under the pinned boundaries (tie -> upper)."""
        return jnp.searchsorted(boundaries, norms,
                                side="right").astype(jnp.int32)

    def data_scale(self, x: jax.Array) -> BandedScale:
        """Quantile boundaries over live (positive-norm) rows + band maxima.

        Dead rows (zeroed by the streaming pipeline before scale
        derivation) have norm 0 and are excluded from the quantiles so
        recycled slots never skew the banding.
        """
        if x.ndim != 2:
            raise ValueError(
                f"banded data_scale expects a (N, d) corpus, got {x.shape}")
        nb = self.n_bands
        norms = jnp.linalg.norm(x, axis=-1)                  # (N,)
        live = norms > 1e-30
        n_live = jnp.sum(live.astype(jnp.int32))
        sorted_norms = jnp.sort(jnp.where(live, norms, jnp.inf))
        js = jnp.arange(1, nb, dtype=jnp.int32)
        pos = jnp.clip((n_live * js) // nb, 0, norms.shape[0] - 1)
        boundaries = sorted_norms[pos]
        # all-dead corpus: no live norm to split on; collapse every row
        # into the top band (the all-rows-in-one-band degenerate case)
        boundaries = jnp.where(jnp.isfinite(boundaries), boundaries, 0.0)
        bands = self.band_of_norms(norms, boundaries)
        scales = jnp.full((nb,), 1e-30, norms.dtype).at[bands].max(
            jnp.where(live, norms, 0.0))
        return BandedScale(boundaries=boundaries,
                           scales=jnp.maximum(scales, 1e-30))

    def augment_data(self, x: jax.Array,
                     scale: Optional[BandedScale] = None) -> jax.Array:
        """[x/M_band, sqrt(1 - ||x/M_band||^2), band] per row."""
        scale = self.data_scale(x) if scale is None else scale
        norms = jnp.linalg.norm(x, axis=-1)
        bands = self.band_of_norms(norms, scale.boundaries)
        m = jnp.take(scale.scales, bands)                    # (...,)
        xs = x / m[..., None]
        sq = jnp.sum(xs * xs, axis=-1, keepdims=True)
        tail = jnp.sqrt(jnp.maximum(1.0 - sq, 0.0))
        return jnp.concatenate(
            [xs, tail, bands[..., None].astype(x.dtype)], axis=-1)

    def augment_query(self, q: jax.Array) -> jax.Array:
        qn = normalize_rows(q)
        zeros = jnp.zeros(qn.shape[:-1] + (2,), qn.dtype)
        return jnp.concatenate([qn, zeros], axis=-1)

    # -- code layout hooks ---------------------------------------------------

    def code_tags(self, x_aug: jax.Array, k: int) -> jax.Array:
        """(N,) uint32 high-bit band tags ORed into the packed codes."""
        band = jnp.round(x_aug[..., -1]).astype(jnp.uint32)
        return band << jnp.uint32(k)

    def mask_projections(self, proj: jax.Array) -> jax.Array:
        """Zero the band coordinate's projection row: hashing must see
        only the Simple-LSH geometry; the band reaches the code via
        ``code_tags``, not the projection."""
        return proj.at[-1, :].set(0.0)

    # -- probabilities -------------------------------------------------------

    def collision_prob(self, x_aug: jax.Array, q_aug: jax.Array) -> jax.Array:
        # SRP law on the Simple-LSH part only (the band coordinate is
        # code layout, not geometry).  Exact at the band's scale because
        # the cosine law normalises internally.
        return srp_collision_prob(x_aug[..., :-1], q_aug[..., :-1])
