"""Asymmetric MIPS family: Simple-LSH augmentation + SRP (Neyshabur & Srebro).

The paper's Eq. 4 weight w*_i is monotonic in the inner product
⟨q, x_i⟩ — NOT in the cosine — so the symmetric SRP family forces
callers to pre-normalise stored rows to unit L2 norm to make cosine a
proxy.  This family drops that restriction with the Simple-LSH
asymmetric transform:

    data:   S(x) = [x / M,  √(1 − ‖x/M‖²)]      M = max_i ‖x_i‖
    query:  Q(q) = [q / ‖q‖,  0]

Every augmented data vector has unit norm by construction, the query is
unit-norm, and

    ⟨S(x), Q(q)⟩ = ⟨x, q⟩ / (M ‖q‖)

so the SRP collision probability on the augmented pair,

    cp = 1 − arccos(⟨x, q⟩ / (M ‖q‖)) / π ,

is exactly computable AND monotonically increasing in the raw inner
product ⟨x, q⟩ — un-normalised corpora sample the paper's weight
directly.  Downstream nothing changes: augmented vectors flow through
the same fused simhash/bucket-probe/gather kernels (``proj_kind =
"dense"`` — it is linear SRP in aug_dim = d+1 dimensions), and
Algorithm 1's weights 1/(p·N) stay exactly unbiased because cp is
exact for whatever vectors were hashed.

SCALE PINNING: M is data-dependent, so partial re-augmentations (the
pipeline's delta refresh re-embeds only dirty rows) must reuse the M of
the original build — ``data_scale`` captures it, ``augment_data(x,
scale=M)`` replays it.  If drifted features push a row norm above the
pinned M, the norm coordinate clamps at 0 and the augmented row's norm
exceeds 1: probabilities REMAIN exact (the cosine formula normalises
internally) and only the monotonicity sharpens/flattens marginally
until the next full refresh recomputes M.

Derivation + statistical pins: docs/ARCHITECTURE.md "LSH-family
contract"; tests/test_families.py (collision law chi-square,
monotonicity in ⟨q, x⟩, E[1/(p·N)] = 1 over index builds, and the
un-normalised heavy-tailed estimator unbiasedness test).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import LSHFamily, normalize_rows
from .srp import srp_collision_prob


@dataclasses.dataclass(frozen=True)
class SimpleLSHMIPSFamily(LSHFamily):
    """Asymmetric Simple-LSH MIPS: [x/M, √(1−‖x/M‖²)] vs [q/‖q‖, 0]."""

    name: str = "mips"
    proj_kind: str = "dense"
    asymmetric: bool = True

    def data_scale(self, x: jax.Array):
        """M = max row norm (guarded): the augmentation's normaliser."""
        return jnp.maximum(jnp.max(jnp.linalg.norm(x, axis=-1)), 1e-30)

    def augment_data(self, x: jax.Array, scale=None) -> jax.Array:
        scale = self.data_scale(x) if scale is None else scale
        xs = x / scale
        sq = jnp.sum(xs * xs, axis=-1, keepdims=True)
        tail = jnp.sqrt(jnp.maximum(1.0 - sq, 0.0))
        return jnp.concatenate([xs, tail], axis=-1)

    def augment_query(self, q: jax.Array) -> jax.Array:
        qn = normalize_rows(q)
        return jnp.concatenate(
            [qn, jnp.zeros(qn.shape[:-1] + (1,), qn.dtype)], axis=-1)

    def aug_dim(self, d: int) -> int:
        return d + 1

    def collision_prob(self, x_aug: jax.Array, q_aug: jax.Array) -> jax.Array:
        # SRP law on the augmented pair — exact for any norms, monotone
        # in the RAW inner product by the Simple-LSH identity above.
        return srp_collision_prob(x_aug, q_aug)
