"""LGD core: LSH-sampled adaptive stochastic gradient estimation.

Chen, Xu & Shrivastava, "LSH-sampling Breaks the Computation
Chicken-and-egg Loop in Adaptive Stochastic Gradient Estimation"
(NeurIPS 2019).
"""

from .families import (  # noqa: F401
    FAMILIES,
    BandedScale,
    LSHFamily,
    NormRangedMIPSFamily,
    family_names,
    get_family,
)
from .simhash import (  # noqa: F401
    LSHParams,
    augment_logistic,
    augment_regression,
    collision_probability,
    collision_probability_quadratic,
    compute_codes,
    logistic_query,
    make_projections,
    probe_masks,
    regression_query,
)
from .tables import (  # noqa: F401
    EMPTY_CODE,
    IndexMutation,
    LSHIndex,
    append_rows,
    band_starts,
    bucket_bounds,
    bucket_bounds_banded,
    bucket_bounds_batched,
    bucket_bounds_multi,
    build_index,
    evict_rows,
    grow_index,
    hash_points,
    mutate_index,
    query_codes,
    refresh_index,
    refresh_index_delta,
)
from .sampler import (  # noqa: F401
    GatherBatch,
    SampleResult,
    sample,
    sample_batched,
    sample_drain,
    sample_gather,
    sample_gather_batched,
)
from .estimator import (  # noqa: F401
    VarianceReport,
    exact_inclusion_probability,
    empirical_estimator_covariance_trace,
    importance_weights,
    lgd_gradient,
    variance_report,
)
from .lgd import (  # noqa: F401
    LGDProblem,
    LGDState,
    full_loss,
    init,
    lgd_step,
    preprocess_logistic,
    preprocess_logistic_mips,
    preprocess_regression,
    preprocess_regression_mips,
    sgd_step,
)
