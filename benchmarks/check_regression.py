"""CI benchmark-regression gate for the fused LSH sampling fast path.

Compares a freshly-measured ``sampling_cost.json`` against the committed
baseline and FAILS (exit 1) on a regression.  CI machines differ wildly
in absolute speed, so the gate never compares raw microseconds:

  fused_vs_ref      us(lsh_fused) / us(lsh_reference), same run — the
                    auto-dispatched fast path must stay within
                    ``--tolerance`` (default 25%) of the committed
                    baseline ratio.  On CPU both paths lower to the same
                    XLA program, so this ratio is structurally ~1 on any
                    host; the limit is max(baseline, 1)*(1+tol) so a
                    favourably-skewed (<1) committed baseline cannot
                    turn ordinary CI noise into failures.
  batched_vs_fused  us(batched, per query) / us(lsh_fused), same run —
                    the B-query amortisation of ``sample_batched``.  Its
                    structural value depends on host core count, so it
                    is gated by an ABSOLUTE cap (default 0.5: batching
                    must amortise at least 2x per query; ~0.05 here)
                    rather than a baseline-relative band.  Losing the
                    fused batch probe sends it to ~1 — a caught
                    regression on any machine.

``--selftest`` proves the gate can actually fail before it is trusted:
it injects a 2x fused slowdown and a 20x batched slowdown and asserts
both comparisons trip.

Usage (mirrors .github/workflows/ci.yml):
    python benchmarks/run.py tab_sampling_cost --quick
    python benchmarks/check_regression.py \
        --baseline /tmp/baseline.json \
        --fresh benchmarks/results/sampling_cost.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT = os.path.join(HERE, "results", "sampling_cost.json")


def ratios(d: dict) -> dict:
    us = d["us_per_call"]
    return {
        "fused_vs_ref": us["lsh_fused"] / us["lsh_reference"],
        "batched_vs_fused":
            us["lsh_fused_batched_per_query"] / us["lsh_fused"],
    }


def compare(baseline: dict, fresh: dict, tolerance: float,
            batched_cap: float) -> list:
    """Return the list of regression messages (empty = pass)."""
    failures = []
    # like-for-like guard: quick vs full runs measure different problem
    # sizes; comparing them gates on the size mismatch, not a regression
    for field in ("quick", "n_points", "query_batch"):
        if baseline.get(field) != fresh.get(field):
            failures.append(
                f"baseline/fresh not comparable: {field} "
                f"{baseline.get(field)} != {fresh.get(field)} — "
                "regenerate the baseline with run.py tab_sampling_cost "
                "--quick")
    if failures:
        for msg in failures:
            print(msg)
        return failures
    base_r, fresh_r = ratios(baseline), ratios(fresh)

    got, base = fresh_r["fused_vs_ref"], base_r["fused_vs_ref"]
    # the ratio is structurally ~1 on CPU (both paths lower to the same
    # XLA program); a sub-1 committed baseline is favourable measurement
    # skew, so gate against max(baseline, 1) — CI must not fail merely
    # for not reproducing the dev machine's skew.
    limit = max(base, 1.0) * (1.0 + tolerance)
    ok = got <= limit
    print(f"fused_vs_ref: baseline {base:.3f}  fresh {got:.3f}  "
          f"limit {limit:.3f}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"fused sampling regressed: ratio {got:.3f} > {limit:.3f} "
            f"(baseline {base:.3f} +{tolerance:.0%})")

    got = fresh_r["batched_vs_fused"]
    ok = got <= batched_cap
    print(f"batched_vs_fused: baseline {base_r['batched_vs_fused']:.3f}  "
          f"fresh {got:.3f}  cap {batched_cap:.3f}  "
          f"[{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"batched sampling amortisation lost: per-query ratio "
            f"{got:.3f} > cap {batched_cap:.3f}")
    return failures


def selftest(baseline: dict, tolerance: float, batched_cap: float) -> int:
    """The gate must trip on injected fused and batched slowdowns."""
    fused_slow = json.loads(json.dumps(baseline))
    fused_slow["us_per_call"]["lsh_fused"] *= 2.0
    print("-- selftest 1: injected 2x lsh_fused slowdown --")
    f1 = compare(baseline, fused_slow, tolerance, batched_cap)

    batched_slow = json.loads(json.dumps(baseline))
    batched_slow["us_per_call"]["lsh_fused_batched_per_query"] *= 20.0
    print("-- selftest 2: injected 20x batched slowdown --")
    f2 = compare(baseline, batched_slow, tolerance, batched_cap)

    if not f1 or not f2:
        print("selftest FAILED: gate did not trip "
              f"(fused findings: {len(f1)}, batched findings: {len(f2)})")
        return 1
    print("selftest passed: gate tripped on both injected slowdowns")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=DEFAULT,
                    help="committed baseline JSON")
    ap.add_argument("--fresh", default=DEFAULT,
                    help="freshly measured JSON")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fused_vs_ref drift over baseline")
    ap.add_argument("--batched-cap", type=float, default=0.5,
                    help="absolute cap on batched per-query / fused ratio")
    ap.add_argument("--selftest", action="store_true",
                    help="verify the gate trips on injected slowdowns")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    if args.selftest:
        return selftest(baseline, args.tolerance, args.batched_cap)

    with open(args.fresh) as f:
        fresh = json.load(f)
    failures = compare(baseline, fresh, args.tolerance, args.batched_cap)
    for msg in failures:
        print(f"::error::{msg}")
    if failures:
        return 1
    print("benchmark gate: no regression")
    return 0


if __name__ == "__main__":
    sys.exit(main())
