"""CI benchmark-regression gate for the LGD fast paths.

Compares freshly-measured benchmark JSONs against the committed
baselines and FAILS (exit 1) on a regression.  CI machines differ
wildly in absolute speed, so the gate never compares raw microseconds —
every comparison is a SAME-RUN ratio (machine speed cancels) checked
against either the committed baseline ratio or an absolute cap.

Sampling (``sampling_cost.json``):
  fused_vs_ref      us(lsh_fused) / us(lsh_reference) — the
                    auto-dispatched fast path must stay within
                    ``--tolerance`` (default 25%) of the committed
                    baseline ratio.  On CPU both paths lower to the same
                    XLA program, so this ratio is structurally ~1 on any
                    host; the limit is max(baseline, 1)*(1+tol) so a
                    favourably-skewed (<1) committed baseline cannot
                    turn ordinary CI noise into failures.
  batched_vs_fused  us(batched, per query) / us(lsh_fused) — the B-query
                    amortisation of ``sample_batched``, gated by an
                    ABSOLUTE cap (default 0.5).  Losing the fused batch
                    probe sends it to ~1 — caught on any machine.
  probe_dispatch    us(probe dispatched) / us(probe reference),
                    interleaved same-run measurement — the dispatch
                    heuristic must never pick a losing path, so this is
                    capped at ``--probe-cap`` (default 1.15: wins or
                    ties, with headroom for timer noise only).

Refresh (``refresh_cost.json``):
  delta_speedup     full-refresh / delta-refresh wall time at 10% dirty
                    fraction, same run.  Delta refresh re-embeds and
                    re-hashes only the dirty rows, so this must stay
                    >= ``--refresh-min-speedup`` (default 2.0 — the
                    device-resident LGD acceptance bar).

Streaming (``streaming.json``):
  append_vs_rebuild total us(append 10% of rows, chunked, with live
                    draws between chunks) / us(one full refresh of the
                    final corpus), same run — appending a tenth of the
                    corpus through the index-mutation API must cost at
                    most ``--streaming-cap`` (default 0.5: half a
                    rebuild) or streaming's amortisation claim is
                    broken.

Train step (``train_step.json``):
  step_overhead     us(lgd step) / us(uniform step), same run — the
                    end-to-end cost of adaptive sampling on the
                    device-resident path, gated within
                    ``--train-tolerance`` (default 35%: trainer-level
                    timings are noisier than microbenchmarks) of the
                    committed baseline ratio.

Robustness (``robustness.json``):
  degraded step     us(stale-index step) / us(healthy step) and
                    us(uniform-fallback step) / us(healthy step), all
                    three trainers interleaved same-run — degraded
                    modes are FALLBACKS, not slow paths, so each ratio
                    is capped at ``--robustness-degraded-cap`` (default
                    1.1: within 10% of healthy).
  recovery          after a bounded injected refresh-failure burst the
                    ladder must report ``recovered: true`` — a run that
                    ends stuck in a degraded state fails the gate.

Multihost (``multihost.json``):
  deployment tax    mean us(2-process jax.distributed step) / mean
                    us(one-process 2-shard step), same worker stack —
                    the cost of going multi-host (barriers + param
                    averaging + core contention) must stay within
                    ``--multihost-tolerance`` (default 0.5: subprocess
                    timings are the noisiest in the suite) of the
                    committed baseline ratio.
  reform            the host-kill drill must report ``reformed: true``
                    with a finite reform-time-to-first-step — a
                    survivor that never reaches a post-reform step
                    fails the gate.

Optimizers (``optimizers.json``):
  adam step         us(lgd-adam step) / us(uniform-adam step), same
                    run, with the LGD pipeline running multiprobe=2 —
                    ABSOLUTE cap ``--optim-step-cap`` (default 1.3:
                    the paper's "works under Adam/AdaGrad" claim must
                    not cost more than 30% per step in quick CPU mode).
  adam variance     Tr Cov(LGD minibatch estimator) / Tr Cov(uniform),
                    Lemma-1 pareto regime — must stay BELOW
                    ``--optim-var-cap`` (default 1.0: adaptive sampling
                    must actually reduce estimator variance).
  fallback          multi-probe fallback rate / single-probe fallback
                    rate on the skewed corpus — capped at
                    ``--fallback-cap`` (default 0.75: the Hamming-ball
                    walk must strictly beat single-probe, with margin).

Families (``families.json``):
  mips step         us(mips draw) / us(srp draw), interleaved same-run —
                    the asymmetric family is linear SRP in one extra
                    dimension, so its sampling step is capped at
                    ``--families-step-cap`` (default 1.15) over SRP.
  mips variance     Tr Cov(MIPS single-sample estimator, averaged over
                    index builds) / Tr Cov(uniform) on the calibrated
                    un-normalised skewed corpus — must stay BELOW
                    ``--families-var-cap`` (default 1.0: hashing
                    un-normalised data, the asymmetric family must
                    still deliver the adaptive-sampling variance win).
  banded calib      E[1/(pN)] of the norm-ranged ``mips_banded`` family
                    on the log-normal heavy-tail corpus — ABSOLUTE gate
                    on the fresh run: must sit within
                    ``--banded-calibration`` (default 0.1) of 1.
  banded variance   Tr Cov(banded) < Tr Cov(plain mips), same heavy-
                    tailed corpus and run — the variance win norm-
                    ranging exists for.

Softmax head (``softmax.json``):
  train ratio       us(sampled-head train step) / us(full-vocab head
                    step), same model/batch/run — ABSOLUTE cap
                    ``--softmax-train-cap`` (default 1.0: the sampled
                    head must beat the O(V) head at the benchmarked V
                    or it has no reason to exist).
  proj decode       roofline-projected shortlist-head tokens/s over
                    full-head tokens/s at V = 131,072 (HBM byte model;
                    machine speed cancels) — floored at
                    ``--softmax-proj-floor`` (default 1.0).
  zhat calib        |E[Zhat]/Z - 1| measured over index builds on the
                    live head rows — ABSOLUTE gate on the fresh run
                    (``--softmax-zhat-cap``, default 0.25: an identity,
                    it does not drift with machine speed).
  shortlist recall  recall@1 of the banded decode shortlist on planted
                    winners — floored at ``--softmax-recall-floor``
                    (default 0.8; measured ~0.98).

``--selftest`` proves the gate can actually fail before it is trusted:
it injects a slowdown into every gated quantity and asserts each
comparison trips.

Usage (mirrors .github/workflows/ci.yml):
    python benchmarks/run.py tab_sampling_cost tab_refresh_cost \
        tab_train_step --quick
    python benchmarks/check_regression.py \
        --baseline /tmp/baseline.json --fresh benchmarks/results/sampling_cost.json \
        --baseline-refresh /tmp/refresh.json --fresh-refresh benchmarks/results/refresh_cost.json \
        --baseline-train /tmp/train.json --fresh-train benchmarks/results/train_step.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT = os.path.join(HERE, "results", "sampling_cost.json")
DEFAULT_REFRESH = os.path.join(HERE, "results", "refresh_cost.json")
DEFAULT_TRAIN = os.path.join(HERE, "results", "train_step.json")
DEFAULT_OPTIM = os.path.join(HERE, "results", "optimizers.json")
DEFAULT_ROBUSTNESS = os.path.join(HERE, "results", "robustness.json")
DEFAULT_MULTIHOST = os.path.join(HERE, "results", "multihost.json")
DEFAULT_FAMILIES = os.path.join(HERE, "results", "families.json")
DEFAULT_STREAMING = os.path.join(HERE, "results", "streaming.json")
DEFAULT_SOFTMAX = os.path.join(HERE, "results", "softmax.json")


def ratios(d: dict) -> dict:
    us = d["us_per_call"]
    out = {
        "fused_vs_ref": us["lsh_fused"] / us["lsh_reference"],
        "batched_vs_fused":
            us["lsh_fused_batched_per_query"] / us["lsh_fused"],
    }
    probe = d.get("probe_stage_us_per_query")
    if probe:
        out["probe_dispatch"] = probe["fused"] / probe["reference"]
    return out


def _comparable(baseline: dict, fresh: dict, fields, what: str) -> list:
    """Like-for-like guard: quick vs full runs measure different problem
    sizes; comparing them gates on the size mismatch, not a regression."""
    failures = []
    for field in fields:
        if baseline.get(field) != fresh.get(field):
            failures.append(
                f"{what} baseline/fresh not comparable: {field} "
                f"{baseline.get(field)} != {fresh.get(field)} — "
                "regenerate the baseline with run.py --quick")
    return failures


def compare(baseline: dict, fresh: dict, tolerance: float,
            batched_cap: float, probe_cap: float) -> list:
    """Sampling-cost gates; returns regression messages (empty = pass)."""
    failures = _comparable(baseline, fresh,
                           ("quick", "n_points", "query_batch"), "sampling")
    if failures:
        for msg in failures:
            print(msg)
        return failures
    base_r, fresh_r = ratios(baseline), ratios(fresh)

    got, base = fresh_r["fused_vs_ref"], base_r["fused_vs_ref"]
    # the ratio is structurally ~1 on CPU (both paths lower to the same
    # XLA program); a sub-1 committed baseline is favourable measurement
    # skew, so gate against max(baseline, 1) — CI must not fail merely
    # for not reproducing the dev machine's skew.
    limit = max(base, 1.0) * (1.0 + tolerance)
    ok = got <= limit
    print(f"fused_vs_ref: baseline {base:.3f}  fresh {got:.3f}  "
          f"limit {limit:.3f}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"fused sampling regressed: ratio {got:.3f} > {limit:.3f} "
            f"(baseline {base:.3f} +{tolerance:.0%})")

    got = fresh_r["batched_vs_fused"]
    ok = got <= batched_cap
    print(f"batched_vs_fused: baseline {base_r['batched_vs_fused']:.3f}  "
          f"fresh {got:.3f}  cap {batched_cap:.3f}  "
          f"[{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"batched sampling amortisation lost: per-query ratio "
            f"{got:.3f} > cap {batched_cap:.3f}")

    got = fresh_r.get("probe_dispatch")
    if got is not None:
        ok = got <= probe_cap
        print(f"probe_dispatch: baseline "
              f"{base_r.get('probe_dispatch', float('nan')):.3f}  "
              f"fresh {got:.3f}  cap {probe_cap:.3f}  "
              f"[{'ok' if ok else 'FAIL'}]")
        if not ok:
            failures.append(
                f"probe dispatch picks a losing path: fused/ref "
                f"{got:.3f} > cap {probe_cap:.3f} (the dispatched probe "
                "must win or tie the reference)")
    return failures


def compare_refresh(baseline: dict, fresh: dict, min_speedup: float) -> list:
    failures = _comparable(baseline, fresh, ("quick", "n_points", "l"),
                           "refresh")
    if failures:
        for msg in failures:
            print(msg)
        return failures
    got = fresh["delta_speedup_at_0.10"]
    base = baseline["delta_speedup_at_0.10"]
    ok = got >= min_speedup
    print(f"refresh delta_speedup@10%: baseline {base:.2f}x  fresh "
          f"{got:.2f}x  floor {min_speedup:.2f}x  "
          f"[{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"delta refresh lost its amortisation: {got:.2f}x < "
            f"{min_speedup:.2f}x over full refresh at 10% dirty")
    return failures


def compare_streaming(baseline: dict, fresh: dict, cap: float) -> list:
    failures = _comparable(baseline, fresh, ("quick", "n0", "l"),
                           "streaming")
    if failures:
        for msg in failures:
            print(msg)
        return failures
    got = fresh["append_vs_rebuild"]
    base = baseline["append_vs_rebuild"]
    ok = got <= cap
    print(f"streaming append_vs_rebuild@10%: baseline {base:.3f}  "
          f"fresh {got:.3f}  cap {cap:.3f}  "
          f"[{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"streaming append lost its amortisation: appending 10% of "
            f"rows cost {got:.3f}x a full rebuild > cap {cap:.3f}")
    return failures


def compare_train(baseline: dict, fresh: dict, tolerance: float) -> list:
    failures = _comparable(baseline, fresh,
                           ("quick", "batch", "n_corpus"), "train")
    if failures:
        for msg in failures:
            print(msg)
        return failures
    got = fresh["step_us"]["overhead"]
    base = baseline["step_us"]["overhead"]
    limit = max(base, 1.0) * (1.0 + tolerance)
    ok = got <= limit
    print(f"train step_overhead: baseline {base:.3f}  fresh {got:.3f}  "
          f"limit {limit:.3f}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"LGD train step regressed: lgd/uniform {got:.3f} > "
            f"{limit:.3f} (baseline {base:.3f} +{tolerance:.0%})")
    return failures


def compare_robustness(baseline: dict, fresh: dict,
                       degraded_cap: float) -> list:
    failures = _comparable(baseline, fresh,
                           ("quick", "batch", "n_corpus"), "robustness")
    if failures:
        for msg in failures:
            print(msg)
        return failures

    for mode in ("stale_index", "uniform_fallback"):
        got = fresh["degraded_over_healthy"][mode]
        base = baseline["degraded_over_healthy"][mode]
        ok = got <= degraded_cap
        print(f"robustness {mode} step: baseline {base:.3f}  fresh "
              f"{got:.3f}  cap {degraded_cap:.3f}  "
              f"[{'ok' if ok else 'FAIL'}]")
        if not ok:
            failures.append(
                f"degraded-mode ({mode}) step regressed: "
                f"{got:.3f}x healthy > cap {degraded_cap:.3f} (a "
                "degradation rung must not be a slow path)")

    rec = fresh["recovery"]
    ok = bool(rec["recovered"])
    print(f"robustness recovery: baseline "
          f"{baseline['recovery']['latency_steps']} steps  fresh "
          f"{rec['latency_steps']} steps  recovered={rec['recovered']}  "
          f"[{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            "degradation ladder did not recover after the injected "
            "refresh-failure burst cleared (run ended degraded — see "
            "robustness.json recovery)")
    return failures


def compare_multihost(baseline: dict, fresh: dict,
                      tolerance: float) -> list:
    failures = _comparable(baseline, fresh,
                           ("quick", "batch", "n_corpus", "nprocs",
                            "sync_every"), "multihost")
    if failures:
        for msg in failures:
            print(msg)
        return failures

    got = fresh["step_us"]["two_proc_over_one_proc"]
    base = baseline["step_us"]["two_proc_over_one_proc"]
    limit = max(base, 1.0) * (1.0 + tolerance)
    ok = got <= limit
    print(f"multihost deployment tax: baseline {base:.3f}  fresh "
          f"{got:.3f}  limit {limit:.3f}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"multi-host deployment tax regressed: 2proc/1proc "
            f"{got:.3f} > {limit:.3f} (baseline {base:.3f} "
            f"+{tolerance:.0%})")

    reform = fresh["reform"]
    ok = bool(reform["reformed"]) and \
        reform.get("to_first_step_s") is not None
    print(f"multihost reform: baseline "
          f"{baseline['reform']['to_first_step_s']:.2f}s  fresh "
          f"{reform.get('to_first_step_s')}s  "
          f"reformed={reform['reformed']}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            "host-kill drill did not reform: the survivor never "
            "reached a post-reform step (see multihost.json reform)")
    return failures


def compare_optimizers(baseline: dict, fresh: dict, step_cap: float,
                       var_cap: float, fallback_cap: float) -> list:
    failures = _comparable(baseline, fresh,
                           ("quick", "batch", "n_corpus", "multiprobe"),
                           "optimizers")
    if failures:
        for msg in failures:
            print(msg)
        return failures

    adam = fresh["optimizers"]["adam"]
    base_adam = baseline["optimizers"]["adam"]

    got = adam["step_us"]["overhead"]
    ok = got <= step_cap
    print(f"optim adam step_overhead: baseline "
          f"{base_adam['step_us']['overhead']:.3f}  fresh {got:.3f}  "
          f"cap {step_cap:.3f}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"LGD-Adam step regressed: lgd/uniform {got:.3f} > cap "
            f"{step_cap:.3f} (adaptive sampling must stay cheap under "
            "adaptive optimizers)")

    got = adam["estimator_variance"]["ratio"]
    ok = got < var_cap
    print(f"optim adam var_ratio: baseline "
          f"{base_adam['estimator_variance']['ratio']:.3f}  fresh "
          f"{got:.3f}  cap {var_cap:.3f}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"LGD-Adam estimator variance not below uniform: ratio "
            f"{got:.3f} >= {var_cap:.3f} (the adaptive-sampling variance "
            "win is the point of the paper)")

    single, multi = fresh["fallback"]["single"], fresh["fallback"]["multi"]
    got = multi / max(single, 1e-12)
    ok = single > 0 and got <= fallback_cap
    print(f"optim fallback multi/single: baseline "
          f"{baseline['fallback']['multi'] / max(baseline['fallback']['single'], 1e-12):.3f}"
          f"  fresh {got:.3f}  cap {fallback_cap:.3f}  "
          f"[{'ok' if ok else 'FAIL'}]")
    if not ok:
        if single <= 0:
            # degenerate regime, not a multi-probe regression: the gate
            # is vacuous without single-probe fallbacks to beat.
            failures.append(
                "skewed-corpus benchmark regime produced ZERO single-"
                "probe fallbacks — the fallback gate is vacuous; "
                "recalibrate tab_optimizers' skewed corpus (run.py)")
        else:
            failures.append(
                f"multi-probe no longer beats single-probe on the skewed "
                f"corpus: fallback ratio {got:.3f} > cap {fallback_cap:.3f} "
                f"(single {single:.3f}, multi {multi:.3f})")
    return failures


def compare_families(baseline: dict, fresh: dict, step_cap: float,
                     var_cap: float, banded_tol: float) -> list:
    failures = _comparable(baseline, fresh,
                           ("quick", "n_points", "d", "k", "l", "draws",
                            "builds"),
                           "families")
    if failures:
        for msg in failures:
            print(msg)
        return failures

    got = fresh["step_us"]["mips_vs_srp"]
    ok = got <= step_cap
    print(f"families mips step: baseline "
          f"{baseline['step_us']['mips_vs_srp']:.3f}  fresh {got:.3f}  "
          f"cap {step_cap:.3f}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"MIPS sampling step regressed: mips/srp {got:.3f} > cap "
            f"{step_cap:.3f} (the asymmetric family is one extra column "
            "of linear SRP — it must not cost more than that)")

    got = fresh["estimator_variance"]["mips"]["ratio"]
    ok = got < var_cap
    print(f"families mips var_ratio: baseline "
          f"{baseline['estimator_variance']['mips']['ratio']:.3f}  fresh "
          f"{got:.3f}  cap {var_cap:.3f}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"MIPS estimator variance not below uniform on the "
            f"un-normalised skewed corpus: ratio {got:.3f} >= "
            f"{var_cap:.3f} (the no-normalisation variance win is the "
            "point of the asymmetric family)")

    # heavy-tail calibration gates: ABSOLUTE on the fresh run (the
    # identity E[1/(pN)] = 1 does not drift with machine speed)
    ht = fresh.get("heavy_tail")
    if ht is None:
        failures.append(
            "families fresh JSON lacks the heavy_tail block — "
            "regenerate with benchmarks/run.py tab_families")
        return failures
    base_ht = baseline.get("heavy_tail", {})
    got = ht["inv_p"]["mips_banded"]
    ok = abs(got - 1.0) <= banded_tol
    print(f"families banded E[1/(pN)]: baseline "
          f"{base_ht.get('inv_p', {}).get('mips_banded', float('nan')):.3f}"
          f"  fresh {got:.3f}  band 1±{banded_tol:.2f}  "
          f"[{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"norm-ranged MIPS miscalibrated on the log-normal corpus: "
            f"E[1/(pN)] = {got:.3f} outside ["
            f"{1 - banded_tol:.2f}, {1 + banded_tol:.2f}] (the composed "
            "per-band inclusion probabilities must stay exact)")
    got_b = ht["trcov"]["mips_banded"]
    got_p = ht["trcov"]["mips"]
    ok = got_b < got_p
    print(f"families banded Tr Cov vs plain mips: baseline "
          f"{base_ht.get('trcov', {}).get('banded_vs_plain', float('nan')):.3f}"
          f"  fresh {got_b / max(got_p, 1e-30):.3f}  cap 1.000  "
          f"[{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"norm-ranged MIPS estimator variance not below plain mips "
            f"on the heavy-tailed corpus: Tr Cov banded {got_b:.4f} >= "
            f"plain {got_p:.4f} (banding exists to win exactly here)")
    return failures


def compare_softmax(baseline: dict, fresh: dict, train_cap: float,
                    proj_floor: float, zhat_cap: float,
                    recall_floor: float) -> list:
    failures = _comparable(baseline, fresh,
                           ("quick", "vocab", "d_model", "decode_family",
                            "decode_k", "shortlist_per_table"),
                           "softmax")
    if failures:
        for msg in failures:
            print(msg)
        return failures

    got = fresh["train_ratio"]
    base = baseline["train_ratio"]
    ok = got <= train_cap
    print(f"softmax train_ratio: baseline {base:.3f}  fresh {got:.3f}  "
          f"cap {train_cap:.3f}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"sampled-softmax train step no longer beats the full-vocab "
            f"head: lsh/full {got:.3f} > cap {train_cap:.3f} (breaking "
            "per-step O(V) is the head's whole claim)")

    got = fresh["proj_decode_ratio"]
    base = baseline["proj_decode_ratio"]
    ok = got >= proj_floor
    print(f"softmax proj_decode_ratio: baseline {base:.1f}x  fresh "
          f"{got:.1f}x  floor {proj_floor:.1f}x  "
          f"[{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"shortlist decode head loses to the full matmul at "
            f"V={fresh.get('proj_vocab')}: projected ratio {got:.2f}x < "
            f"floor {proj_floor:.2f}x (candidate count grew past the "
            "roofline win)")

    got = fresh["zhat_rel_err"]
    base = baseline["zhat_rel_err"]
    ok = got <= zhat_cap
    print(f"softmax zhat_rel_err: baseline {base:.4f}  fresh {got:.4f}  "
          f"cap {zhat_cap:.4f}  [{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"sampled normaliser miscalibrated: |E[Zhat]/Z - 1| = "
            f"{got:.3f} > cap {zhat_cap:.3f} (the unbiasedness identity "
            "the sampled loss rests on)")

    got = fresh["shortlist_recall"]
    base = baseline["shortlist_recall"]
    ok = got >= recall_floor
    print(f"softmax shortlist_recall: baseline {base:.3f}  fresh "
          f"{got:.3f}  floor {recall_floor:.3f}  "
          f"[{'ok' if ok else 'FAIL'}]")
    if not ok:
        failures.append(
            f"decode shortlist recall collapsed: {got:.3f} < floor "
            f"{recall_floor:.3f} (the banded index must keep holding "
            "the argmax in a probed bucket)")
    return failures


def selftest(baseline: dict, refresh_base: dict, train_base: dict,
             optim_base: dict, families_base: dict,
             robustness_base: dict, streaming_base: dict,
             multihost_base: dict, softmax_base: dict, args) -> int:
    """Every gate must trip on an injected slowdown of its quantity."""
    results = []

    fused_slow = json.loads(json.dumps(baseline))
    fused_slow["us_per_call"]["lsh_fused"] *= 2.0
    print("-- selftest 1: injected 2x lsh_fused slowdown --")
    results.append(bool(compare(baseline, fused_slow, args.tolerance,
                                args.batched_cap, args.probe_cap)))

    batched_slow = json.loads(json.dumps(baseline))
    batched_slow["us_per_call"]["lsh_fused_batched_per_query"] *= 20.0
    print("-- selftest 2: injected 20x batched slowdown --")
    results.append(bool(compare(baseline, batched_slow, args.tolerance,
                                args.batched_cap, args.probe_cap)))

    probe_slow = json.loads(json.dumps(baseline))
    probe_slow["probe_stage_us_per_query"]["fused"] *= 2.0
    print("-- selftest 3: injected 2x dispatched-probe slowdown --")
    results.append(bool(compare(baseline, probe_slow, args.tolerance,
                                args.batched_cap, args.probe_cap)))

    refresh_slow = json.loads(json.dumps(refresh_base))
    refresh_slow["delta_speedup_at_0.10"] = args.refresh_min_speedup * 0.5
    print("-- selftest 4: injected delta-refresh amortisation loss --")
    results.append(bool(compare_refresh(refresh_base, refresh_slow,
                                        args.refresh_min_speedup)))

    train_slow = json.loads(json.dumps(train_base))
    train_slow["step_us"]["overhead"] *= 2.0
    print("-- selftest 5: injected 2x LGD step-overhead slowdown --")
    results.append(bool(compare_train(train_base, train_slow,
                                      args.train_tolerance)))

    optim_args = (args.optim_step_cap, args.optim_var_cap,
                  args.fallback_cap)
    adam_slow = json.loads(json.dumps(optim_base))
    adam_slow["optimizers"]["adam"]["step_us"]["overhead"] *= 2.0
    print("-- selftest 6: injected 2x LGD-Adam step slowdown --")
    results.append(bool(compare_optimizers(optim_base, adam_slow,
                                           *optim_args)))

    var_bad = json.loads(json.dumps(optim_base))
    var_bad["optimizers"]["adam"]["estimator_variance"]["ratio"] = \
        args.optim_var_cap * 1.5
    print("-- selftest 7: injected LGD-Adam variance-win loss --")
    results.append(bool(compare_optimizers(optim_base, var_bad,
                                           *optim_args)))

    fb_bad = json.loads(json.dumps(optim_base))
    fb_bad["fallback"]["multi"] = fb_bad["fallback"]["single"]
    print("-- selftest 8: injected multi-probe fallback-win loss --")
    results.append(bool(compare_optimizers(optim_base, fb_bad,
                                           *optim_args)))

    fam_args = (args.families_step_cap, args.families_var_cap,
                args.banded_calibration)
    fam_slow = json.loads(json.dumps(families_base))
    fam_slow["step_us"]["mips_vs_srp"] *= 2.0
    print("-- selftest 9: injected 2x MIPS sampling-step slowdown --")
    results.append(bool(compare_families(families_base, fam_slow,
                                         *fam_args)))

    fam_var = json.loads(json.dumps(families_base))
    fam_var["estimator_variance"]["mips"]["ratio"] = \
        args.families_var_cap * 1.5
    print("-- selftest 10: injected MIPS variance-win loss --")
    results.append(bool(compare_families(families_base, fam_var,
                                         *fam_args)))

    rob_slow = json.loads(json.dumps(robustness_base))
    rob_slow["degraded_over_healthy"]["uniform_fallback"] = \
        args.robustness_degraded_cap * 1.5
    print("-- selftest 11: injected degraded-mode step slowdown --")
    results.append(bool(compare_robustness(robustness_base, rob_slow,
                                           args.robustness_degraded_cap)))

    rob_stuck = json.loads(json.dumps(robustness_base))
    rob_stuck["recovery"]["recovered"] = False
    rob_stuck["recovery"]["latency_steps"] = None
    print("-- selftest 12: injected lost ladder recovery --")
    results.append(bool(compare_robustness(robustness_base, rob_stuck,
                                           args.robustness_degraded_cap)))

    stream_slow = json.loads(json.dumps(streaming_base))
    stream_slow["append_vs_rebuild"] = args.streaming_cap * 1.5
    print("-- selftest 13: injected streaming-append amortisation loss --")
    results.append(bool(compare_streaming(streaming_base, stream_slow,
                                          args.streaming_cap)))

    mh_slow = json.loads(json.dumps(multihost_base))
    mh_slow["step_us"]["two_proc_over_one_proc"] *= 2.0
    print("-- selftest 14: injected 2x multi-host deployment-tax "
          "slowdown --")
    results.append(bool(compare_multihost(multihost_base, mh_slow,
                                          args.multihost_tolerance)))

    mh_stuck = json.loads(json.dumps(multihost_base))
    mh_stuck["reform"]["reformed"] = False
    mh_stuck["reform"]["to_first_step_s"] = None
    print("-- selftest 15: injected lost host-kill reform --")
    results.append(bool(compare_multihost(multihost_base, mh_stuck,
                                          args.multihost_tolerance)))

    fam_cal = json.loads(json.dumps(families_base))
    fam_cal["heavy_tail"]["inv_p"]["mips_banded"] = \
        1.0 + args.banded_calibration * 1.5
    print("-- selftest 16: injected banded E[1/(pN)] miscalibration --")
    results.append(bool(compare_families(families_base, fam_cal,
                                         *fam_args)))

    fam_tr = json.loads(json.dumps(families_base))
    fam_tr["heavy_tail"]["trcov"]["mips_banded"] = \
        fam_tr["heavy_tail"]["trcov"]["mips"] * 1.1
    fam_tr["heavy_tail"]["trcov"]["banded_vs_plain"] = 1.1
    print("-- selftest 17: injected banded variance-win loss --")
    results.append(bool(compare_families(families_base, fam_tr,
                                         *fam_args)))

    sm_args = (args.softmax_train_cap, args.softmax_proj_floor,
               args.softmax_zhat_cap, args.softmax_recall_floor)
    sm_slow = json.loads(json.dumps(softmax_base))
    sm_slow["train_ratio"] = args.softmax_train_cap * 1.5
    print("-- selftest 18: injected sampled-head train-step win loss --")
    results.append(bool(compare_softmax(softmax_base, sm_slow, *sm_args)))

    sm_proj = json.loads(json.dumps(softmax_base))
    sm_proj["proj_decode_ratio"] = args.softmax_proj_floor * 0.5
    print("-- selftest 19: injected shortlist decode projection loss --")
    results.append(bool(compare_softmax(softmax_base, sm_proj, *sm_args)))

    sm_zhat = json.loads(json.dumps(softmax_base))
    sm_zhat["zhat_rel_err"] = args.softmax_zhat_cap * 1.5
    print("-- selftest 20: injected Zhat miscalibration --")
    results.append(bool(compare_softmax(softmax_base, sm_zhat, *sm_args)))

    sm_rec = json.loads(json.dumps(softmax_base))
    sm_rec["shortlist_recall"] = args.softmax_recall_floor * 0.5
    print("-- selftest 21: injected shortlist recall collapse --")
    results.append(bool(compare_softmax(softmax_base, sm_rec, *sm_args)))

    if not all(results):
        missed = [i + 1 for i, r in enumerate(results) if not r]
        print(f"selftest FAILED: gate(s) {missed} did not trip")
        return 1
    print("selftest passed: every gate tripped on its injected slowdown")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=DEFAULT,
                    help="committed sampling-cost baseline JSON")
    ap.add_argument("--fresh", default=DEFAULT,
                    help="freshly measured sampling-cost JSON")
    ap.add_argument("--baseline-refresh", default=DEFAULT_REFRESH,
                    help="committed refresh-cost baseline JSON")
    ap.add_argument("--fresh-refresh", default=DEFAULT_REFRESH,
                    help="freshly measured refresh-cost JSON")
    ap.add_argument("--baseline-train", default=DEFAULT_TRAIN,
                    help="committed train-step baseline JSON")
    ap.add_argument("--fresh-train", default=DEFAULT_TRAIN,
                    help="freshly measured train-step JSON")
    ap.add_argument("--baseline-optim", default=DEFAULT_OPTIM,
                    help="committed optimizers baseline JSON")
    ap.add_argument("--fresh-optim", default=DEFAULT_OPTIM,
                    help="freshly measured optimizers JSON")
    ap.add_argument("--baseline-families", default=DEFAULT_FAMILIES,
                    help="committed families baseline JSON")
    ap.add_argument("--fresh-families", default=DEFAULT_FAMILIES,
                    help="freshly measured families JSON")
    ap.add_argument("--baseline-robustness", default=DEFAULT_ROBUSTNESS,
                    help="committed robustness baseline JSON")
    ap.add_argument("--fresh-robustness", default=DEFAULT_ROBUSTNESS,
                    help="freshly measured robustness JSON")
    ap.add_argument("--baseline-multihost", default=DEFAULT_MULTIHOST,
                    help="committed multihost baseline JSON")
    ap.add_argument("--fresh-multihost", default=DEFAULT_MULTIHOST,
                    help="freshly measured multihost JSON")
    ap.add_argument("--baseline-streaming", default=DEFAULT_STREAMING,
                    help="committed streaming baseline JSON")
    ap.add_argument("--fresh-streaming", default=DEFAULT_STREAMING,
                    help="freshly measured streaming JSON")
    ap.add_argument("--baseline-softmax", default=DEFAULT_SOFTMAX,
                    help="committed sampled-softmax baseline JSON")
    ap.add_argument("--fresh-softmax", default=DEFAULT_SOFTMAX,
                    help="freshly measured sampled-softmax JSON")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fused_vs_ref drift over baseline")
    ap.add_argument("--batched-cap", type=float, default=0.5,
                    help="absolute cap on batched per-query / fused ratio")
    ap.add_argument("--probe-cap", type=float, default=1.15,
                    help="absolute cap on dispatched-probe / reference-"
                         "probe ratio (dispatch must win or tie)")
    ap.add_argument("--refresh-min-speedup", type=float, default=2.0,
                    help="required full/delta refresh speedup at 10% dirty")
    ap.add_argument("--train-tolerance", type=float, default=0.35,
                    help="allowed lgd/uniform step-overhead drift")
    ap.add_argument("--optim-step-cap", type=float, default=1.3,
                    help="absolute cap on LGD-Adam/uniform-Adam step ratio")
    ap.add_argument("--optim-var-cap", type=float, default=1.0,
                    help="LGD-Adam estimator variance ratio must stay "
                         "below this (adaptive sampling must win)")
    ap.add_argument("--fallback-cap", type=float, default=0.75,
                    help="cap on multi-probe / single-probe fallback-rate "
                         "ratio on the skewed corpus")
    ap.add_argument("--families-step-cap", type=float, default=1.15,
                    help="absolute cap on MIPS/SRP per-draw sampling "
                         "cost ratio")
    ap.add_argument("--families-var-cap", type=float, default=1.0,
                    help="MIPS estimator variance ratio vs uniform must "
                         "stay below this on the un-normalised corpus")
    ap.add_argument("--banded-calibration", type=float, default=0.1,
                    help="allowed |E[1/(pN)] - 1| for the norm-ranged "
                         "banded family on the log-normal heavy-tail "
                         "corpus (absolute gate on the fresh run)")
    ap.add_argument("--streaming-cap", type=float, default=0.5,
                    help="absolute cap on (total 10% append) / (full "
                         "rebuild) wall-time ratio")
    ap.add_argument("--robustness-degraded-cap", type=float, default=1.1,
                    help="absolute cap on degraded-mode (stale-index / "
                         "uniform-fallback) over healthy step-time ratio")
    ap.add_argument("--multihost-tolerance", type=float, default=0.5,
                    help="allowed 2proc/1proc deployment-tax drift over "
                         "the committed baseline ratio")
    ap.add_argument("--softmax-train-cap", type=float, default=1.0,
                    help="absolute cap on sampled-head / full-vocab-head "
                         "train-step ratio (the sampled head must win)")
    ap.add_argument("--softmax-proj-floor", type=float, default=1.0,
                    help="floor on the roofline-projected shortlist/full "
                         "decode tokens/s ratio at V=131k")
    ap.add_argument("--softmax-zhat-cap", type=float, default=0.25,
                    help="absolute cap on |E[Zhat]/Z - 1| measured over "
                         "index builds (unbiasedness identity)")
    ap.add_argument("--softmax-recall-floor", type=float, default=0.8,
                    help="floor on decode-shortlist recall@1 on planted "
                         "winners (measured ~0.98 on the banded index)")
    ap.add_argument("--selftest", action="store_true",
                    help="verify the gates trip on injected slowdowns")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.baseline_refresh) as f:
        refresh_base = json.load(f)
    with open(args.baseline_train) as f:
        train_base = json.load(f)
    with open(args.baseline_optim) as f:
        optim_base = json.load(f)
    with open(args.baseline_families) as f:
        families_base = json.load(f)
    with open(args.baseline_robustness) as f:
        robustness_base = json.load(f)
    with open(args.baseline_streaming) as f:
        streaming_base = json.load(f)
    with open(args.baseline_multihost) as f:
        multihost_base = json.load(f)
    with open(args.baseline_softmax) as f:
        softmax_base = json.load(f)
    if args.selftest:
        return selftest(baseline, refresh_base, train_base, optim_base,
                        families_base, robustness_base, streaming_base,
                        multihost_base, softmax_base, args)

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.fresh_refresh) as f:
        refresh_fresh = json.load(f)
    with open(args.fresh_train) as f:
        train_fresh = json.load(f)
    with open(args.fresh_optim) as f:
        optim_fresh = json.load(f)
    with open(args.fresh_families) as f:
        families_fresh = json.load(f)
    with open(args.fresh_robustness) as f:
        robustness_fresh = json.load(f)
    with open(args.fresh_streaming) as f:
        streaming_fresh = json.load(f)
    with open(args.fresh_multihost) as f:
        multihost_fresh = json.load(f)
    with open(args.fresh_softmax) as f:
        softmax_fresh = json.load(f)
    failures = compare(baseline, fresh, args.tolerance, args.batched_cap,
                       args.probe_cap)
    failures += compare_refresh(refresh_base, refresh_fresh,
                                args.refresh_min_speedup)
    failures += compare_train(train_base, train_fresh,
                              args.train_tolerance)
    failures += compare_optimizers(optim_base, optim_fresh,
                                   args.optim_step_cap, args.optim_var_cap,
                                   args.fallback_cap)
    failures += compare_families(families_base, families_fresh,
                                 args.families_step_cap,
                                 args.families_var_cap,
                                 args.banded_calibration)
    failures += compare_robustness(robustness_base, robustness_fresh,
                                   args.robustness_degraded_cap)
    failures += compare_streaming(streaming_base, streaming_fresh,
                                  args.streaming_cap)
    failures += compare_multihost(multihost_base, multihost_fresh,
                                  args.multihost_tolerance)
    failures += compare_softmax(softmax_base, softmax_fresh,
                                args.softmax_train_cap,
                                args.softmax_proj_floor,
                                args.softmax_zhat_cap,
                                args.softmax_recall_floor)
    for msg in failures:
        print(f"::error::{msg}")
    if failures:
        return 1
    print("benchmark gate: no regression")
    return 0


if __name__ == "__main__":
    sys.exit(main())
